"""Re-validate HSCC engine-vs-reference parity over the FULL workload table.

The ROADMAP's "HSCC port tie-break parity" item: the fixed-shape engine ports
of the HSCC utility loop (engine.simloop._hscc4k_migrate / _hscc2m_migrate)
could in principle differ from the numpy reference in f32 benefit ties.  This
script checks migrations / MPKI / IPC / mig_bytes for every workload (the
BENCH_QUICK=0 table: all apps + mixes) x {hscc-4kb-mig, hscc-2mb-mig} at the
same 4x25k scale the original 4-app validation used.

Modes:
  --record    compare the engine against the eager numpy host loop AND write
              scripts/hscc_parity_snapshot.json from the engine results.  Only
              runnable at a git revision that still has the eager HSCC classes
              (they were deleted once this validation passed, PR 2).
  --stream    run the table through the STREAMED fleet path (one SweepPlan,
              FleetRunner.run_iter retiring groups incrementally) instead of
              per-cell simulate().  Nothing is re-recorded: the streamed
              results must match the snapshot EXACTLY (rel-err 0.0), which
              pins streaming + sharding + padding to the recorded oracle.
  --apps A,B  restrict to a comma-separated workload subset — the ci.sh leg
              runs `--stream --apps soplex` so every CI pass regresses the
              streamed path against the snapshot without the full-table cost.
  (default)   regression mode: compare the engine against the recorded
              snapshot — the durable equivalence oracle for the HSCC path.

Run: PYTHONPATH=src python scripts/validate_hscc_parity.py [--record|--stream]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

from repro.sim.runner import simulate, workloads

SNAPSHOT = pathlib.Path(__file__).with_name("hscc_parity_snapshot.json")
POLICIES = ("hscc-4kb-mig", "hscc-2mb-mig")
SCALE = {"intervals": 4, "accesses": 25_000, "seed": 7}
FIELDS = ("migrations", "evictions", "mpki", "ipc", "mig_bytes")


def _row(m) -> dict:
    return {f: getattr(m, f) for f in FIELDS}


def _relerr(a: float, b: float) -> float:
    if a == b:
        return 0.0
    return abs(a - b) / max(abs(a), abs(b), 1e-12)


def _engine_rows_simulate(apps) -> dict[str, dict[str, dict]]:
    return {
        app: {p: _row(simulate(app, p, **SCALE)) for p in POLICIES}
        for app in apps
    }


def _engine_rows_streamed(apps) -> dict[str, dict[str, dict]]:
    """The whole table as ONE streamed fleet sweep (groups retire as they
    finish; rows print in retirement order — the streaming is visible)."""
    from repro.engine import fleet

    plan = fleet.SweepPlan.grid(
        list(apps), list(POLICIES), (SCALE["seed"],),
        intervals=SCALE["intervals"], accesses=SCALE["accesses"],
    )
    rows: dict[str, dict[str, dict]] = {app: {} for app in apps}
    t0 = time.time()
    for i, (cell, m) in enumerate(fleet.FleetRunner().run_iter(plan)):
        rows[cell.app][cell.policy] = _row(m)
        print(
            f"  [streamed {i + 1:3d}/{len(plan)} {time.time() - t0:5.0f}s] "
            f"{cell.app:14s} {cell.policy:12s} mig={m.migrations:6d}",
            flush=True,
        )
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(
        description="HSCC engine-vs-snapshot parity over the workload table"
    )
    ap.add_argument("--record", action="store_true",
                    help="re-record the snapshot from the eager references")
    ap.add_argument("--stream", action="store_true",
                    help="run through the streamed FleetRunner.run_iter path "
                         "(must match the snapshot at rel-err 0.0)")
    ap.add_argument("--apps", default=None,
                    help="comma-separated workload subset (default: full table)")
    args = ap.parse_args()
    if args.record and args.stream:
        ap.error("--record re-validates the eager path; it cannot be streamed")
    if args.record and args.apps:
        ap.error("--record rewrites the WHOLE snapshot; a subset would "
                 "destroy the recorded full-table oracle")

    apps = args.apps.split(",") if args.apps else workloads()
    unknown = sorted(set(apps) - set(workloads()))
    if unknown:
        ap.error(f"unknown workloads {unknown}; known: {workloads()}")

    if args.record:
        from repro.sim.policies import POLICY_CLASSES

        missing = [p for p in POLICIES if p not in POLICY_CLASSES]
        if missing:
            raise SystemExit(
                f"--record needs the eager numpy HSCC classes ({missing} not in "
                "POLICY_CLASSES); they were deleted after this validation "
                "passed — check out the pre-deletion revision to re-record."
            )
        from repro.sim.runner import simulate_eager

    t0 = time.time()
    engine_rows = (
        _engine_rows_streamed(apps) if args.stream
        else _engine_rows_simulate(apps)
    )
    reference = None if args.record else json.loads(SNAPSHOT.read_text())["cells"]
    worst = (0.0, None)
    for app in apps:
        for policy in POLICIES:
            eng = engine_rows[app][policy]
            ref = (
                _row(simulate_eager(app, policy, **SCALE))
                if args.record
                else reference[app][policy]
            )
            errs = {f: _relerr(eng[f], ref[f]) for f in FIELDS}
            bad = max(errs.values())
            if bad > worst[0]:
                worst = (bad, (app, policy))
            status = "OK " if bad == 0.0 else f"rel-err {bad:.2e}"
            print(
                f"  {app:14s} {policy:12s} mig={eng['migrations']:6d} "
                f"mpki={eng['mpki']:10.4f} ipc={eng['ipc']:.4f}  {status}",
                flush=True,
            )
    if args.record:
        SNAPSHOT.write_text(
            json.dumps({"scale": SCALE, "fields": list(FIELDS),
                        "cells": engine_rows}, indent=1)
        )
        print(f"snapshot written: {SNAPSHOT}")
    mode = (
        "engine-vs-eager" if args.record
        else "streamed-fleet-vs-snapshot" if args.stream
        else "engine-vs-snapshot"
    )
    print(
        f"hscc parity [{mode}] over {len(apps)} workloads x "
        f"{len(POLICIES)} policies in {time.time() - t0:.0f}s: "
        f"worst rel-err {worst[0]:.3e} at {worst[1]}"
    )
    # exact parity was observed at this scale when the snapshot was recorded.
    # The streamed fleet path is bit-identical by construction, so it gets NO
    # float-noise allowance; the per-cell path tolerates noise only.
    tol = 0.0 if args.stream else 1e-6
    if worst[0] > tol:
        print("PARITY FAILURE")
        return 1
    print("PARITY OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
