"""Re-validate HSCC engine-vs-reference parity over the FULL workload table.

The ROADMAP's "HSCC port tie-break parity" item: the fixed-shape engine ports
of the HSCC utility loop (engine.simloop._hscc4k_migrate / _hscc2m_migrate)
could in principle differ from the numpy reference in f32 benefit ties.  This
script checks migrations / MPKI / IPC / mig_bytes for every workload (the
BENCH_QUICK=0 table: all apps + mixes) x {hscc-4kb-mig, hscc-2mb-mig} at the
same 4x25k scale the original 4-app validation used.

Modes:
  --record    compare the engine against the eager numpy host loop AND write
              scripts/hscc_parity_snapshot.json from the engine results.  Only
              runnable at a git revision that still has the eager HSCC classes
              (they were deleted once this validation passed, PR 2).
  (default)   regression mode: compare the engine against the recorded
              snapshot — the durable equivalence oracle for the HSCC path.

Run: PYTHONPATH=src python scripts/validate_hscc_parity.py [--record]
"""
from __future__ import annotations

import json
import pathlib
import sys
import time

from repro.sim.runner import simulate, workloads

SNAPSHOT = pathlib.Path(__file__).with_name("hscc_parity_snapshot.json")
POLICIES = ("hscc-4kb-mig", "hscc-2mb-mig")
SCALE = {"intervals": 4, "accesses": 25_000, "seed": 7}
FIELDS = ("migrations", "evictions", "mpki", "ipc", "mig_bytes")


def _row(m) -> dict:
    return {f: getattr(m, f) for f in FIELDS}


def _relerr(a: float, b: float) -> float:
    if a == b:
        return 0.0
    return abs(a - b) / max(abs(a), abs(b), 1e-12)


def main() -> int:
    record = "--record" in sys.argv
    if record:
        from repro.sim.policies import POLICY_CLASSES

        missing = [p for p in POLICIES if p not in POLICY_CLASSES]
        if missing:
            raise SystemExit(
                f"--record needs the eager numpy HSCC classes ({missing} not in "
                "POLICY_CLASSES); they were deleted after this validation "
                "passed — check out the pre-deletion revision to re-record."
            )
        from repro.sim.runner import simulate_eager

    reference = None if record else json.loads(SNAPSHOT.read_text())["cells"]
    engine_rows: dict[str, dict[str, dict]] = {}
    worst = (0.0, None)
    t0 = time.time()
    for app in workloads():
        engine_rows[app] = {}
        for policy in POLICIES:
            eng = _row(simulate(app, policy, **SCALE))
            engine_rows[app][policy] = eng
            ref = (
                _row(simulate_eager(app, policy, **SCALE))
                if record
                else reference[app][policy]
            )
            errs = {f: _relerr(eng[f], ref[f]) for f in FIELDS}
            bad = max(errs.values())
            if bad > worst[0]:
                worst = (bad, (app, policy))
            status = "OK " if bad == 0.0 else f"rel-err {bad:.2e}"
            print(
                f"  {app:14s} {policy:12s} mig={eng['migrations']:6d} "
                f"mpki={eng['mpki']:10.4f} ipc={eng['ipc']:.4f}  {status}",
                flush=True,
            )
    if record:
        SNAPSHOT.write_text(
            json.dumps({"scale": SCALE, "fields": list(FIELDS),
                        "cells": engine_rows}, indent=1)
        )
        print(f"snapshot written: {SNAPSHOT}")
    mode = "engine-vs-eager" if record else "engine-vs-snapshot"
    print(
        f"hscc parity [{mode}] over {len(engine_rows)} workloads x "
        f"{len(POLICIES)} policies in {time.time() - t0:.0f}s: "
        f"worst rel-err {worst[0]:.3e} at {worst[1]}"
    )
    # exact parity was observed at this scale when the snapshot was recorded;
    # tolerate float noise only
    if worst[0] > 1e-6:
        print("PARITY FAILURE")
        return 1
    print("PARITY OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
