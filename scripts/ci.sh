#!/usr/bin/env bash
# Tier-1 verify + engine smoke, reproducible from a clean checkout:
#   pip install -r requirements.txt && bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: unit + system tests =="
python -m pytest -x -q

echo "== engine smoke: 2-interval scanned sim (rainbow + flat-static) =="
python - <<'EOF'
from repro.sim.runner import simulate

for policy in ("rainbow", "flat-static"):
    m = simulate("streamcluster", policy, intervals=2, accesses=4000)
    assert m.ipc > 0 and m.total_cycles > 0, (policy, m)
    print(f"  {policy:12s} ipc={m.ipc:.4f} mpki={m.mpki:.4f} "
          f"migrations={m.migrations}")
print("engine smoke OK")
EOF
