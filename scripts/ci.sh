#!/usr/bin/env bash
# Tier-1 verify + engine smoke, reproducible from a clean checkout:
#   pip install -r requirements.txt && bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: unit + system tests =="
python -m pytest -x -q

echo "== engine smoke: 2-interval scanned sim (rainbow + flat-static) =="
python - <<'EOF'
from repro.sim.runner import simulate

for policy in ("rainbow", "flat-static"):
    m = simulate("streamcluster", policy, intervals=2, accesses=4000)
    assert m.ipc > 0 and m.total_cycles > 0, (policy, m)
    print(f"  {policy:12s} ipc={m.ipc:.4f} mpki={m.mpki:.4f} "
          f"migrations={m.migrations}")
print("engine smoke OK")
EOF

echo "== multi-device smoke: sharded FleetRunner on 4 forced host devices =="
XLA_FLAGS="--xla_force_host_platform_device_count=4" python - <<'EOF'
import jax
from repro.engine import fleet
from repro.sim.runner import simulate

assert len(jax.devices()) == 4, jax.devices()
plan = fleet.SweepPlan.grid(
    ["streamcluster"], ["rainbow", "flat-static"], (0, 1, 2),
    intervals=2, accesses=3000,
)  # 6 cells -> 2 groups of 3, each padded to the 4-device mesh
res = fleet.FleetRunner().run(plan)
assert len(res) == 6
one = simulate("streamcluster", "rainbow", intervals=2, accesses=3000, seed=2)
got = res[("streamcluster", "rainbow", 2)]
assert got.ipc == one.ipc and got.migrations == one.migrations, (got, one)
print(f"  sharded fleet: {len(res)} cells across {len(jax.devices())} devices, "
      "bit-identical to single-device engine")
EOF

echo "== scenario smoke: fused in-scan generation vs staged oracle on a 4-device fleet =="
XLA_FLAGS="--xla_force_host_platform_device_count=4" python - <<'EOF'
import jax
from repro.engine import fleet
from repro.sim.runner import simulate

assert len(jax.devices()) == 4, jax.devices()
plan = fleet.SweepPlan.grid(
    policies=["rainbow", "flat-static"], seeds=(0, 1, 2),
    scenario=["stress/zipf-hotspot", "stress/seq-scan"],
    intervals=2, accesses=3000,
)  # 4 fused groups of 3 cells each, all padded to the 4-device mesh
res = fleet.FleetRunner().run(plan)
assert len(res) == 12
for name in ("stress/zipf-hotspot", "stress/seq-scan"):
    fused = res.one(app=name, policy="rainbow", seed=2)
    staged = simulate(name, "rainbow", intervals=2, accesses=3000, seed=2)
    assert fused.ipc == staged.ipc and fused.migrations == staged.migrations, (
        name, fused, staged)
    assert fused.total_cycles == staged.total_cycles
print(f"  scenario fleet: {len(res)} fused cells across "
      f"{len(jax.devices())} devices, bit-identical to the staged oracle")
EOF

echo "== distributed smoke: 2-process x 2-device fleet vs single-device oracle =="
# Gated on platform: the spawned workers force CPU host devices, which only
# emulates a multi-host fleet when this host itself runs the CPU backend.
if python -c "import jax; raise SystemExit(0 if jax.default_backend() == 'cpu' else 1)"; then
    python -m repro.launch.distributed --processes 2 --local-devices 2 --check
else
    echo "  skipped (non-CPU backend: real hosts join via jax.distributed, not spawn)"
fi

echo "== streamed sweep: run_iter + journal resume bit-identical to barrier run =="
python - <<'EOF'
import pathlib
import tempfile

from repro.engine import fleet
from repro.launch.distributed import _smoke_plan

plan = _smoke_plan()  # 2 compile signatures, group sizes (3, 2): always padded
runner = fleet.FleetRunner()
barrier = runner.run(plan)
assert dict(runner.run_iter(plan)) == dict(barrier.items()), "stream != barrier"
with tempfile.TemporaryDirectory() as td:
    journal = pathlib.Path(td) / "sweep.jsonl"
    it = runner.run_iter(plan, journal=journal)
    for _ in range(3):
        next(it)  # retire only the first group, then abandon the sweep
    it.close()
    resumed = runner.run(plan, journal=journal)
    assert dict(resumed.items()) == dict(barrier.items()), "resume != barrier"
print(f"  streamed + resumed: {len(barrier)} cells bit-identical to barrier run")
EOF

echo "== atlas smoke: policy atlas 2x2x2, streamed + journaled + resume-checked =="
ATLAS_TMP="$(mktemp -d)"
trap 'rm -rf "$ATLAS_TMP"' EXIT
REPRO_FLEET_CACHE_DIR="$ATLAS_TMP/xla-cache" python -m benchmarks.policy_atlas \
    --scenarios 2 --policies 2 --seeds 2 \
    --journal "$ATLAS_TMP/atlas.jsonl" --out "$ATLAS_TMP/BENCH_atlas.json" \
    --resume-check
python - "$ATLAS_TMP/BENCH_atlas.json" <<'EOF'
import json, sys

atlas = json.load(open(sys.argv[1]))
assert atlas["cells"] == 8 and atlas["winners"], atlas["config"]
assert len(atlas["timings"]) == len(atlas["journal_timings"]) == 4
print(f"  atlas smoke: {atlas['cells']} cells, winners={atlas['winners']}")
EOF

echo "== autotune smoke: tuned ControlPolicy beats the default on a recorded trace =="
python - <<'EOF'
import jax
from repro.configs import get_reduced_config
from repro.engine.autotune import TunePlan, autotune, evaluate
from repro.memory.kvcache import PagedConfig
from repro.models import model as M
from repro.serving.rainbow_decode import record_mass_trace

cfg = get_reduced_config("qwen3-4b")
key = jax.random.PRNGKey(0)
B, S = 2, 16
pcfg = PagedConfig(block_size=4, blocks_per_seq=S // 4, hot_slots=4,
                   top_n=4, max_promotions=4, interval_steps=8)
prompt = jax.random.randint(key, (B, 8), 0, cfg.vocab_size)
params = M.init_params(cfg, key, tp=1)
trace, _ = record_mass_trace(cfg, pcfg, params, prompt, steps=S)

plan = TunePlan.grid(pcfg.policy, interval_steps=(2, 8))  # 2 candidates
res = autotune(plan, trace)
assert res.improved, f"tuned must beat default: {res.summary()}"
cands = plan.candidates()
assert evaluate(trace, cands, runner="vmap") == evaluate(
    trace, cands, runner="sharded"), "vmap vs sharded evaluation diverged"
print(f"  {res.summary()}")
print("autotune smoke OK")
EOF

echo "== engine throughput smoke: hot-path gate (fastpath >= 1.4x, bit-identical) + BENCH_engine.json schema =="
python -m benchmarks.engine_throughput
python - <<'EOF'
import json

from benchmarks.engine_throughput import GATE_FLOOR, GATE_POLICIES, POLICY

bench = json.load(open("BENCH_engine.json"))
for key in ("benchmark", "quick", "unit", "rows", "headline",
            "scanned_vs_host_speedup", "profile", "gate"):
    assert key in bench, f"BENCH_engine.json missing {key!r}"
assert bench["unit"] == "accesses_per_sec"
gate = bench["gate"]
assert gate["floor"] == GATE_FLOOR and gate["bit_identical"] is True
assert gate["speedup"] >= GATE_FLOOR, (
    f"hot-path gate below floor in BENCH_engine.json: {gate['speedup']}")
assert set(gate["per_policy"]) == set(GATE_POLICIES)
for leg in gate["per_policy"].values():
    assert {"reference_s", "fast_s", "speedup", "accesses_per_sec"} <= set(leg)
phases = bench["profile"]["phases"]
assert {"tlb", "observe", "plan", "apply"} <= set(phases), sorted(phases)
for p in phases.values():
    assert {"wall_s", "compile_s", "calls", "flops", "bytes_accessed"} <= set(p)
print(f"  engine gate: {POLICY} fastpath {gate['speedup']:.2f}x reference "
      f"(floor {GATE_FLOOR}x), profile phases: {sorted(phases)}")
EOF

echo "== timing smoke: flat == queueing-with-infinite-banks (bitwise) + contention sanity =="
python - <<'EOF'
import dataclasses

from repro.sim.runner import simulate
from repro.timing import QueueGeometry

for policy in ("rainbow", "hscc-4kb-mig"):
    flat = simulate("streamcluster", policy, intervals=2, accesses=4000)
    inf = simulate("streamcluster", policy, intervals=2, accesses=4000,
                   timing_model="queueing",
                   queue_geometry=QueueGeometry.flat_floor())
    assert dataclasses.asdict(flat) == dataclasses.asdict(inf), (
        f"{policy}: flat != queueing-with-infinite-banks (bitwise)")
    tight = simulate("streamcluster", policy, intervals=2, accesses=4000,
                     timing_model="queueing",
                     queue_geometry=QueueGeometry(1, 2, 1, 2))
    assert tight.bank_stall_cycles > 0, policy
    assert tight.total_cycles > flat.total_cycles, policy
    print(f"  {policy:12s} flat-floor bitwise OK, constrained "
          f"bank_stall={tight.bank_stall_cycles:.3e}")
print("timing smoke OK")
EOF

echo "== timing contention: bank-geometry x policy sweep + BENCH_timing.json schema =="
python -m benchmarks.timing_contention
python - <<'EOF'
import json

bench = json.load(open("BENCH_timing.json"))
for key in ("benchmark", "quick", "headline", "rows", "flat_floor_bitwise",
            "gap_ipc_flat", "gap_ipc_constrained", "gate"):
    assert key in bench, f"BENCH_timing.json missing {key!r}"
assert bench["flat_floor_bitwise"] is True, "flat-floor invariant broken"
gate = bench["gate"]
assert {"floor", "speedup"} <= set(gate)
assert gate["speedup"] >= gate["floor"], (
    f"policy-gap shift below floor: {gate['speedup']} < {gate['floor']}")
for row in bench["rows"]:
    assert {"geometry", "app", "policy", "ipc", "total_cycles",
            "bank_stall_cycles", "mig_stall_cycles", "queue_occ_dram",
            "queue_occ_nvm"} <= set(row), row
print(f"  timing gate: {bench['headline']}")
EOF

echo "== nomad smoke: async family vs rainbow, staged == fused bitwise + BENCH_nomad.json schema =="
python - <<'EOF'
import dataclasses

from repro.sim.runner import simulate
from repro.timing import get_geometry

kw = dict(intervals=3, accesses=4000, seed=3, timing_model="queueing",
          queue_geometry=get_geometry("constrained"))
staged = simulate("stress/zipf-hotspot", "nomad", **kw)
fused = simulate("stress/zipf-hotspot", "nomad", fused=True, **kw)
assert dataclasses.asdict(staged) == dataclasses.asdict(fused), (
    "nomad: staged != fused (bitwise)")
rainbow = simulate("stress/zipf-hotspot", "rainbow", **kw)
assert staged.migrations > 0 and staged.mig_aborts > 0, staged
assert rainbow.mig_aborts == 0, rainbow
print(f"  nomad staged==fused bitwise OK: {staged.migrations} migrations, "
      f"{staged.mig_aborts} aborts (rainbow mig_stall="
      f"{rainbow.mig_stall_cycles:.3e}, nomad={staged.mig_stall_cycles:.3e})")
EOF
python -m benchmarks.nomad_async
python - <<'EOF'
import json

bench = json.load(open("BENCH_nomad.json"))
for key in ("benchmark", "quick", "headline", "rows",
            "sync_degenerate_bitwise", "mig_stall_relief", "total_aborts",
            "gate"):
    assert key in bench, f"BENCH_nomad.json missing {key!r}"
assert bench["sync_degenerate_bitwise"] is True, (
    "async_window=1 must be bit-identical to synchronous rainbow")
gate = bench["gate"]
assert {"floor", "speedup"} <= set(gate)
assert gate["speedup"] >= gate["floor"], (
    f"mig_stall relief below floor: {gate['speedup']} < {gate['floor']}")
assert bench["total_aborts"] > 0, "abort path never exercised"
for row in bench["rows"]:
    assert {"geometry", "app", "policy", "ipc", "total_cycles", "migrations",
            "mig_aborts", "bank_stall_cycles", "mig_stall_cycles"} <= set(row), row
print(f"  nomad gate: {bench['headline']}")
EOF

echo "== hscc parity: STREAMED fleet vs recorded snapshot (spot check, rel-err 0.0) =="
python scripts/validate_hscc_parity.py --stream --apps soplex
echo "  (full table: scripts/validate_hscc_parity.py [--stream])"

echo "== bench aggregate: every BENCH_*.json gate must pass (non-zero exit on failure) =="
python -m benchmarks.run --aggregate-only
