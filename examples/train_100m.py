"""End-to-end driver (deliverable b): train a ~100M-param qwen3-family model for
a few hundred steps with the fault-tolerant loop + checkpointing.

Run: PYTHONPATH=src python examples/train_100m.py [--steps 300]
(CPU: expect ~1-2 s/step at this size; loss should drop well below ln(V).)
"""
import argparse

import jax

from repro.data.pipeline import SyntheticLM
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, linear_warmup_cosine
from repro.train.loop import LoopConfig, Trainer
from repro.train.step import TrainStepConfig, build_train_step, init_train_state

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--ckpt-dir", default="checkpoints/train_100m")
args = ap.parse_args()

# ~100M params: 12L x 512d x 8H, 16k vocab (qwen3 family: qk_norm + GQA)
cfg = ModelConfig(
    name="qwen3-100m", family="dense", num_layers=12, d_model=512,
    num_heads=8, num_kv_heads=4, d_ff=1536, vocab_size=16384, head_dim=64,
    qk_norm=True, vocab_pad_multiple=64,
)
print(f"params: {cfg.param_count() / 1e6:.1f}M")

tcfg = TrainStepConfig(tp=1, remat="none", adamw=AdamWConfig(lr=1e-3))
schedule = linear_warmup_cosine(1e-3, 20, args.steps)
step = jax.jit(build_train_step(cfg, tcfg, lr_schedule=schedule),
               donate_argnums=(0,))
data = iter(SyntheticLM(cfg.vocab_size, seq_len=256, global_batch=8, seed=0))
trainer = Trainer(step, data, LoopConfig(
    total_steps=args.steps, checkpoint_every=100, checkpoint_dir=args.ckpt_dir,
    log_every=10))
state, start = trainer.ckpt.restore_or_init(
    lambda: init_train_state(cfg, jax.random.PRNGKey(0), tcfg))
if start:
    print(f"resumed from checkpoint at step {start}")
state, hist = trainer.run(state, start)
print(f"\nloss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
      f"over {len(hist)} steps")
