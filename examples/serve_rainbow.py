"""Serve a small model with batched requests over the Rainbow paged KV cache
(deliverable b, serving flavor): tiered decode with hot-block promotion, exact
vs the flat cache.

Run: PYTHONPATH=src python examples/serve_rainbow.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_reduced_config
from repro.engine.policy import get_policy
from repro.memory.kvcache import PagedConfig, paged_init
from repro.models import model as M
from repro.serving.rainbow_decode import rainbow_decode_step
from repro.serving.steps import greedy_sample

cfg = get_reduced_config("qwen3-4b")
key = jax.random.PRNGKey(0)
B, STEPS = 4, 48
# controller knobs come from the unified ControlPolicy surface (docs/policy.md);
# `python -m repro.launch.serve --autotune` searches these engine-in-the-loop
pcfg = PagedConfig(
    block_size=8, blocks_per_seq=STEPS // 8 + 1,
    policy=get_policy("serving-default").replace(
        hot_slots=12, top_n=4, max_promotions=8, interval_steps=8),
)
params = M.init_params(cfg, key, tp=1)
kv = paged_init(cfg, pcfg, B, 1, cfg.num_layers)
cache = M.init_cache(cfg, B, STEPS + 8, tp=1)

rb = jax.jit(lambda p, t, k: rainbow_decode_step(cfg, pcfg, p, t, k))
flat = jax.jit(lambda p, t, c: M.decode_step(cfg, p, t, c))

tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
tok_f = tok
t0 = time.time()
agree = 0
for step in range(STEPS):
    lr, kv = rb(params, tok, kv)
    lf, cache = flat(params, tok_f, cache)
    tok = greedy_sample(lr, cfg.vocab_size)
    tok_f = greedy_sample(lf, cfg.vocab_size)
    agree += int((tok == tok_f).all())
print(f"decoded {STEPS} steps x {B} seqs in {time.time() - t0:.1f}s")
print(f"rainbow/flat token agreement: {agree}/{STEPS} steps")
print(f"hot blocks promoted: {int((kv.remap.remap >= 0).sum())} "
      f"(pool capacity {pcfg.hot_slots})")
print(f"adaptive threshold: {float(kv.threshold):.1f}")
