"""Reproduce the paper's headline comparison on one workload (Layer A).

Runs all five policies of §IV-A on the soplex trace and prints the Fig. 7/10/11
metrics side by side.

Run: PYTHONPATH=src python examples/memsim_repro.py [app]
"""
import sys

from repro.sim.config import POLICIES
from repro.sim.runner import simulate

app = sys.argv[1] if len(sys.argv) > 1 else "soplex"
print(f"workload: {app} (synthetic trace calibrated to paper Tables I/II)\n")
print(f"{'policy':16s} {'IPC':>7s} {'vs flat':>8s} {'MPKI':>9s} "
      f"{'TLB%':>6s} {'mig':>6s} {'traffic':>8s} {'energy(J)':>10s}")
base = None
for pol in POLICIES:
    m = simulate(app, pol, intervals=5, accesses=40_000)
    if base is None:
        base = m.ipc
    print(f"{pol:16s} {m.ipc:7.3f} {m.ipc / base:7.2f}x {m.mpki:9.3f} "
          f"{100 * m.tlb_service_frac:6.2f} {m.migrations:6d} "
          f"{m.traffic_ratio:8.3f} {m.energy['total_j']:10.3f}")
print("\npaper claims (averages over its full workload set): Rainbow vs "
      "Flat-static +72.7% IPC, vs HSCC-4KB +22.8%, vs HSCC-2MB +17.3%; "
      "TLB misses -99.8% vs 4KB paging.")
