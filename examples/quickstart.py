"""Quickstart: the Rainbow core library in 60 lines.

Drives the paper's mechanism directly: synthesize a hot/cold access stream,
run two monitoring intervals (stage-1 counting -> top-N -> stage-2 counting ->
utility admission), and watch translations redirect to the fast tier.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (
    RainbowConfig, end_interval, make_timing, observe, rainbow_init,
    translate_accesses,
)

cfg = RainbowConfig(
    num_superpages=256,  # capacity tier managed at superpage grain
    pages_per_sp=64,
    top_n=16,  # stage-2 monitors the 16 hottest superpages
    dram_slots=128,  # performance tier: 128 small-page slots
    max_migrations_per_interval=64,
)
# Table III timing (cycles): NVM read/write, DRAM read/write, T_mig, T_writeback
timing = make_timing(62.4, 547.2, 43.2, 91.2, 1000.0, 1000.0)
state = rainbow_init(cfg)

key = jax.random.PRNGKey(0)
# hot set: superpage 7, pages 0..7, heavily written; cold background elsewhere
hot_sp = jnp.full((3000,), 7, jnp.int32)
hot_pg = jax.random.randint(key, (3000,), 0, 8)
cold_sp = jax.random.randint(key, (1000,), 0, 256)
cold_pg = jax.random.randint(jax.random.PRNGKey(1), (1000,), 0, 64)
sp = jnp.concatenate([hot_sp, cold_sp])
pg = jnp.concatenate([hot_pg, cold_pg])
wr = jax.random.bernoulli(jax.random.PRNGKey(2), 0.4, sp.shape)

for interval in range(3):
    state = observe(cfg, state, sp, pg, wr, jnp.int32(interval))
    state, report = end_interval(cfg, state, timing)
    print(
        f"interval {interval}: monitored top-{cfg.top_n} superpages, "
        f"migrated {int(report.n_migrated)} pages, "
        f"evicted {int(report.n_evicted)}, "
        f"threshold -> {float(report.threshold):.1f}"
    )

in_fast, slot = translate_accesses(
    state, jnp.full((8,), 7, jnp.int32), jnp.arange(8, dtype=jnp.int32)
)
print("\nsuperpage 7, pages 0..7 after two intervals:")
print("  in fast tier:", in_fast.tolist())
print("  fast-tier slots:", slot.tolist())
print("\nThe superpage itself was never splintered: translations for its cold")
print("pages still resolve through the (intact) superpage entry.")
