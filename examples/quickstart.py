"""Quickstart: the Rainbow core library, then a full scenario simulation.

Part 1 drives the paper's mechanism directly: synthesize a hot/cold access
stream, run two monitoring intervals (stage-1 counting -> top-N -> stage-2
counting -> utility admission), and watch translations redirect to the fast
tier. Part 2 runs one registered workload scenario end-to-end through the
device-resident engine — trace generation fused into the scan — and compares
policies on it (docs/workloads.md).

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (
    RainbowConfig, end_interval, make_timing, observe, rainbow_init,
    translate_accesses,
)

cfg = RainbowConfig(
    num_superpages=256,  # capacity tier managed at superpage grain
    pages_per_sp=64,
    top_n=16,  # stage-2 monitors the 16 hottest superpages
    dram_slots=128,  # performance tier: 128 small-page slots
    max_migrations_per_interval=64,
)
# Table III timing (cycles): NVM read/write, DRAM read/write, T_mig, T_writeback
timing = make_timing(62.4, 547.2, 43.2, 91.2, 1000.0, 1000.0)
state = rainbow_init(cfg)

key = jax.random.PRNGKey(0)
# hot set: superpage 7, pages 0..7, heavily written; cold background elsewhere
hot_sp = jnp.full((3000,), 7, jnp.int32)
hot_pg = jax.random.randint(key, (3000,), 0, 8)
cold_sp = jax.random.randint(key, (1000,), 0, 256)
cold_pg = jax.random.randint(jax.random.PRNGKey(1), (1000,), 0, 64)
sp = jnp.concatenate([hot_sp, cold_sp])
pg = jnp.concatenate([hot_pg, cold_pg])
wr = jax.random.bernoulli(jax.random.PRNGKey(2), 0.4, sp.shape)

for interval in range(3):
    state = observe(cfg, state, sp, pg, wr, jnp.int32(interval))
    state, report = end_interval(cfg, state, timing)
    print(
        f"interval {interval}: monitored top-{cfg.top_n} superpages, "
        f"migrated {int(report.n_migrated)} pages, "
        f"evicted {int(report.n_evicted)}, "
        f"threshold -> {float(report.threshold):.1f}"
    )

in_fast, slot = translate_accesses(
    state, jnp.full((8,), 7, jnp.int32), jnp.arange(8, dtype=jnp.int32)
)
print("\nsuperpage 7, pages 0..7 after two intervals:")
print("  in fast tier:", in_fast.tolist())
print("  fast-tier slots:", slot.tolist())
print("\nThe superpage itself was never splintered: translations for its cold")
print("pages still resolve through the (intact) superpage entry.")

# --- Part 2: one scenario preset, end to end through the engine ------------
# A registered workload scenario (repro.workloads) is a first-class workload
# name: simulate() runs it with the trace generator FUSED into the engine's
# interval scan (fused=True stages nothing host-side), and the staged path
# materializes the same generator stream as the bit-identical oracle.
from repro.sim.runner import simulate  # noqa: E402

SCENARIO = "stress/phase-shift"  # working-set drift: hot window slides 50%/interval
print(f"\nscenario {SCENARIO!r}, fused in-scan generation:")
for policy in ("rainbow", "hscc-2mb-mig", "flat-static"):
    m = simulate(SCENARIO, policy, intervals=3, accesses=4000, fused=True)
    print(f"  {policy:12s} ipc={m.ipc:.4f} mpki={m.mpki:.3f} "
          f"migrations={m.migrations:4d} traffic={m.mig_bytes/2**20:.1f}MiB")
staged = simulate(SCENARIO, "rainbow", intervals=3, accesses=4000)
fused = simulate(SCENARIO, "rainbow", intervals=3, accesses=4000, fused=True)
assert staged.ipc == fused.ipc and staged.migrations == fused.migrations
print("staged oracle == fused path, bit for bit (docs/workloads.md)")
