"""Optimizer, schedules, compression, data pipeline, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_state, save_state
from repro.data.pipeline import SyntheticLM
from repro.optim import AdamWConfig, adamw_init, adamw_update, linear_warmup_cosine
from repro.optim.compression import (
    compress_grads_int8,
    decompress_grads_int8,
    error_init,
    topk_densify,
    topk_sparsify,
)


def test_adamw_matches_reference_math():
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      grad_clip=1e9)
    p = {"w": jnp.array([1.0, -2.0])}
    st = adamw_init(p)
    g = {"w": jnp.array([0.5, 0.5])}
    newp, st2, _ = adamw_update(cfg, g, st, p)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    want = 1.0 - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(float(newp["w"][0]), want, rtol=1e-5)


def test_grad_clip_engages():
    cfg = AdamWConfig(grad_clip=1.0)
    p = {"w": jnp.ones(4)}
    st = adamw_init(p)
    g = {"w": jnp.full(4, 100.0)}
    _, _, metrics = adamw_update(cfg, g, st, p)
    assert float(metrics["grad_norm"]) > 1.0
    assert float(metrics["clip_scale"]) < 1.0


def test_schedule_warmup_then_decay():
    lr = linear_warmup_cosine(1.0, 10, 100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1.0) < 0.2
    assert float(lr(jnp.int32(95))) < float(lr(jnp.int32(20)))


def test_int8_compression_error_feedback_unbiased():
    """Error feedback: accumulated compressed updates track the true sum."""
    key = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(key, (256,))}
    err = error_init(g)
    total_true = jnp.zeros(256)
    total_comp = jnp.zeros(256)
    for i in range(20):
        gi = {"w": jax.random.normal(jax.random.PRNGKey(i), (256,))}
        total_true += gi["w"]
        q, s, err = compress_grads_int8(gi, err, jax.random.PRNGKey(100 + i))
        deq = decompress_grads_int8(q, s)
        total_comp += deq["w"]
    resid = jnp.abs(total_true - total_comp - err["w"]).max()
    assert float(resid) < 1e-3  # drift is exactly the residual error state


def test_topk_sparsify_roundtrip():
    g = jnp.array([0.1, -5.0, 0.2, 3.0])
    err = jnp.zeros(4)
    vals, idx, err2 = topk_sparsify(g, 0.5, err)
    dense = topk_densify(vals, idx, (4,))
    np.testing.assert_allclose(np.asarray(dense), [0.0, -5.0, 0.0, 3.0])
    np.testing.assert_allclose(np.asarray(err2), [0.1, 0.0, 0.2, 0.0])


def test_synthetic_data_deterministic_and_resumable():
    d1 = SyntheticLM(vocab_size=100, seq_len=16, global_batch=4, seed=7)
    b1 = [d1.next_batch() for _ in range(3)]
    d2 = SyntheticLM(vocab_size=100, seq_len=16, global_batch=4, seed=7)
    d2.load_state({"step": 2, "seed": 7})
    b2 = d2.next_batch()
    np.testing.assert_array_equal(b1[2]["tokens"], b2["tokens"])


def test_checkpoint_roundtrip_and_gc(tmp_path):
    state = {"params": {"w": jnp.arange(8.0)}, "step_data": {"step": jnp.int32(5)}}
    for s in (10, 20, 30, 40):
        save_state(str(tmp_path), s, state, keep_last=2)
    assert latest_step(str(tmp_path)) == 40
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 2  # retention honored
    like = jax.eval_shape(lambda: state)
    restored, step = restore_state(str(tmp_path), like)
    assert step == 40
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]), np.arange(8.0))


def test_checkpoint_detects_corruption(tmp_path):
    state = {"w": jnp.ones(4)}
    path = save_state(str(tmp_path), 1, state)
    shard = os.path.join(path, "shard_00000.npz")
    data = dict(np.load(shard))
    data["w"] = data["w"] + 1
    np.savez(shard, **data)
    like = jax.eval_shape(lambda: state)
    with pytest.raises(ValueError, match="checksum"):
        restore_state(str(tmp_path), like)


def test_checkpoint_elastic_resharding(tmp_path):
    """Save unsharded, restore under a different device layout (elastic)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_state(str(tmp_path), 1, state)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    like = jax.eval_shape(lambda: state)
    restored, _ = restore_state(str(tmp_path), like, shardings=sh)
    assert restored["w"].sharding.spec == P("data", None)
