"""Differential harness for the multi-process / streamed / resumed fleet.

Every new execution path of the scale-out (multi-process shard_map, streamed
run_iter retirement, journal resume) is pinned to the SAME oracle: the
single-device vmap engine. The tests run workers in subprocesses because
jax.distributed can be initialized only once per process (and forcing host
device counts must happen before jax touches its backends) — see
docs/fleet.md "Troubleshooting".

The shared smoke plan (launch.distributed._smoke_plan) is adversarial by
construction: two compile signatures (streamcluster vs soplex shapes) and
group sizes (3, 2) that divide no even mesh, so every leg exercises the
non-divisible padding path.
"""
import functools
import json
import os
import subprocess
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TIMEOUT = 600


def _run_script(script: str):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=TIMEOUT,
    )


@functools.lru_cache(maxsize=1)
def _reference_result():
    """Single-device (vmap-path) barrier oracle for the shared smoke plan.

    pipeline=False is the pre-pipeline inline path — the reference every
    pipelined/streamed/journaled leg must reproduce bit for bit.
    """
    from repro.engine import fleet
    from repro.launch.distributed import _smoke_plan

    return fleet.FleetRunner(pipeline=False).run(_smoke_plan())


def _reference_rows():
    from repro.launch.distributed import _result_rows

    return _result_rows(_reference_result())


def test_two_process_fleet_bit_identical(tmp_path):
    """2 spawned processes x 2 forced devices == single-device vmap, bitwise.

    The worker side (launch.distributed._worker_main) additionally asserts
    the mesh really spans both processes and that the in-fleet streamed
    run_iter equals the in-fleet barrier run — so a pass here certifies the
    whole chain: spawn -> jax.distributed bring-up -> cross-process staging
    (make_array_from_callback) -> sharded scan -> all-gather retire.
    """
    out = tmp_path / "fleet_rows.json"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.distributed",
         "--processes", "2", "--local-devices", "2", "--out", str(out)],
        capture_output=True, text=True, cwd=ROOT, timeout=TIMEOUT,
        env=dict(os.environ, PYTHONPATH="src"),
    )
    assert r.returncode == 0, r.stderr[-4000:]
    fleet_rows = json.loads(out.read_text())
    assert fleet_rows == _reference_rows()


def test_streamed_run_iter_matches_barrier_run_cell_by_cell():
    """run_iter == run, cell for cell (in-parent; the multi-device streamed
    equality runs inside the 2x2 fleet worker of the test above).

    The default runner is the PIPELINED engine (prepare thread + compile
    cache + pooled staging), so this pins pipelined streaming to the
    pipeline=False reference — and per-group timings must be surfaced.
    """
    from repro.engine import fleet
    from repro.launch.distributed import _smoke_plan

    plan = _smoke_plan()
    barrier = _reference_result()
    runner = fleet.FleetRunner()
    streamed = list(runner.run_iter(plan))
    assert len(streamed) == len(barrier) == 5
    for cell, metrics in streamed:
        assert metrics == barrier[cell], cell.label
    # per-group wall-clock attribution rides on the runner
    assert len(runner.timings) == len(fleet.plan_groups(plan))
    for t in runner.timings:
        assert t.cells >= 1 and t.stage_s >= 0 and t.compile_s >= 0
        assert t.scan_s >= 0 and t.retire_s >= 0
    # run(stream=True) is the same path wrapped into a FleetResult
    res = fleet.FleetRunner().run(plan, stream=True)
    assert dict(res.items()) == dict(barrier.items())


def test_kill_and_resume_matches_uninterrupted(tmp_path):
    """A hard-killed streamed sweep resumes from the journal, bit-identically.

    Worker 1 retires exactly one group then os._exit's (no cleanup, the
    real kill shape); worker 2 resumes against the same journal and must
    (a) not recompute the journaled group and (b) reproduce the oracle.

    flush_groups=1 pins the legacy every-group durability contract (the
    batched default is covered by test_batched_journal_kill_mid_coalesce).
    """
    journal = tmp_path / "sweep.journal.jsonl"
    rows_out = tmp_path / "resumed_rows.json"

    killed = _run_script(f"""
        import os
        from repro.engine import fleet
        from repro.launch.distributed import _smoke_plan

        plan = _smoke_plan()
        (g0, g1) = fleet.plan_groups(plan)
        jnl = fleet.FleetJournal({str(journal)!r}, flush_groups=1)
        it = fleet.FleetRunner().run_iter(plan, journal=jnl)
        for _ in g0.cells:
            next(it)
        os._exit(41)  # killed mid-sweep: the generator never finalizes
    """)
    assert killed.returncode == 41, killed.stderr[-4000:]
    lines = journal.read_text().splitlines()
    assert len(lines) == 2  # header + exactly the first retired group
    assert json.loads(lines[0])["kind"] == "fleet-journal"
    first_group_keys = set(json.loads(lines[1])["cells"])

    resumed = _run_script(f"""
        import json
        from repro.engine import fleet
        from repro.launch.distributed import _result_rows, _smoke_plan

        plan = _smoke_plan()
        runner = fleet.FleetRunner()
        staged = []
        real_stage = runner._stage_pooled
        runner._stage_pooled = lambda g: (staged.append(g), real_stage(g))[1]
        res = runner.run(plan, journal={str(journal)!r})
        # group 0 must come from the journal, not from a re-run
        assert [len(g.cells) for g in staged] == [2], staged
        json.dump(_result_rows(res), open({str(rows_out)!r}, "w"))
        print("RESUME_OK")
    """)
    assert "RESUME_OK" in resumed.stdout, resumed.stderr[-4000:]
    assert json.loads(rows_out.read_text()) == _reference_rows()

    # the journal now holds both groups; group 0 was appended exactly once
    lines = journal.read_text().splitlines()
    assert len(lines) == 3
    assert set(json.loads(lines[1])["cells"]) == first_group_keys
    assert set(json.loads(lines[2])["cells"]).isdisjoint(first_group_keys)


def test_journal_schema_version_enforced(tmp_path):
    """A journal from a different build fails LOUDLY at load(), never silently.

    Three mixed-schema shapes, all of which a resume must refuse:
      * a header with an older version number (pre-schema v1 journal);
      * a headerless file (pre-versioning writer, or the header line lost
        to truncation) whose cell records would otherwise parse fine;
      * a current-version header recording SimMetrics fields this build
        does not know (journal written by a NEWER build).
    A journal this build wrote itself must round-trip, including the new
    fields (mig_aborts), and its header must carry the schema list.
    """
    import dataclasses

    import pytest

    from repro.engine import fleet
    from repro.engine.fleet import FleetJournal
    from repro.sim.runner import SimMetrics

    schema = sorted(f.name for f in dataclasses.fields(SimMetrics))
    cell_line = json.dumps({"cells": {}, "timing": {"cells": 1}})

    v1 = tmp_path / "v1.jsonl"
    v1.write_text(
        json.dumps({"kind": "fleet-journal", "version": 1}) + "\n"
        + cell_line + "\n"
    )
    with pytest.raises(ValueError, match="journal version 1"):
        FleetJournal(v1).load()

    headerless = tmp_path / "headerless.jsonl"
    headerless.write_text(cell_line + "\n")
    with pytest.raises(ValueError, match="before any fleet-journal header"):
        FleetJournal(headerless).load()

    newer = tmp_path / "newer.jsonl"
    newer.write_text(
        json.dumps({
            "kind": "fleet-journal",
            "version": FleetJournal.VERSION,
            "schema": schema + ["field_from_the_future"],
        }) + "\n" + cell_line + "\n"
    )
    with pytest.raises(ValueError, match="field_from_the_future"):
        FleetJournal(newer).load()

    # a journal this build writes round-trips, mixed-version error paths
    # notwithstanding — and the header records the full field list
    from repro.launch.distributed import _smoke_plan

    journal = tmp_path / "own.jsonl"
    res = fleet.FleetRunner().run(_smoke_plan(), journal=journal)
    header = json.loads(journal.read_text().splitlines()[0])
    assert header["version"] == FleetJournal.VERSION
    assert header["schema"] == schema
    loaded = FleetJournal(journal).load()
    assert set(loaded) == {c.key() for c, _ in res.items()}
    assert all(isinstance(m, SimMetrics) for m in loaded.values())


def test_batched_journal_kill_mid_coalesce(tmp_path):
    """Hard kill mid-coalesce under batched retirement (flush_groups=2).

    Worker 1 retires all three groups of a 3-signature plan but is killed
    while the third group is still coalescing in the append buffer: the
    watermark flushed groups 0-1, so exactly those survive on disk. Worker 2
    resumes, re-executes ONLY the lost group, and the merged result is
    bit-identical to an uninterrupted pipeline=False run — with every cell
    key appearing exactly once across the final journal.
    """
    journal = tmp_path / "batched.journal.jsonl"
    rows_out = tmp_path / "resumed_rows.json"
    plan_src = """
        def _plan():
            from repro.engine import fleet
            kw = dict(intervals=2, accesses=1500)
            return (
                fleet.SweepPlan.grid(["streamcluster"], ["rainbow"], (0, 1), **kw)
                + fleet.SweepPlan.grid(["soplex"], ["rainbow"], (0, 1), **kw)
                + fleet.SweepPlan.grid(["mcf"], ["rainbow"], (0, 1), **kw)
            )
    """

    killed = _run_script(plan_src + f"""
        import os
        from repro.engine import fleet

        plan = _plan()
        groups = fleet.plan_groups(plan)
        assert len(groups) == 3
        jnl = fleet.FleetJournal({str(journal)!r}, flush_groups=2)
        it = fleet.FleetRunner().run_iter(plan, journal=jnl)
        for _ in range(sum(len(g.cells) for g in groups)):
            next(it)  # all three groups retired; group 2 is still buffered
        assert jnl.pending == 1, jnl.pending
        os._exit(41)  # the coalesced tail never reaches disk
    """)
    assert killed.returncode == 41, killed.stderr[-4000:]
    lines = journal.read_text().splitlines()
    assert len(lines) == 3  # header + the two watermark-flushed groups
    assert json.loads(lines[0])["kind"] == "fleet-journal"
    flushed_keys = set()
    for line in lines[1:]:
        keys = set(json.loads(line)["cells"])
        assert keys.isdisjoint(flushed_keys)
        flushed_keys |= keys

    resumed = _run_script(plan_src + f"""
        import json
        from repro.engine import fleet
        from repro.launch.distributed import _result_rows

        plan = _plan()
        runner = fleet.FleetRunner()
        staged = []
        real_stage = runner._stage_pooled
        runner._stage_pooled = lambda g: (staged.append(g), real_stage(g))[1]
        jnl = fleet.FleetJournal({str(journal)!r}, flush_groups=2)
        res = runner.run(plan, journal=jnl)
        # only the lost (unflushed) group is re-executed
        assert [len(g.cells) for g in staged] == [2], staged

        oracle = fleet.FleetRunner(pipeline=False).run(plan)
        assert dict(res.items()) == dict(oracle.items())
        json.dump(_result_rows(res), open({str(rows_out)!r}, "w"))
        print("RESUME_OK")
    """)
    assert "RESUME_OK" in resumed.stdout, resumed.stderr[-4000:]

    # final journal: header + 3 group records, each cell key exactly once
    lines = journal.read_text().splitlines()
    assert len(lines) == 4
    all_keys = []
    for line in lines[1:]:
        all_keys.extend(json.loads(line)["cells"])
    assert len(all_keys) == len(set(all_keys)) == 6
