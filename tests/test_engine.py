"""MemoryEngine (engine.simloop / engine.control): equivalence + parity.

The load-bearing guarantee: the whole-simulation lax.scan engine produces
BIT-IDENTICAL SimMetrics to the pre-refactor eager interval loop, so every
paper figure driven through sim.runner is unchanged by the refactor.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sim.config import MachineConfig
from repro.sim.runner import simulate, simulate_eager, sweep

EQUIV_CASES = [
    ("streamcluster", "rainbow"),
    ("streamcluster", "flat-static"),
    ("soplex", "rainbow"),
    ("soplex", "flat-static"),
    ("streamcluster", "dram-only"),
]


@pytest.mark.parametrize("app,policy", EQUIV_CASES)
def test_engine_matches_eager_loop_bit_identical(app, policy):
    """scanned device engine == host-looped reference, field for field."""
    kw = dict(intervals=3, accesses=5000, seed=11)
    eng = simulate(app, policy, engine=True, **kw)
    ref = simulate_eager(app, policy, **kw)
    assert eng.migrations == ref.migrations
    assert eng.evictions == ref.evictions
    assert eng.shootdowns == ref.shootdowns
    assert eng.mpki == ref.mpki
    assert eng.tlb_service_cycles == ref.tlb_service_cycles
    assert eng.ipc == ref.ipc
    assert eng.total_cycles == ref.total_cycles
    assert eng.mig_bytes == ref.mig_bytes
    for k in eng.breakdown:
        assert eng.breakdown[k] == ref.breakdown[k], k


@pytest.mark.parametrize("policy", ["hscc-4kb-mig", "hscc-2mb-mig"])
def test_engine_hscc_snapshot_parity(policy):
    """The engine is the ONLY HSCC path now (the numpy host loops were deleted
    after exact full-table parity, scripts/validate_hscc_parity.py); spot-check
    one workload against the recorded snapshot and pin the deletion."""
    import json
    import pathlib

    snap = json.loads(
        (pathlib.Path(__file__).parents[1] / "scripts"
         / "hscc_parity_snapshot.json").read_text()
    )
    scale = snap["scale"]
    eng = simulate("streamcluster", policy, intervals=scale["intervals"],
                   accesses=scale["accesses"], seed=scale["seed"])
    ref = snap["cells"]["streamcluster"][policy]
    assert eng.migrations == ref["migrations"]
    assert eng.mpki == pytest.approx(ref["mpki"], rel=1e-9)
    assert eng.ipc == pytest.approx(ref["ipc"], rel=1e-9)
    with pytest.raises(KeyError, match="no eager reference"):
        simulate_eager("streamcluster", policy, intervals=2, accesses=2000)


SWEEP_SCENARIOS = ["stress/zipf-hotspot", "syn/GUPS", "stress/seq-scan"]
ALL_POLICIES = [
    "flat-static", "dram-only", "rainbow", "hscc-4kb-mig", "hscc-2mb-mig",
]


@pytest.mark.parametrize("scenario", SWEEP_SCENARIOS)
@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_fastpath_sweep_policies_x_scenarios(policy, scenario):
    """PR 7 hot-path sweep: on every policy x registered scenario, the
    vectorized fast path (staged AND fused) is bit-identical to the
    fastpath=False reference program — and to the eager oracle where one
    exists (the HSCC ports have no eager loop; the reference spec + the
    parity snapshot anchor them instead)."""
    kw = dict(intervals=2, accesses=2500, seed=13)
    fast = simulate(scenario, policy, **kw)
    ref = simulate(scenario, policy, fastpath=False, **kw)
    assert dataclasses.asdict(fast) == dataclasses.asdict(ref)
    fused = simulate(scenario, policy, fused=True, **kw)
    assert dataclasses.asdict(fast) == dataclasses.asdict(fused)
    if policy in ("flat-static", "dram-only", "rainbow"):
        eager = simulate_eager(scenario, policy, **kw)
        assert dataclasses.asdict(fast) == dataclasses.asdict(eager)


def test_engine_vmap_over_seeds_shapes():
    """sweep vmaps (seed fleet) per cell; shapes and per-seed values line up."""
    from repro.engine import simloop

    seeds = [1, 5, 9]
    finals, stats, meta = simloop.sweep_seeds(
        "streamcluster", "rainbow", MachineConfig(), seeds,
        intervals=2, accesses=3000,
    )
    assert stats.migrations.shape == (len(seeds), 2)
    assert finals.sim.counters.cycles_mem.shape == (len(seeds),)
    # batched run must agree with the single-seed engine
    single = simulate("streamcluster", "rainbow", intervals=2, accesses=3000,
                      seed=seeds[1])
    out = sweep(["streamcluster"], ["rainbow"], seeds,
                intervals=2, accesses=3000)
    got = out[("streamcluster", "rainbow", seeds[1])]
    assert got.migrations == single.migrations
    assert got.ipc == single.ipc


def test_fused_counter_backend_bit_identical():
    """counter_backend='ref' (fused one-pass histograms) == scatter-add path."""
    kw = dict(intervals=2, accesses=3000, seed=3)
    a = simulate("streamcluster", "rainbow", counter_backend="jax", **kw)
    b = simulate("streamcluster", "rainbow", counter_backend="ref", **kw)
    assert a.migrations == b.migrations
    assert a.evictions == b.evictions
    assert a.ipc == b.ipc
    assert a.mpki == b.mpki


# (the fused-observe interpret-vs-ref parity check moved into the kernel
# parity matrix, tests/test_kernels.py::test_kernel_parity_matrix)


def test_observe_separates_reads_and_writes():
    """Pins satellite fix: the read counter must NOT count writes (and vice
    versa) — the old `is_write * 0 > 0` dead expression is replaced by explicit
    read/write weights."""
    from repro.core import counting
    from repro.core.rainbow import RainbowConfig, observe, rainbow_init

    cfg = RainbowConfig(num_superpages=8, pages_per_sp=4, top_n=2, dram_slots=4)
    st = rainbow_init(cfg)
    # monitor superpage 2 so stage-2 records
    st = dataclasses.replace(
        st,
        s2_reads=counting.stage2_begin(jnp.array([2, -1], jnp.int32), 4),
        s2_writes=counting.stage2_begin(jnp.array([2, -1], jnp.int32), 4),
    )
    sp = jnp.array([2, 2, 2, 2, 2], jnp.int32)
    page = jnp.array([0, 0, 1, 1, 1], jnp.int32)
    wr = jnp.array([False, False, False, True, True])
    st = observe(cfg, st, sp, page, wr, jnp.int32(0))
    reads = counting.counter_value(st.s2_reads.counts)
    writes = counting.counter_value(st.s2_writes.counts)
    assert reads[0].tolist() == [2, 1, 0, 0]
    assert writes[0].tolist() == [0, 2, 0, 0]
    # stage-1 weights writes by write_weight=2: 3 reads + 2 writes*2 = 7
    assert int(counting.counter_value(st.s1.counts)[2]) == 7


def test_rainbow_totals_accumulate():
    """Cumulative totals (documented int32) track per-interval reports."""
    m = simulate("streamcluster", "rainbow", intervals=3, accesses=5000, seed=2)
    from repro.engine import simloop

    mc = MachineConfig()
    chunks, meta = simloop.make_chunks("streamcluster", "rainbow", mc, 2, 3, 5000)
    spec = simloop.EngineSpec(
        policy="rainbow", mc=mc,
        num_superpages=meta["num_superpages"],
        footprint_pages=meta["footprint_pages"],
    )
    state, stats = simloop.engine_run(spec, simloop.engine_init(spec), chunks)
    assert int(state.pol.migrations_total) == int(stats.migrations.sum())
    assert int(state.pol.evictions_total) == int(stats.evictions.sum())
    assert state.pol.migrations_total.dtype == jnp.int32
