"""Queueing timing subsystem tests (repro.timing; docs/timing.md).

Four layers:

  * construction validation: QueueGeometry, core.migration.make_timing and
    the TIMING_PRESETS table reject malformed inputs loudly;
  * charge_queues against a naive per-server python FIFO reference, plus the
    queue-clock invariants (avail_cycle monotone non-decreasing, total
    charged cycles conserved under any server relabeling);
  * the traffic decomposition: timing.migration_cycles splits EXACTLY the
    mig_cycles that sim.policies.interval_costs charges, per policy;
  * the flat floor: timing_model="flat" is BITWISE identical to
    queueing-with-infinite-banks on the staged and fused engine paths, the
    engine matches the eager oracle under queueing, and a constrained
    geometry actually stalls.

The hypothesis layer mirrors tests/test_workloads.py: @given property tests
share the deterministic check functions below and skip cleanly when
hypothesis is not installed.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import migration
from repro.sim.config import MachineConfig
from repro.sim.policies import interval_costs
from repro.sim.runner import simulate, simulate_eager
from repro.timing import (
    MIGRATING_POLICIES,
    QueueGeometry,
    charge_queues,
    charged_service_cycles,
    interval_step,
    migration_cycles,
    queue_init,
)
from repro.workloads import scenarios as S

MC = MachineConfig()
ALL_POLICIES = ("flat-static", "dram-only") + MIGRATING_POLICIES
FLOOR_SCENARIOS = ("syn/streamcluster", "stress/zipf-hotspot", "stress/seq-scan")
INTERVALS = 2
ACCESSES = 800


# ---------------------------------------------------------------------------
# construction validation
# ---------------------------------------------------------------------------


def test_queue_geometry_validation():
    QueueGeometry().validate()
    assert QueueGeometry(3, 5, 2, 7).dram_servers == 15
    assert QueueGeometry(3, 5, 2, 7).nvm_servers == 14
    assert QueueGeometry.flat_floor().infinite
    for bad in (
        QueueGeometry(dram_channels=0),
        QueueGeometry(dram_banks=-1),
        QueueGeometry(nvm_channels=0),
        QueueGeometry(nvm_banks=0),
        QueueGeometry(dram_channels=2.5),  # non-int
        QueueGeometry(issue_gap=0.0),
        QueueGeometry(issue_gap=-8.0),
        QueueGeometry(issue_gap=float("nan")),
    ):
        with pytest.raises(ValueError):
            bad.validate()
        with pytest.raises(ValueError):  # queue_init validates too
            queue_init(bad)


def test_make_timing_validation():
    migration.make_timing(1.0, 2.0, 3.0, 4.0, 0.0, 0.0)  # zero bulk costs OK
    for kw in (
        {"t_nr": 0.0},
        {"t_nw": -1.0},
        {"t_dr": float("nan")},
        {"t_dw": float("inf")},
        {"t_nr": "fast"},
        {"t_mig": -1.0},
        {"t_writeback": -0.5},
    ):
        args = dict(t_nr=1.0, t_nw=1.0, t_dr=1.0, t_dw=1.0,
                    t_mig=0.0, t_writeback=0.0)
        args.update(kw)
        with pytest.raises(ValueError):
            migration.make_timing(**args)


def test_timing_preset_validation():
    for name in migration.TIMING_PRESETS:  # built-ins all well-formed
        migration.preset_timing(name)
    with pytest.raises(KeyError):  # unknown name stays a KeyError
        migration.preset_timing("a100")
    good = dict(migration.TIMING_PRESETS["paper-table4-sim"])
    with pytest.raises(ValueError):
        migration._validate_preset("p", [1, 2, 3])  # not a dict
    with pytest.raises(ValueError):
        migration._validate_preset("p", {k: v for k, v in good.items()
                                         if k != "t_nr"})  # missing key
    with pytest.raises(ValueError):
        migration._validate_preset("p", {**good, "t_xx": 1.0})  # extra key
    with pytest.raises(ValueError):
        migration._validate_preset("p", {**good, "t_dw": 0.0})  # bad value


def test_unknown_timing_model_rejected():
    with pytest.raises(ValueError):
        simulate("syn/streamcluster", "rainbow", intervals=1, accesses=256,
                 timing_model="bogus")
    with pytest.raises(ValueError):
        simulate_eager("streamcluster", "rainbow", intervals=1, accesses=256,
                       timing_model="bogus")


# ---------------------------------------------------------------------------
# charge_queues vs a naive FIFO reference + queue-clock invariants
# ---------------------------------------------------------------------------


def _naive_fifo(avail0, sid, arrivals, service, active):
    """Reference semantics, one lane at a time: each lane starts at
    max(arrival, avail[server]) and occupies its server for its service;
    stall counts only active lanes."""
    avail = np.array(avail0, np.float32)
    stall = 0.0
    for s, a, svc, act in zip(sid, arrivals, service, active):
        start = max(np.float32(a), avail[s])
        comp = np.float32(start + np.float32(svc))
        if act:
            stall += float(comp) - float(svc) - float(a)
        avail[s] = comp
    return avail, stall


def _random_case(rng, n_servers, lanes):
    avail0 = (rng.random(n_servers) * 200.0).astype(np.float32)
    sid = rng.integers(0, n_servers, lanes).astype(np.int32)
    arrivals = np.cumsum(rng.random(lanes) * 16.0).astype(np.float32)
    service = (rng.random(lanes) * 50.0).astype(np.float32)
    active = rng.random(lanes) < 0.8
    service = np.where(active, service, 0.0).astype(np.float32)
    return avail0, sid, arrivals, service, active


def check_charge_matches_fifo(avail0, sid, arrivals, service, active):
    avail_new, stall = charge_queues(
        jnp.asarray(avail0), jnp.asarray(sid), jnp.asarray(arrivals),
        jnp.asarray(service), jnp.asarray(active),
    )
    ref_avail, ref_stall = _naive_fifo(avail0, sid, arrivals, service, active)
    np.testing.assert_allclose(np.asarray(avail_new), ref_avail,
                               rtol=1e-5, atol=1e-2)
    assert np.isclose(float(stall), ref_stall, rtol=1e-5, atol=1e-2)
    # avail_cycle is monotone non-decreasing across charges
    assert np.all(np.asarray(avail_new) >= avail0)
    assert float(stall) >= 0.0


def check_permutation_conservation(avail0, sid, arrivals, service, active,
                                   rng):
    """Relabeling the servers permutes per-server charge vectors bitwise and
    leaves every total invariant."""
    n_servers = avail0.shape[0]
    perm = rng.permutation(n_servers).astype(np.int32)
    sid2 = perm[sid]
    avail2 = np.empty_like(avail0)
    avail2[perm] = avail0

    new1, stall1 = charge_queues(
        jnp.asarray(avail0), jnp.asarray(sid), jnp.asarray(arrivals),
        jnp.asarray(service), jnp.asarray(active))
    new2, stall2 = charge_queues(
        jnp.asarray(avail2), jnp.asarray(sid2), jnp.asarray(arrivals),
        jnp.asarray(service), jnp.asarray(active))
    # relabeling shifts segment offsets inside the associative-scan tree, so
    # completions may move by an ulp — totals and vectors match to fp noise
    np.testing.assert_allclose(np.asarray(new2)[perm], np.asarray(new1),
                               rtol=1e-6, atol=1e-2)
    assert np.isclose(float(stall1), float(stall2), rtol=1e-6, atol=1e-2)

    csc1 = np.asarray(charged_service_cycles(
        jnp.asarray(sid), jnp.asarray(service), n_servers))
    csc2 = np.asarray(charged_service_cycles(
        jnp.asarray(sid2), jnp.asarray(service), n_servers))
    np.testing.assert_array_equal(csc2[perm], csc1)  # vector permutes bitwise
    assert np.isclose(csc1.sum(), service.sum(dtype=np.float64), rtol=1e-5)


@pytest.mark.parametrize("seed,n_servers,lanes",
                         [(0, 1, 64), (1, 3, 96), (2, 8, 128), (3, 16, 48)])
def test_charge_queues_floor(seed, n_servers, lanes):
    rng = np.random.default_rng(seed)
    case = _random_case(rng, n_servers, lanes)
    check_charge_matches_fifo(*case)
    check_permutation_conservation(*case, rng)


def test_interval_step_monotone_and_aliasing():
    geom = QueueGeometry(2, 2, 1, 2)
    rng = np.random.default_rng(0)
    n = 256
    vpn = jnp.asarray(rng.integers(0, 4096, n).astype(np.int32))
    wr = jnp.asarray(rng.random(n) < 0.3)
    dram = jnp.asarray(rng.random(n) < 0.5)

    q0 = queue_init(geom)
    q1, tm1 = interval_step(geom, MC, "rainbow", q0, vpn, wr, dram,
                            jnp.int32(0), jnp.int32(3), jnp.int32(1),
                            jnp.int32(1))
    q2, tm2 = interval_step(geom, MC, "rainbow", q1, vpn, wr, dram,
                            jnp.int32(n), jnp.int32(0), jnp.int32(0),
                            jnp.int32(0))
    for prev, nxt in ((q0, q1), (q1, q2)):
        for a, b in zip(prev, nxt):  # all four chains monotone
            assert np.all(np.asarray(b) >= np.asarray(a))
    for tm in (tm1, tm2):
        assert all(float(x) >= 0.0 for x in tm)

    # non-migrating policies alias the counterfactual chain -> mig_stall 0.0
    q3, tm3 = interval_step(geom, MC, "flat-static", q0, vpn, wr, dram,
                            jnp.int32(0), jnp.int32(0), jnp.int32(0),
                            jnp.int32(0))
    assert q3.dram_nomig is q3.dram_avail and q3.nvm_nomig is q3.nvm_avail
    assert float(tm3.mig_stall) == 0.0

    # the infinite floor is an exact-zero no-op
    gi = QueueGeometry.flat_floor()
    qi = queue_init(gi)
    qi2, tmi = interval_step(gi, MC, "rainbow", qi, vpn, wr, dram,
                             jnp.int32(0), jnp.int32(9), jnp.int32(2),
                             jnp.int32(2))
    assert qi2 is qi
    assert all(float(x) == 0.0 for x in tmi)


# ---------------------------------------------------------------------------
# traffic decomposition
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_traffic_decomposition(policy):
    """Per-tier migration traffic sums EXACTLY to the flat cost model's
    mig_cycles — the queues charge the same cycles the counters price."""
    for m, e, d in ((0, 0, 0), (3, 1, 1), (17, 5, 4), (0, 2, 2)):
        dram, nvm = migration_cycles(
            policy, MC, jnp.int32(m), jnp.int32(e), jnp.int32(d))
        ref = interval_costs(policy, MC, m, e, d, 0)["mig_cycles"]
        assert np.isclose(float(dram) + float(nvm), ref, rtol=1e-5), (
            policy, m, e, d)
    with pytest.raises(KeyError):
        migration_cycles("bogus", MC, jnp.int32(1), jnp.int32(0), jnp.int32(0))


# ---------------------------------------------------------------------------
# the flat floor + engine/eager/constrained differentials
# ---------------------------------------------------------------------------


def check_flat_floor(app, policy, fused):
    kw = dict(intervals=INTERVALS, accesses=ACCESSES, fused=fused)
    flat = simulate(app, policy, **kw)
    inf = simulate(app, policy, timing_model="queueing",
                   queue_geometry=QueueGeometry.flat_floor(), **kw)
    assert dataclasses.asdict(flat) == dataclasses.asdict(inf), (
        f"{app} x {policy} (fused={fused}): flat != infinite-banks bitwise")
    assert flat.bank_stall_cycles == 0.0 and flat.mig_stall_cycles == 0.0
    assert flat.queue_occupancy_dram == 0.0 and flat.queue_occupancy_nvm == 0.0


@pytest.mark.parametrize("policy", ALL_POLICIES)
@pytest.mark.parametrize("app", FLOOR_SCENARIOS)
def test_flat_floor_staged(app, policy):
    check_flat_floor(app, policy, fused=False)


@pytest.mark.parametrize("policy", ALL_POLICIES)
@pytest.mark.parametrize("app", FLOOR_SCENARIOS)
def test_flat_floor_fused(app, policy):
    check_flat_floor(app, policy, fused=True)


@pytest.mark.parametrize("policy", ("rainbow", "flat-static"))
def test_engine_matches_eager_queueing(policy):
    kw = dict(intervals=INTERVALS, accesses=ACCESSES,
              timing_model="queueing", queue_geometry=QueueGeometry(2, 4, 1, 4))
    eng = simulate("streamcluster", policy, **kw)
    eag = simulate_eager("streamcluster", policy, **kw)
    assert dataclasses.asdict(eng) == dataclasses.asdict(eag)


def test_constrained_geometry_stalls():
    tight = QueueGeometry(1, 2, 1, 2)
    for policy in ("rainbow", "flat-static"):
        flat = simulate("syn/streamcluster", policy,
                        intervals=INTERVALS, accesses=2000)
        q = simulate("syn/streamcluster", policy,
                     intervals=INTERVALS, accesses=2000,
                     timing_model="queueing", queue_geometry=tight)
        assert q.bank_stall_cycles > 0.0, policy
        assert q.total_cycles > flat.total_cycles, policy
        assert q.queue_occupancy_dram >= 0.0 and q.queue_occupancy_nvm >= 0.0
        if policy == "flat-static":
            assert q.mig_stall_cycles == 0.0  # no migration traffic at all


# ---------------------------------------------------------------------------
# hypothesis property layer (shares the check functions above)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # environment without hypothesis: keep the floors only
    st = None

if st is not None:

    @given(seed=st.integers(0, 2**31 - 1), n_servers=st.integers(1, 32),
           lanes=st.integers(1, 128))
    @settings(max_examples=25, deadline=None)
    def test_charge_queues_properties(seed, n_servers, lanes):
        rng = np.random.default_rng(seed)
        case = _random_case(rng, n_servers, lanes)
        check_charge_matches_fifo(*case)
        check_permutation_conservation(*case, rng)

    @given(app=st.sampled_from(S.available_scenarios()),
           policy=st.sampled_from(ALL_POLICIES))
    @settings(max_examples=10, deadline=None)
    def test_flat_floor_registry(app, policy):
        check_flat_floor(app, policy, fused=False)

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_charge_queues_properties():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_flat_floor_registry():
        pass
