"""repro.workloads: device-resident trace generators + scenario registry.

The load-bearing guarantee is the DIFFERENTIAL GATE: for every registered
scenario preset, chunks generated in-scan (EngineSpec.source, fused mode)
produce bit-identical SimMetrics to the same generator stream materialized
to host and fed through the staged path — single cell, vmap-over-seeds, and
the 4-device sharded fleet. A scenario that drifted between its two modes
would corrupt every sweep that mixes them.

Generator invariants (shapes, vpn ranges, determinism under jit/vmap,
write-fraction bounds) run as deterministic floors everywhere and as a
hypothesis property layer where hypothesis is installed (the same
optional-dependency convention as tests/test_core_* / test_fleet.py).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.engine.simloop as simloop
from repro.engine import fleet
from repro.sim import trace as trace_mod
from repro.sim.config import MachineConfig, PAGES_PER_SP
from repro.sim.runner import simulate
from repro.workloads import generators as G
from repro.workloads import scenarios as S

INTERVALS = 2
ACCESSES = 1200


# ---------------------------------------------------------------------------
# Generator invariants: plain-function checks (deterministic floors +
# hypothesis property layer share them)
# ---------------------------------------------------------------------------


def _emit(gen, seed: int, interval: int):
    aux = gen.setup(jnp.int32(seed))
    key = G.interval_key(jnp.int32(seed), jnp.int32(interval))
    pages, wr = gen.emit(aux, key, jnp.int32(interval))
    return np.asarray(pages), np.asarray(wr)


def check_generator_invariants(gen, seed: int = 3, interval: int = 1):
    """Shapes, ranges, dtype, and 5-sigma write-fraction bounds of one emit."""
    gen.validate()
    pages, wr = _emit(gen, seed, interval)
    a = gen.accesses
    assert pages.shape == (a,) and wr.shape == (a,)
    assert pages.dtype == np.int32 and wr.dtype == np.bool_
    assert pages.min() >= 0 and pages.max() < gen.footprint_pages
    ratio = getattr(gen, "write_ratio", None)
    if ratio is None:  # mix: bound by the members' extreme ratios
        ratios = [m.write_ratio for m in gen.members]
        lo, hi = min(ratios), max(ratios)
    else:
        lo = hi = ratio
    sigma = 5.0 * np.sqrt(0.25 / a)  # max Bernoulli var at p=1/2
    assert lo - sigma <= wr.mean() <= hi + sigma, (wr.mean(), lo, hi)


def check_generator_determinism(gen, seed: int = 5, interval: int = 2):
    """Same seed => identical chunks; emit is invariant under jit and vmap."""
    p1, w1 = _emit(gen, seed, interval)
    p2, w2 = _emit(gen, seed, interval)
    np.testing.assert_array_equal(p1, p2)
    np.testing.assert_array_equal(w1, w2)

    def emit(sd, iv):
        aux = gen.setup(sd)
        return gen.emit(aux, G.interval_key(sd, iv), iv)

    pj, wj = jax.jit(emit)(jnp.int32(seed), jnp.int32(interval))
    np.testing.assert_array_equal(np.asarray(pj), p1)
    np.testing.assert_array_equal(np.asarray(wj), w1)

    seeds = jnp.asarray([seed, seed + 9], jnp.int32)
    ivs = jnp.full_like(seeds, interval)
    pv, wv = jax.jit(jax.vmap(emit))(seeds, ivs)
    np.testing.assert_array_equal(np.asarray(pv)[0], p1)
    np.testing.assert_array_equal(np.asarray(wv)[0], w1)


SMALL_GENERATORS = [
    G.ZipfHotspot(footprint_pages=2048, accesses=1500, hot_frac=0.03,
                  zipf_alpha=1.2, hot_traffic=0.8, write_ratio=0.3),
    G.PhaseShift(footprint_pages=2048, accesses=1500, ws_frac=0.25,
                 drift_frac=0.5, hot_frac=0.2, write_ratio=0.25),
    G.SequentialScan(footprint_pages=1024, accesses=1500, stride=3,
                     write_ratio=0.1),
    G.PointerChase(footprint_pages=4096, accesses=1500, write_ratio=0.2),
    G.InterleavedMix(members=(
        G.ZipfHotspot(footprint_pages=700, accesses=500, write_ratio=0.4),
        G.SequentialScan(footprint_pages=1024, accesses=500, write_ratio=0.0),
        G.PointerChase(footprint_pages=600, accesses=500, write_ratio=0.2),
    )),
]


@pytest.mark.parametrize("gen", SMALL_GENERATORS,
                         ids=lambda g: type(g).__name__)
def test_generator_invariants_floor(gen):
    check_generator_invariants(gen)
    check_generator_determinism(gen)


def test_different_seeds_and_intervals_differ():
    gen = SMALL_GENERATORS[0]
    p1, _ = _emit(gen, seed=1, interval=0)
    p2, _ = _emit(gen, seed=2, interval=0)
    p3, _ = _emit(gen, seed=1, interval=1)
    assert not np.array_equal(p1, p2)  # fresh key stream per seed
    assert not np.array_equal(p1, p3)  # fold_in moves the stream per interval


def test_seq_scan_resumes_across_intervals():
    gen = G.SequentialScan(footprint_pages=10_000, accesses=64, stride=2)
    p0, _ = _emit(gen, seed=0, interval=0)
    p1, _ = _emit(gen, seed=0, interval=1)
    assert p0[0] == 0 and p1[0] == (64 * 2) % 10_000  # picks up where 0 left
    np.testing.assert_array_equal(np.diff(p0) % 10_000, 2)


def test_pointer_chase_matches_stepped_lcg():
    """The closed-form uint32 chain == literally stepping the LCG on host."""
    gen = G.PointerChase(footprint_pages=3000, accesses=200)
    pages, _ = _emit(gen, seed=4, interval=0)
    key = G.interval_key(jnp.int32(4), jnp.int32(0))
    x = int(np.asarray(
        jax.random.bits(jax.random.fold_in(key, 19), (), jnp.uint32)
    ))
    ref = []
    for _ in range(200):
        ref.append((x >> 7) % 3000)
        x = (1664525 * x + 1013904223) % (1 << 32)
    np.testing.assert_array_equal(pages, np.asarray(ref, np.int32))


def test_mix_members_stay_in_their_superpage_lanes():
    gen = SMALL_GENERATORS[4]
    bases = gen._bases
    spans = [(-(-m.footprint_pages // PAGES_PER_SP)) * PAGES_PER_SP
             for m in gen.members]
    pages, _ = _emit(gen, seed=7, interval=0)
    for base, span, m in zip(bases, spans, gen.members):
        in_lane = (pages >= base) & (pages < base + span)
        assert in_lane.sum() >= m.accesses  # every member emitted its share
    assert gen.footprint_pages == bases[-1] + spans[-1]


# ---------------------------------------------------------------------------
# Hypothesis property layer (optional, as in tests/test_core_*)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # pragma: no cover - exercised via the floors above
    st = None

if st is not None:

    def _gens():
        zipf = st.builds(
            G.ZipfHotspot,
            footprint_pages=st.integers(64, 4096),
            accesses=st.integers(32, 1024),
            hot_frac=st.floats(0.01, 1.0),
            zipf_alpha=st.floats(0.3, 2.0),
            hot_traffic=st.floats(0.0, 1.0),
            write_ratio=st.floats(0.0, 1.0),
        )
        phase = st.builds(
            G.PhaseShift,
            footprint_pages=st.integers(64, 4096),
            accesses=st.integers(32, 1024),
            ws_frac=st.floats(0.05, 1.0),
            drift_frac=st.floats(0.0, 1.0),
            hot_frac=st.floats(0.01, 1.0),
            zipf_alpha=st.floats(0.3, 2.0),
            hot_traffic=st.floats(0.0, 1.0),
            write_ratio=st.floats(0.0, 1.0),
        )
        seq = st.builds(
            G.SequentialScan,
            footprint_pages=st.integers(64, 4096),
            accesses=st.integers(32, 1024),
            stride=st.integers(1, 9),
            write_ratio=st.floats(0.0, 1.0),
        )
        chase = st.builds(
            G.PointerChase,
            footprint_pages=st.integers(64, 4096),
            accesses=st.integers(32, 1024),
            write_ratio=st.floats(0.0, 1.0),
        )
        leaf = st.one_of(zipf, phase, seq, chase)
        mix = st.builds(
            lambda ms: G.InterleavedMix(members=tuple(ms)),
            st.lists(leaf, min_size=1, max_size=3),
        )
        return st.one_of(leaf, mix)

    @settings(max_examples=20, deadline=None)
    @given(_gens(), st.integers(0, 2**31 - 1), st.integers(0, 50))
    def test_generator_properties(gen, seed, interval):
        check_generator_invariants(gen, seed, interval)

    @settings(max_examples=10, deadline=None)
    @given(_gens(), st.integers(0, 2**31 - 1), st.integers(0, 50))
    def test_generator_determinism_property(gen, seed, interval):
        check_generator_determinism(gen, seed, interval)

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_generator_properties():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_generator_determinism_property():
        pass


# ---------------------------------------------------------------------------
# Registry + probe_meta dispatch
# ---------------------------------------------------------------------------


def test_registry_covers_app_profiles_and_stressors():
    names = S.available_scenarios()
    from repro.sim.config import APPS

    assert {f"syn/{a}" for a in APPS} <= set(names)  # all 14 paper profiles
    assert {"stress/zipf-hotspot", "stress/phase-shift", "stress/seq-scan",
            "stress/pointer-chase", "stress/mix"} <= set(names)


def test_registry_rejects_duplicates_and_shadows():
    sc = S.get_scenario("stress/seq-scan")
    with pytest.raises(ValueError, match="already registered"):
        S.register_scenario(sc)
    with pytest.raises(ValueError, match="shadows"):
        S.register_scenario(dataclasses.replace(sc, name="streamcluster"))
    with pytest.raises(KeyError, match="unknown scenario"):
        S.get_scenario("nope/missing")


def test_probe_meta_dispatches_and_matches_materialized_shapes():
    """trace.probe_meta must report EXACTLY what the generator emits — the
    compile-signature contract fleet grouping rests on (satellite fix)."""
    for name in ("stress/mix", "syn/soplex"):
        for accesses in (None, 640):
            meta = trace_mod.probe_meta(name, accesses)
            tr = trace_mod.generate(name, seed=1, interval=0, accesses=accesses)
            assert meta["footprint_pages"] == tr.footprint_pages
            assert meta["num_superpages"] == tr.num_superpages
            assert meta["accesses_per_interval"] == tr.sp.shape[0]
            assert meta["inst_per_access"] == tr.inst_per_access
            assert tr.vpn.max() < meta["footprint_pages"]
    with pytest.raises(KeyError):
        trace_mod.probe_meta("not-a-workload")


def test_fused_spec_shape_mismatch_fails_loudly():
    spec = simloop.EngineSpec(
        policy="flat-static", mc=MachineConfig(), num_superpages=1,
        footprint_pages=999,  # wrong on purpose
        source=simloop.TraceSource("stress/seq-scan", 500),
    )
    with pytest.raises(ValueError, match="shape mismatch"):
        simloop.engine_run_fused(spec, simloop.engine_init(spec), 0, 1)
    staged = dataclasses.replace(spec, source=None)
    with pytest.raises(ValueError, match="staged compile"):
        simloop.batch_run_fused(staged, 1)


# ---------------------------------------------------------------------------
# The differential gate: fused in-scan generation == staged materialization
# ---------------------------------------------------------------------------


def _metrics_tuple(m):
    return (m.ipc, m.total_cycles, m.mpki, m.migrations, m.evictions,
            m.shootdowns, m.mig_bytes, tuple(sorted(m.breakdown.items())))


@pytest.mark.parametrize("name", S.available_scenarios())
def test_every_preset_fused_matches_staged(name):
    """EVERY registered preset: staged oracle == fused path, bitwise."""
    staged = simulate(name, "flat-static", intervals=INTERVALS,
                      accesses=ACCESSES, seed=3)
    fused = simulate(name, "flat-static", intervals=INTERVALS,
                     accesses=ACCESSES, seed=3, fused=True)
    assert _metrics_tuple(staged) == _metrics_tuple(fused)


@pytest.mark.parametrize("policy", ["rainbow", "hscc-4kb-mig", "hscc-2mb-mig",
                                    "flat-static", "dram-only"])
def test_all_policies_fused_match_staged(policy):
    """One scenario across ALL five policy programs (stateful included)."""
    staged = simulate("stress/phase-shift", policy, intervals=INTERVALS,
                      accesses=ACCESSES, seed=9)
    fused = simulate("stress/phase-shift", policy, intervals=INTERVALS,
                     accesses=ACCESSES, seed=9, fused=True)
    assert _metrics_tuple(staged) == _metrics_tuple(fused)


def test_fused_vmap_over_seeds_matches_per_seed():
    """engine_run_fused_batch == stacked per-seed engine_run_fused, bitwise."""
    name, seeds = "stress/zipf-hotspot", [0, 1, 2]
    meta = trace_mod.probe_meta(name, ACCESSES)
    spec = simloop.EngineSpec(
        policy="rainbow", mc=MachineConfig(),
        num_superpages=meta["num_superpages"],
        footprint_pages=meta["footprint_pages"],
        source=simloop.TraceSource(name, ACCESSES),
    )
    state0 = simloop.engine_init(spec)
    states = jax.tree.map(lambda x: jnp.stack([x] * len(seeds)), state0)
    finals_b, stats_b = simloop.engine_run_fused_batch(
        spec, states, jnp.asarray(seeds, jnp.int32), INTERVALS
    )
    for i, seed in enumerate(seeds):
        finals_1, stats_1 = simloop.engine_run_fused(
            spec, state0, seed, INTERVALS
        )
        for b, one in zip(stats_b, stats_1):
            np.testing.assert_array_equal(np.asarray(b)[i], np.asarray(one))
        for b, one in zip(finals_b.sim.counters, finals_1.sim.counters):
            np.testing.assert_array_equal(np.asarray(b)[i], np.asarray(one))


# ---------------------------------------------------------------------------
# Fleet integration: grouping, staging, and the 4-device sharded fleet
# ---------------------------------------------------------------------------


def test_grid_rejects_lopsided_axes():
    """Workloads without policies/seeds (or vice versa) would silently build
    an EMPTY plan; grid must reject the combination loudly instead."""
    with pytest.raises(ValueError, match="ZERO cells"):
        fleet.SweepPlan.grid(scenario=["stress/mix"], seeds=(0, 1))
    with pytest.raises(ValueError, match="ZERO cells"):
        fleet.SweepPlan.grid(policies=["rainbow"])
    with pytest.raises(ValueError, match="ZERO cells"):
        fleet.SweepPlan.grid(apps=["soplex"], policies=["rainbow"], seeds=())
    assert len(fleet.SweepPlan.grid()) == 0  # explicitly empty stays legal


def test_app_presets_keep_exact_hot_page_counts():
    """syn/<app> hot-set sizes must round-trip the Table-I integer count
    through ZipfHotspot.hot_frac without losing a page to f64 truncation."""
    from repro.sim.config import APPS
    from repro.sim.trace import _mb_to_pages

    for app, prof in APPS.items():
        gen = S.get_scenario(f"syn/{app}").gen
        fp = _mb_to_pages(prof.footprint_mb)
        ws = min(_mb_to_pages(prof.working_set_mb), fp)
        want = max(1, int(ws * prof.hot_page_pct / 100.0))
        assert gen._n_hot == want, (app, gen._n_hot, want)


def test_bucket_sampler_respects_quotas():
    """sp_hot_buckets (Table II): every superpage's hot-page count stays
    within its sampled bucket's [lo, hi] cap, the hot set is unique and
    in-range, and the same seed reproduces the same set bitwise."""
    gen = G.ZipfHotspot(
        footprint_pages=16 * PAGES_PER_SP, accesses=1000, hot_frac=0.01,
        sp_hot_buckets=((1.0, 2, 6), (1.0, 8, 12)),
    )
    gen.validate()
    hot = np.asarray(gen.setup(jnp.int32(5)))
    assert hot.shape == (gen._n_hot,)
    assert len(np.unique(hot)) == hot.shape[0]
    assert hot.min() >= 0 and hot.max() < gen.footprint_pages
    per_sp = np.bincount(hot // PAGES_PER_SP, minlength=16)
    # quotas cap per-superpage counts at the widest bucket's hi
    assert per_sp.max() <= 12, per_sp
    assert np.array_equal(hot, np.asarray(gen.setup(jnp.int32(5))))
    assert not np.array_equal(hot, np.asarray(gen.setup(jnp.int32(6))))


def test_bucket_validation_rejects_malformed_entries():
    base = dict(footprint_pages=PAGES_PER_SP, accesses=100)
    for bad in (
        ((1.0, 2),),  # not a 3-tuple
        ((-1.0, 1, 4),),  # negative weight
        ((1.0, 0, 4),),  # lo < 1
        ((1.0, 5, 4),),  # lo > hi
        ((1.0, 1, PAGES_PER_SP + 1),),  # hi past the superpage
        ((0.0, 1, 4),),  # all weights zero
    ):
        with pytest.raises(ValueError):
            G.ZipfHotspot(sp_hot_buckets=bad, **base).validate()


def test_plan_groups_fused_cells():
    """Fused cells group per scenario program (spec.source in the signature);
    fused and staged modes of one scenario never share a compile."""
    plan = fleet.SweepPlan.grid(
        apps=["stress/seq-scan"], policies=["rainbow"], seeds=(0, 1),
        scenario=["stress/seq-scan", "stress/pointer-chase"],
        intervals=2, accesses=900,
    )
    groups = fleet.plan_groups(plan)
    assert len(groups) == 3  # staged seq, fused seq, fused chase
    by_source = {g.spec.source: g for g in groups}
    assert None in by_source  # the staged oracle cells
    fused_seq = by_source[simloop.TraceSource("stress/seq-scan", 900)]
    assert len(fused_seq.cells) == 2  # seeds fuse on one fleet axis
    assert fused_seq.meta == by_source[None].meta  # same compile metadata
    for g in groups:
        assert all(c.fused == (g.spec.source is not None) for c in g.cells)


def test_fleet_fused_matches_staged_and_single():
    plan = fleet.SweepPlan.grid(
        apps=["stress/zipf-hotspot"], policies=["rainbow"], seeds=(0, 1),
        scenario=["stress/zipf-hotspot"], intervals=2, accesses=1500,
    )
    res = fleet.FleetRunner().run(plan)
    assert len(res) == 4
    for seed in (0, 1):
        staged = res.one(seed=seed, fused=False)
        fused = res.one(seed=seed, fused=True)
        single = simulate("stress/zipf-hotspot", "rainbow", intervals=2,
                          accesses=1500, seed=seed)
        assert _metrics_tuple(staged) == _metrics_tuple(fused) \
            == _metrics_tuple(single)


def test_sharded_fused_fleet_bit_identical_on_4_devices():
    """4 forced host devices: the fused shard_map fleet == staged fleet ==
    single-device engine, including the padding path (3 cells on 4 devs)."""
    script = textwrap.dedent("""
        import jax
        import numpy as np
        from repro.engine import fleet
        from repro.sim.runner import simulate, sweep

        assert len(jax.devices()) == 4
        plan = fleet.SweepPlan.grid(
            apps=["stress/mix"], policies=["rainbow"], seeds=(0, 1, 2),
            scenario=["stress/mix"], intervals=2, accesses=1800,
        )  # 3 cells per group: NOT divisible by 4 devices
        runner = fleet.FleetRunner()
        fused_groups = [g for g in fleet.plan_groups(plan)
                        if g.spec.source is not None]
        (fg,) = fused_groups
        states, seeds = runner._stage(fg)
        assert seeds.shape == (4,) and seeds.dtype == np.int32  # padded 3->4
        assert len(seeds.sharding.device_set) == 4, seeds.sharding

        res = runner.run(plan)
        for seed in (0, 1, 2):
            staged = res.one(seed=seed, fused=False)
            fused = res.one(seed=seed, fused=True)
            one = simulate("stress/mix", "rainbow", intervals=2,
                           accesses=1800, seed=seed)
            assert staged.ipc == fused.ipc == one.ipc
            assert staged.total_cycles == fused.total_cycles == one.total_cycles
            assert staged.migrations == fused.migrations == one.migrations
            assert staged.mig_bytes == fused.mig_bytes == one.mig_bytes
        out = sweep([], ["rainbow"], [1], intervals=2, accesses=1800,
                    scenarios=["stress/mix"])
        assert out[("stress/mix", "rainbow", 1)].ipc == res.one(
            seed=1, fused=True).ipc
        print("WORKLOADS_SHARDED_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert "WORKLOADS_SHARDED_OK" in out.stdout, out.stderr[-2000:]


def test_calibration_mode_works_on_scenarios():
    """Scenario cells flow through the host-only calibration path too."""
    plan = fleet.SweepPlan.grid(
        apps=["stress/zipf-hotspot"], policies=["rainbow"], seeds=(1,),
        intervals=1, accesses=2000,
    )
    stats = fleet.FleetRunner().calibration(plan)[plan.cells[0]]
    assert stats["working_set_pages"] > 0
    assert 0 < stats["hot_page_pct_measured"] <= 100
