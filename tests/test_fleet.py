"""FleetRunner (engine.fleet): sweep plans, grouping, sharding, staging.

The load-bearing guarantee mirrors test_engine.py's: the mesh-sharded fleet
path produces BIT-IDENTICAL results to the single-device engine — per cell,
per field — including the padded (fleet % devices != 0) path, so scaling a
parameter study across devices can never change a paper figure.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import repro.engine.simloop as simloop
from repro.engine import fleet
from repro.sim.config import MachineConfig
from repro.sim.runner import simulate, sweep


def test_plan_groups_by_compile_signature():
    """Same-shape cells fuse; apps/configs/backends split; duplicates collapse."""
    mc2 = MachineConfig(top_n=50)
    plan = fleet.SweepPlan.grid(
        ["streamcluster"], ["rainbow"], (1, 2), intervals=2, accesses=2000
    ) + fleet.SweepPlan.grid(
        ["soplex"], ["rainbow"], (1,), intervals=2, accesses=2000
    ) + fleet.SweepPlan.grid(
        ["streamcluster"], ["rainbow"], (1,), mc=mc2, intervals=2, accesses=2000
    ) + fleet.SweepPlan.grid(  # exact duplicate of the first grid's seed 1
        ["streamcluster"], ["rainbow"], (1,), intervals=2, accesses=2000
    )
    groups = fleet.plan_groups(plan)
    assert [len(g.cells) for g in groups] == [2, 1, 1]
    assert groups[0].spec.policy == "rainbow"
    assert groups[0].meta["accesses_per_interval"] == 2000
    assert groups[1].spec.footprint_pages != groups[0].spec.footprint_pages
    assert groups[2].spec.mc.top_n == 50


def test_fleet_matches_simulate_bit_identical():
    """FleetRunner cell == unbatched simulate(), field for field."""
    plan = fleet.SweepPlan.grid(
        ["streamcluster", "soplex"], ["rainbow", "flat-static"], (3,),
        intervals=2, accesses=2500,
    )
    res = fleet.FleetRunner().run(plan)
    assert len(res) == 4
    for cell in res:
        single = simulate(cell.app, cell.policy, intervals=2, accesses=2500,
                          seed=3)
        got = res[cell]
        assert got.migrations == single.migrations, cell.label
        assert got.ipc == single.ipc, cell.label
        assert got.mpki == single.mpki, cell.label
        assert got.total_cycles == single.total_cycles, cell.label
        assert got.mig_bytes == single.mig_bytes, cell.label


def test_runner_sweep_is_fleet_backed():
    """sim.runner.sweep routes through FleetRunner and keys by (app,policy,seed)."""
    out = sweep(["streamcluster"], ["rainbow"], [1, 4], intervals=2,
                accesses=2000)
    single = simulate("streamcluster", "rainbow", intervals=2, accesses=2000,
                      seed=4)
    assert out[("streamcluster", "rainbow", 4)].ipc == single.ipc
    assert out[("streamcluster", "rainbow", 4)].migrations == single.migrations


def test_result_selection_and_tags():
    plan = fleet.SweepPlan.grid(
        ["streamcluster"], ["rainbow"], (1, 2), intervals=2, accesses=2000,
        tags=(("sweep", "demo"),),
    )
    res = fleet.FleetRunner().run(plan)
    assert res[("streamcluster", "rainbow", 2)].ipc > 0
    with pytest.raises(KeyError, match="matched 2 cells"):  # seed ambiguous
        res[("streamcluster", "rainbow")]
    assert len(res.select(sweep="demo")) == 2
    assert res.select(sweep="other") == []
    rows = res.rows(seed=1)
    assert len(rows) == 1 and rows[0]["sweep"] == "demo" and rows[0]["seed"] == 1
    assert res.apps() == ["streamcluster"] and res.policies() == ["rainbow"]


def test_sweep_seeds_meta_mismatch_raises(monkeypatch):
    """Satellite fix: the fleet must not silently trust meta[0] per seed."""
    real = simloop.trace_mod.generate

    def skewed(app, seed, interval, accesses=None):
        t = real(app, seed, interval, accesses)
        if seed == 2:
            t = dataclasses.replace(t, footprint_pages=t.footprint_pages + 7)
        return t

    monkeypatch.setattr(simloop.trace_mod, "generate", skewed)
    with pytest.raises(ValueError, match="disagree on trace meta"):
        simloop.sweep_seeds("streamcluster", "rainbow", MachineConfig(),
                            [1, 2], intervals=1, accesses=1000)


def test_require_uniform_meta_names_offender():
    base = {"num_superpages": 4, "footprint_pages": 2048,
            "accesses_per_interval": 1000, "inst_per_access": 9.0}
    bad = dict(base, footprint_pages=4096)
    with pytest.raises(ValueError, match=r"seed=9.*4096"):
        simloop.require_uniform_meta([base, bad], ["seed=7", "seed=9"])


def test_journal_append_after_torn_tail_recovers(tmp_path):
    """A kill mid-write leaves a partial line; the NEXT append must truncate
    it instead of gluing onto it, so no later load() discards valid groups."""
    plan = fleet.SweepPlan.grid(["streamcluster"], ["rainbow"], (0, 1),
                                intervals=1, accesses=1000)
    cell_a, cell_b = plan.cells
    m_a, m_b = _dummy_metrics(cell_a), _dummy_metrics(cell_b)
    path = tmp_path / "j.jsonl"
    journal = fleet.FleetJournal(path, flush_groups=1)  # fsync-per-group
    journal.append({cell_a: m_a})
    with path.open("ab") as f:
        f.write(b'{"cells": {"torn')  # the kill: no trailing newline
    journal.append({cell_b: m_b})
    loaded = journal.load()
    assert loaded == {cell_a.key(): m_a, cell_b.key(): m_b}
    # a journal whose ONLY line is torn re-writes the header too
    path2 = tmp_path / "j2.jsonl"
    path2.write_bytes(b'{"kind": "fleet-jour')
    fleet.FleetJournal(path2, flush_groups=1).append({cell_a: m_a})
    assert fleet.FleetJournal(path2).load() == {cell_a.key(): m_a}
    assert json.loads(path2.read_text().splitlines()[0])["kind"] == "fleet-journal"


def test_calibration_mode_matches_direct_stats():
    from repro.sim import trace as trace_mod

    plan = fleet.SweepPlan.grid(["streamcluster"], ["rainbow"])
    got = fleet.FleetRunner().calibration(plan)[plan.cells[0]]
    want = fleet.trace_calibration_stats(
        trace_mod.generate("streamcluster", 7, interval=1)
    )
    assert got == want
    assert 0 < got["hot_page_pct_measured"] <= 100


# ---------------------------------------------------------------------------
# Property tests of the plan/grouping/selection layer (pure host-side: no
# device work — plan_groups probes trace meta without generating an access).
# The invariants are plain functions so deterministic edge cases run even
# where hypothesis is absent (the optional-dependency convention of
# tests/test_core_*), and hypothesis feeds generated plans where it exists.
# ---------------------------------------------------------------------------

PROP_APPS = ["streamcluster", "soplex", "mcf", "mix1"]
PROP_POLICIES = ["rainbow", "flat-static", "hscc-2mb-mig", "dram-only"]


def check_plan_groups_roundtrip(plan: fleet.SweepPlan):
    """plan_groups loses no cell, duplicates none, and groups homogeneously."""
    groups = fleet.plan_groups(plan)
    grouped = [c for g in groups for c in g.cells]
    assert len(grouped) == len(set(grouped)), "cell duplicated across groups"
    assert set(grouped) == set(plan.cells), "cell lost (or invented)"
    for g in groups:
        metas = [
            fleet.trace_mod.probe_meta(c.app, c.accesses) for c in g.cells
        ]
        assert all(m == g.meta for m in metas), "mixed shapes in one group"
        assert all(
            (c.policy, c.counter_backend, c.mc, c.control, c.intervals)
            == (g.spec.policy, g.spec.counter_backend, g.spec.mc,
                g.spec.control, g.intervals)
            for c in g.cells
        ), "mixed compile signatures in one group"


def check_selection_consistency(plan: fleet.SweepPlan, filters: dict):
    """FleetResult.select/one/rows agree with a hand-rolled plan filter."""
    cells = tuple(dict.fromkeys(plan.cells))
    res = fleet.FleetResult(
        cells=cells, metrics={c: _dummy_metrics(c) for c in cells}
    )
    fields = {f.name for f in dataclasses.fields(fleet.SweepCell)}
    want = [
        c for c in cells
        if all(
            (getattr(c, k) if k in fields else c.tag.get(k)) == v
            for k, v in filters.items()
        )
    ]
    got = res.select(**filters)
    assert [c for c, _ in got] == want
    assert all(m is res.metrics[c] for c, m in got)
    if len(want) == 1:
        assert res.one(**filters) is res.metrics[want[0]]
    else:
        with pytest.raises(KeyError, match=f"matched {len(want)} cells"):
            res.one(**filters)
    rows = res.rows(**filters)
    assert len(rows) == len(want)
    for c, row in zip(want, rows):
        assert row["seed"] == c.seed
        for k, v in c.tags:
            assert row[k] == v


def _dummy_metrics(cell: fleet.SweepCell):
    from repro.sim.runner import SimMetrics

    return SimMetrics(
        app=cell.app, policy=cell.policy, instructions=1.0, total_cycles=1.0,
        ipc=1.0, mpki=0.0, tlb_service_cycles=0.0, tlb_service_frac=0.0,
        breakdown={}, migrations=0, evictions=0, shootdowns=0, mig_bytes=0.0,
        footprint_bytes=1.0, traffic_ratio=0.0, energy={},
    )


def test_plan_groups_roundtrip_edge_cases():
    """Deterministic floor under the property: empty, size-1, dup, mixed."""
    check_plan_groups_roundtrip(fleet.SweepPlan(cells=()))
    one = fleet.SweepPlan.grid(["soplex"], ["rainbow"], (1,), intervals=1,
                               accesses=1000)
    check_plan_groups_roundtrip(one)
    mixed = (
        one + one  # exact duplicates must collapse, not double-run
        + fleet.SweepPlan.grid(PROP_APPS, PROP_POLICIES, (1, 2), intervals=1,
                               accesses=1000)
        + fleet.SweepPlan.grid(["soplex"], ["rainbow"], (1,),
                               mc=MachineConfig(top_n=50), intervals=1,
                               accesses=1000)
    )
    check_plan_groups_roundtrip(mixed)
    assert len(fleet.plan_groups(fleet.SweepPlan(cells=()))) == 0


def test_selection_consistency_edge_cases():
    check_selection_consistency(fleet.SweepPlan(cells=()), {})
    check_selection_consistency(fleet.SweepPlan(cells=()), {"app": "soplex"})
    tagged = fleet.SweepPlan.grid(
        ["soplex"], ["rainbow"], (1, 2), intervals=1, accesses=1000,
        tags=(("sweep", "s"),),
    )
    check_selection_consistency(tagged, {"seed": 1})
    check_selection_consistency(tagged, {"sweep": "s"})
    check_selection_consistency(tagged, {"sweep": "other"})


try:  # optional, as in tests/test_core_*: property layer on the same checks
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # pragma: no cover - exercised via the edge-case tests
    st = None

if st is not None:

    def _grids():
        return st.builds(
            lambda apps, policies, seeds, intervals, accesses, tags: (
                fleet.SweepPlan.grid(
                    apps, policies, tuple(seeds), intervals=intervals,
                    accesses=accesses, tags=tags,
                )
            ),
            # min_size=1 per axis: grid() rejects lopsided axis combinations
            # loudly; EMPTY plans are still covered via empty grid-lists in
            # _plans() and the deterministic floors
            apps=st.lists(st.sampled_from(PROP_APPS), min_size=1, max_size=3,
                          unique=True),
            policies=st.lists(st.sampled_from(PROP_POLICIES), min_size=1,
                              max_size=3, unique=True),
            seeds=st.lists(st.integers(0, 5), min_size=1, max_size=3,
                           unique=True),
            intervals=st.integers(1, 3),
            accesses=st.sampled_from([None, 1000, 2000]),
            tags=st.sampled_from([
                (), (("sweep", "a"),), (("sweep", "b"), ("setting", 1)),
            ]),
        )

    def _plans():
        return st.lists(_grids(), min_size=0, max_size=3).map(
            lambda gs: sum(gs, fleet.SweepPlan(cells=()))
        )

    @settings(max_examples=25, deadline=None)
    @given(_plans())
    def test_plan_groups_roundtrip_property(plan):
        check_plan_groups_roundtrip(plan)

    @settings(max_examples=25, deadline=None)
    @given(
        _plans(),
        st.dictionaries(
            st.sampled_from(["app", "policy", "seed", "sweep", "setting"]),
            st.sampled_from(["streamcluster", "soplex", "rainbow", "a", "b",
                             1, 2]),
            max_size=2,
        ),
    )
    def test_selection_consistency_property(plan, filters):
        check_selection_consistency(plan, filters)

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_plan_groups_roundtrip_property():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_selection_consistency_property():
        pass


def test_sharded_fleet_bit_identical_on_4_devices():
    """4 forced host devices: shard_map fleet == single-device vmap, including
    the non-divisible padding path (6 cells on 4 devices -> pad to 8)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax
        import numpy as np
        import repro.engine.simloop as simloop
        from repro.engine import fleet
        from repro.sim.config import MachineConfig

        assert len(jax.devices()) == 4
        seeds = [0, 1, 2, 3, 4, 5]  # 6 cells: NOT divisible by 4 devices
        plan = fleet.SweepPlan.grid(["streamcluster"], ["rainbow"],
                                    tuple(seeds), intervals=2, accesses=2500)
        runner = fleet.FleetRunner()
        (group,) = fleet.plan_groups(plan)

        # staged inputs must actually be sharded across all 4 devices
        states, chunks = runner._stage(group)
        assert len(chunks.sp.sharding.device_set) == 4, chunks.sp.sharding
        assert chunks.sp.shape[0] == 8  # padded 6 -> 8

        # raw engine outputs: sharded shard_map == single-device vmap, bitwise
        finals_s, stats_s = fleet._sharded_fleet_fn(group.spec, runner.mesh)(
            states, chunks)
        finals_v, stats_v, meta = simloop.sweep_seeds(
            "streamcluster", "rainbow", MachineConfig(), seeds,
            intervals=2, accesses=2500)
        for f_s, f_v in zip(stats_s, stats_v):
            np.testing.assert_array_equal(np.asarray(f_s)[:6], np.asarray(f_v))
        for c_s, c_v in zip(finals_s.sim.counters, finals_v.sim.counters):
            np.testing.assert_array_equal(np.asarray(c_s)[:6], np.asarray(c_v))

        # and the full metrics path agrees with the unbatched engine
        res = runner.run(plan)
        from repro.sim.runner import simulate
        one = simulate("streamcluster", "rainbow", intervals=2,
                       accesses=2500, seed=5)
        got = res[("streamcluster", "rainbow", 5)]
        assert got.ipc == one.ipc and got.migrations == one.migrations
        assert got.total_cycles == one.total_cycles
        print("FLEET_SHARDED_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert "FLEET_SHARDED_OK" in out.stdout, out.stderr[-2000:]

# ---------------------------------------------------------------------------
# Atlas-scale fast path: prefetch pipeline, compile cache, staging pool,
# batched journal, per-group timings. The oracle everywhere is the inline
# pipeline=False runner (the pre-pipeline barrier path).
# ---------------------------------------------------------------------------

def _two_sig_plan():
    """Two compile signatures (streamcluster/soplex shapes), 2 cells each."""
    kw = dict(intervals=2, accesses=2000)
    return (
        fleet.SweepPlan.grid(["streamcluster"], ["rainbow"], (0, 1), **kw)
        + fleet.SweepPlan.grid(["soplex"], ["rainbow"], (0, 1), **kw)
    )


def test_pipelined_matches_legacy_across_depths():
    """Every prefetch depth (serial, double-buffer, deeper) is bit-identical
    to the inline barrier path, and surfaces one GroupTiming per group."""
    plan = _two_sig_plan()
    oracle = dict(fleet.FleetRunner(pipeline=False).run(plan).items())
    for depth in (1, 2, 3):
        runner = fleet.FleetRunner(prefetch_depth=depth)
        assert dict(runner.run(plan).items()) == oracle, f"depth={depth}"
        assert len(runner.timings) == 2
        for t in runner.timings:
            assert t.cells == 2 and t.signature
            assert t.stage_s >= 0 and t.compile_s >= 0
            assert t.scan_s >= 0 and t.retire_s >= 0
    with pytest.raises(ValueError, match="prefetch_depth"):
        fleet.FleetRunner(prefetch_depth=0)
    with pytest.raises(ValueError, match="flush_groups"):
        fleet.FleetJournal("unused.jsonl", flush_groups=0)


def test_compile_cache_hits_across_runners():
    """An isolated CompileCache compiles each signature once; a second runner
    sharing it hits on every group (timings record the cached flag)."""
    cache = fleet.CompileCache()
    plan = _two_sig_plan()
    r1 = fleet.FleetRunner(compile_cache=cache)
    res1 = r1.run(plan)
    s = cache.stats()
    assert s["misses"] == 2 and s["hits"] == 0 and s["entries"] == 2
    assert s["compile_seconds"] > 0
    assert [t.compile_cached for t in r1.timings] == [False, False]

    r2 = fleet.FleetRunner(compile_cache=cache)
    res2 = r2.run(plan)
    s = cache.stats()
    assert s["misses"] == 2 and s["hits"] == 2 and s["entries"] == 2
    assert [t.compile_cached for t in r2.timings] == [True, True]
    assert all(t.compile_s == 0.0 for t in r2.timings)
    assert dict(res2.items()) == dict(res1.items())


def test_staging_pool_reuse_same_geometry():
    """Two groups with identical padded geometry share one staging buffer
    when run serially (the buffer is released at retire, re-acquired next)."""
    plan = fleet.SweepPlan.grid(
        ["streamcluster"], ["rainbow", "flat-static"], (0, 1),
        intervals=2, accesses=2000,
    )
    assert len(fleet.plan_groups(plan)) == 2
    runner = fleet.FleetRunner(prefetch_depth=1)
    oracle = fleet.FleetRunner(pipeline=False).run(plan)
    assert dict(runner.run(plan).items()) == dict(oracle.items())
    pool = runner._staging_pool
    assert pool.allocated == 1 and pool.reused == 1


def test_run_iter_journal_batches_and_records_timings(tmp_path):
    """flush_groups=2: the first retired group stays in the coalesce buffer
    (nothing durable), the watermark flushes both, and the journal carries
    per-group GroupTiming rows that load() ignores but load_timings() sees."""
    plan = _two_sig_plan()
    (g0, g1) = fleet.plan_groups(plan)
    path = tmp_path / "batched.jsonl"
    jnl = fleet.FleetJournal(path, flush_groups=2)
    runner = fleet.FleetRunner()
    it = runner.run_iter(plan, journal=jnl)
    for _ in g0.cells:
        next(it)
    assert jnl.pending == 1 and not path.exists()  # coalescing, not durable
    rest = list(it)
    assert jnl.pending == 0 and len(rest) == len(g1.cells)

    reloaded = fleet.FleetJournal(path)
    assert set(reloaded.load()) == {c.key() for c in plan.cells}
    timing_rows = reloaded.load_timings()
    assert len(timing_rows) == 2
    for row, t in zip(timing_rows, runner.timings):
        assert row == t.row()
        assert {"label", "signature", "cells", "stage_s", "compile_s",
                "scan_s", "retire_s", "compile_cached"} <= set(row)

    # resuming from the journal replays everything: zero groups re-executed
    loaded = reloaded.load()
    r2 = fleet.FleetRunner()
    res = r2.run(plan, journal=path)
    assert not r2.timings
    assert {c.key(): m for c, m in res.items()} == loaded
