"""Migration bitmap + remap tables (paper §III-D/E): invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import bitmap as bm
from repro.core import remap as rm


def test_bitmap_set_get_roundtrip(rng):
    b = bm.bitmap_init(8, 64)
    sp = jnp.array([0, 3, 3, 7], jnp.int32)
    pg = jnp.array([0, 31, 32, 63], jnp.int32)
    b = bm.bitmap_update(b, sp, pg, True)
    assert bool(bm.bitmap_get(b, jnp.int32(3), jnp.int32(31)))
    assert bool(bm.bitmap_get(b, jnp.int32(3), jnp.int32(32)))
    assert not bool(bm.bitmap_get(b, jnp.int32(3), jnp.int32(33)))
    b = bm.bitmap_update(b, jnp.array([3], jnp.int32), jnp.array([31], jnp.int32), False)
    assert not bool(bm.bitmap_get(b, jnp.int32(3), jnp.int32(31)))
    assert bool(bm.bitmap_get(b, jnp.int32(3), jnp.int32(32)))  # untouched


def test_bitmap_duplicates_safe():
    b = bm.bitmap_init(2, 32)
    sp = jnp.zeros(10, jnp.int32)
    pg = jnp.full(10, 5, jnp.int32)
    b = bm.bitmap_update(b, sp, pg, True)
    assert int(bm.bitmap_popcount(b)[0]) == 1


def test_bitmap_cache_lru():
    c = bm.bitmap_cache_init(entries=8, ways=2)  # 4 sets x 2 ways
    c, h = bm.bitmap_cache_lookup(c, jnp.int32(0), jnp.int32(1))
    assert not bool(h)
    c, h = bm.bitmap_cache_lookup(c, jnp.int32(0), jnp.int32(2))
    assert bool(h)
    # fill the set of psn 0 (psns congruent mod 4): 0, 4, 8 -> evicts LRU (0? no, 4)
    c, _ = bm.bitmap_cache_lookup(c, jnp.int32(4), jnp.int32(3))
    c, _ = bm.bitmap_cache_lookup(c, jnp.int32(8), jnp.int32(4))  # evicts 4 (LRU=0@2? 0 touched t=2, 4 t=3) -> evicts 0
    c, h = bm.bitmap_cache_lookup(c, jnp.int32(4), jnp.int32(5))
    assert bool(h)  # 4 still resident


def test_storage_overhead_matches_paper():
    assert bm.storage_overhead_bytes(4000, 512) == 4000 * (4 + 64)  # 272 KB


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 7), st.integers(0, 15)), min_size=1, max_size=40))
def test_remap_consistency_invariant(ops):
    """bitmap bit set <=> remap slot >= 0, under arbitrary install/evict mixes."""
    state = rm.remap_init(8, 16)
    for i, (sp, pg) in enumerate(ops):
        if i % 3 == 2:
            state = rm.remap_evict(state, jnp.array([sp], jnp.int32), jnp.array([pg], jnp.int32))
        else:
            state = rm.remap_install(
                state, jnp.array([sp], jnp.int32), jnp.array([pg], jnp.int32),
                jnp.array([i % 5], jnp.int32),
            )
        assert bool(rm.check_consistency(state))


def test_translate_redirects_only_installed():
    state = rm.remap_init(4, 8)
    state = rm.remap_install(
        state, jnp.array([1], jnp.int32), jnp.array([3], jnp.int32), jnp.array([7], jnp.int32)
    )
    in_fast, slot = rm.translate(
        state, jnp.array([1, 1, 0], jnp.int32), jnp.array([3, 4, 3], jnp.int32)
    )
    assert np.asarray(in_fast).tolist() == [True, False, False]
    assert int(slot[0]) == 7 and int(slot[1]) == -1
