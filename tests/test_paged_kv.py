"""Rainbow paged KV cache (Layer B): exactness + promotion behaviour."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced_config
from repro.core.remap import check_consistency
from repro.memory.kvcache import PagedConfig, end_interval_promote, paged_init
from repro.models import model as M
from repro.serving.rainbow_decode import rainbow_decode_step


def _setup(interval_steps=4, S=24):
    cfg = get_reduced_config("qwen3-4b")
    key = jax.random.PRNGKey(3)
    B = 2
    pcfg = PagedConfig(block_size=4, blocks_per_seq=S // 4, hot_slots=6, top_n=4,
                       max_promotions=4, interval_steps=interval_steps)
    params = M.init_params(cfg, key, tp=1)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return cfg, pcfg, params, toks, B, S


def test_rainbow_decode_exact_vs_flat():
    """THE invariant: tiered decode is numerically identical to flat decode,
    across promotions AND evictions (hot pool smaller than hot blocks)."""
    cfg, pcfg, params, toks, B, S = _setup()
    flat_step = jax.jit(lambda p, t, c: M.decode_step(cfg, p, t, c))
    rb_step = jax.jit(lambda p, t, k: rainbow_decode_step(cfg, pcfg, p, t, k))
    cache = M.init_cache(cfg, B, S, tp=1)
    kv = paged_init(cfg, pcfg, B, 1, cfg.num_layers)
    errs = []
    for t in range(S):
        tok = toks[:, t:t + 1]
        fl, cache = flat_step(params, tok, cache)
        rl, kv = rb_step(params, tok, kv)
        errs.append(float(jnp.abs(
            fl[..., :cfg.vocab_size] - rl[..., :cfg.vocab_size]).max()))
    assert max(errs) == 0.0, f"tiered decode diverged: {max(errs)}"
    assert int((kv.remap.remap >= 0).sum()) > 0, "no promotions happened"
    assert bool(check_consistency(kv.remap))


def test_promotion_respects_hot_pool_capacity():
    cfg, pcfg, params, toks, B, S = _setup(interval_steps=2)
    rb_step = jax.jit(lambda p, t, k: rainbow_decode_step(cfg, pcfg, p, t, k))
    kv = paged_init(cfg, pcfg, B, 1, cfg.num_layers)
    for t in range(S):
        _, kv = rb_step(params, toks[:, t:t + 1], kv)
        resident = int((kv.remap.remap >= 0).sum())
        assert resident <= pcfg.hot_slots
    assert int(kv.length) == S


def test_sparse_mode_runs_and_is_bounded():
    cfg, pcfg, params, toks, B, S = _setup()
    rb_full = jax.jit(lambda p, t, k: rainbow_decode_step(cfg, pcfg, p, t, k, mode="full"))
    rb_sparse = jax.jit(lambda p, t, k: rainbow_decode_step(cfg, pcfg, p, t, k, mode="sparse"))
    kv_f = paged_init(cfg, pcfg, B, 1, cfg.num_layers)
    kv_s = paged_init(cfg, pcfg, B, 1, cfg.num_layers)
    for t in range(S):
        lf, kv_f = rb_full(params, toks[:, t:t + 1], kv_f)
        ls, kv_s = rb_sparse(params, toks[:, t:t + 1], kv_s)
        assert bool(jnp.isfinite(ls).all())
    # sparse attends the trailing window; early-context divergence is allowed
    # but outputs must stay sane (same argmax for most steps is typical)


def _read_set_blocks(kv, pcfg, batch, seq):
    """Seq-local block ids currently in `seq`'s sparse read set."""
    from repro.serving.rainbow_decode import sparse_read_set

    _, valid, blocks = sparse_read_set(kv, pcfg, batch)
    v = np.asarray(valid[seq])
    return set(np.asarray(blocks[seq])[v].tolist())


def test_sparse_promotion_rejoin_crafted_mass():
    """THE rejoin invariant (satellite): a cold block outside the trailing
    window whose attention mass grows must be promoted at end_interval_promote
    and re-enter the sparse read set."""
    from repro.memory.kvcache import observe_block_mass

    # 12 blocks >> the 8-block trailing window, so old blocks fall out of
    # the sparse read set unless promotion brings them back
    cfg, pcfg, params, toks, B, S = _setup(S=48)
    nblk = pcfg.blocks_per_seq
    kv = paged_init(cfg, pcfg, B, 1, cfg.num_layers)
    kv = dataclasses.replace(kv, length=jnp.int32(S))  # all blocks valid

    target = 0  # block 0 is far behind the trailing window at length S
    assert target not in _read_set_blocks(kv, pcfg, B, seq=0)

    # interval 1: stage-1 sees seq 0's heat -> monitors rotate onto it
    hot = jnp.zeros((B, nblk), jnp.float32).at[0, target].set(4.0)
    kv = observe_block_mass(kv, pcfg, hot)
    kv, _ = end_interval_promote(kv, pcfg)
    # interval 2: stage-2 (now monitoring seq 0) sees the block's mass grow
    kv = observe_block_mass(kv, pcfg, hot)
    kv, rep = end_interval_promote(kv, pcfg)
    assert int(rep["promoted"]) >= 1

    rejoined = _read_set_blocks(kv, pcfg, B, seq=0)
    assert target in rejoined, (
        f"promoted block {target} must re-enter the sparse read set "
        f"(got {sorted(rejoined)})"
    )


def test_sparse_decode_promotes_and_rejoins_end_to_end():
    """Decode-driven rejoin: sparse mode must record real block mass (not
    zeros), promote hot history blocks, and read them once resident."""
    cfg, pcfg, params, toks, B, S = _setup(interval_steps=2)
    rb_sparse = jax.jit(
        lambda p, t, k: rainbow_decode_step(cfg, pcfg, p, t, k, mode="sparse"))
    kv = paged_init(cfg, pcfg, B, 1, cfg.num_layers)
    for t in range(S):
        _, kv = rb_sparse(params, toks[:, t:t + 1], kv)
    resident = int((kv.remap.remap >= 0).sum())
    assert resident > 0, "sparse decode never promoted a block"
    # every resident block is part of the sparse read set again
    for seq in range(B):
        in_set = _read_set_blocks(kv, pcfg, B, seq)
        rm = np.asarray(kv.remap.remap[seq])
        for blk in np.nonzero(rm >= 0)[0].tolist():
            assert blk in in_set


def test_interval_promote_copies_payload():
    cfg, pcfg, params, toks, B, S = _setup()
    kv = paged_init(cfg, pcfg, B, 1, cfg.num_layers)
    # fabricate stage-2 heat on (seq 0, block 1)
    s2c = kv.s2.counts
    kv = dataclasses.replace(
        kv,
        s2=dataclasses.replace(kv.s2, psn=jnp.array([0, 1, -1, -1], jnp.int32),
                               counts=s2c.at[0, 1].set(jnp.uint16(2000))),
        cap_k=kv.cap_k.at[:, 1].set(1.25),  # block 1 of seq 0
        length=jnp.int32(S),
    )
    kv2, rep = end_interval_promote(kv, pcfg)
    assert int(rep["promoted"]) >= 1
    in_fast, slot = jax.jit(
        lambda r: __import__("repro.core.remap", fromlist=["translate"]).translate(
            r, jnp.array([0], jnp.int32), jnp.array([1], jnp.int32))
    )(kv2.remap)
    assert bool(in_fast[0])
    s = int(slot[0])
    np.testing.assert_allclose(np.asarray(kv2.hot_k[:, s], np.float32), 1.25)


def test_int8_quantized_paged_decode_close():
    """Beyond-paper A3: int8 pools + per-token scales track flat decode."""
    import jax

    from repro.memory.kvcache import paged_scales_init

    cfg, pcfg0, params, toks, B, S = _setup()
    pcfg = dataclasses.replace(pcfg0) if False else PagedConfig(
        block_size=4, blocks_per_seq=S // 4, hot_slots=6, top_n=4,
        max_promotions=4, interval_steps=4, quantize=True)
    flat_step = jax.jit(lambda p, t, c: M.decode_step(cfg, p, t, c))
    q8_step = jax.jit(
        lambda p, t, k, s: rainbow_decode_step(cfg, pcfg, p, t, k, scales=s))
    cache = M.init_cache(cfg, B, S, tp=1)
    kv = paged_init(cfg, pcfg, B, 1, cfg.num_layers)
    sc = paged_scales_init(pcfg, B, cfg.kv_store(1), cfg.num_layers)
    agree = 0
    for t in range(S):
        tok = toks[:, t:t + 1]
        fl, cache = flat_step(params, tok, cache)
        rl, kv, sc = q8_step(params, tok, kv, sc)
        v = cfg.vocab_size
        err = float(jnp.abs(fl[..., :v] - rl[..., :v]).max())
        assert err < 0.1, f"int8 decode drifted: {err}"
        agree += int((jnp.argmax(fl[..., :v], -1) == jnp.argmax(rl[..., :v], -1)).all())
    assert agree >= S - 4  # near-perfect greedy agreement
    assert kv.cap_k.dtype == jnp.int8
