"""Elastic checkpoint restore across DIFFERENT mesh shapes (subprocess with 8
host devices — the scale-up/scale-down restart path of DESIGN.md §5)."""
import os
import subprocess
import sys
import textwrap


def test_save_on_4x2_restore_on_2x2(tmp_path):
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import restore_state, save_state

        ckpt = {str(tmp_path)!r}
        state = {{"w": jnp.arange(64.0).reshape(8, 8),
                  "m": jnp.ones((8, 8)) * 3}}

        # "job 1": 4x2 mesh, sharded state
        mesh1 = jax.make_mesh((4, 2), ("data", "model"))
        sh1 = {{"w": NamedSharding(mesh1, P("data", "model")),
               "m": NamedSharding(mesh1, P("data", None))}}
        state1 = jax.tree.map(lambda a, s: jax.device_put(a, s), state, sh1)
        save_state(ckpt, 7, state1)

        # "job 2": relaunched at HALF the devices, different layout
        mesh2 = jax.make_mesh((2, 2), ("data", "model"))
        sh2 = {{"w": NamedSharding(mesh2, P("model", "data")),
               "m": NamedSharding(mesh2, P(None, "data"))}}
        like = jax.eval_shape(lambda: state)
        restored, step = restore_state(ckpt, like, shardings=sh2)
        assert step == 7
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.arange(64.0).reshape(8, 8))
        assert restored["w"].sharding.spec == P("model", "data")
        assert len(restored["w"].sharding.device_set) == 4
        print("ELASTIC_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=300,
    )
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]


def test_sharded_train_step_on_4x2_mesh(tmp_path):
    """Full train step (TP=2, DP=4, ZeRO specs) on 8 real host devices."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.configs import get_reduced_config
        from repro.data.pipeline import SyntheticLM
        from repro.launch.sharding import make_constrainer, sharding_tree
        from repro.train.step import (TrainStepConfig, batch_specs,
                                      build_train_step, init_train_state,
                                      train_state_specs)

        cfg = get_reduced_config("qwen3-4b")  # 4 heads, kv 2 -> TP=2 works
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        sc = make_constrainer(mesh)
        tcfg = TrainStepConfig(tp=2, remat="full")
        state = init_train_state(cfg, jax.random.PRNGKey(0), tcfg)
        state_sh = sharding_tree(train_state_specs(cfg, tcfg, dp_size=4), mesh)
        batch_sh = sharding_tree(batch_specs(cfg), mesh)
        data = SyntheticLM(cfg.vocab_size, 32, 8, seed=1)
        step = jax.jit(build_train_step(cfg, tcfg, sc=sc),
                       in_shardings=(state_sh, batch_sh),
                       out_shardings=(state_sh, None), donate_argnums=(0,))
        with mesh:
            state = jax.device_put(state, state_sh)
            losses = []
            for _ in range(3):
                batch = jax.device_put(data.next_batch(), batch_sh)
                state, metrics = step(state, batch)
                losses.append(float(metrics["loss"]))
        assert all(l == l for l in losses), losses  # finite
        assert losses[-1] < losses[0] + 0.5
        print("SHARDED_TRAIN_OK", losses)
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert "SHARDED_TRAIN_OK" in out.stdout, out.stderr[-2000:]
