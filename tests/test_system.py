"""End-to-end behaviour tests: training loop fault tolerance, simulator policy
ordering (the paper's headline directions), sharded step execution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.data.pipeline import SyntheticLM
from repro.optim import AdamWConfig
from repro.sim.runner import simulate
from repro.train.loop import LoopConfig, Trainer
from repro.train.step import TrainStepConfig, build_train_step, init_train_state


def test_training_loss_decreases(tmp_path):
    cfg = get_reduced_config("qwen3-0.6b")
    tcfg = TrainStepConfig(tp=1, remat="none", adamw=AdamWConfig(lr=3e-3))
    state = init_train_state(cfg, jax.random.PRNGKey(0), tcfg)
    step = jax.jit(build_train_step(cfg, tcfg))
    data = iter(SyntheticLM(cfg.vocab_size, 32, 8, seed=5))
    trainer = Trainer(step, data, LoopConfig(
        total_steps=30, checkpoint_every=10, checkpoint_dir=str(tmp_path),
        log_every=1000))
    state, hist = trainer.run(state, 0)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.2, f"loss did not fall: {first:.3f} -> {last:.3f}"


def test_trainer_resume_from_checkpoint(tmp_path):
    cfg = get_reduced_config("smollm-360m")
    tcfg = TrainStepConfig(tp=1, remat="none")
    state = init_train_state(cfg, jax.random.PRNGKey(0), tcfg)
    step = jax.jit(build_train_step(cfg, tcfg))
    data = iter(SyntheticLM(cfg.vocab_size, 16, 4, seed=1))
    tr = Trainer(step, data, LoopConfig(
        total_steps=6, checkpoint_every=3, checkpoint_dir=str(tmp_path),
        log_every=1000))
    tr.run(state, 0)
    # a "relaunched job" resumes from the saved step
    state2, start = tr.ckpt.restore_or_init(
        lambda: init_train_state(cfg, jax.random.PRNGKey(0), tcfg)
    )
    assert start >= 3
    assert int(state2["opt"]["step"]) == start


def test_trainer_retries_transient_failures(tmp_path):
    cfg = get_reduced_config("smollm-360m")
    tcfg = TrainStepConfig(tp=1, remat="none")
    state = init_train_state(cfg, jax.random.PRNGKey(0), tcfg)
    real = jax.jit(build_train_step(cfg, tcfg))
    calls = {"n": 0}

    def flaky(state, batch):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("simulated ICI link flap")
        return real(state, batch)

    data = iter(SyntheticLM(cfg.vocab_size, 16, 4, seed=2))
    tr = Trainer(flaky, data, LoopConfig(
        total_steps=3, checkpoint_every=100, checkpoint_dir=str(tmp_path),
        log_every=1000))
    _, hist = tr.run(state, 0)
    assert len(hist) == 3
    assert any(e["event"] == "retry" for e in tr.events)


def test_trainer_nan_guard(tmp_path):
    cfg = get_reduced_config("smollm-360m")
    tcfg = TrainStepConfig(tp=1, remat="none")
    state = init_train_state(cfg, jax.random.PRNGKey(0), tcfg)
    real = jax.jit(build_train_step(cfg, tcfg))
    calls = {"n": 0}

    def poisoned(state, batch):
        s, m = real(state, batch)
        calls["n"] += 1
        if calls["n"] == 2:
            m = dict(m)
            m["loss"] = jnp.float32(np.nan)
        return s, m

    data = iter(SyntheticLM(cfg.vocab_size, 16, 4, seed=3))
    tr = Trainer(poisoned, data, LoopConfig(
        total_steps=4, checkpoint_every=100, checkpoint_dir=str(tmp_path),
        log_every=1000))
    _, hist = tr.run(state, 0)
    assert any(e["event"] == "nan_skip" for e in tr.events)
    assert len(hist) == 3  # the poisoned step was dropped


def test_sharded_train_step_single_device_mesh():
    """The pjit path with sharding constraints runs on a real mesh."""
    from repro.launch.mesh import make_test_mesh
    from repro.launch.sharding import make_constrainer

    cfg = get_reduced_config("qwen3-0.6b")
    mesh = make_test_mesh(devices=1, model=1)
    sc = make_constrainer(mesh)
    tcfg = TrainStepConfig(tp=1, remat="full")
    state = init_train_state(cfg, jax.random.PRNGKey(0), tcfg)
    step = jax.jit(build_train_step(cfg, tcfg, sc=sc))
    data = SyntheticLM(cfg.vocab_size, 16, 4, seed=4)
    with mesh:
        _, metrics = step(state, data.next_batch())
    assert bool(jnp.isfinite(metrics["loss"]))


# ---------------------------------------------------------------------------
# Layer-A simulator: headline directional claims on a quick configuration
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def sim_results():
    out = {}
    for pol in ("flat-static", "hscc-4kb-mig", "rainbow", "dram-only"):
        out[pol] = simulate("soplex", pol, intervals=4, accesses=25_000)
    return out


def test_sim_superpages_crush_mpki(sim_results):
    """Paper Fig. 7: superpage policies cut TLB MPKI by a large factor.

    (The paper reports -99.8% with full-size TLBs; the 1/16-scaled TLBs here
    cap the reduction for mid-size working sets — see EXPERIMENTS.md §Repro.)
    """
    assert sim_results["rainbow"].mpki < 0.2 * sim_results["flat-static"].mpki


def test_sim_rainbow_beats_flat_ipc(sim_results):
    assert sim_results["rainbow"].ipc > sim_results["flat-static"].ipc


def test_sim_dram_only_is_upper_bound(sim_results):
    for pol in ("flat-static", "hscc-4kb-mig", "rainbow"):
        assert sim_results["dram-only"].ipc >= sim_results[pol].ipc * 0.99


def test_sim_rainbow_traffic_below_2mb_migration():
    r = simulate("GUPS", "rainbow", intervals=3, accesses=30_000)
    h2 = simulate("GUPS", "hscc-2mb-mig", intervals=3, accesses=30_000)
    if h2.mig_bytes > 0:
        assert r.mig_bytes <= h2.mig_bytes


def test_sim_breakdown_fields_present(sim_results):
    b = sim_results["rainbow"].breakdown
    for k in ("cycles_tlb", "cycles_walk", "cycles_bitmap", "cycles_remap",
              "cycles_mem", "cycles_mig"):
        assert k in b and b[k] >= 0
