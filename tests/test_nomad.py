"""Differential gates for the transactional async migration family.

engine.nomad wraps the unchanged rainbow controller with an in-flight
transaction ring and installment-spread queue charging, so it inherits the
repo's two standing equivalence contracts and adds one of its own:

  * engine == eager oracle, bitwise, on SimMetrics — the scanned nomad step
    program against sim.policies.Nomad (which drives the SAME pure
    functions host-side), across flat and queueing timing models;
  * staged == fused, bitwise — the in-scan synthesized trace against the
    host-staged chunks;
  * the sync-degenerate invariant: with async_window=1 every async code
    path is STATICALLY skipped and the nomad program is bit-identical to
    the synchronous rainbow program (stats AND final sim state) — the
    anchor that pins the whole family to the already-validated baseline.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.sim.config import MachineConfig
from repro.sim.runner import simulate, simulate_eager
from repro.timing import get_geometry


def _tree_equal(a, b) -> bool:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


@pytest.mark.parametrize("timing_model,geometry", [
    ("flat", None),
    ("queueing", "constrained"),
])
def test_engine_matches_eager_oracle(timing_model, geometry):
    kw = dict(
        intervals=4, accesses=4000, seed=7,
        timing_model=timing_model,
        queue_geometry=None if geometry is None else get_geometry(geometry),
    )
    eng = simulate("streamcluster", "nomad", **kw)
    ref = simulate_eager("streamcluster", "nomad", **kw)
    assert dataclasses.asdict(eng) == dataclasses.asdict(ref)
    # the default preset is the full transactional config: write-heavy
    # streamcluster must actually exercise the abort path
    assert eng.mig_aborts > 0
    assert eng.shootdowns == eng.evictions + eng.mig_aborts


def test_staged_matches_fused():
    kw = dict(intervals=3, accesses=4000, seed=3,
              timing_model="queueing")
    staged = simulate("stress/zipf-hotspot", "nomad", **kw)
    fused = simulate("stress/zipf-hotspot", "nomad", fused=True, **kw)
    assert dataclasses.asdict(staged) == dataclasses.asdict(fused)


def test_sync_degenerate_bitwise_equals_rainbow():
    """async_window=1 ("nomad-sync") == rainbow, program-for-program.

    Not just equal SimMetrics: the per-interval stats vector and the final
    TLB/counter state must match bitwise, under a constrained queue
    geometry where any charging-schedule difference would show up in the
    stall fields. 0.0 + C/1.0 is bitwise C in f32, so the single
    installment lands exactly where rainbow lands its lump.
    """
    from repro.engine import simloop
    from repro.engine.policy import get_policy

    mc = MachineConfig()
    chunks, meta = simloop.make_chunks("streamcluster", "rainbow", mc, 7, 4, 3000)

    def run(policy, control):
        spec = simloop.EngineSpec(
            policy=policy, mc=mc,
            num_superpages=meta["num_superpages"],
            footprint_pages=meta["footprint_pages"],
            control=control,
            timing_model="queueing",
            queue_geometry=get_geometry("constrained"),
        )
        return simloop.engine_run(spec, simloop.engine_init(spec), chunks)

    st_r, stats_r = run("rainbow", None)
    st_n, stats_n = run("nomad", get_policy("nomad-sync", mc=mc))
    assert int(np.asarray(stats_n.aborts).sum()) == 0
    for f in stats_r._fields:
        a = getattr(stats_r, f)
        if a is None or f == "aborts":
            continue
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(getattr(stats_n, f)), err_msg=f
        )
    assert _tree_equal(st_r.sim, st_n.sim)
    assert _tree_equal(st_r.q, st_n.q)


def test_exclusive_window_matches_rainbow_counts():
    """"nomad-exclusive" (async_window=4, no aborts, exclusive residency)
    isolates the charging-schedule axis: the CONTROLLER decisions are
    rainbow's verbatim, so counts and flat-model metrics are identical;
    only the queueing stall fields may differ (installments vs lump)."""
    from repro.engine import simloop
    from repro.engine.policy import get_policy

    mc = MachineConfig()
    chunks, meta = simloop.make_chunks("streamcluster", "rainbow", mc, 5, 4, 3000)

    def run(policy, control):
        spec = simloop.EngineSpec(
            policy=policy, mc=mc,
            num_superpages=meta["num_superpages"],
            footprint_pages=meta["footprint_pages"],
            control=control,
            timing_model="queueing",
            queue_geometry=get_geometry("constrained"),
        )
        return simloop.engine_run(spec, simloop.engine_init(spec), chunks)

    _, stats_r = run("rainbow", None)
    _, stats_n = run("nomad", get_policy("nomad-exclusive", mc=mc))
    for f in ("migrations", "evictions", "dirty_evictions", "shootdowns"):
        np.testing.assert_array_equal(
            np.asarray(getattr(stats_r, f)), np.asarray(getattr(stats_n, f)),
            err_msg=f,
        )
    assert int(np.asarray(stats_n.aborts).sum()) == 0
    # W=4 spreads the charge: the stall profile must actually differ
    assert not np.array_equal(
        np.asarray(stats_r.mig_stall), np.asarray(stats_n.mig_stall)
    )


def test_abort_rollback_semantics():
    """A written in-flight page is rolled back: counted, shot down, and no
    longer DRAM-resident — while untouched in-flight lanes stay installed."""
    import jax.numpy as jnp

    from repro.core.remap import translate
    from repro.engine import nomad as nomad_mod
    from repro.engine import simloop
    from repro.engine.policy import get_policy

    mc = MachineConfig()
    control = get_policy("nomad-sim", mc=mc)  # W=4, aborts + shadow on
    spec = simloop.EngineSpec(
        policy="nomad", mc=mc, num_superpages=8, footprint_pages=8 * 512,
        control=control,
    )
    cfg = simloop._rainbow_cfg(spec)
    state = simloop.engine_init(spec)

    def interval(state, sp, page, is_write):
        chunk = simloop.TraceChunks(
            sp=jnp.asarray(sp, jnp.int32)[None],
            page=jnp.asarray(page, jnp.int32)[None],
            vpn=jnp.asarray(np.asarray(sp) * 512 + np.asarray(page),
                            jnp.int32)[None],
            is_write=jnp.asarray(is_write, bool)[None],
            in_dram=jnp.zeros((1, len(sp)), bool),
        )
        return simloop.engine_run(spec, state, chunk)

    # two hot read-only pages: warm-up interval, then the migrating interval
    n = 1000
    sp = np.zeros(n, np.int32)
    page = np.where(np.arange(n) % 2 == 0, 3, 9).astype(np.int32)
    reads = np.zeros(n, bool)
    state, _ = interval(state, sp, page, reads)
    state, stats = interval(state, sp, page, reads)
    assert int(np.asarray(stats.migrations)[-1]) == 2
    in_flight = np.asarray(nomad_mod._in_flight_map(cfg, state.pol))
    assert in_flight[3] and in_flight[9]

    # page 3 is written while mid-copy -> exactly that transaction aborts
    state, stats = interval(state, sp, page,
                            (page == 3) & (np.arange(n) % 100 == 0))
    assert int(np.asarray(stats.aborts)[-1]) == 1
    resident, _ = translate(state.pol.rb.remap, jnp.asarray([0, 0]),
                            jnp.asarray([3, 9]))
    assert not bool(resident[0]) and bool(resident[1])
    assert int(np.asarray(state.pol.aborts_total)) == 1
