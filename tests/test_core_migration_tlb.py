"""Utility migration (Eq. 1/2) + split TLB model: unit + property tests."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import migration as mig
from repro.core import tlb

TIMING = mig.make_timing(t_nr=62.4, t_nw=547.2, t_dr=43.2, t_dw=91.2,
                         t_mig=1000.0, t_writeback=1000.0)


def test_eq1_benefit_values():
    b = mig.migration_benefit(jnp.float32(10), jnp.float32(5), TIMING)
    want = (62.4 - 43.2) * 10 + (547.2 - 91.2) * 5 - 1000.0
    assert abs(float(b) - want) < 1e-3


def test_eq2_dirty_victim_pays_writeback():
    clean = mig.swap_benefit(jnp.float32(50), jnp.float32(0), jnp.float32(5),
                             jnp.float32(0), TIMING, jnp.bool_(False))
    dirty = mig.swap_benefit(jnp.float32(50), jnp.float32(0), jnp.float32(5),
                             jnp.float32(0), TIMING, jnp.bool_(True))
    assert abs(float(clean) - float(dirty) - 1000.0) < 1e-3


def _plan(cand_r, dram, threshold=0.0):
    k = len(cand_r)
    return mig.plan_migrations(
        jnp.arange(k, dtype=jnp.int32),
        jnp.zeros(k, jnp.int32),
        jnp.asarray(cand_r, jnp.float32),
        jnp.zeros(k, jnp.float32),
        dram,
        TIMING,
        jnp.float32(threshold),
    )


def test_plan_prefers_free_then_clean_then_dirty():
    import dataclasses

    d = mig.dram_init(3)
    # slot 0 dirty, slot 1 clean, slot 2 free
    d = dataclasses.replace(
        d,
        slot_state=jnp.array([2, 1, 0], jnp.int32),
        slot_sp=jnp.array([5, 6, -1], jnp.int32),
        slot_page=jnp.array([0, 0, -1], jnp.int32),
    )
    plan = _plan([1000.0, 900.0, 800.0], d)
    # best candidate lands on the free slot
    order = {int(s) for s in np.asarray(plan.dst_slot[plan.migrate])}
    assert 2 in order
    got = np.asarray(plan.dst_slot)
    assert got[0] == 2  # hottest -> free slot


def test_plan_no_duplicate_slots():
    d = mig.dram_init(4)
    plan = _plan([500.0] * 8, d)
    slots = np.asarray(plan.dst_slot[plan.migrate])
    assert len(slots) == len(set(slots.tolist()))


def test_threshold_blocks_cold_candidates():
    d = mig.dram_init(4)
    plan = _plan([10.0, 5.0], d, threshold=1e9)
    assert int(plan.migrate.sum()) == 0


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(0, 1e4), min_size=1, max_size=16), st.integers(1, 8))
def test_property_plan_within_capacity(reads, slots):
    d = mig.dram_init(slots)
    plan = _plan(reads, d)
    assert int(plan.migrate.sum()) <= slots
    sl = np.asarray(plan.dst_slot[plan.migrate])
    assert len(sl) == len(set(sl.tolist()))
    assert (sl >= 0).all() and (sl < slots).all()


def test_adapt_threshold_rises_with_evictions_and_decays():
    t0 = jnp.float32(100.0)
    t1 = mig.adapt_threshold(t0, jnp.int32(10))
    assert float(t1) > float(t0)
    t2 = mig.adapt_threshold(t1, jnp.int32(0))
    assert float(t2) < float(t1)


# ---------------------------------------------------------------------------


def test_tlb_hit_after_fill_and_lru_eviction():
    t = tlb.tlb_init(entries=4, ways=4)  # 1 set, 4 ways
    now = 0
    for v in [1, 2, 3, 4]:
        now += 1
        t, h = tlb.tlb_lookup(t, jnp.int32(v), jnp.int32(now))
        assert not bool(h)
    now += 1
    t, h = tlb.tlb_lookup(t, jnp.int32(1), jnp.int32(now))
    assert bool(h)
    now += 1
    t, _ = tlb.tlb_lookup(t, jnp.int32(5), jnp.int32(now))  # evicts LRU = 2
    now += 1
    t, h2 = tlb.tlb_lookup(t, jnp.int32(2), jnp.int32(now))
    assert not bool(h2)
    now += 1
    t, h1 = tlb.tlb_lookup(t, jnp.int32(1), jnp.int32(now))
    assert bool(h1)


def test_tlb_invalidate():
    t = tlb.tlb_init(4, 4)
    t, _ = tlb.tlb_lookup(t, jnp.int32(9), jnp.int32(1))
    t = tlb.tlb_invalidate(t, jnp.int32(9))
    t, h = tlb.tlb_lookup(t, jnp.int32(9), jnp.int32(2))
    assert not bool(h)


def test_split_tlb_l2_fills_l1():
    s = tlb.split_tlb_init(2, 2, 8, 8)
    s, h1, h2 = tlb.split_tlb_lookup(s, jnp.int32(7), jnp.int32(1))
    assert not bool(h1) and not bool(h2)
    s, h1, h2 = tlb.split_tlb_lookup(s, jnp.int32(7), jnp.int32(2))
    assert bool(h1) and bool(h2)
