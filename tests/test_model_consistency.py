"""Model-level numerical consistency: prefill/decode vs forward; chunked vs
dense attention; MoE dispatch vs dense loop; SSD vs naive recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import model as M
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod


@pytest.mark.parametrize("arch", ["qwen3-4b", "hymba-1.5b", "mamba2-1.3b",
                                  "whisper-medium", "internvl2-2b"])
def test_prefill_matches_forward(arch):
    cfg = get_reduced_config(arch)
    key = jax.random.PRNGKey(1)
    B, S = 2, 32
    params = M.init_params(cfg, key, tp=1)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        nv = cfg.num_vision_tokens
        batch["tokens"] = toks[:, : S - nv]
        batch["vision_embeds"] = jax.random.normal(key, (B, nv, cfg.d_model))
    if cfg.is_encoder_decoder:
        se = S // cfg.encoder_seq_divisor
        batch["tokens"] = toks[:, : S - se]
        batch["frames"] = jax.random.normal(key, (B, se, cfg.d_model))
    full = M.forward(cfg, params, batch)
    cache = M.init_cache(cfg, B, S, tp=1)
    pl_, _ = M.prefill(cfg, params, batch, cache, tp=1)
    err = float(jnp.abs(pl_[:, 0, : cfg.vocab_size] - full[:, -1, : cfg.vocab_size]).max())
    assert err < 5e-3, err


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "deepseek-moe-16b"])
def test_decode_matches_forward(arch):
    cfg = get_reduced_config(arch)
    key = jax.random.PRNGKey(2)
    B, S, half = 2, 24, 12
    params = M.init_params(cfg, key, tp=1)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full = M.forward(cfg, params, {"tokens": toks})
    cache = M.init_cache(cfg, B, S, tp=1)
    _, cache = M.prefill(cfg, params, {"tokens": toks[:, :half]}, cache, tp=1)
    errs = []
    for t in range(half, S - 1):
        dl, cache = M.decode_step(cfg, params, toks[:, t:t + 1], cache)
        errs.append(float(jnp.abs(
            dl[:, 0, : cfg.vocab_size] - full[:, t, : cfg.vocab_size]).max()))
    assert max(errs) < 5e-2, max(errs)  # bf16 + MoE capacity drops


def test_chunked_attention_matches_dense():
    cfg = get_reduced_config("qwen3-4b")
    key = jax.random.PRNGKey(3)
    params = M.init_params(cfg, key, tp=1)
    toks = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)
    d = M.forward(cfg, params, {"tokens": toks}, attn_impl="dense")
    c = M.forward(cfg, params, {"tokens": toks}, attn_impl="chunked")
    assert float(jnp.abs(d - c).max()) < 2e-2


def test_moe_dispatch_vs_dense_loop():
    """Capacity-gather dispatch == explicit per-token expert loop (cap ample)."""
    cfg = get_reduced_config("qwen2-moe-a2.7b")
    import dataclasses
    cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0, moe_num_shared=0)
    key = jax.random.PRNGKey(4)
    p = moe_mod.moe_init(cfg, key, tp=1)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 8, cfg.d_model), jnp.float32) * 0.1
    x = x.astype(jnp.bfloat16)
    got = moe_mod.apply_moe(cfg, p, x, tp=1)

    # reference: dense loop over tokens
    xt = x.reshape(-1, cfg.d_model)
    logits = xt.astype(jnp.float32) @ p["router"]
    e = logits.shape[-1]
    mask = jnp.arange(e) < cfg.moe_num_experts
    logits = jnp.where(mask, logits, -1e9)
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, cfg.moe_top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    out = []
    for t in range(xt.shape[0]):
        acc = jnp.zeros((cfg.d_model,), jnp.float32)
        for j in range(cfg.moe_top_k):
            ei = int(idx[t, j])
            h = xt[t] @ p["wi"][ei]
            g = xt[t] @ p["wg"][ei]
            acc += float(gate[t, j]) * ((jax.nn.silu(g.astype(jnp.float32))
                                         * h.astype(jnp.float32)).astype(jnp.bfloat16)
                                        @ p["wo"][ei]).astype(jnp.float32)
        out.append(acc)
    want = jnp.stack(out).reshape(got.shape)
    err = float(jnp.abs(got.astype(jnp.float32) - want).max())
    assert err < 5e-2, err


def test_ssd_matches_naive_recurrence():
    """Chunked SSD == token-by-token linear recurrence."""
    key = jax.random.PRNGKey(6)
    B, S, H, P, N, chunk = 2, 32, 4, 8, 16, 8
    x = jax.random.normal(key, (B, S, H, P), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(7), (B, S, H)))
    a = -jnp.exp(jax.random.normal(jax.random.PRNGKey(8), (H,)) * 0.3)
    b_in = jax.random.normal(jax.random.PRNGKey(9), (B, S, N)) * 0.3
    c_in = jax.random.normal(jax.random.PRNGKey(10), (B, S, N)) * 0.3
    y, st = ssm_mod.ssd_chunked(x, dt, a, b_in, c_in, chunk)

    state = np.zeros((B, H, P, N))
    ys = np.zeros((B, S, H, P))
    for t in range(S):
        da = np.exp(np.asarray(dt[:, t]) * np.asarray(a))  # [B,H]
        xdt = np.asarray(x[:, t]) * np.asarray(dt[:, t])[..., None]  # [B,H,P]
        state = state * da[..., None, None] + np.einsum(
            "bhp,bn->bhpn", xdt, np.asarray(b_in[:, t]))
        ys[:, t] = np.einsum("bn,bhpn->bhp", np.asarray(c_in[:, t]), state)
    np.testing.assert_allclose(np.asarray(y), ys, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(st), state, atol=2e-3, rtol=2e-3)


def test_tp_padding_preserves_function():
    """tp=4 padded/replicated weights give the same function as tp=1 for a
    divisible-head config (kv replication is exact)."""
    cfg = get_reduced_config("qwen3-0.6b")  # 4 heads, kv 2
    key = jax.random.PRNGKey(11)
    p1 = M.init_params(cfg, key, tp=1)
    p4 = M.init_params(cfg, key, tp=4)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    l1 = M.forward(cfg, p1, {"tokens": toks})
    l4 = M.forward(cfg, p4, {"tokens": toks}, tp=4)
    assert float(jnp.abs(l1 - l4).max()) < 5e-2
