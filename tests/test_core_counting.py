"""Two-stage access counting (paper §III-B): unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import counting


def test_stage1_matches_bincount(rng):
    nsp = 64
    sp = rng.integers(-1, nsp, 500).astype(np.int32)
    wr = rng.random(500) < 0.3
    st1 = counting.stage1_record(counting.stage1_init(nsp), jnp.asarray(sp), jnp.asarray(wr), 2)
    got = counting.counter_value(st1.counts)
    want = np.zeros(nsp, np.int64)
    for s, w in zip(sp, wr):
        if s >= 0:
            want[s] += 2 if w else 1
    np.testing.assert_array_equal(np.asarray(got), want)


def test_counter_saturates_and_overflows():
    st1 = counting.stage1_init(2)
    ids = jnp.zeros(1000, jnp.int32)
    wr = jnp.ones(1000, bool)
    for _ in range(40):  # 40*2000 >> 2^15
        st1 = counting.stage1_record(st1, ids, wr, 2)
    val = counting.counter_value(st1.counts)
    assert int(val[0]) == counting.COUNTER_MAX + 1  # overflow => definitely hot
    assert int(val[1]) == 0


def test_select_top_n_and_padding():
    st1 = counting.stage1_init(5)
    st1 = counting.stage1_record(
        st1, jnp.array([0, 0, 0, 3, 3, 4], jnp.int32), jnp.zeros(6, bool), 2
    )
    psn, vals = counting.select_top_n(st1, 8)
    assert psn.shape == (8,)
    assert int(psn[0]) == 0 and int(psn[1]) == 3
    assert set(np.asarray(psn[vals == 0]).tolist()) <= {-1}


def test_stage2_counts_only_monitored(rng):
    nsp, pages, topn = 16, 8, 3
    mon = jnp.array([2, 9, 14], jnp.int32)
    st2 = counting.stage2_begin(mon, pages)
    sp = rng.integers(0, nsp, 400).astype(np.int32)
    pg = rng.integers(0, pages, 400).astype(np.int32)
    st2 = counting.stage2_record(st2, jnp.asarray(sp), jnp.asarray(pg), jnp.zeros(400, bool), 1)
    got = np.asarray(counting.counter_value(st2.counts))
    want = np.zeros((topn, pages), np.int64)
    for s, p in zip(sp, pg):
        for row, m in enumerate([2, 9, 14]):
            if s == m:
                want[row, p] += 1
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(
    st.lists(st.tuples(st.integers(-1, 15), st.integers(0, 7), st.booleans()),
             min_size=1, max_size=120),
    st.integers(1, 8),
)
def test_property_counts_conserved(accesses, topn):
    """Sum of stage-1 counter values == weighted number of valid accesses."""
    sp = jnp.array([a[0] for a in accesses], jnp.int32)
    pg = jnp.array([a[1] for a in accesses], jnp.int32)
    wr = jnp.array([a[2] for a in accesses], bool)
    st1 = counting.stage1_record(counting.stage1_init(16), sp, wr, 2)
    total = int(counting.counter_value(st1.counts).sum())
    want = sum((2 if w else 1) for s, _, w in accesses if s >= 0)
    assert total == want


def test_storage_overhead_matches_table6():
    # paper Table VI: 1 TB PCM -> 1 MB stage-1 counters, N KB stage-2, 4N PSN
    o = counting.storage_overhead_bytes(512 * 1024, 100, 512)
    assert o["stage1_counters"] == 1024 * 1024
    assert o["stage2_counters"] == 100 * 1024
    assert o["stage2_psn_tags"] == 400
