"""Additional Layer-A coverage: mixes, sensitivity direction, trace calibration."""
import numpy as np
import pytest

from repro.sim.config import APPS, MIXES, MachineConfig, PAGES_PER_SP
from repro.sim.runner import simulate
from repro.sim.trace import generate


def test_mix_trace_combines_address_spaces():
    tr = generate("mix2", seed=3, interval=0, accesses=8000)
    members = MIXES["mix2"]
    assert tr.sp.shape[0] == 8000 - 8000 % len(members)
    # superpage ids must span multiple member regions
    assert tr.num_superpages > max(
        generate(m, 3, 0, 100).num_superpages for m in members
    )


def test_trace_hot_set_persists_across_intervals():
    """History-based migration only works if hot pages persist (paper premise)."""
    t0 = generate("soplex", seed=5, interval=1, accesses=20000)
    t1 = generate("soplex", seed=5, interval=2, accesses=20000)

    def hot_set(tr, k=50):
        counts = np.bincount(tr.vpn.astype(np.int64), minlength=tr.footprint_pages)
        return set(np.argsort(-counts)[:k].tolist())

    overlap = len(hot_set(t0) & hot_set(t1)) / 50.0
    # zipf sampling noise jitters the top-k boundary; >30% overlap of the
    # traffic-weighted head is what history-based migration needs
    assert overlap > 0.3, f"hot-set overlap too low: {overlap}"


def test_trace_respects_footprint_bounds():
    for app in ("GUPS", "bodytrack"):
        tr = generate(app, seed=1, interval=0, accesses=5000)
        assert tr.vpn.max() < tr.footprint_pages
        assert (tr.page >= 0).all() and (tr.page < PAGES_PER_SP).all()


def test_mix_runs_through_rainbow_policy():
    m = simulate("mix1", "rainbow", intervals=2, accesses=16000)
    assert m.ipc > 0 and np.isfinite(m.mpki)


def test_higher_threshold_migrates_less():
    """§IV-F: raising the hot-page threshold reduces migrations (and IPC)."""
    lo = simulate("streamcluster", "rainbow",
                  mc=MachineConfig(mig_threshold=0.0), intervals=4, accesses=25000)
    hi = simulate("streamcluster", "rainbow",
                  mc=MachineConfig(mig_threshold=5e4), intervals=4, accesses=25000)
    assert hi.migrations < lo.migrations


def test_slower_nvm_migrates_more():
    """§IV-F: larger NVM latencies raise Eq.1 benefit -> more pages migrate."""
    base = MachineConfig()
    slow = MachineConfig(t_nr=base.t_nr * 2, t_nw=base.t_nw * 2)
    m_base = simulate("soplex", "rainbow", mc=base, intervals=4, accesses=25000)
    m_slow = simulate("soplex", "rainbow", mc=slow, intervals=4, accesses=25000)
    assert m_slow.migrations >= m_base.migrations
