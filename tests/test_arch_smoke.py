"""Per-architecture smoke tests (deliverable f): reduced config, one forward +
one train step on CPU, asserting output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced_config
from repro.models import model as M
from repro.train.step import TrainStepConfig, build_train_step, init_train_state


def _batch(cfg, key, b=2, s=32):
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "targets": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "loss_mask": jnp.ones((b, s), jnp.float32),
    }
    if cfg.family == "vlm":
        nv = cfg.num_vision_tokens
        for k in ("tokens", "targets", "loss_mask"):
            batch[k] = batch[k][:, : s - nv]
        batch["vision_embeds"] = jax.random.normal(key, (b, nv, cfg.d_model))
    if cfg.is_encoder_decoder:
        se = s // cfg.encoder_seq_divisor
        for k in ("tokens", "targets", "loss_mask"):
            batch[k] = batch[k][:, : s - se]
        batch["frames"] = jax.random.normal(key, (b, se, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_reduced_config(arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key, tp=1)
    batch = _batch(cfg, key)
    logits = M.forward(cfg, params, batch)
    assert logits.shape[:2] == batch["targets"].shape
    assert logits.shape[-1] == cfg.padded_vocab
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_reduced_config(arch)
    key = jax.random.PRNGKey(1)
    tcfg = TrainStepConfig(tp=1, remat="none")
    state = init_train_state(cfg, key, tcfg)
    step = jax.jit(build_train_step(cfg, tcfg))
    batch = _batch(cfg, key)
    state2, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    before = jax.tree.leaves(state["params"])[0]
    after = jax.tree.leaves(state2["params"])[0]
    assert not jnp.array_equal(before, after)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_construction(arch):
    """Full (unreduced) configs are valid and sized right (no allocation)."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "qwen3-4b": (3.5e9, 5.5e9),
        "qwen3-0.6b": (0.5e9, 0.9e9),
        "smollm-360m": (0.25e9, 0.50e9),
        "granite-8b": (7e9, 9e9),
        "deepseek-moe-16b": (14e9, 19e9),
        "qwen2-moe-a2.7b": (13e9, 16e9),
        "whisper-medium": (0.6e9, 1.0e9),
        "hymba-1.5b": (1.2e9, 2.2e9),
        "internvl2-2b": (1.7e9, 2.6e9),
        "mamba2-1.3b": (1.0e9, 1.8e9),
    }[arch]
    assert expected[0] <= n <= expected[1], f"{arch}: {n:.3e}"
    # padded heads divide cleanly under tp=16 (the production mesh)
    if not cfg.attn_free:
        assert cfg.padded_heads(16) % 16 == 0
        assert cfg.padded_heads(16) % cfg.kv_store(16) == 0
    assert cfg.padded_vocab % 256 == 0 or cfg.vocab_pad_multiple != 256
