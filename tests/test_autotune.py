"""engine.autotune: TunePlan search, engine-in-the-loop replay, path parity."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.engine.autotune import MassTrace, TunePlan, autotune, evaluate
from repro.engine.policy import ControlPolicy


def _synthetic_trace(T=24, B=2, nblk=8, block_size=4):
    """A stationary hot set (blocks 1 and 3) + light background traffic —
    the shape that rewards early promotion over the do-nothing default."""
    mass = np.zeros((T, B, nblk), np.float32)
    mass[:, :, 1] = 0.5
    mass[:, :, 3] = 0.3
    mass[:, :, 5] = 0.05
    return MassTrace(mass=mass, block_size=block_size,
                     start_length=block_size * nblk)


def _base():
    return ControlPolicy(interval_steps=8, top_n=2, max_promotions=2,
                         hot_slots=4)


def test_tune_plan_candidates_and_validation():
    plan = TunePlan.grid(_base(), interval_steps=(2, 8),
                         threshold_init=(0.0, 64.0))
    cands = plan.candidates()
    assert len(cands) == 4
    assert {c.interval_steps for c in cands} == {2, 8}
    assert all(c.top_n == 2 for c in cands)  # base fields ride along
    with pytest.raises(ValueError, match="unknown ControlPolicy fields"):
        TunePlan.grid(_base(), block_size=(4, 8))
    with pytest.raises(ValueError, match="interval_steps must be >= 1"):
        TunePlan.grid(_base(), interval_steps=(0,)).candidates()
    assert TunePlan.grid(_base()).candidates() == (_base(),)


def test_mass_trace_prefix():
    tr = _synthetic_trace(T=24)
    assert tr.steps == 24 and tr.batch == 2 and tr.blocks_per_seq == 8
    assert tr.prefix(6).steps == 6
    assert tr.prefix(6).start_length == tr.start_length


def test_replay_promotes_and_prices_the_hot_set():
    """Engine-in-the-loop: the replay runs the REAL controller (promotions
    happen), and promoted mass gets re-priced from t_nr to t_dr."""
    tr = _synthetic_trace()
    [row] = evaluate(tr, [_base().replace(interval_steps=2)])
    assert row["promotions"] > 0
    # an impossible admission threshold keeps everything in the slow tier
    [frozen] = evaluate(tr, [_base().replace(interval_steps=2,
                                             threshold_init=1e9)])
    assert frozen["promotions"] == 0
    assert row["cost_per_step"] < frozen["cost_per_step"]


def test_autotune_beats_default_and_is_deterministic():
    tr = _synthetic_trace()
    plan = TunePlan.grid(_base(), interval_steps=(2, 8))
    res = autotune(plan, tr)
    assert res.improved, res.summary()
    assert res.tuned_policy().interval_steps == 2
    assert res.baseline == _base()
    # same inputs -> same winner, same table
    res2 = autotune(plan, tr)
    assert res2.best == res.best and res2.best_cost == res.best_cost
    # rungs recorded for every evaluated candidate
    assert {r["rung"] for r in res.table} == {0, 1}
    assert "tuned" in res.summary()


def test_vmap_and_sharded_paths_bit_identical_in_process():
    tr = _synthetic_trace()
    plan = TunePlan.grid(_base(), interval_steps=(2, 4, 8),
                         threshold_init=(0.0, 128.0))
    cands = plan.candidates()
    assert evaluate(tr, cands, runner="vmap") == evaluate(
        tr, cands, runner="sharded")
    with pytest.raises(ValueError, match="unknown runner"):
        evaluate(tr, cands, runner="pmap")


def test_candidates_validate_against_trace_geometry():
    tr = _synthetic_trace(nblk=8)
    with pytest.raises(ValueError, match="top_n .* blocks_per_seq"):
        evaluate(tr, [_base().replace(top_n=16, max_promotions=1)])


def test_sharded_autotune_bit_identical_on_4_devices():
    """4 forced host devices: the shard_mapped replay (padding included —
    6 candidates pad to 8) picks the identical winner at identical cost."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax
        import numpy as np
        from repro.engine.autotune import MassTrace, TunePlan, autotune, evaluate
        from repro.engine.policy import ControlPolicy

        assert len(jax.devices()) == 4
        mass = np.zeros((24, 2, 8), np.float32)
        mass[:, :, 1] = 0.5; mass[:, :, 3] = 0.3; mass[:, :, 5] = 0.05
        tr = MassTrace(mass=mass, block_size=4, start_length=32)
        base = ControlPolicy(interval_steps=8, top_n=2, max_promotions=2,
                             hot_slots=4)
        plan = TunePlan.grid(base, interval_steps=(2, 4, 8),
                             threshold_init=(0.0, 128.0))
        cands = plan.candidates()
        assert len(cands) == 6  # NOT divisible by 4: exercises padding
        rows_v = evaluate(tr, cands, runner="vmap")
        rows_s = evaluate(tr, cands, runner="sharded")
        assert rows_v == rows_s, (rows_v, rows_s)
        a = autotune(plan, tr, runner="vmap")
        b = autotune(plan, tr, runner="sharded")
        assert a.best == b.best and a.best_cost == b.best_cost
        assert a.improved
        print("AUTOTUNE_SHARDED_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert "AUTOTUNE_SHARDED_OK" in out.stdout, out.stderr[-2000:]


def test_record_mass_trace_feeds_autotune():
    """The serving recorder -> autotuner loop on a real reduced model."""
    import jax

    from repro.configs import get_reduced_config
    from repro.memory.kvcache import PagedConfig
    from repro.models import model as M
    from repro.serving.rainbow_decode import record_mass_trace

    cfg = get_reduced_config("qwen3-4b")
    key = jax.random.PRNGKey(0)
    B, S = 2, 16
    pcfg = PagedConfig(block_size=4, blocks_per_seq=S // 4, hot_slots=4,
                       top_n=4, max_promotions=4, interval_steps=8)
    params = M.init_params(cfg, key, tp=1)
    prompt = jax.random.randint(key, (B, 8), 0, cfg.vocab_size)
    trace, kv = record_mass_trace(cfg, pcfg, params, prompt, steps=S)
    assert trace.mass.shape == (S, B, S // 4)
    assert float(trace.mass.sum()) > 0
    assert int(kv.length) == S

    res = autotune(
        TunePlan.grid(pcfg.policy, interval_steps=(2, 8)), trace)
    assert res.improved, res.summary()
    # the tuned policy drops straight back into the serving config
    tuned = PagedConfig(block_size=4, blocks_per_seq=S // 4,
                        policy=res.tuned_policy())
    assert tuned.interval_steps == res.best.interval_steps

    with pytest.raises(ValueError, match="must cover the prompt"):
        record_mass_trace(cfg, pcfg, params, prompt, steps=4)
