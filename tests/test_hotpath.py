"""PR 7 hot-path overhaul: vectorized ops pinned bit-identical to references.

Every rewrite on the interval hot path keeps its pre-overhaul form alive as
the differential anchor — `first_k_valid_ref` (stable argsort), the per-vpn
`split_tlb_invalidate` scan, the serial `make_access_step` walk compiled
under EngineSpec.fastpath=False, and an argsort re-statement of
`plan_migrations`'s top_k selection. These tests pin each pair bit-identical
across random inputs and the edge floors that broke naive rewrites
(all-valid, all-invalid, k > n-valid, duplicate scores), plus the profiled
host-driven run against the scanned run.

Property tests use hypothesis when available (pytest.importorskip — the
pinned environment may not ship it); the deterministic sweeps below cover
the same edge floors regardless.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tlb as tlb_mod
from repro.sim import tlbsim
from repro.sim.config import MachineConfig
from repro.utils.select import first_k_valid, first_k_valid_ref


def _assert_tree_equal(a, b, msg=""):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), msg
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=msg)


# ---------------------------------------------------------------------------
# first_k_valid: masked-cumsum scatter vs stable argsort reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", [1, 4, 256])
@pytest.mark.parametrize(
    "case", ["all-valid", "all-invalid", "sparse", "k-exceeds-valid", "dups"]
)
def test_first_k_valid_edge_floors(k, case):
    rng = np.random.RandomState(k * 31 + len(case))
    n = 97
    values = rng.randint(0, 50, n).astype(np.int32)  # duplicates guaranteed
    if case == "all-valid":
        valid = np.ones(n, bool)
    elif case == "all-invalid":
        valid = np.zeros(n, bool)
    elif case == "k-exceeds-valid":
        valid = np.zeros(n, bool)
        valid[rng.choice(n, min(3, max(k - 1, 1)), replace=False)] = True
    elif case == "dups":
        values = np.full(n, 7, np.int32)
        valid = rng.rand(n) < 0.5
    else:
        valid = rng.rand(n) < 0.3
    got = first_k_valid(jnp.asarray(values), jnp.asarray(valid), k)
    ref = first_k_valid_ref(jnp.asarray(values), jnp.asarray(valid), k)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert got.dtype == ref.dtype == jnp.int32
    assert got.shape == (k,)


def test_first_k_valid_random_sweep():
    rng = np.random.RandomState(0)
    for _ in range(50):
        n = rng.randint(1, 300)
        k = rng.randint(1, 300)
        values = rng.randint(-5, 40, n).astype(np.int32)
        valid = rng.rand(n) < rng.rand()
        got = first_k_valid(jnp.asarray(values), jnp.asarray(valid), k)
        ref = first_k_valid_ref(jnp.asarray(values), jnp.asarray(valid), k)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_first_k_valid_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hypothesis.settings(deadline=None, max_examples=80)
    @hypothesis.given(
        values=st.lists(st.integers(0, 31), min_size=1, max_size=64),
        seed=st.integers(0, 2**16),
        density=st.sampled_from([0.0, 0.2, 0.8, 1.0]),
        k=st.integers(1, 96),
    )
    def check(values, seed, density, k):
        rng = np.random.RandomState(seed)
        vals = np.asarray(values, np.int32)
        valid = rng.rand(len(values)) < density
        got = first_k_valid(jnp.asarray(vals), jnp.asarray(valid), k)
        ref = first_k_valid_ref(jnp.asarray(vals), jnp.asarray(valid), k)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    check()


# ---------------------------------------------------------------------------
# split_tlb_invalidate_many vs the per-vpn sequential shootdown
# ---------------------------------------------------------------------------


def _random_split_tlb(rng, mc):
    st = tlb_mod.split_tlb_init(
        mc.l1_tlb_entries, mc.l1_tlb_ways, mc.l2_tlb_entries, mc.l2_tlb_ways
    )

    def fill(t):
        tags = rng.randint(-1, 64, t.tags.shape).astype(np.int32)
        lru = rng.randint(0, 1000, t.lru.shape).astype(np.int32)
        return tlb_mod.TLBState(
            tags=jnp.asarray(tags), lru=jnp.asarray(lru),
            sets=t.sets, ways=t.ways,
        )

    return tlb_mod.SplitTLB(l1=fill(st.l1), l2=fill(st.l2))


@pytest.mark.parametrize("case", ["random", "dups", "all-pad", "absent"])
def test_invalidate_many_matches_sequential(case):
    mc = MachineConfig()
    rng = np.random.RandomState(hash(case) % 2**31)
    st = _random_split_tlb(rng, mc)
    if case == "dups":
        vpns = np.asarray([3, 3, 3, 7, 7, -1, 3], np.int32)
    elif case == "all-pad":
        vpns = np.full(8, -1, np.int32)
    elif case == "absent":
        vpns = np.asarray([1000, 2000, -1], np.int32)  # no tag matches
    else:
        vpns = np.concatenate(
            [rng.randint(0, 64, 20), np.full(4, -1)]
        ).astype(np.int32)

    got = tlb_mod.split_tlb_invalidate_many(st, jnp.asarray(vpns))
    ref = st
    for v in vpns:
        ref = tlb_mod.split_tlb_invalidate(ref, jnp.asarray(v))
    _assert_tree_equal(got, ref, msg=case)
    # lru is untouched by design (shootdown only clears tags)
    np.testing.assert_array_equal(np.asarray(got.l1.lru), np.asarray(st.l1.lru))


def test_invalidate_many_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    st_mod = pytest.importorskip("hypothesis.strategies")
    mc = MachineConfig()

    @hypothesis.settings(deadline=None, max_examples=40)
    @hypothesis.given(
        seed=st_mod.integers(0, 2**16),
        vpns=st_mod.lists(st_mod.integers(-1, 63), min_size=1, max_size=24),
    )
    def check(seed, vpns):
        rng = np.random.RandomState(seed)
        st = _random_split_tlb(rng, mc)
        v = jnp.asarray(np.asarray(vpns, np.int32))
        got = tlb_mod.split_tlb_invalidate_many(st, v)
        ref = st
        for x in vpns:
            ref = tlb_mod.split_tlb_invalidate(ref, jnp.asarray(x, jnp.int32))
        _assert_tree_equal(got, ref)

    check()


# ---------------------------------------------------------------------------
# Vectorized interval runner vs the serial per-access reference scan
# ---------------------------------------------------------------------------


def _random_interval(rng, n, num_sp=40):
    sp = rng.randint(0, num_sp, n).astype(np.int32)
    page = rng.randint(0, 512, n).astype(np.int32)
    vpn = sp * 512 + page
    in_dram = rng.rand(n) < 0.6
    is_write = rng.rand(n) < 0.3
    return (jnp.asarray(vpn), jnp.asarray(sp), jnp.asarray(in_dram),
            jnp.asarray(is_write))


@pytest.mark.parametrize("kind", ["flat4k", "sp2m", "rainbow"])
def test_interval_runner_fast_matches_reference(kind):
    """run_interval_fast == run_interval, cold AND warm-continuation."""
    mc = MachineConfig()
    rng = np.random.RandomState(17)
    ref = tlbsim.init_state(mc)
    fast = tlbsim.init_state(mc)
    for _ in range(2):  # second interval starts from warm TLB/counter state
        vpn, sp, in_dram, is_write = _random_interval(rng, 3000)
        ref = tlbsim.run_interval(kind, mc, ref, vpn, sp, in_dram, is_write)
        fast = tlbsim.run_interval_fast(
            kind, mc, fast, vpn, sp, in_dram, is_write
        )
        _assert_tree_equal(fast, ref, msg=kind)


def test_engine_fastpath_matches_reference_spec():
    """Whole-engine differential: fastpath=True vs the fastpath=False program
    (serial walks, argsort selection, per-vpn shootdowns, f32 histograms)."""
    from repro.sim.runner import simulate

    kw = dict(intervals=3, accesses=4000, seed=11)
    for policy in ["rainbow", "hscc-4kb-mig"]:
        a = simulate("streamcluster", policy, fastpath=True, **kw)
        b = simulate("streamcluster", policy, fastpath=False, **kw)
        assert dataclasses.asdict(a) == dataclasses.asdict(b), policy


# ---------------------------------------------------------------------------
# plan_migrations: top_k selection vs the former argsort statement
# ---------------------------------------------------------------------------


def test_plan_migrations_topk_matches_argsort():
    from repro.core import migration as mig

    def plan_argsort(cand_sp, cand_page, cand_r, cand_w, dram, timing, thr):
        """The pre-overhaul selection, restated: stable full argsorts."""
        k = cand_sp.shape[0]
        base = mig.migration_benefit(cand_r, cand_w, timing)
        base = jnp.where(cand_sp >= 0, base, -jnp.inf)
        cand_order = jnp.argsort(-base, stable=True)
        prio = dram.slot_state.astype(jnp.float32) * 1e9 + dram.last_touch.astype(
            jnp.float32
        )
        take = min(k, dram.slot_state.shape[0])
        vslots = jnp.argsort(prio, stable=True)[:take].astype(jnp.int32)
        return cand_order, base[cand_order], vslots

    mc_timing = mig.preset_timing("paper-table4-sim")
    rng = np.random.RandomState(5)
    for _ in range(50):
        k, n_slots = rng.randint(1, 64), rng.randint(1, 96)
        # duplicate-heavy counts so benefit ties are common
        cand_sp = jnp.asarray(
            np.where(rng.rand(k) < 0.2, -1, rng.randint(0, 8, k)), jnp.int32
        )
        cand_page = jnp.asarray(rng.randint(0, 512, k), jnp.int32)
        cand_r = jnp.asarray(rng.randint(0, 4, k), jnp.float32)
        cand_w = jnp.asarray(rng.randint(0, 3, k), jnp.float32)
        dram = mig.DramState(
            slot_state=jnp.asarray(rng.randint(0, 3, n_slots), jnp.int32),
            slot_sp=jnp.asarray(rng.randint(-1, 8, n_slots), jnp.int32),
            slot_page=jnp.asarray(rng.randint(0, 512, n_slots), jnp.int32),
            slot_reads=jnp.asarray(rng.randint(0, 4, n_slots), jnp.float32),
            slot_writes=jnp.asarray(rng.randint(0, 3, n_slots), jnp.float32),
            last_touch=jnp.asarray(rng.randint(0, 5, n_slots), jnp.int32),
        )
        thr = jnp.float32(rng.rand() * 100)

        base = mig.migration_benefit(cand_r, cand_w, mc_timing)
        base = jnp.where(cand_sp >= 0, base, -jnp.inf)
        ref_order, ref_sorted, ref_vslots = plan_argsort(
            cand_sp, cand_page, cand_r, cand_w, dram, mc_timing, thr
        )
        got_sorted, got_order = jax.lax.top_k(base, int(base.shape[0]))
        np.testing.assert_array_equal(np.asarray(got_order), np.asarray(ref_order))
        np.testing.assert_array_equal(
            np.asarray(got_sorted), np.asarray(ref_sorted)
        )
        prio = dram.slot_state.astype(jnp.float32) * 1e9 \
            + dram.last_touch.astype(jnp.float32)
        take = min(int(cand_sp.shape[0]), n_slots)
        _, got_vslots = jax.lax.top_k(-prio, take)
        np.testing.assert_array_equal(
            np.asarray(got_vslots.astype(jnp.int32)), np.asarray(ref_vslots)
        )
        # and the full planner is self-consistent on these inputs
        plan = mig.plan_migrations(
            cand_sp, cand_page, cand_r, cand_w, dram, mc_timing, thr
        )
        assert bool(jnp.all(plan.dst_slot[plan.migrate] >= 0))


# ---------------------------------------------------------------------------
# Histograms: int32 scatter-add fast path vs f32 reference
# ---------------------------------------------------------------------------


def test_histograms_int_path_exact():
    from repro.engine import simloop

    rng = np.random.RandomState(3)
    idx = jnp.asarray(rng.randint(0, 50, 20_000), jnp.int32)
    wr = jnp.asarray(rng.rand(20_000) < 0.4)
    r_fast, w_fast = simloop._histograms(idx, wr, 50, fastpath=True)
    r_ref, w_ref = simloop._histograms(idx, wr, 50, fastpath=False)
    np.testing.assert_array_equal(np.asarray(r_fast), np.asarray(r_ref))
    np.testing.assert_array_equal(np.asarray(w_fast), np.asarray(w_ref))
    assert r_fast.dtype == jnp.float32


# ---------------------------------------------------------------------------
# Profiled host-driven run == scanned engine_run
# ---------------------------------------------------------------------------


def test_profiled_run_bit_identical_to_scan():
    from repro.engine import simloop

    mc = MachineConfig()
    chunks, meta = simloop.make_chunks("streamcluster", "rainbow", mc, 5, 3, 3000)
    spec = simloop.EngineSpec(
        policy="rainbow", mc=mc,
        num_superpages=meta["num_superpages"],
        footprint_pages=meta["footprint_pages"],
    )
    s1, st1 = simloop.engine_run(spec, simloop.engine_init(spec), chunks)
    s2, st2, prof = simloop.engine_run(
        spec, simloop.engine_init(spec), chunks, profile=True
    )
    _assert_tree_equal(s1, s2)
    _assert_tree_equal(st1, st2)
    assert set(prof.phases) == {"tlb", "observe", "plan", "apply"}
    assert prof.intervals == 3
    # each phase compiled once and then executed intervals-1 timed calls
    assert all(p.calls == 2 for p in prof.phases.values())
    assert all(p.compile_s > 0 for p in prof.phases.values())


def test_donated_run_matches_default():
    from repro.engine import simloop

    mc = MachineConfig()
    chunks, meta = simloop.make_chunks("streamcluster", "rainbow", mc, 9, 2, 2000)
    spec = simloop.EngineSpec(
        policy="rainbow", mc=mc,
        num_superpages=meta["num_superpages"],
        footprint_pages=meta["footprint_pages"],
    )
    s1, st1 = simloop.engine_run(spec, simloop.engine_init(spec), chunks)
    s2, st2 = simloop.engine_run(
        spec, simloop.engine_init(spec), chunks, donate=True
    )
    _assert_tree_equal(s1, s2)
    _assert_tree_equal(st1, st2)


@pytest.mark.parametrize("policy", ["rainbow", "nomad"])
def test_donate_profile_queueing_bit_identical(policy):
    """donate=True x profile=True x timing_model="queueing" vs the default.

    Each pairwise interaction was pinned separately; this pins the triple —
    the queue carry must survive buffer donation, and the profiled
    host-driven run (which recomputes residency for the queue phase from
    PRE-interval state) must stay bitwise on the queueing path too.
    profile=True takes precedence over donate=True by contract, so the
    combined call exercises the profiled path with a donation request.
    """
    from repro.engine import simloop
    from repro.timing import get_geometry

    mc = MachineConfig()
    chunks, meta = simloop.make_chunks("streamcluster", policy, mc, 5, 3, 3000)
    spec = simloop.EngineSpec(
        policy=policy, mc=mc,
        num_superpages=meta["num_superpages"],
        footprint_pages=meta["footprint_pages"],
        timing_model="queueing",
        queue_geometry=get_geometry("constrained"),
    )
    s1, st1 = simloop.engine_run(spec, simloop.engine_init(spec), chunks)
    s2, st2 = simloop.engine_run(
        spec, simloop.engine_init(spec), chunks, donate=True
    )
    s3, st3, prof = simloop.engine_run(
        spec, simloop.engine_init(spec), chunks, donate=True, profile=True
    )
    _assert_tree_equal(s1, s2, msg=f"{policy}: donated != default")
    _assert_tree_equal(st1, st2, msg=f"{policy}: donated != default")
    _assert_tree_equal(s1, s3, msg=f"{policy}: profiled != default")
    _assert_tree_equal(st1, st3, msg=f"{policy}: profiled != default")
    assert {"tlb", "observe", "plan", "apply", "queue"} == set(prof.phases)
    assert np.asarray(st1.mig_stall).sum() > 0.0  # the pin is non-vacuous


def test_mig_stall_exact_zero_without_migration_traffic():
    """mig_stall is EXACTLY 0.0 whenever no migration traffic was charged.

    The counterfactual demand-only chain aliases the real chain bitwise
    until the first bulk charge, so the difference must short-circuit to
    exact 0.0 — for the non-migrating presets on EVERY interval, and for the
    async family with async_window=1 ("nomad-sync": no pending installments
    can leak across intervals) on every interval BEFORE its first migration.
    After the first charge the chains legitimately diverge for good (the
    residual migration backlog keeps stalling later demand), so only the
    pre-traffic prefix is pinned. The trace concentrates all accesses on
    four read-only pages so the nomad run has a quiet warm-up interval
    before the one migration burst.
    """
    import jax.numpy as jnp

    from repro.engine import simloop
    from repro.engine.policy import get_policy
    from repro.timing import get_geometry

    mc = MachineConfig()
    intervals, accesses = 4, 2000
    sp = np.zeros((intervals, accesses), np.int32)
    page = np.tile(np.arange(accesses) % 4, (intervals, 1)).astype(np.int32)
    chunks = simloop.TraceChunks(
        sp=jnp.asarray(sp),
        page=jnp.asarray(page),
        vpn=jnp.asarray(sp * 512 + page),
        is_write=jnp.zeros((intervals, accesses), bool),
        in_dram=jnp.zeros((intervals, accesses), bool),
    )
    for policy, control in [
        ("flat-static", None),
        ("dram-only", None),
        ("nomad", get_policy("nomad-sync", mc=mc)),
    ]:
        spec = simloop.EngineSpec(
            policy=policy, mc=mc,
            num_superpages=8,
            footprint_pages=8 * 512,
            control=control,
            timing_model="queueing",
            queue_geometry=get_geometry("constrained"),
        )
        _, stats = simloop.engine_run(spec, simloop.engine_init(spec), chunks)
        moved = np.asarray(stats.migrations) + np.asarray(stats.evictions)
        mig_stall = np.asarray(stats.mig_stall)
        if policy == "nomad":
            assert moved.sum() > 0 and moved[0] == 0, moved
            prefix = int(np.argmax(moved > 0))  # intervals before traffic
            assert prefix >= 1
        else:
            assert (moved == 0).all(), (policy, moved)
            prefix = len(moved)
        assert (mig_stall[:prefix] == 0.0).all(), (policy, mig_stall)
        # contention itself is present — the zeros are not vacuous
        assert np.asarray(stats.stall_dram).sum() > 0.0 \
            or np.asarray(stats.stall_nvm).sum() > 0.0, policy
