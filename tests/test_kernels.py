"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.block_gather.ops import migrate_blocks
from repro.kernels.flash_attention.ops import attention
from repro.kernels.page_counter.ops import count_accesses
from repro.kernels.rainbow_attention.ops import paged_decode_attention


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hp,kvs,hd,block,nblk", [
    (1, 4, 4, 16, 4, 3),
    (2, 8, 4, 32, 8, 6),
    (3, 8, 2, 64, 16, 4),
])
def test_rainbow_attention_sweep(b, hp, kvs, hd, block, nblk, dtype):
    key = jax.random.PRNGKey(b * 7 + hp)
    npool = b * nblk + 4
    q = jax.random.normal(key, (b, hp, hd), dtype)
    pk = jax.random.normal(jax.random.PRNGKey(1), (npool, block, kvs, hd), dtype)
    pv = jax.random.normal(jax.random.PRNGKey(2), (npool, block, kvs, hd), dtype)
    vidx = jax.random.randint(jax.random.PRNGKey(3), (b, nblk), 0, npool)
    length = jnp.int32(nblk * block - 2)
    ref = paged_decode_attention(q, pk, pv, vidx, length, force="ref")
    ker = paged_decode_attention(q, pk, pv, vidx, length, force="interpret")
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(ker, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("a,nsp,pages,n", [(100, 16, 8, 4), (1000, 32, 16, 8),
                                           (517, 8, 32, 2)])
def test_page_counter_sweep(a, nsp, pages, n, rng):
    sp = jnp.asarray(rng.integers(-1, nsp, a).astype(np.int32))
    pg = jnp.asarray(rng.integers(0, pages, a).astype(np.int32))
    w = jnp.asarray(rng.integers(1, 4, a).astype(np.uint32))
    mon = jnp.asarray(
        np.concatenate([rng.choice(nsp, n - 1, replace=False), [-1]]).astype(np.int32)
    )
    s1r, s2r = count_accesses(sp, pg, w, mon, nsp, pages, force="ref")
    s1k, s2k = count_accesses(sp, pg, w, mon, nsp, pages, force="interpret")
    np.testing.assert_array_equal(np.asarray(s1r, np.int64), np.asarray(s1k, np.int64))
    np.testing.assert_array_equal(np.asarray(s2r, np.int64), np.asarray(s2k, np.int64))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("nb,hot,k", [(24, 6, 6), (8, 3, 5), (64, 16, 1)])
def test_block_gather_sweep(nb, hot, k, dtype, rng):
    cap = jax.random.normal(jax.random.PRNGKey(0), (nb, 4, 2, 8), dtype)
    hotp = jax.random.normal(jax.random.PRNGKey(1), (hot, 4, 2, 8), dtype)
    src = jnp.asarray(rng.integers(-1, nb, k).astype(np.int32))
    dst_pool = rng.choice(hot, min(k, hot), replace=False)
    dst = jnp.asarray(
        np.resize(dst_pool, k).astype(np.int32)
    )
    # ensure valid lanes have unique dst
    srcs = np.array(src)  # writable copy
    seen = set()
    for i in range(k):
        if srcs[i] >= 0 and int(dst[i]) in seen:
            srcs[i] = -1
        elif srcs[i] >= 0:
            seen.add(int(dst[i]))
    src = jnp.asarray(srcs)
    r = migrate_blocks(cap, hotp, src, dst, force="ref")
    kk = migrate_blocks(cap, hotp, src, dst, force="interpret")
    np.testing.assert_array_equal(np.asarray(r, np.float32), np.asarray(kk, np.float32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,hd,causal", [
    (1, 128, 2, 32, True),
    (2, 256, 4, 64, True),
    (1, 256, 1, 128, False),
])
def test_flash_attention_sweep(b, s, h, hd, causal, dtype):
    key = jax.random.PRNGKey(s + hd)
    q = jax.random.normal(key, (b, s, h, hd), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, hd), dtype)
    ref = attention(q, k, v, causal=causal, force="ref")
    ker = attention(q, k, v, causal=causal, force="interpret")
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(
        np.asarray(ker, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )
