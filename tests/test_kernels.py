"""Pallas kernels vs pure-jnp oracles: ONE parity matrix across backends.

The matrix is the ready gate for flipping the TPU default backend (ROADMAP):
every accelerated kernel with a ref oracle — the fused observe counter, the
two-stage counter, block_gather, and rainbow (paged decode) attention — is
checked through the same parametrized sweep of backend x dtype x odd shapes,
including the degenerate chunks the engine can legitimately produce
(zero-access intervals, single monitored row, single block, no valid
migration lanes). On CPU the kernel leg runs the Pallas interpreter; on a
real TPU the SAME matrix additionally runs compiled ("pallas"), so hardware
bring-up needs no new tests.

Integer kernels must match bit-for-bit (tol None); float kernels to
accumulation tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.block_gather.ops import migrate_blocks
from repro.kernels.flash_attention.ops import attention
from repro.kernels.page_counter.ops import count_accesses, observe_counts
from repro.kernels.rainbow_attention.ops import paged_decode_attention

PARITY_BACKENDS = (
    ("interpret", "pallas") if jax.default_backend() == "tpu"
    else ("interpret",)
)


# -- case builders: closure(force, rng) -> (ref_outs, kernel_outs, tol) ------


def _counting_inputs(rng, a, nsp, pages, n):
    sp = jnp.asarray(rng.integers(-1, nsp, a).astype(np.int32))
    pg = jnp.asarray(rng.integers(0, pages, a).astype(np.int32))
    wr = jnp.asarray(rng.random(a) < 0.3)
    mon = np.full(n, -1, np.int32)  # -1 holes: partially-filled monitor set
    mon[: max(n - 1, 1)] = rng.choice(nsp, max(n - 1, 1), replace=False)
    return sp, pg, wr, jnp.asarray(mon)


def _two_stage(a, nsp, pages, n):
    def run(force, rng):
        sp, pg, wr, mon = _counting_inputs(rng, a, nsp, pages, n)
        w = jnp.where(wr, 2, 1).astype(jnp.uint32)
        ref = count_accesses(sp, pg, w, mon, nsp, pages, force="ref")
        ker = count_accesses(sp, pg, w, mon, nsp, pages, force=force)
        return ref, ker, None

    return run


def _fused_observe(a, nsp, pages, n, write_weight):
    def run(force, rng):
        sp, pg, wr, mon = _counting_inputs(rng, a, nsp, pages, n)
        kw = dict(write_weight=write_weight)
        ref = observe_counts(sp, pg, wr, mon, nsp, pages, force="ref", **kw)
        ker = observe_counts(sp, pg, wr, mon, nsp, pages, force=force, **kw)
        return ref, ker, None

    return run


def _block_gather(nb, hot, k, dtype, all_invalid=False):
    def run(force, rng):
        cap = jax.random.normal(jax.random.PRNGKey(0), (nb, 4, 2, 8), dtype)
        hotp = jax.random.normal(jax.random.PRNGKey(1), (hot, 4, 2, 8), dtype)
        src = rng.integers(-1, nb, k).astype(np.int32)
        if all_invalid:
            src[:] = -1  # an interval that migrates nothing
        dst = np.resize(rng.choice(hot, min(k, hot), replace=False),
                        k).astype(np.int32)
        seen = set()  # valid lanes must target unique dst slots
        for i in range(k):
            if src[i] >= 0 and int(dst[i]) in seen:
                src[i] = -1
            elif src[i] >= 0:
                seen.add(int(dst[i]))
        src, dst = jnp.asarray(src), jnp.asarray(dst)
        ref = migrate_blocks(cap, hotp, src, dst, force="ref")
        ker = migrate_blocks(cap, hotp, src, dst, force=force)
        return (ref,), (ker,), None  # gather moves bits: exact in any dtype

    return run


def _rainbow_attention(b, hp, kvs, hd, block, nblk, dtype):
    def run(force, rng):
        npool = b * nblk + 4
        q = jax.random.normal(jax.random.PRNGKey(b * 7 + hp), (b, hp, hd), dtype)
        pk = jax.random.normal(jax.random.PRNGKey(1), (npool, block, kvs, hd), dtype)
        pv = jax.random.normal(jax.random.PRNGKey(2), (npool, block, kvs, hd), dtype)
        vidx = jax.random.randint(jax.random.PRNGKey(3), (b, nblk), 0, npool)
        length = jnp.int32(max(nblk * block - 2, 1))
        ref = paged_decode_attention(q, pk, pv, vidx, length, force="ref")
        ker = paged_decode_attention(q, pk, pv, vidx, length, force=force)
        return (ref,), (ker,), (2e-2 if dtype == jnp.bfloat16 else 2e-5)

    return run


def _dtype_tag(dtype):
    return "bf16" if dtype == jnp.bfloat16 else "f32"


PARITY_MATRIX = [
    # two-stage counter: baseline / odd lengths / single row / single sp
    pytest.param(_two_stage(100, 16, 8, 4), id="two_stage-100a"),
    pytest.param(_two_stage(517, 8, 32, 2), id="two_stage-517a"),
    pytest.param(_two_stage(1000, 32, 16, 8), id="two_stage-1000a"),
    pytest.param(_two_stage(0, 16, 8, 4), id="two_stage-zero_access"),
    pytest.param(_two_stage(129, 8, 8, 1), id="two_stage-single_row"),
    pytest.param(_two_stage(64, 1, 4, 1), id="two_stage-single_sp"),
    # fused observe counter (read/write split + write weighting)
    pytest.param(_fused_observe(300, 16, 8, 4, 3), id="fused_observe-300a"),
    pytest.param(_fused_observe(517, 8, 32, 2, 2), id="fused_observe-517a"),
    pytest.param(_fused_observe(0, 16, 8, 4, 2), id="fused_observe-zero_access"),
    pytest.param(_fused_observe(129, 8, 8, 1, 2), id="fused_observe-single_row"),
]
for dt in (jnp.float32, jnp.bfloat16):
    tag = _dtype_tag(dt)
    PARITY_MATRIX += [
        # block gather: baseline / overflow lanes / single lane / no lanes
        pytest.param(_block_gather(24, 6, 6, dt), id=f"block_gather-{tag}-24nb"),
        pytest.param(_block_gather(8, 3, 5, dt), id=f"block_gather-{tag}-8nb"),
        pytest.param(_block_gather(64, 16, 1, dt),
                     id=f"block_gather-{tag}-single_lane"),
        pytest.param(_block_gather(16, 4, 4, dt, all_invalid=True),
                     id=f"block_gather-{tag}-no_valid_lanes"),
        # rainbow paged decode attention: sweep + single-block edge
        pytest.param(_rainbow_attention(1, 4, 4, 16, 4, 3, dt),
                     id=f"rainbow_attn-{tag}-3blk"),
        pytest.param(_rainbow_attention(2, 8, 4, 32, 8, 6, dt),
                     id=f"rainbow_attn-{tag}-6blk"),
        pytest.param(_rainbow_attention(3, 8, 2, 64, 16, 4, dt),
                     id=f"rainbow_attn-{tag}-4blk"),
        pytest.param(_rainbow_attention(2, 4, 2, 32, 8, 1, dt),
                     id=f"rainbow_attn-{tag}-single_block"),
    ]


@pytest.mark.parametrize("backend", PARITY_BACKENDS)
@pytest.mark.parametrize("case", PARITY_MATRIX)
def test_kernel_parity_matrix(case, backend, rng):
    refs, kers, tol = case(backend, rng)
    for r, k in zip(refs, kers):
        if tol is None:  # float64 is exact for uint32 counts and bf16 blocks
            np.testing.assert_array_equal(
                np.asarray(k, np.float64), np.asarray(r, np.float64)
            )
        else:
            np.testing.assert_allclose(
                np.asarray(k, np.float32), np.asarray(r, np.float32),
                atol=tol, rtol=tol,
            )


# -- flash attention keeps its own sweep (no engine-facing ref-vs-default
#    dispatch to gate; tolerances are seq-length dependent) ------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,hd,causal", [
    (1, 128, 2, 32, True),
    (2, 256, 4, 64, True),
    (1, 256, 1, 128, False),
])
def test_flash_attention_sweep(b, s, h, hd, causal, dtype):
    key = jax.random.PRNGKey(s + hd)
    q = jax.random.normal(key, (b, s, h, hd), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, hd), dtype)
    ref = attention(q, k, v, causal=causal, force="ref")
    ker = attention(q, k, v, causal=causal, force="interpret")
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(
        np.asarray(ker, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )
