"""HLO-text collective parser + roofline terms (launch/hlo_analysis)."""
import jax
import jax.numpy as jnp

from repro.launch import hlo_analysis as H


def test_shape_bytes():
    assert H.shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert H.shape_bytes("bf16[2,3]") == 12
    assert H.shape_bytes("(f32[4], s8[8])") == 16 + 8
    assert H.shape_bytes("pred[]") == 1


def test_collective_bytes_from_synthetic_hlo():
    hlo = """
HloModule m
ENTRY e {
  %p0 = f32[1024,32]{1,0} parameter(0)
  %ar = f32[1024,32]{1,0} all-reduce(%p0), replica_groups={}
  %ag = f32[2048,32]{1,0} all-gather(%ar), dimensions={0}
  %x = f32[1024,32]{1,0} add(%p0, %ar)
}
"""
    stats = H.collective_bytes(hlo)
    assert stats.bytes_by_op["all-reduce"] == 1024 * 32 * 4
    assert stats.bytes_by_op["all-gather"] == 1024 * 32 * 4  # operand size
    assert stats.count_by_op["all-reduce"] == 1


def test_collective_bytes_on_real_compiled_module():
    """End-to-end: psum over a 1-device mesh still emits an all-reduce."""
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    def f(x):
        return jax.lax.with_sharding_constraint(
            x.sum(keepdims=True), NamedSharding(mesh, P())
        )

    with mesh:
        c = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    stats = H.collective_bytes(c.as_text())
    assert isinstance(stats.total_bytes, int)  # parser runs on real HLO


def test_roofline_terms_dominance():
    t = H.roofline_terms(197e12, 819e9 * 2, 0)  # 1 s compute, 2 s memory
    assert t["dominant"] == "memory_s"
    assert abs(t["roofline_fraction"] - 0.5) < 1e-6


def test_decode_bytes_global_sane():
    from repro.configs import get_config, get_shape

    cfg = get_config("qwen3-4b")
    shape = get_shape("decode_32k")
    b = H.decode_bytes_global(cfg, shape)
    # params (~8 GB) + KV sweep (~1.2 TB global at kv_store=16)
    assert 0.5e12 < b < 2.5e12
    # sliding-window arch reads far less
    hy = H.decode_bytes_global(get_config("hymba-1.5b"), get_shape("long_500k"))
    assert hy < b
