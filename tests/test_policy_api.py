"""The unified ControlPolicy surface: validation, registry, shims, timing dedupe.

Acceptance: sim.policies presets, memory.kvcache, and launch/serve.py all
construct their interval controller from the same registered ControlPolicy
objects; the old flat-knob configs keep working through deprecation shims.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.migration import TIMING_PRESETS, preset_timing
from repro.core.rainbow import RainbowConfig
from repro.engine.policy import (
    ControlPolicy,
    available_policies,
    get_policy,
    register_policy,
    resolve_policy,
    sim_policy_for,
)
from repro.memory.kvcache import PagedConfig, default_timing
from repro.sim.config import MachineConfig


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------


def test_control_policy_rejects_bad_knobs():
    with pytest.raises(ValueError, match="interval_steps must be >= 1"):
        ControlPolicy(interval_steps=0).validate()
    with pytest.raises(ValueError, match="top_n must be >= 1"):
        ControlPolicy(top_n=0).validate()
    with pytest.raises(ValueError, match="counter_decay"):
        ControlPolicy(counter_decay=1.0).validate()
    with pytest.raises(ValueError, match="counter_backend"):
        ControlPolicy(counter_backend="numpy").validate()
    # replace() validates too (the TunePlan candidate path)
    with pytest.raises(ValueError, match="max_promotions must be >= 1"):
        ControlPolicy().replace(max_promotions=0)


def test_paged_config_rejects_impossible_geometry():
    with pytest.raises(ValueError, match="top_n .* blocks_per_seq"):
        PagedConfig(blocks_per_seq=4, top_n=8)
    with pytest.raises(ValueError, match="max_promotions .* hot_slots"):
        PagedConfig(hot_slots=4, max_promotions=16)
    with pytest.raises(ValueError, match="interval_steps must be >= 1"):
        PagedConfig(interval_steps=0)
    with pytest.raises(ValueError, match="block_size"):
        PagedConfig(block_size=0)


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------


def test_paged_config_legacy_kwargs_compose_policy():
    pcfg = PagedConfig(block_size=4, blocks_per_seq=8, hot_slots=6, top_n=4,
                       max_promotions=4, interval_steps=2)
    assert pcfg.policy == ControlPolicy(
        interval_steps=2, top_n=4, max_promotions=4, hot_slots=6
    )
    # the flat read surface still works
    assert (pcfg.hot_slots, pcfg.top_n, pcfg.max_promotions,
            pcfg.interval_steps) == (6, 4, 4, 2)
    # dataclasses.replace with a legacy knob routes through the policy
    assert dataclasses.replace(pcfg, interval_steps=3).policy.interval_steps == 3
    # and with the new field
    p2 = dataclasses.replace(pcfg, policy=pcfg.policy.replace(top_n=8))
    assert p2.top_n == 8


def test_paged_config_accepts_policy_and_preset_name():
    pol = ControlPolicy(interval_steps=4, top_n=2, max_promotions=2, hot_slots=4)
    assert PagedConfig(block_size=2, blocks_per_seq=4, policy=pol).policy == pol
    byname = PagedConfig(policy="serving-default")
    assert byname.policy == get_policy("serving-default")
    # defaults unchanged vs the pre-redesign flat config
    d = PagedConfig()
    assert (d.block_size, d.blocks_per_seq, d.hot_slots, d.top_n,
            d.max_promotions, d.interval_steps, d.quantize) == (
        16, 512, 256, 16, 64, 8, False)


def test_rainbow_config_legacy_kwargs_and_properties():
    cfg = RainbowConfig(num_superpages=8, pages_per_sp=4, top_n=2, dram_slots=4)
    assert (cfg.top_n, cfg.dram_slots) == (2, 4)
    assert cfg.policy.hot_slots == 4
    # untouched legacy knobs keep their old defaults
    assert (cfg.write_weight, cfg.max_migrations_per_interval,
            cfg.counter_backend) == (2, 512, "jax")
    # configs stay hashable/static (jit static args, fleet group keys)
    assert hash(cfg) == hash(RainbowConfig(num_superpages=8, pages_per_sp=4,
                                           top_n=2, dram_slots=4))


def test_configs_are_pytree_static():
    pcfg = PagedConfig(block_size=2, blocks_per_seq=4, hot_slots=2, top_n=2,
                       max_promotions=2)
    leaves, treedef = jax.tree.flatten(pcfg)
    assert leaves == []  # all-static: policy+geometry ride in the treedef
    assert jax.tree.unflatten(treedef, leaves) == pcfg


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_presets_and_errors():
    names = available_policies()
    assert {"serving-default", "sim-rainbow", "hscc-4kb", "hscc-2mb"} <= set(names)
    with pytest.raises(KeyError, match="unknown policy preset"):
        get_policy("nope")
    with pytest.raises(ValueError, match="already registered"):
        register_policy("serving-default")(lambda **kw: ControlPolicy())
    assert resolve_policy(None, "serving-default") == ControlPolicy()
    assert resolve_policy("serving-default", "sim-rainbow") == ControlPolicy()


def test_one_policy_surface_across_layers():
    """sim.policies, engine.simloop, and memory.kvcache all derive their
    controller from the same registered ControlPolicy objects."""
    import repro.engine.simloop as simloop
    from repro.sim.policies import Rainbow
    from repro.sim.trace import generate

    mc = MachineConfig()
    want = get_policy("sim-rainbow", mc=mc)
    assert want.top_n == mc.top_n and want.hot_slots == mc.dram_pages
    assert want.threshold_init == mc.mig_threshold

    tr = generate("streamcluster", seed=0, interval=0, accesses=500)
    pol = Rainbow(mc, tr)
    assert pol.cfg.policy == want

    spec = simloop.EngineSpec(
        policy="rainbow", mc=mc,
        num_superpages=tr.num_superpages, footprint_pages=tr.footprint_pages,
    )
    assert spec.control_policy() == want
    assert simloop._rainbow_cfg(spec).policy == want
    # EngineSpec.control overrides win (the autotune / sweep hook)
    tuned = want.replace(top_n=7, threshold_init=5.0)
    spec2 = dataclasses.replace(spec, control=tuned)
    assert spec2.control_policy() == tuned
    # HSCC ports read their presets
    assert sim_policy_for("hscc-4kb-mig", mc).max_promotions == 512
    assert sim_policy_for("hscc-2mb-mig", mc).max_promotions == 64
    assert sim_policy_for("hscc-2mb-mig", mc).hot_slots == mc.dram_superpages


def test_sweep_grid_accepts_policy_override():
    from repro.engine import fleet

    tuned = get_policy("sim-rainbow").replace(top_n=12)
    plan = fleet.SweepPlan.grid(["streamcluster"], ["rainbow"], (0,),
                                policy=tuned, intervals=2, accesses=1000)
    (cell,) = plan.cells
    assert cell.control == tuned
    (group,) = fleet.plan_groups(plan)
    assert group.spec.control == tuned
    assert group.spec.control_policy().top_n == 12
    # a preset name resolves through the registry too
    plan2 = fleet.SweepPlan.grid(["streamcluster"], ["rainbow"], (0,),
                                 policy="sim-rainbow", intervals=2,
                                 accesses=1000)
    assert plan2.cells[0].control == get_policy(
        "sim-rainbow", mc=plan2.cells[0].mc)


def test_sweep_grid_override_rejects_mixed_stateful_kinds():
    """One ControlPolicy's knobs are in one policy kind's units — applying it
    across rainbow AND hscc-2mb would silently give the 2MB baseline a
    4KB-page slot count (~512x the real capacity)."""
    from repro.engine import fleet

    with pytest.raises(ValueError, match="multiple stateful policy kinds"):
        fleet.SweepPlan.grid(
            ["streamcluster"], ["rainbow", "hscc-2mb-mig"], (0,),
            policy="sim-rainbow", intervals=2, accesses=1000,
        )
    # state-free policies riding along are fine (they ignore the override)
    plan = fleet.SweepPlan.grid(
        ["streamcluster"], ["rainbow", "flat-static"], (0,),
        policy="sim-rainbow", intervals=2, accesses=1000,
    )
    assert len(plan) == 2


def test_control_override_counter_backend_is_authoritative():
    """A backend set on the override must not be clobbered by the cell/spec
    default 'jax' (and an explicit conflict errors loudly at grid time)."""
    import repro.engine.simloop as simloop
    from repro.engine import fleet

    pallas_pol = get_policy("sim-rainbow").replace(counter_backend="interpret")
    spec = simloop.EngineSpec(
        policy="rainbow", mc=MachineConfig(),
        num_superpages=8, footprint_pages=64, control=pallas_pol,
    )  # spec.counter_backend defaults to "jax"
    assert spec.control_policy().counter_backend == "interpret"
    with pytest.raises(ValueError, match="conflicting counter_backend"):
        fleet.SweepPlan.grid(["streamcluster"], ["rainbow"], (0,),
                             policy=pallas_pol, counter_backend="ref")


def test_policy_override_changes_engine_behaviour():
    """A ControlPolicy override must actually reach the scanned engine."""
    from repro.sim.runner import SimMetrics, finalize_metrics, totals_from_stats
    import repro.engine.simloop as simloop

    mc = MachineConfig()
    chunks, meta = simloop.make_chunks("streamcluster", "rainbow", mc, 0, 2,
                                       3000)
    base_spec = simloop.EngineSpec(
        policy="rainbow", mc=mc,
        num_superpages=meta["num_superpages"],
        footprint_pages=meta["footprint_pages"],
    )
    # a prohibitive admission threshold must kill all migrations
    frozen = get_policy("sim-rainbow", mc=mc).replace(threshold_init=1e9)
    hi_spec = dataclasses.replace(base_spec, control=frozen)
    _, stats_base = simloop.engine_run(
        base_spec, simloop.engine_init(base_spec), chunks)
    _, stats_hi = simloop.engine_run(
        hi_spec, simloop.engine_init(hi_spec), chunks)
    assert int(np.asarray(stats_base.migrations).sum()) > 0
    assert int(np.asarray(stats_hi.migrations).sum()) == 0


# ---------------------------------------------------------------------------
# counter decay
# ---------------------------------------------------------------------------


def test_counter_decay_keeps_stage1_history():
    from repro.engine import control
    from repro.core import counting, migration

    s1 = counting.Stage1State(
        counts=jnp.asarray([100, 3, 0, 40000], jnp.uint16))
    dram = migration.dram_init(4)
    # default: full reset (bit-identical to the paper)
    cfg0 = control.ControlConfig(num_units=4, pages_per_unit=2, top_n=2)
    fresh, _, _ = control.rotate_monitors(cfg0, s1, dram)
    assert int(fresh.counts.sum()) == 0
    # decay: floor(value * decay), overflow bit re-derived from the value
    cfgd = control.ControlConfig(num_units=4, pages_per_unit=2, top_n=2,
                                 counter_decay=0.5)
    kept, _, _ = control.rotate_monitors(cfgd, s1, dram)
    vals = counting.counter_value(kept.counts)
    assert vals[0] == 50 and vals[1] == 1 and vals[2] == 0
    # 40000 has the overflow bit set -> effective 32768, decays to 16384
    assert vals[3] == 16384


# ---------------------------------------------------------------------------
# timing dedupe
# ---------------------------------------------------------------------------


def test_one_timing_table():
    from repro.sim.policies import machine_timing

    # serving: kvcache.default_timing IS the v5e preset
    v5e = preset_timing("v5e-serving")
    for a, b in zip(jax.tree.leaves(default_timing()), jax.tree.leaves(v5e)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # sim: MachineConfig's latencies read the paper preset verbatim
    mc = MachineConfig()
    t4 = TIMING_PRESETS["paper-table4-sim"]
    assert (mc.t_nr, mc.t_nw, mc.t_dr, mc.t_dw) == (
        t4["t_nr"], t4["t_nw"], t4["t_dr"], t4["t_dw"])
    assert (mc.mig_page_cost, mc.writeback_page_cost) == (
        t4["t_mig"], t4["t_writeback"])
    tp = machine_timing(mc)
    assert float(tp.t_nr) == np.float32(t4["t_nr"])
    with pytest.raises(KeyError, match="unknown timing preset"):
        preset_timing("a100")
