"""Fig. 12: energy consumption normalized to Flat-static."""
import time

from benchmarks.common import emit
from benchmarks.paper_policies import all_cells
from repro.sim.config import POLICIES


def run():
    t0 = time.time()
    cells = all_cells()  # FleetResult: the sharded sweep-plan run
    apps = cells.apps()
    rows = []
    ratios = {p: [] for p in POLICIES}
    for app in apps:
        base = cells[(app, "flat-static")].energy["total_j"]
        row = {"app": app}
        for pol in POLICIES:
            r = cells[(app, pol)].energy["total_j"] / base
            row[pol] = round(r, 3)
            ratios[pol].append(r)
        rows.append(row)
    g = lambda p: sum(ratios[p]) / len(ratios[p])
    emit("paper_fig12_energy", rows, t0,
         f"rainbow_vs_flat={g('rainbow'):.2f}_paper=0.549;"
         f"rainbow_vs_dramonly={g('rainbow')/g('dram-only'):.2f}_paper=0.315")
    return rows


if __name__ == "__main__":
    run()
