"""Synchronous vs transactional-async migration under queue contention.

Rainbow charges each interval's whole migration plan onto the queues as one
bulk at interval end; the Nomad-style async family (engine.nomad) spreads
the same priced traffic over `async_window` interval ends as installments,
aborting transactions whose page is written mid-copy. Under the flat cost
model the two are indistinguishable (same counts, same priced cycles) — the
difference only exists in the queueing timing model, where rainbow's lump
backlogs the constrained NVM/DRAM channels into the next interval's demand
window while nomad's installments drain between intervals.

Runs {rainbow, nomad} x scenarios at seed 7 under the flat model and the
"constrained" QueueGeometry preset and reports the migration-stall relief.
Emits BENCH_nomad.json with:

  * `gate`: `speedup` = mean over scenarios of rainbow-over-nomad
    mig_stall ratio under the constrained geometry (floor 1.0: spreading
    the charge must not stall MORE than the synchronous lump);
  * `sync_degenerate_bitwise`: the live differential anchor — the nomad
    step program with `async_window=1` (preset "nomad-sync") run against
    the SAME chunks must be bit-identical to rainbow, stats and final
    TLB/sim state included. scripts/ci.sh asserts it is true.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import QUICK, emit, write_bench_json
from repro.engine import simloop
from repro.engine.policy import get_policy
from repro.sim import runner
from repro.sim.config import MachineConfig
from repro.timing import get_geometry

POLICIES = ["rainbow", "nomad"]


def _scenarios():
    if QUICK:
        return ["syn/streamcluster", "stress/zipf-hotspot"]
    return ["syn/streamcluster", "stress/zipf-hotspot", "syn/mcf",
            "syn/canneal"]


def _sweep_kwargs():
    return ({"intervals": 4, "accesses": 20_000} if QUICK
            else {"intervals": 7, "accesses": 50_000})


def _sync_degenerate_bitwise() -> bool:
    """nomad-sync (async_window=1) vs rainbow on one staged run, bitwise."""
    mc = MachineConfig()
    chunks, meta = simloop.make_chunks(
        "streamcluster", "rainbow", mc, 7, 3, 4000
    )

    def final(policy, control):
        spec = simloop.EngineSpec(
            policy=policy, mc=mc,
            num_superpages=meta["num_superpages"],
            footprint_pages=meta["footprint_pages"],
            control=control,
            timing_model="queueing",
            queue_geometry=get_geometry("constrained"),
        )
        state, stats = simloop.engine_run(spec, simloop.engine_init(spec), chunks)
        return state.sim, stats

    sim_r, stats_r = final("rainbow", None)
    sim_n, stats_n = final("nomad", get_policy("nomad-sync", mc=mc))
    if int(np.asarray(stats_n.aborts).sum()) != 0:
        return False
    for f in stats_r._fields:
        a = getattr(stats_r, f)
        if a is None or f == "aborts":
            continue
        if not np.array_equal(np.asarray(a), np.asarray(getattr(stats_n, f))):
            return False
    return bool(
        jax.tree.all(jax.tree.map(
            lambda a, b: bool(np.array_equal(np.asarray(a), np.asarray(b))),
            sim_r, sim_n,
        ))
    )


def run():
    t0 = time.time()
    scenarios = _scenarios()
    results = {}  # (geom_label, scenario, policy) -> SimMetrics
    for label, model, geom in (
        ("flat", "flat", None),
        ("constrained", "queueing", get_geometry("constrained")),
    ):
        res = runner.sweep(
            [], POLICIES, [7], scenarios=scenarios,
            timing_model=model, queue_geometry=geom, **_sweep_kwargs(),
        )
        for (app, policy, _seed), m in res.items():
            results[(label, app, policy)] = m

    rows = []
    for (label, app, policy), m in sorted(results.items()):
        rows.append({
            "geometry": label,
            "app": app,
            "policy": policy,
            "ipc": round(m.ipc, 6),
            "total_cycles": round(m.total_cycles, 1),
            "migrations": m.migrations,
            "mig_aborts": m.mig_aborts,
            "bank_stall_cycles": round(m.bank_stall_cycles, 1),
            "mig_stall_cycles": round(m.mig_stall_cycles, 1),
        })

    # mean rainbow-over-nomad migration-stall ratio, constrained geometry
    # (+1 cycle regularizer: a scenario with zero stall on both sides is 1.0)
    ratios = [
        (results[("constrained", app, "rainbow")].mig_stall_cycles + 1.0)
        / (results[("constrained", app, "nomad")].mig_stall_cycles + 1.0)
        for app in scenarios
    ]
    relief = sum(ratios) / len(ratios)
    aborts = sum(
        results[("constrained", app, "nomad")].mig_aborts for app in scenarios
    )
    sync_ok = _sync_degenerate_bitwise()
    headline = (
        f"async installments: rainbow/nomad mig_stall x{relief:.3f} "
        f"(constrained), {aborts} aborts; sync-degenerate bitwise: {sync_ok}"
    )
    write_bench_json("nomad", {
        "headline": headline,
        "sync_degenerate_bitwise": sync_ok,
        "mig_stall_relief": relief,
        "total_aborts": aborts,
        "gate": {"floor": 1.0, "speedup": relief},
        "rows": rows,
    })
    emit("nomad_async", rows, t0, headline)
    if not sync_ok:
        raise AssertionError(
            "nomad with async_window=1 is not bit-identical to rainbow: "
            "the sync-degenerate invariant is broken (see docs/policy.md)"
        )
    return rows


if __name__ == "__main__":
    run()
