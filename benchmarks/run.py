"""Benchmark driver: one module per paper table/figure + the roofline table.

``PYTHONPATH=src python -m benchmarks.run``                 (quick mode)
``BENCH_QUICK=0 PYTHONPATH=src python -m benchmarks.run``   (full workload table)

Each module prints its rows as CSV plus a ``name,us_per_call,derived`` line,
where `derived` carries the paper-claim comparison for EXPERIMENTS.md.
"""
from __future__ import annotations

import glob
import json
import os
import sys
import traceback


def aggregate() -> None:
    """Summarize every BENCH_*.json the modules wrote at the repo root.

    Each file carries a `headline` string and (when the module has a floor)
    a `gate` object with `floor` + `speedup`; this prints the one-screen
    roll-up the CI log and EXPERIMENTS.md link to.
    """
    from benchmarks.common import ROOT

    paths = sorted(glob.glob(os.path.join(ROOT, "BENCH_*.json")))
    if not paths:
        return
    print("\n===== BENCH_*.json aggregate =====")
    for p in paths:
        try:
            with open(p) as f:
                d = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{os.path.basename(p)}: unreadable ({e})")
            continue
        gate = d.get("gate") or {}
        status = ""
        if "floor" in gate and "speedup" in gate:
            ok = gate["speedup"] >= gate["floor"]
            status = f" [gate {'PASS' if ok else 'FAIL'}]"
        print(f"{os.path.basename(p)}: {d.get('headline', '(no headline)')}"
              f"{status}")


def main() -> None:
    from benchmarks import (
        autotune_serving,
        engine_throughput,
        fleet_throughput,
        paper_fig1_table12,
        paper_fig7_mpki,
        paper_fig8_tlb_cycles,
        paper_fig9_breakdown,
        paper_fig10_ipc,
        paper_fig11_traffic,
        paper_fig12_energy,
        paper_fig13_14_sensitivity,
        paper_fig15_runtime,
        paper_table6_storage,
        policy_atlas,
        roofline,
        serving_rainbow,
        timing_contention,
    )

    modules = [
        paper_table6_storage,  # cheap first
        paper_fig1_table12,
        paper_fig7_mpki,
        paper_fig8_tlb_cycles,
        paper_fig9_breakdown,
        paper_fig10_ipc,
        paper_fig11_traffic,
        paper_fig12_energy,
        paper_fig15_runtime,
        paper_fig13_14_sensitivity,
        engine_throughput,
        fleet_throughput,
        timing_contention,
        policy_atlas,
        serving_rainbow,
        autotune_serving,
        roofline,
    ]
    failed = []
    for mod in modules:
        name = mod.__name__.split(".")[-1]
        print(f"\n===== {name} =====")
        try:
            mod.run()
        except Exception:
            traceback.print_exc()
            failed.append(name)
    aggregate()
    if failed:
        print(f"\nFAILED benchmarks: {failed}")
        sys.exit(1)
    print("\nall benchmarks completed")


if __name__ == "__main__":
    main()
