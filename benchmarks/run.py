"""Benchmark driver: one module per paper table/figure + the roofline table.

``PYTHONPATH=src python -m benchmarks.run``                 (quick mode)
``BENCH_QUICK=0 PYTHONPATH=src python -m benchmarks.run``   (full workload table)

Each module prints its rows as CSV plus a ``name,us_per_call,derived`` line,
where `derived` carries the paper-claim comparison for EXPERIMENTS.md.
"""
from __future__ import annotations

import glob
import json
import os
import sys
import traceback


def aggregate() -> list[str]:
    """Summarize every BENCH_*.json the modules wrote at the repo root.

    Each file carries a `headline` string and (when the module has a floor)
    a `gate` object with `floor` + `speedup`; this prints the one-screen
    roll-up the CI log and EXPERIMENTS.md link to.

    Returns the list of failures (an unreadable BENCH file or a gate whose
    `speedup` fell below its `floor`) — callers MUST treat a non-empty list
    as a hard failure. Before this returned anything, a regressed gate
    printed "[gate FAIL]" into a green CI log and nobody looked; now
    `main()` and `--aggregate-only` both exit non-zero on it.
    """
    from benchmarks.common import ROOT

    failures: list[str] = []
    paths = sorted(glob.glob(os.path.join(ROOT, "BENCH_*.json")))
    if not paths:
        return failures
    print("\n===== BENCH_*.json aggregate =====")
    for p in paths:
        name = os.path.basename(p)
        try:
            with open(p) as f:
                d = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{name}: unreadable ({e})")
            failures.append(f"{name}: unreadable ({e})")
            continue
        gate = d.get("gate") or {}
        status = ""
        if "floor" in gate and "speedup" in gate:
            ok = gate["speedup"] >= gate["floor"]
            status = f" [gate {'PASS' if ok else 'FAIL'}]"
            if not ok:
                failures.append(
                    f"{name}: gate speedup {gate['speedup']} < floor "
                    f"{gate['floor']}"
                )
        print(f"{name}: {d.get('headline', '(no headline)')}{status}")
    return failures


def main() -> None:
    if "--aggregate-only" in sys.argv[1:]:
        # gate check over already-written BENCH files (scripts/ci.sh runs
        # this after the benchmark legs; no benchmarks are re-run)
        gate_failures = aggregate()
        if gate_failures:
            print(f"\nFAILED gates: {gate_failures}")
            sys.exit(1)
        print("\nall BENCH gates pass")
        return
    from benchmarks import (
        autotune_serving,
        engine_throughput,
        fleet_throughput,
        nomad_async,
        paper_fig1_table12,
        paper_fig7_mpki,
        paper_fig8_tlb_cycles,
        paper_fig9_breakdown,
        paper_fig10_ipc,
        paper_fig11_traffic,
        paper_fig12_energy,
        paper_fig13_14_sensitivity,
        paper_fig15_runtime,
        paper_table6_storage,
        policy_atlas,
        roofline,
        serving_rainbow,
        timing_contention,
    )

    modules = [
        paper_table6_storage,  # cheap first
        paper_fig1_table12,
        paper_fig7_mpki,
        paper_fig8_tlb_cycles,
        paper_fig9_breakdown,
        paper_fig10_ipc,
        paper_fig11_traffic,
        paper_fig12_energy,
        paper_fig15_runtime,
        paper_fig13_14_sensitivity,
        engine_throughput,
        fleet_throughput,
        timing_contention,
        nomad_async,
        policy_atlas,
        serving_rainbow,
        autotune_serving,
        roofline,
    ]
    failed = []
    for mod in modules:
        name = mod.__name__.split(".")[-1]
        print(f"\n===== {name} =====")
        try:
            mod.run()
        except Exception:
            traceback.print_exc()
            failed.append(name)
    failed += aggregate()
    if failed:
        print(f"\nFAILED benchmarks: {failed}")
        sys.exit(1)
    print("\nall benchmarks completed")


if __name__ == "__main__":
    main()
