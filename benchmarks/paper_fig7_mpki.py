"""Fig. 7: TLB misses per kilo-instruction per (workload x policy)."""
import time

from benchmarks.common import emit
from benchmarks.paper_policies import all_cells
from repro.sim.config import POLICIES


def run():
    t0 = time.time()
    cells = all_cells()  # FleetResult: the sharded sweep-plan run
    apps = cells.apps()
    rows = []
    red = []
    for app in apps:
        row = {"app": app}
        for pol in POLICIES:
            row[pol] = round(cells[(app, pol)].mpki, 4)
        rows.append(row)
        if row["flat-static"] > 0:
            red.append(1 - row["rainbow"] / row["flat-static"])
    avg_red = 100 * sum(red) / max(len(red), 1)
    emit("paper_fig7_mpki", rows, t0,
         f"rainbow_mpki_reduction_vs_4kb={avg_red:.2f}%_paper=99.8%")
    return rows


if __name__ == "__main__":
    run()
