"""Table VI: SRAM storage overhead of Rainbow for a 1 TB PCM system."""
import time

from benchmarks.common import emit
from repro.core import bitmap, counting


def run():
    t0 = time.time()
    tb = 1 << 40
    num_sp = tb // (2 << 20)  # 512K superpages
    c = counting.storage_overhead_bytes(num_sp, 100, 512)
    bm = bitmap.storage_overhead_bytes(4000, 512)
    rows = [
        {"structure": "migration_bitmap_cache", "bytes": bm, "paper": "272 KB"},
        {"structure": "stage1_superpage_counters", "bytes": c["stage1_counters"],
         "paper": "1 MB"},
        {"structure": "stage2_psn_tags", "bytes": c["stage2_psn_tags"],
         "paper": "4N = 400 B"},
        {"structure": "stage2_page_counters", "bytes": c["stage2_counters"],
         "paper": "N KB = 100 KB"},
    ]
    total = sum(r["bytes"] for r in rows)
    rows.append({"structure": "TOTAL", "bytes": total, "paper": "1.372 MB"})
    emit("paper_table6_storage", rows, t0,
         f"total_mb={total/1024/1024:.3f}_paper=1.372MB")
    return rows


if __name__ == "__main__":
    run()
