"""Fig. 8: percent of execution cycles spent servicing TLB misses."""
import time

from benchmarks.common import emit
from benchmarks.paper_policies import all_cells
from repro.sim.config import POLICIES


def run():
    t0 = time.time()
    cells = all_cells()  # FleetResult: the sharded sweep-plan run
    apps = cells.apps()
    rows = []
    for app in apps:
        row = {"app": app}
        for pol in POLICIES:
            m = cells[(app, pol)]
            walk_frac = (m.breakdown["cycles_walk"] + m.breakdown["cycles_tlb"]) / m.total_cycles
            row[pol] = round(100 * walk_frac, 3)
        rows.append(row)
    emit("paper_fig8_tlb_cycles", rows, t0, "pct_cycles_tlb_service")
    return rows


if __name__ == "__main__":
    run()
