"""Fig. 15: breakdown of Rainbow's runtime overhead (remapping, bitmap cache,
migration, shootdown, clflush)."""
import time

from benchmarks.common import emit
from benchmarks.paper_policies import all_cells


def run():
    t0 = time.time()
    cells = all_cells()  # FleetResult: the sharded sweep-plan run
    apps = cells.apps()
    rows = []
    for app in apps:
        m = cells[(app, "rainbow")]
        b = m.breakdown
        over = (b["cycles_remap"] + b["cycles_bitmap"] + b["cycles_mig"]
                + b["cycles_shootdown"] + b["cycles_clflush"])
        rows.append({
            "app": app,
            "overhead_pct_of_cycles": round(100 * over / m.total_cycles, 2),
            "remap_pct": round(100 * b["cycles_remap"] / max(over, 1), 1),
            "bitmap_pct": round(100 * b["cycles_bitmap"] / max(over, 1), 1),
            "migration_pct": round(100 * b["cycles_mig"] / max(over, 1), 1),
            "shootdown_pct": round(100 * b["cycles_shootdown"] / max(over, 1), 1),
            "clflush_pct": round(100 * b["cycles_clflush"] / max(over, 1), 1),
        })
    avg = sum(r["overhead_pct_of_cycles"] for r in rows) / max(len(rows), 1)
    emit("paper_fig15_runtime", rows, t0,
         f"avg_runtime_overhead={avg:.1f}%_paper=9.8%")
    return rows


if __name__ == "__main__":
    run()
