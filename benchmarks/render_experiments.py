"""Render §Dry-run and §Roofline markdown tables into EXPERIMENTS.md from the
dry-run artifacts (idempotent: replaces the <!-- *_TABLE --> markers)."""
from __future__ import annotations

import json

from benchmarks.roofline import load_cells, rows_from_cells
from repro.configs import ARCH_IDS, applicable_shapes
from repro.models.config import SHAPES


def fmt_bytes(b):
    if not b:
        return "-"
    return f"{b / (1 << 30):.2f} GiB"


def dryrun_table() -> str:
    cells = {(c["arch"], c["shape"], c["mesh"]): c for c in load_cells()}
    lines = [
        "| arch | shape | 16x16 | 2x16x16 | bytes/dev (peak) | HLO GFLOP/dev | collective B/dev | top collectives |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_IDS:
        shapes = applicable_shapes(arch)
        for shape in ["train_4k", "prefill_32k", "decode_32k", "long_500k"]:
            if shape not in shapes:
                if shape == "long_500k":
                    lines.append(
                        f"| {arch} | {shape} | skip | skip | — | — | — | "
                        f"full-attention arch (DESIGN.md §4) |"
                    )
                continue
            c1 = cells.get((arch, shape, "16x16"))
            c2 = cells.get((arch, shape, "2x16x16"))
            ok1 = "PASS" if c1 and c1.get("ok") else "FAIL"
            ok2 = "PASS" if c2 and c2.get("ok") else "FAIL"
            mem = c1["memory"].get("peak_bytes_per_device", 0) if c1 else 0
            fl = c1["cost_analysis"].get("flops", 0) / 1e9 if c1 else 0
            coll = c1["collectives"]["total_bytes_per_device"] if c1 else 0
            ops = (
                ", ".join(
                    f"{k}:{v / 1e9:.2f}GB"
                    for k, v in sorted(
                        c1["collectives"]["bytes_by_op"].items(),
                        key=lambda kv: -kv[1],
                    )[:2]
                )
                if c1
                else ""
            )
            lines.append(
                f"| {arch} | {shape} | {ok1} | {ok2} | {fmt_bytes(mem)} |"
                f" {fl:,.0f} | {coll / 1e9:.2f} GB | {ops} |"
            )
    return "\n".join(lines)


def roofline_table() -> str:
    rows = [
        r for r in rows_from_cells(load_cells())
        if r["mesh"] == "16x16"
    ]
    lines = [
        "| arch | shape | kind | compute_s | memory_s | collective_s | dominant | fraction | MODEL_FLOPS | useful ratio | what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    order = {a: i for i, a in enumerate(ARCH_IDS)}
    rows.sort(key=lambda r: (order.get(r["arch"], 99), r["shape"]))
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | {r['compute_s']:.4f} |"
            f" {r['memory_s']:.4f} | {r['collective_s']:.4f} | {r['dominant']} |"
            f" {r['roofline_fraction']:.3f} | {r['model_flops']} |"
            f" {r['useful_flops_ratio']:.3f} | {r['note']} |"
        )
    return "\n".join(lines)


def main() -> None:
    with open("EXPERIMENTS.md") as f:
        text = f.read()
    text = text.replace("<!-- DRYRUN_TABLE -->", dryrun_table(), 1)
    text = text.replace("<!-- ROOFLINE_TABLE -->", roofline_table(), 1)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("tables rendered into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
