"""Shared driver for Figs. 7-12 + 15: the full (workload x policy) grid is
declared ONCE as an engine.fleet.SweepPlan and executed by the mesh-sharded
FleetRunner; figure scripts slice their columns from the cached FleetResult."""
from __future__ import annotations

import functools

from benchmarks.common import sim_kwargs, workloads
from repro.engine import fleet
from repro.sim.config import POLICIES


def grid_plan() -> "fleet.SweepPlan":
    """The paper's §V evaluation grid (Figs. 7-12, 15)."""
    kw = sim_kwargs()
    return fleet.SweepPlan.grid(
        workloads(), POLICIES,
        intervals=kw["intervals"], accesses=kw["accesses"],
    )


@functools.lru_cache(maxsize=None)
def all_cells() -> "fleet.FleetResult":
    """Run the grid once per process; every figure renders from this result."""
    return fleet.FleetRunner().run(grid_plan())
