"""Shared driver for Figs. 7-12 + 15: run every (workload x policy) cell once,
cache the SimMetrics, and let each figure script slice its columns."""
from __future__ import annotations

import functools

from benchmarks.common import sim_kwargs, workloads
from repro.sim.config import POLICIES
from repro.sim.runner import simulate


@functools.lru_cache(maxsize=None)
def _cell(app: str, policy: str, intervals: int, accesses) -> object:
    return simulate(app, policy, intervals=intervals, accesses=accesses)


def all_cells():
    kw = sim_kwargs()
    out = {}
    for app in workloads():
        for pol in POLICIES:
            out[(app, pol)] = _cell(app, pol, kw["intervals"], kw["accesses"])
    return out
