"""Fig. 10: IPC normalized to Flat-static — the paper's headline comparison."""
import time

from benchmarks.common import emit
from benchmarks.paper_policies import all_cells
from repro.sim.config import POLICIES


def run():
    t0 = time.time()
    cells = all_cells()  # FleetResult: the sharded sweep-plan run
    apps = cells.apps()
    rows = []
    ratios = {p: [] for p in POLICIES}
    for app in apps:
        base = cells[(app, "flat-static")].ipc
        row = {"app": app}
        for pol in POLICIES:
            r = cells[(app, pol)].ipc / base
            row[pol] = round(r, 3)
            ratios[pol].append(r)
        rows.append(row)
    g = lambda p: sum(ratios[p]) / len(ratios[p])
    derived = (
        f"rainbow_vs_flat={g('rainbow'):.2f}x_paper=1.727x;"
        f"rainbow_vs_hscc4k={g('rainbow')/g('hscc-4kb-mig'):.2f}x_paper=1.228x;"
        f"rainbow_vs_hscc2m={g('rainbow')/g('hscc-2mb-mig'):.2f}x_paper=1.173x;"
        f"dramonly_vs_rainbow={g('dram-only')/g('rainbow'):.2f}x_paper=1.14x"
    )
    emit("paper_fig10_ipc", rows, t0, derived)
    return rows


if __name__ == "__main__":
    run()
