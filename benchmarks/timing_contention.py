"""Bank-geometry x policy sweep through the queueing timing model.

Runs the fleet over {flat, infinite-banks, roomy, constrained} queue
geometries and reports how the rainbow-vs-HSCC gap moves when DRAM/NVM
bandwidth is scarce. Under the flat cost model superpage migration looks
cheap per unit of hotness captured; once migration traffic queues behind
demand accesses on real channels, HSCC-2MB's 512-page bulk copies back the
NVM queues up for whole intervals while Rainbow's page-granularity
lightweight migrations charge a tiny fraction of those cycles — so
constraining the geometry swings the rainbow/hscc-2mb IPC ratio from below
1 (flat) to ~2x (constrained). The flat == infinite-banks rows double as
the live differential check of the flat-floor invariant (docs/timing.md).

Emits BENCH_timing.json with a `gate`: `speedup` is the constrained-over-flat
shift of the mean rainbow/hscc-2mb IPC ratio (floor 1.0 = the gap must
widen, not shrink), plus `flat_floor_bitwise` which scripts/ci.sh asserts
is true.
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import QUICK, emit, write_bench_json
from repro.sim import runner
from repro.timing import QueueGeometry

MIG_POLICIES = ["rainbow", "hscc-4kb-mig", "hscc-2mb-mig"]

#: geometry label -> (timing_model, QueueGeometry | None)
GEOMETRIES = {
    "flat": ("flat", None),
    "infinite": ("queueing", QueueGeometry.flat_floor()),
    "roomy": ("queueing", QueueGeometry(
        dram_channels=8, dram_banks=16, nvm_channels=4, nvm_banks=16)),
    "constrained": ("queueing", QueueGeometry(
        dram_channels=1, dram_banks=2, nvm_channels=1, nvm_banks=2)),
}


def _scenarios():
    if QUICK:
        return ["syn/streamcluster", "syn/mcf"]
    return ["syn/streamcluster", "syn/mcf", "syn/canneal", "syn/GUPS"]


def _sweep_kwargs():
    return ({"intervals": 4, "accesses": 20_000} if QUICK
            else {"intervals": 7, "accesses": 50_000})


def run():
    t0 = time.time()
    scenarios = _scenarios()
    results = {}  # (geom_label, scenario, policy) -> SimMetrics
    for label, (model, geom) in GEOMETRIES.items():
        res = runner.sweep(
            [], MIG_POLICIES, [7], scenarios=scenarios,
            timing_model=model, queue_geometry=geom, **_sweep_kwargs(),
        )
        for (app, policy, _seed), m in res.items():
            results[(label, app, policy)] = m

    # flat-floor differential: flat must be BITWISE identical to infinite
    floor_ok = all(
        dataclasses.asdict(results[("flat", app, pol)])
        == dataclasses.asdict(results[("infinite", app, pol)])
        for app in scenarios for pol in MIG_POLICIES
    )

    rows = []
    for (label, app, policy), m in sorted(results.items()):
        rows.append({
            "geometry": label,
            "app": app,
            "policy": policy,
            "ipc": round(m.ipc, 6),
            "total_cycles": round(m.total_cycles, 1),
            "bank_stall_cycles": round(m.bank_stall_cycles, 1),
            "mig_stall_cycles": round(m.mig_stall_cycles, 1),
            "queue_occ_dram": round(m.queue_occupancy_dram, 1),
            "queue_occ_nvm": round(m.queue_occupancy_nvm, 1),
        })

    def gap(label):  # mean rainbow-over-hscc-2mb IPC ratio at one geometry
        ratios = [
            results[(label, app, "rainbow")].ipc
            / results[(label, app, "hscc-2mb-mig")].ipc
            for app in scenarios
        ]
        return sum(ratios) / len(ratios)

    gap_flat, gap_constrained = gap("flat"), gap("constrained")
    shift = gap_constrained / gap_flat
    headline = (
        f"flat-floor bitwise: {floor_ok}; rainbow/hscc-2mb IPC gap "
        f"{gap_flat:.3f} (flat) -> {gap_constrained:.3f} (constrained), "
        f"shift x{shift:.3f}"
    )
    write_bench_json("timing", {
        "headline": headline,
        "flat_floor_bitwise": floor_ok,
        "gap_ipc_flat": gap_flat,
        "gap_ipc_constrained": gap_constrained,
        "gate": {"floor": 1.0, "speedup": shift},
        "rows": rows,
    })
    emit("timing_contention", rows, t0, headline)
    if not floor_ok:
        raise AssertionError(
            "flat != queueing-with-infinite-banks: the flat-floor invariant "
            "is broken (see docs/timing.md)"
        )
    return rows


if __name__ == "__main__":
    run()
