"""Sweep-cell throughput: host-loop vs batched (vmap) vs mesh-sharded fleet.

cells/sec over a homogeneous 32-cell fleet (one app x policy, many seeds):

  host-loop   one simulate() per cell, serially — how the figure drivers
              called the engine before the FleetRunner
  batched     the PR 1 path: sweep_seeds (one vmapped compile, device 0)
              + the same per-cell finalize the old sim.runner.sweep did
  sharded     FleetRunner: shard_map over the fleet mesh, padded fleet axis,
              double-buffered host staging, per-cell SimMetrics
  barrier/streamed
              the same 32 cells split over 4 compile-signature groups, run
              through FleetRunner.run (all results at the end) vs
              FleetRunner.run_iter (each group retired as its scan finishes);
              total cells/sec should tie — the streamed win is
              time-to-first-result (first_result_s column)
  staged-scenario/fused-scenario
              the same fleet on a workload SCENARIO (repro.workloads): traces
              materialized host-side from the generator stream and staged
              (the differential-oracle path) vs synthesized INSIDE the
              sharded engine scan (EngineSpec.source) — the fused leg stages
              only a seed vector, so generation rides the mesh instead of
              the host (target: >= 1.2x staged cells/sec on 4 host devices)

The atlas-scale THROUGHPUT GATE (second emit line) runs a multi-signature
plan (8 signatures x 128 seeds = 1024 cells in full mode; 4 x 32 quick) in
controlled subprocesses on 4 forced host devices:

  baseline    pipeline=False (the pre-pipeline double-buffered path), cold
              compiles, per-group-fsync journal — what atlas-scale plans
              cost before this optimization
  pipelined   prefetch pipeline + CompileCache backed by a persistent
              compilation cache a prior subprocess populated + batched
              journal — the resumed/repeated-run shape the atlas lives in
  resume      the same journal replayed by a fresh process: zero groups may
              re-execute

The gate ASSERTS pipelined >= 1.5x baseline cells/sec and that baseline,
pipelined, resumed rows are all identical, with one cell cross-checked
against the single-device simulate() oracle in the parent process.

The fleet axis needs enough lanes for device parallelism to beat the vmap
lanes' vectorization (per-scan-step op overhead dominates small fleets on
CPU); 32 cells is the knee on a 4-device host mesh and matches the paper
grid's scale (17 workloads x 5 policies).

Standalone (python -m benchmarks.fleet_throughput) forces 4 host devices so
the mesh is real; under benchmarks.run it uses whatever devices exist.
"""
from __future__ import annotations

import os
import sys

if (
    __name__ == "__main__"
    and "jax" not in sys.modules
    and "host_platform_device_count" not in os.environ.get("XLA_FLAGS", "")
):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=4"
    ).strip()

import json
import shutil
import subprocess
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import QUICK, ROOT, emit, write_bench_json

APP = "streamcluster"
# The staged/fused contrast is staging-bound, so the scenario legs use a
# footprint large enough that host materialization (which, like the numpy
# app path, re-derives the interval-invariant setup every interval) is a
# real cost; the fused scan runs setup once per simulation.
SCENARIO = "stress/zipf-hotspot"
POLICY = "rainbow"
FLEET = 32
INTERVALS = 3 if QUICK else 6
ACCESSES = 10_000 if QUICK else 60_000

# Throughput-gate plan: GATE_SIGS compile signatures (MachineConfig.top_n
# variants change monitor-state shapes, hence programs) x GATE_SEEDS cells
# each — 1024 cells in full mode, per the atlas acceptance floor.
GATE_SIGS = 4 if QUICK else 8
GATE_SEEDS = 32 if QUICK else 128
GATE_INTERVALS = 2
GATE_ACCESSES = 1000 if QUICK else 1500
GATE_FLOOR = 1.5


def _bench(fn, reps: int = 2) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _measure() -> dict:
    import repro.engine.simloop as simloop
    from repro.engine import fleet
    from repro.sim.config import MachineConfig
    from repro.sim.runner import finalize_metrics, simulate, totals_from_stats

    mc = MachineConfig()
    seeds = list(range(FLEET))
    plan = fleet.SweepPlan.grid(
        [APP], [POLICY], tuple(seeds), intervals=INTERVALS, accesses=ACCESSES
    )
    runner = fleet.FleetRunner()

    def host_loop():
        for s in seeds:
            simulate(APP, POLICY, mc, intervals=INTERVALS, accesses=ACCESSES,
                     seed=s)

    def batched():
        finals, stats, meta = simloop.sweep_seeds(
            APP, POLICY, mc, seeds, intervals=INTERVALS, accesses=ACCESSES
        )
        for i in range(len(seeds)):
            per = type(stats)(*(np.asarray(x)[i] for x in stats))
            totals = totals_from_stats(POLICY, mc, per,
                                       meta["accesses_per_interval"])
            counters = type(finals.sim.counters)(
                *(np.asarray(x)[i] for x in finals.sim.counters)
            )
            finalize_metrics(APP, POLICY, mc, totals, counters,
                             meta["inst_per_access"], meta["footprint_pages"])

    def sharded():
        runner.run(plan)

    # Streaming leg: same cell count split over 4 compile-signature groups
    # (4 MachineConfig variants x 8 seeds, identical trace shapes), so
    # run_iter actually has groups to retire incrementally.  Barrier vs
    # streamed total throughput should tie; the streamed win is
    # TIME-TO-FIRST-RESULT — downstream consumers start after group 0.
    group_plans = [
        fleet.SweepPlan.grid(
            [APP], [POLICY], tuple(range(FLEET // 4)),
            mc=MachineConfig(top_n=mc.top_n + 8 * i),
            intervals=INTERVALS, accesses=ACCESSES,
        )
        for i in range(4)
    ]
    grouped_plan = sum(group_plans[1:], group_plans[0])
    first_cell = {}

    def barrier_grouped():
        t0 = time.perf_counter()
        res = runner.run(grouped_plan)
        next(iter(res.metrics.values()))
        first_cell["barrier-grouped"] = time.perf_counter() - t0

    def streamed_grouped():
        t0 = time.perf_counter()
        for i, _ in enumerate(runner.run_iter(grouped_plan)):
            if i == 0:
                first_cell["streamed-fleet"] = time.perf_counter() - t0

    # Fused-generation leg: the same seed fleet on a workload scenario,
    # staged (host materialization of the generator stream -> device_put)
    # vs fused (chunks synthesized inside the sharded scan; only a seed
    # vector is staged).  Same cells, bit-identical metrics — the delta is
    # purely where trace generation runs.
    staged_plan = fleet.SweepPlan.grid(
        apps=[SCENARIO], policies=[POLICY], seeds=tuple(seeds),
        intervals=INTERVALS, accesses=ACCESSES,
    )
    fused_plan = fleet.SweepPlan.grid(
        policies=[POLICY], seeds=tuple(seeds), scenario=[SCENARIO],
        intervals=INTERVALS, accesses=ACCESSES,
    )

    def staged_scenario():
        runner.run(staged_plan)

    def fused_scenario():
        runner.run(fused_plan)

    modes = [("host-loop", host_loop, 1), ("batched-vmap", batched, 2),
             ("sharded-fleet", sharded, 2),
             ("barrier-grouped", barrier_grouped, 2),
             ("streamed-fleet", streamed_grouped, 2),
             ("staged-scenario", staged_scenario, 2),
             ("fused-scenario", fused_scenario, 2)]
    rows, rates = [], {}
    simulate(APP, POLICY, mc, intervals=INTERVALS, accesses=ACCESSES,
             seed=seeds[0])  # warm the single-cell compile for host-loop
    for name, fn, reps in modes:
        fn()  # warm (compile + caches)
        t = _bench(fn, reps=reps)
        rates[name] = FLEET / t
        rows.append({
            "mode": name,
            "cells": FLEET,
            "intervals": INTERVALS,
            "accesses_per_interval": ACCESSES,
            "devices": len(jax.devices()),
            "seconds": round(t, 3),
            "cells_per_sec": round(FLEET / t, 3),
            # only the grouped barrier/streamed legs instrument first-result
            # latency; blank elsewhere rather than passing off total runtime
            "first_result_s": (
                round(first_cell[name], 3) if name in first_cell else ""
            ),
        })
    return {
        "rows": rows,
        "sharded_vs_vmap": rates["sharded-fleet"] / rates["batched-vmap"],
        "sharded_vs_host": rates["sharded-fleet"] / rates["host-loop"],
        "streamed_vs_barrier": rates["streamed-fleet"] / rates["barrier-grouped"],
        "first_result_speedup": (
            first_cell["barrier-grouped"] / first_cell["streamed-fleet"]
        ),
        "fused_vs_staged": rates["fused-scenario"] / rates["staged-scenario"],
    }


# ---------------------------------------------------------------------------
# Atlas-scale throughput gate (pipelined vs pre-pipeline, subprocess-isolated)
# ---------------------------------------------------------------------------


def _gate_plan():
    from repro.engine import fleet
    from repro.sim.config import MachineConfig

    base_top_n = MachineConfig().top_n
    plans = [
        fleet.SweepPlan.grid(
            [APP], [POLICY], tuple(range(GATE_SEEDS)),
            mc=MachineConfig(top_n=base_top_n + 8 * i),
            intervals=GATE_INTERVALS, accesses=GATE_ACCESSES,
        )
        for i in range(GATE_SIGS)
    ]
    return sum(plans[1:], plans[0])


def _gate_child(mode: str, out_path: str, journal: str | None) -> None:
    """One gate leg, in a fresh process (so compile-cache state is exact)."""
    from repro.engine import fleet

    plan = _gate_plan()
    if mode == "baseline":
        # the pre-pipeline path: inline double buffer, per-group fsync
        runner = fleet.FleetRunner(pipeline=False)
        jnl = fleet.FleetJournal(journal, flush_groups=1) if journal else None
    else:  # pipelined-cold / pipelined / resume
        runner = fleet.FleetRunner()
        jnl = fleet.FleetJournal(journal) if journal else None
    t0 = time.perf_counter()
    pairs = list(runner.run_iter(plan, journal=jnl))
    elapsed = time.perf_counter() - t0
    rows = sorted(
        [c.mc.top_n, c.seed, m.ipc, m.total_cycles, m.migrations, m.mig_bytes]
        for c, m in pairs
    )
    with open(out_path, "w") as f:
        json.dump({
            "mode": mode,
            "elapsed": elapsed,
            "cells": len(pairs),
            "groups_executed": len(runner.timings),
            "compile_s": sum(t.compile_s for t in runner.timings),
            "stage_s": sum(t.stage_s for t in runner.timings),
            "scan_s": sum(t.scan_s for t in runner.timings),
            "retire_s": sum(t.retire_s for t in runner.timings),
            "rows": rows,
        }, f)


def _gate() -> dict:
    """Run the gate legs and ASSERT the pipelined floor + bit-identity."""
    from repro.sim.config import MachineConfig
    from repro.sim.runner import simulate

    tmp = tempfile.mkdtemp(prefix="fleet_gate_")
    cache_dir = os.path.join(tmp, "xla-cache")
    journal = os.path.join(tmp, "gate.journal.jsonl")

    def child(mode: str, cache: bool, jnl: str | None = None) -> dict:
        out = os.path.join(tmp, f"{mode}.json")
        env = dict(
            os.environ,
            PYTHONPATH=os.pathsep.join(
                [os.path.join(ROOT, "src"), ROOT,
                 os.environ.get("PYTHONPATH", "")]
            ),
            XLA_FLAGS="--xla_force_host_platform_device_count=4",
        )
        env.pop("REPRO_FLEET_CACHE_DIR", None)
        if cache:
            env["REPRO_FLEET_CACHE_DIR"] = cache_dir
        args = [sys.executable, "-m", "benchmarks.fleet_throughput",
                "--gate-child", mode, out] + ([jnl] if jnl else [])
        r = subprocess.run(args, env=env, cwd=ROOT, capture_output=True,
                           text=True, timeout=3600)
        if r.returncode != 0:
            raise RuntimeError(f"gate child {mode} failed:\n{r.stderr[-3000:]}")
        with open(out) as f:
            return json.load(f)

    try:
        # populate the persistent compilation cache (also the cold-pipelined
        # column: pipeline alone, no cross-process cache to lean on)
        cold = child("pipelined-cold", cache=True)
        base = child("baseline", cache=False)
        pipe = child("pipelined", cache=True, jnl=journal)
        resume = child("resume", cache=True, jnl=journal)

        assert base["rows"] == pipe["rows"] == cold["rows"] == resume["rows"], \
            "gate legs disagree: pipelined path is not bit-identical"
        assert resume["groups_executed"] == 0, (
            f"resume re-executed {resume['groups_executed']} groups instead "
            "of replaying the journal"
        )
        # single-device vmap oracle: the (default-top_n, seed 0) cell
        one = simulate(APP, POLICY, MachineConfig(), intervals=GATE_INTERVALS,
                       accesses=GATE_ACCESSES, seed=0)
        top0, s0, ipc, cyc, migs, mig_b = sorted(base["rows"])[0]
        assert (ipc, cyc, migs, mig_b) == (
            one.ipc, one.total_cycles, one.migrations, one.mig_bytes
        ), "gate rows diverge from the single-device simulate() oracle"

        cells = base["cells"]
        legs = {"baseline": base, "pipelined-cold": cold, "pipelined": pipe,
                "resume": resume}
        rows = [
            {
                "mode": name,
                "cells": d["cells"],
                "signatures": GATE_SIGS,
                "seconds": round(d["elapsed"], 3),
                "cells_per_sec": round(d["cells"] / d["elapsed"], 3),
                "groups_executed": d["groups_executed"],
                "compile_s": round(d["compile_s"], 3),
                "stage_s": round(d["stage_s"], 3),
                "scan_s": round(d["scan_s"], 3),
                "retire_s": round(d["retire_s"], 3),
            }
            for name, d in legs.items()
        ]
        speedup = base["elapsed"] / pipe["elapsed"]
        if speedup < GATE_FLOOR:
            raise RuntimeError(
                f"fleet throughput gate FAILED: pipelined path is only "
                f"{speedup:.2f}x the double-buffered baseline over {cells} "
                f"cells x {GATE_SIGS} signatures (floor: {GATE_FLOOR}x)"
            )
        return {
            "rows": rows,
            "speedup": speedup,
            "cold_speedup": base["elapsed"] / cold["elapsed"],
            "resume_speedup": base["elapsed"] / resume["elapsed"],
            "cells": cells,
        }
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def run() -> None:
    t0 = time.time()
    out = _measure()
    emit(
        "fleet_throughput", out["rows"], t0,
        derived=(
            f"sharded_vs_vmap={out['sharded_vs_vmap']:.2f}x;"
            f"sharded_vs_hostloop={out['sharded_vs_host']:.2f}x;"
            f"streamed_vs_barrier={out['streamed_vs_barrier']:.2f}x;"
            f"first_result_speedup={out['first_result_speedup']:.2f}x;"
            f"fused_vs_staged={out['fused_vs_staged']:.2f}x;"
            f"devices={len(jax.devices())}"
        ),
    )
    t1 = time.time()
    gate = _gate()
    emit(
        "fleet_throughput_gate", gate["rows"], t1,
        derived=(
            f"pipelined_vs_baseline={gate['speedup']:.2f}x(floor {GATE_FLOOR}x);"
            f"cold_pipelined_vs_baseline={gate['cold_speedup']:.2f}x;"
            f"resume_vs_baseline={gate['resume_speedup']:.2f}x;"
            f"cells={gate['cells']};devices=4(forced,subprocess)"
        ),
    )
    write_bench_json("fleet", {
        "unit": "cells_per_sec",
        "app": APP,
        "policy": POLICY,
        "cells": FLEET,
        "devices": len(jax.devices()),
        "rows": out["rows"],
        "sharded_vs_vmap_speedup": round(out["sharded_vs_vmap"], 3),
        "fused_vs_staged_speedup": round(out["fused_vs_staged"], 3),
        "gate": {
            "floor": GATE_FLOOR,
            "speedup": round(gate["speedup"], 3),
            "cold_speedup": round(gate["cold_speedup"], 3),
            "resume_speedup": round(gate["resume_speedup"], 3),
            "cells": gate["cells"],
            "rows": gate["rows"],
            "bit_identical": True,
        },
        "headline": (
            f"pipelined {gate['speedup']:.2f}x baseline over {gate['cells']} "
            f"cells (floor {GATE_FLOOR}x), rows bit-identical across legs"
        ),
    })


if __name__ == "__main__":
    if len(sys.argv) >= 4 and sys.argv[1] == "--gate-child":
        _gate_child(sys.argv[2], sys.argv[3],
                    sys.argv[4] if len(sys.argv) > 4 else None)
    else:
        run()
