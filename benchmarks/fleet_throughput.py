"""Sweep-cell throughput: host-loop vs batched (vmap) vs mesh-sharded fleet.

cells/sec over a homogeneous 32-cell fleet (one app x policy, many seeds):

  host-loop   one simulate() per cell, serially — how the figure drivers
              called the engine before the FleetRunner
  batched     the PR 1 path: sweep_seeds (one vmapped compile, device 0)
              + the same per-cell finalize the old sim.runner.sweep did
  sharded     FleetRunner: shard_map over the fleet mesh, padded fleet axis,
              double-buffered host staging, per-cell SimMetrics
  barrier/streamed
              the same 32 cells split over 4 compile-signature groups, run
              through FleetRunner.run (all results at the end) vs
              FleetRunner.run_iter (each group retired as its scan finishes);
              total cells/sec should tie — the streamed win is
              time-to-first-result (first_result_s column)
  staged-scenario/fused-scenario
              the same fleet on a workload SCENARIO (repro.workloads): traces
              materialized host-side from the generator stream and staged
              (the differential-oracle path) vs synthesized INSIDE the
              sharded engine scan (EngineSpec.source) — the fused leg stages
              only a seed vector, so generation rides the mesh instead of
              the host (target: >= 1.2x staged cells/sec on 4 host devices)

The fleet axis needs enough lanes for device parallelism to beat the vmap
lanes' vectorization (per-scan-step op overhead dominates small fleets on
CPU); 32 cells is the knee on a 4-device host mesh and matches the paper
grid's scale (17 workloads x 5 policies).

Standalone (python -m benchmarks.fleet_throughput) forces 4 host devices so
the mesh is real; under benchmarks.run it uses whatever devices exist.
"""
from __future__ import annotations

import os
import sys

if (
    __name__ == "__main__"
    and "jax" not in sys.modules
    and "host_platform_device_count" not in os.environ.get("XLA_FLAGS", "")
):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=4"
    ).strip()

import time

import jax
import numpy as np

from benchmarks.common import QUICK, emit

APP = "streamcluster"
# The staged/fused contrast is staging-bound, so the scenario legs use a
# footprint large enough that host materialization (which, like the numpy
# app path, re-derives the interval-invariant setup every interval) is a
# real cost; the fused scan runs setup once per simulation.
SCENARIO = "stress/zipf-hotspot"
POLICY = "rainbow"
FLEET = 32
INTERVALS = 3 if QUICK else 6
ACCESSES = 10_000 if QUICK else 60_000


def _bench(fn, reps: int = 2) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _measure() -> dict:
    import repro.engine.simloop as simloop
    from repro.engine import fleet
    from repro.sim.config import MachineConfig
    from repro.sim.runner import finalize_metrics, simulate, totals_from_stats

    mc = MachineConfig()
    seeds = list(range(FLEET))
    plan = fleet.SweepPlan.grid(
        [APP], [POLICY], tuple(seeds), intervals=INTERVALS, accesses=ACCESSES
    )
    runner = fleet.FleetRunner()

    def host_loop():
        for s in seeds:
            simulate(APP, POLICY, mc, intervals=INTERVALS, accesses=ACCESSES,
                     seed=s)

    def batched():
        finals, stats, meta = simloop.sweep_seeds(
            APP, POLICY, mc, seeds, intervals=INTERVALS, accesses=ACCESSES
        )
        for i in range(len(seeds)):
            per = type(stats)(*(np.asarray(x)[i] for x in stats))
            totals = totals_from_stats(POLICY, mc, per,
                                       meta["accesses_per_interval"])
            counters = type(finals.sim.counters)(
                *(np.asarray(x)[i] for x in finals.sim.counters)
            )
            finalize_metrics(APP, POLICY, mc, totals, counters,
                             meta["inst_per_access"], meta["footprint_pages"])

    def sharded():
        runner.run(plan)

    # Streaming leg: same cell count split over 4 compile-signature groups
    # (4 MachineConfig variants x 8 seeds, identical trace shapes), so
    # run_iter actually has groups to retire incrementally.  Barrier vs
    # streamed total throughput should tie; the streamed win is
    # TIME-TO-FIRST-RESULT — downstream consumers start after group 0.
    group_plans = [
        fleet.SweepPlan.grid(
            [APP], [POLICY], tuple(range(FLEET // 4)),
            mc=MachineConfig(top_n=mc.top_n + 8 * i),
            intervals=INTERVALS, accesses=ACCESSES,
        )
        for i in range(4)
    ]
    grouped_plan = sum(group_plans[1:], group_plans[0])
    first_cell = {}

    def barrier_grouped():
        t0 = time.perf_counter()
        res = runner.run(grouped_plan)
        next(iter(res.metrics.values()))
        first_cell["barrier-grouped"] = time.perf_counter() - t0

    def streamed_grouped():
        t0 = time.perf_counter()
        for i, _ in enumerate(runner.run_iter(grouped_plan)):
            if i == 0:
                first_cell["streamed-fleet"] = time.perf_counter() - t0

    # Fused-generation leg: the same seed fleet on a workload scenario,
    # staged (host materialization of the generator stream -> device_put)
    # vs fused (chunks synthesized inside the sharded scan; only a seed
    # vector is staged).  Same cells, bit-identical metrics — the delta is
    # purely where trace generation runs.
    staged_plan = fleet.SweepPlan.grid(
        apps=[SCENARIO], policies=[POLICY], seeds=tuple(seeds),
        intervals=INTERVALS, accesses=ACCESSES,
    )
    fused_plan = fleet.SweepPlan.grid(
        policies=[POLICY], seeds=tuple(seeds), scenario=[SCENARIO],
        intervals=INTERVALS, accesses=ACCESSES,
    )

    def staged_scenario():
        runner.run(staged_plan)

    def fused_scenario():
        runner.run(fused_plan)

    modes = [("host-loop", host_loop, 1), ("batched-vmap", batched, 2),
             ("sharded-fleet", sharded, 2),
             ("barrier-grouped", barrier_grouped, 2),
             ("streamed-fleet", streamed_grouped, 2),
             ("staged-scenario", staged_scenario, 2),
             ("fused-scenario", fused_scenario, 2)]
    rows, rates = [], {}
    simulate(APP, POLICY, mc, intervals=INTERVALS, accesses=ACCESSES,
             seed=seeds[0])  # warm the single-cell compile for host-loop
    for name, fn, reps in modes:
        fn()  # warm (compile + caches)
        t = _bench(fn, reps=reps)
        rates[name] = FLEET / t
        rows.append({
            "mode": name,
            "cells": FLEET,
            "intervals": INTERVALS,
            "accesses_per_interval": ACCESSES,
            "devices": len(jax.devices()),
            "seconds": round(t, 3),
            "cells_per_sec": round(FLEET / t, 3),
            # only the grouped barrier/streamed legs instrument first-result
            # latency; blank elsewhere rather than passing off total runtime
            "first_result_s": (
                round(first_cell[name], 3) if name in first_cell else ""
            ),
        })
    return {
        "rows": rows,
        "sharded_vs_vmap": rates["sharded-fleet"] / rates["batched-vmap"],
        "sharded_vs_host": rates["sharded-fleet"] / rates["host-loop"],
        "streamed_vs_barrier": rates["streamed-fleet"] / rates["barrier-grouped"],
        "first_result_speedup": (
            first_cell["barrier-grouped"] / first_cell["streamed-fleet"]
        ),
        "fused_vs_staged": rates["fused-scenario"] / rates["staged-scenario"],
    }


def run() -> None:
    t0 = time.time()
    out = _measure()
    emit(
        "fleet_throughput", out["rows"], t0,
        derived=(
            f"sharded_vs_vmap={out['sharded_vs_vmap']:.2f}x;"
            f"sharded_vs_hostloop={out['sharded_vs_host']:.2f}x;"
            f"streamed_vs_barrier={out['streamed_vs_barrier']:.2f}x;"
            f"first_result_speedup={out['first_result_speedup']:.2f}x;"
            f"fused_vs_staged={out['fused_vs_staged']:.2f}x;"
            f"devices={len(jax.devices())}"
        ),
    )


if __name__ == "__main__":
    run()
