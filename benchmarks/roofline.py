"""§Roofline: aggregate the dry-run artifacts into the per-cell roofline table.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and prints the
three terms, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and a one-line
"what would move the dominant term" note per (arch x shape x mesh)."""
from __future__ import annotations

import glob
import json
import os
import time

from benchmarks.common import emit

NOTES = {
    ("memory_s", "train"): "chunked/flash attention kills S^2 softmax HBM traffic",
    ("memory_s", "decode"): "paged+quantized KV; fuse gather into attention kernel",
    ("memory_s", "prefill"): "chunked attention + bf16 logits; larger fusion blocks",
    ("collective_s", "train"): "seq-parallel resid (AR -> RS+AG) + overlap w/ compute",
    ("collective_s", "decode"): "shard KV heads not batch; duplicate small params",
    ("collective_s", "prefill"): "overlap all-gather with per-layer compute (async)",
    ("compute_s", "train"): "already MXU-bound: raise per-chip batch or quantize",
    ("compute_s", "decode"): "batch more sequences per chip (decode is latency-bound)",
    ("compute_s", "prefill"): "already MXU-bound: good roofline position",
}


def load_cells(out_dir: str = "experiments/dryrun", tag: str = ""):
    cells = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(f) as fh:
            c = json.load(fh)
        if (c.get("tag") or "") != tag:
            continue
        cells.append(c)
    return cells


def rows_from_cells(cells):
    from repro.configs import get_config, get_shape
    from repro.launch.hlo_analysis import PEAK_FLOPS, HBM_BW, decode_bytes_global

    rows = []
    for c in cells:
        r = dict(c.get("roofline", {}))
        if c["kind"] == "decode" and "error" not in r:
            # correct the HloCostAnalysis DUS full-buffer artifact (§Roofline)
            cfg = get_config(c["arch"])
            shape = get_shape(c["shape"])
            mem_corr = decode_bytes_global(cfg, shape) / c["chips"] / HBM_BW
            r["memory_s"] = mem_corr
            bound = max(r["compute_s"], mem_corr, r["collective_s"])
            r["dominant"] = max(
                ("compute_s", "memory_s", "collective_s"),
                key=lambda k: r[k],
            )
            r["roofline_fraction"] = r["compute_s"] / bound if bound else 0.0
        dom = r.get("dominant", "?")
        rows.append({
            "arch": c["arch"],
            "shape": c["shape"],
            "mesh": c["mesh"],
            "kind": c["kind"],
            "compute_s": round(r.get("compute_s", 0), 5),
            "memory_s": round(r.get("memory_s", 0), 5),
            "collective_s": round(r.get("collective_s", 0), 5),
            "dominant": dom,
            "roofline_fraction": round(r.get("roofline_fraction", 0), 4),
            "model_flops": f"{c.get('model_flops', 0):.3e}",
            "useful_flops_ratio": round(c.get("useful_flops_ratio", 0), 4),
            "bytes_per_device": c.get("memory", {}).get("peak_bytes_per_device", 0),
            "note": NOTES.get((dom, c["kind"]), ""),
        })
    return rows


def run():
    t0 = time.time()
    rows = rows_from_cells(load_cells())
    frac = [r["roofline_fraction"] for r in rows if r["mesh"] == "16x16"]
    avg = sum(frac) / max(len(frac), 1)
    emit("roofline", rows, t0, f"cells={len(rows)};avg_fraction_single_pod={avg:.3f}")
    return rows


if __name__ == "__main__":
    run()
