"""Fig. 1 + Tables I/II: trace-generator calibration check.

Verifies the synthetic traces actually reproduce the paper's measured
statistics: CDF of touched 4KB pages per superpage, hot-page percentage, and
the distribution of hot pages across superpages."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.sim.config import APPS, PAGES_PER_SP
from repro.sim.trace import generate


def run(apps=None):
    t0 = time.time()
    rows = []
    for app in apps or list(APPS):
        tr = generate(app, seed=7, interval=1)
        sp_touched = {}
        for s, p in zip(tr.sp, tr.page):
            sp_touched.setdefault(int(s), set()).add(int(p))
        touched = np.array([len(v) for v in sp_touched.values()])
        counts = np.bincount(tr.vpn.astype(np.int64), minlength=tr.footprint_pages)
        order = np.argsort(-counts)
        csum = np.cumsum(counts[order])
        n_hot = int(np.searchsorted(csum, 0.70 * csum[-1])) + 1
        ws_pages = int((counts > 0).sum())
        rows.append({
            "app": app,
            "sp_with_le32_touched_pct": round(float((touched <= 32).mean() * 100), 1),
            "median_touched_per_sp": int(np.median(touched)),
            "pages_per_sp": PAGES_PER_SP,
            "hot_page_pct_measured": round(100 * n_hot / max(ws_pages, 1), 2),
            "hot_page_pct_paper": APPS[app].hot_page_pct if app in APPS else "",
            "working_set_pages": ws_pages,
        })
    emit("paper_fig1_table12", rows, t0, "calibration")
    return rows


if __name__ == "__main__":
    run()
