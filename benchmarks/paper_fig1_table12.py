"""Fig. 1 + Tables I/II: trace-generator calibration check.

Verifies the synthetic traces actually reproduce the paper's measured
statistics: CDF of touched 4KB pages per superpage, hot-page percentage, and
the distribution of hot pages across superpages. The app grid is declared as
the same SweepPlan schema the simulation figures use; FleetRunner's
calibration mode computes the per-cell trace statistics.

The grid defaults to the `syn/<app>` device scenarios: since the generators
grew the Table-II bucket sampler (ZipfHotspot.sp_hot_buckets), the fused
in-scan programs carry the superpage-clustering statistic themselves and the
calibration path no longer touches the numpy host loop."""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.engine import fleet
from repro.sim.config import APPS, PAGES_PER_SP


def run(apps=None):
    t0 = time.time()
    plan = fleet.SweepPlan.grid(
        apps or [f"syn/{a}" for a in APPS], ["rainbow"]
    )
    stats = fleet.FleetRunner().calibration(plan)
    rows = []
    for cell in plan:
        s = stats[cell]
        paper_name = cell.app.removeprefix("syn/")
        rows.append({
            "app": paper_name,
            "sp_with_le32_touched_pct": s["sp_with_le32_touched_pct"],
            "median_touched_per_sp": s["median_touched_per_sp"],
            "pages_per_sp": PAGES_PER_SP,
            "hot_page_pct_measured": s["hot_page_pct_measured"],
            "hot_page_pct_paper": APPS[paper_name].hot_page_pct
            if paper_name in APPS else "",
            "working_set_pages": s["working_set_pages"],
        })
    emit("paper_fig1_table12", rows, t0, "calibration")
    return rows


if __name__ == "__main__":
    run()
