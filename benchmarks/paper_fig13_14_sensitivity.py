"""Figs. 13/14: sensitivity of Rainbow to the sampling interval and top-N."""
import dataclasses
import time

from benchmarks.common import emit, sim_kwargs
from repro.sim.config import MachineConfig
from repro.sim.runner import simulate

APPS = ["soplex", "GUPS"]


def run():
    t0 = time.time()
    kw = sim_kwargs()
    rows = []
    base_acc = kw["accesses"] or 120_000
    # Fig 13: interval scaling — emulate longer intervals with more accesses
    # (and top-N scaled by the same factor, as the paper does)
    for factor, label in ((0.25, "0.25x"), (1.0, "1x"), (4.0, "4x")):
        mc = MachineConfig(top_n=max(4, int(100 * factor)))
        for app in APPS:
            m = simulate(app, "rainbow", mc=mc, intervals=kw["intervals"],
                         accesses=int(base_acc * factor))
            rows.append({"sweep": "interval", "setting": label, "app": app,
                         "ipc": round(m.ipc, 4),
                         "traffic": round(m.traffic_ratio, 4),
                         "migrations": m.migrations})
    # Fig 14: top-N sweep at fixed interval
    for topn in (10, 50, 100, 200):
        mc = MachineConfig(top_n=topn)
        for app in APPS:
            m = simulate(app, "rainbow", mc=mc, intervals=kw["intervals"],
                         accesses=base_acc)
            rows.append({"sweep": "top_n", "setting": topn, "app": app,
                         "ipc": round(m.ipc, 4),
                         "traffic": round(m.traffic_ratio, 4),
                         "migrations": m.migrations})
    emit("paper_fig13_14_sensitivity", rows, t0, "ipc_stabilizes_by_topN=50")
    return rows


if __name__ == "__main__":
    run()
