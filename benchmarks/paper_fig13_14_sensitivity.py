"""Figs. 13/14: sensitivity of Rainbow to the sampling interval and top-N.

The two sweeps are declared as tagged SweepPlans (machine-config overrides x
apps) and run through the same FleetRunner as every other figure — cells that
share a (config, shape) signature fuse onto one sharded fleet axis."""
import time

from benchmarks.common import emit, sim_kwargs
from repro.engine import fleet
from repro.sim.config import MachineConfig

APPS = ["soplex", "GUPS"]


def sweep_plan() -> "fleet.SweepPlan":
    kw = sim_kwargs()
    base_acc = kw["accesses"] or 120_000
    plan = fleet.SweepPlan(())
    # Fig 13: interval scaling — emulate longer intervals with more accesses
    # (and top-N scaled by the same factor, as the paper does)
    for factor, label in ((0.25, "0.25x"), (1.0, "1x"), (4.0, "4x")):
        plan += fleet.SweepPlan.grid(
            APPS, ["rainbow"],
            mc=MachineConfig(top_n=max(4, int(100 * factor))),
            intervals=kw["intervals"], accesses=int(base_acc * factor),
            tags=(("sweep", "interval"), ("setting", label)),
        )
    # Fig 14: top-N sweep at fixed interval
    for topn in (10, 50, 100, 200):
        plan += fleet.SweepPlan.grid(
            APPS, ["rainbow"], mc=MachineConfig(top_n=topn),
            intervals=kw["intervals"], accesses=base_acc,
            tags=(("sweep", "top_n"), ("setting", topn)),
        )
    return plan


def run():
    t0 = time.time()
    result = fleet.FleetRunner().run(sweep_plan())
    rows = [
        {"sweep": cell.tag["sweep"], "setting": cell.tag["setting"],
         "app": cell.app, "ipc": round(m.ipc, 4),
         "traffic": round(m.traffic_ratio, 4), "migrations": m.migrations}
        for cell, m in result.items()
    ]
    emit("paper_fig13_14_sensitivity", rows, t0, "ipc_stabilizes_by_topN=50")
    return rows


if __name__ == "__main__":
    run()
