"""Shared benchmark helpers: workload sets, CSV emission, quick/full modes."""
from __future__ import annotations

import json
import os
import sys
import time

QUICK = os.environ.get("BENCH_QUICK", "1") != "0"

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# quick mode: subset of apps + short traces (CI-friendly); full mode: the
# paper's complete workload table (BENCH_QUICK=0)
QUICK_APPS = ["cactusADM", "soplex", "streamcluster", "GUPS", "mcf", "mix2"]


def workloads():
    from repro.sim.runner import workloads as all_w

    return QUICK_APPS if QUICK else all_w()


def sim_kwargs():
    # quick mode still needs enough intervals for history-based migration to
    # converge (the paper's steady state); full mode uses the calibrated
    # per-app access counts.
    return {"intervals": 7, "accesses": 50_000} if QUICK else {
        "intervals": 8, "accesses": None}


def write_bench_json(name: str, payload: dict) -> str:
    """Write BENCH_<name>.json at the repo root (machine-readable results).

    Every payload carries `benchmark`, `quick`, and a one-line `headline`;
    benchmarks.run aggregates whatever BENCH_*.json files exist at the end
    and scripts/ci.sh asserts the schema of the gate-bearing ones.
    """
    payload = dict(payload, benchmark=name, quick=QUICK)
    path = os.path.join(ROOT, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}")
    return path


def emit(name: str, rows: list[dict], t0: float, derived: str = "") -> None:
    """Print rows as CSV plus the harness-standard summary line."""
    if rows:
        cols = list(rows[0].keys())
        print(",".join(cols))
        for r in rows:
            print(",".join(str(r[c]) for c in cols))
    us = (time.time() - t0) * 1e6 / max(len(rows), 1)
    print(f"{name},{us:.1f},{derived}")
    sys.stdout.flush()
