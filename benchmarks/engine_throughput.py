"""Microbenchmark: interval-control-loop throughput (accesses/sec).

Compares three ways of driving the same Rainbow simulation:

  looped-host     — the pre-refactor path: per-interval host trace generation +
                    one device dispatch per interval + eager (unjitted) Python
                    controller round-trips (sim.runner.simulate_eager).
  scanned-device  — the MemoryEngine: traces pre-generated and staged once,
                    the full simulation runs as a single lax.scan jit
                    (engine.simloop.engine_run); steady-state scan time.
  scanned+fused   — same scan with the fused one-pass counting kernel path
                    ("ref" oracle off-TPU, the Pallas kernel on TPU).

Then two PR 7 hot-path artifacts:

  per-phase profile — `engine_run(..., profile=True)`: where each interval's
      wall time goes (tlb walk / observe / plan / apply), with XLA
      compiled-cost analysis per phase (engine.profile; docs/engine.md).
  HOT-PATH GATE — warm `engine_run` with the vectorized fast path
      (EngineSpec.fastpath=True, the default) vs the pre-overhaul reference
      ops (fastpath=False: per-access serial lookups, full argsort selection,
      per-vpn shootdown scan, f32 histogram adds).  Each leg runs in its own
      subprocess (same isolation discipline as the fleet throughput gate) and
      dumps its per-interval stats + final counters; the parent ASSERTS the
      legs are bit-identical and that the rainbow fast path clears
      GATE_FLOOR x the reference.

Results land in BENCH_engine.json at the repo root (aggregated by
benchmarks.run, schema-checked by scripts/ci.sh).

Run: PYTHONPATH=src python -m benchmarks.engine_throughput
"""
from __future__ import annotations

import json
import os
import shutil
import subprocess
import sys
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import QUICK, ROOT, emit, write_bench_json
from repro.sim.config import MachineConfig
from repro.sim.runner import simulate_eager

APP = "streamcluster"
POLICY = "rainbow"
INTERVALS = 6 if QUICK else 10
ACCESSES = 20_000 if QUICK else 120_000
SEED = 7

# Hot-path gate: the floor applies to the headline rainbow leg (the paper's
# system — TLB walk + bitmap cache + monitor/plan/apply all active); the
# other policies ride along for bit-identity and informational speedups.
GATE_FLOOR = 1.4
GATE_POLICIES = ("rainbow", "flat-static", "hscc-4kb-mig")


def _bench(fn, reps: int = 3) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _measure() -> dict:
    from repro.engine import simloop

    mc = MachineConfig()
    total_accesses = INTERVALS * ACCESSES

    # --- looped host (one interval per dispatch; includes per-interval
    # trace generation, exactly as the pre-refactor runner executed) ---
    simulate_eager(APP, POLICY, mc, intervals=1, accesses=ACCESSES, seed=SEED)  # warm caches
    t_host = _bench(
        lambda: simulate_eager(
            APP, POLICY, mc, intervals=INTERVALS, accesses=ACCESSES, seed=SEED
        ),
        reps=1 if QUICK else 2,
    )

    rows = [{
        "mode": "looped-host",
        "intervals": INTERVALS,
        "accesses_per_interval": ACCESSES,
        "seconds": round(t_host, 4),
        "accesses_per_sec": round(total_accesses / t_host, 1),
    }]

    # --- scanned device engine (counting backends) ---
    backends = ["jax", "ref"] + (["pallas"] if jax.default_backend() == "tpu" else [])
    results = {"looped-host": total_accesses / t_host}
    chunks, meta = simloop.make_chunks(APP, POLICY, mc, SEED, INTERVALS, ACCESSES)
    for backend in backends:
        spec = simloop.EngineSpec(
            policy=POLICY, mc=mc,
            num_superpages=meta["num_superpages"],
            footprint_pages=meta["footprint_pages"],
            counter_backend=backend,
        )
        state0 = simloop.engine_init(spec)
        out = simloop.engine_run(spec, state0, chunks)  # compile + warm
        jax.block_until_ready(out)

        def scan_once():
            jax.block_until_ready(simloop.engine_run(spec, state0, chunks))

        t_scan = _bench(scan_once)
        mode = "scanned-device" if backend == "jax" else f"scanned+fused({backend})"
        rows.append({
            "mode": mode,
            "intervals": INTERVALS,
            "accesses_per_interval": ACCESSES,
            "seconds": round(t_scan, 4),
            "accesses_per_sec": round(total_accesses / t_scan, 1),
        })
        results[mode] = total_accesses / t_scan

    speedup = results["scanned-device"] / results["looped-host"]
    return {"rows": rows, "speedup": speedup}


# ---------------------------------------------------------------------------
# Per-phase profile (engine.profile via engine_run(..., profile=True))
# ---------------------------------------------------------------------------


def _profile() -> dict:
    """Phase-attributed interval costs for the headline rainbow workload."""
    from repro.engine import simloop

    mc = MachineConfig()
    chunks, meta = simloop.make_chunks(APP, POLICY, mc, SEED, INTERVALS, ACCESSES)
    spec = simloop.EngineSpec(
        policy=POLICY, mc=mc,
        num_superpages=meta["num_superpages"],
        footprint_pages=meta["footprint_pages"],
    )
    _, _, prof = simloop.engine_run(
        spec, simloop.engine_init(spec), chunks, profile=True
    )
    d = prof.as_dict()
    total_wall = sum(p["wall_s"] for p in d["phases"].values()) or 1.0
    rows = [
        {
            "phase": name,
            "wall_s": round(p["wall_s"], 4),
            "wall_frac": round(p["wall_s"] / total_wall, 3),
            "compile_s": round(p["compile_s"], 4),
            "calls": p["calls"],
            "gflops_per_call": round(p["flops"] / 1e9, 4),
            "mbytes_per_call": round(p["bytes_accessed"] / 1e6, 3),
        }
        for name, p in d["phases"].items()
    ]
    return {"rows": rows, "profile": d}


# ---------------------------------------------------------------------------
# Hot-path gate (fastpath=True vs fastpath=False, subprocess-isolated)
# ---------------------------------------------------------------------------


def _gate_child(mode: str, out_path: str) -> None:
    """One gate leg in a fresh process: warm engine_run per policy + digest.

    `mode` selects the compiled program: "fast" = the PR 7 vectorized hot
    path (EngineSpec default), "reference" = the pre-overhaul ops kept under
    fastpath=False.  The digest (per-interval stats + final counters, exact
    float64 of the f32 values) lets the parent assert bit-identity.
    """
    from repro.engine import simloop

    fastpath = mode == "fast"
    mc = MachineConfig()
    legs = {}
    for policy in GATE_POLICIES:
        chunks, meta = simloop.make_chunks(
            APP, policy, mc, SEED, INTERVALS, ACCESSES
        )
        spec = simloop.EngineSpec(
            policy=policy, mc=mc,
            num_superpages=meta["num_superpages"],
            footprint_pages=meta["footprint_pages"],
            fastpath=fastpath,
        )
        state0 = simloop.engine_init(spec)
        state, stats = simloop.engine_run(spec, state0, chunks)  # compile + warm
        jax.block_until_ready((state, stats))
        t = _bench(
            lambda: jax.block_until_ready(
                simloop.engine_run(spec, state0, chunks)
            ),
            reps=3 if QUICK else 2,
        )
        digest = [
            np.asarray(x, np.float64).reshape(-1).tolist() for x in stats
        ] + [float(np.asarray(c)) for c in state.sim.counters]
        legs[policy] = {"seconds": t, "digest": digest}
    with open(out_path, "w") as f:
        json.dump({
            "mode": mode,
            "intervals": INTERVALS,
            "accesses_per_interval": ACCESSES,
            "legs": legs,
        }, f)


def _gate() -> dict:
    """Run both legs in subprocesses; assert bit-identity + the rainbow floor."""
    tmp = tempfile.mkdtemp(prefix="engine_gate_")

    def child(mode: str) -> dict:
        out = os.path.join(tmp, f"{mode}.json")
        env = dict(
            os.environ,
            PYTHONPATH=os.pathsep.join(
                [os.path.join(ROOT, "src"), ROOT,
                 os.environ.get("PYTHONPATH", "")]
            ),
        )
        args = [sys.executable, "-m", "benchmarks.engine_throughput",
                "--gate-child", mode, out]
        r = subprocess.run(args, env=env, cwd=ROOT, capture_output=True,
                           text=True, timeout=3600)
        if r.returncode != 0:
            raise RuntimeError(f"gate child {mode} failed:\n{r.stderr[-3000:]}")
        with open(out) as f:
            return json.load(f)

    try:
        ref = child("reference")
        fast = child("fast")
        total_accesses = INTERVALS * ACCESSES
        rows, per_policy = [], {}
        for policy in GATE_POLICIES:
            a, b = ref["legs"][policy], fast["legs"][policy]
            assert a["digest"] == b["digest"], (
                f"hot-path gate FAILED: fastpath SimMetrics inputs diverge "
                f"from the reference ops on {policy}"
            )
            sp = a["seconds"] / b["seconds"]
            per_policy[policy] = {
                "reference_s": round(a["seconds"], 4),
                "fast_s": round(b["seconds"], 4),
                "speedup": round(sp, 3),
                "accesses_per_sec": round(total_accesses / b["seconds"], 1),
            }
            rows.append({
                "policy": policy,
                "intervals": INTERVALS,
                "accesses_per_interval": ACCESSES,
                "reference_s": round(a["seconds"], 4),
                "fast_s": round(b["seconds"], 4),
                "speedup": round(sp, 3),
                "bit_identical": True,
            })
        speedup = per_policy[POLICY]["speedup"]
        if speedup < GATE_FLOOR:
            raise RuntimeError(
                f"engine hot-path gate FAILED: fastpath warm engine_run is "
                f"only {speedup:.2f}x the pre-overhaul reference on {POLICY} "
                f"(floor: {GATE_FLOOR}x)"
            )
        return {
            "rows": rows,
            "speedup": speedup,
            "per_policy": per_policy,
            "floor": GATE_FLOOR,
        }
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def run() -> None:
    t0 = time.time()
    out = _measure()
    emit(
        "engine_throughput", out["rows"], t0,
        derived=f"scanned_vs_host_speedup={out['speedup']:.1f}x",
    )
    t1 = time.time()
    prof = _profile()
    emit("engine_profile", prof["rows"], t1,
         derived=f"intervals={INTERVALS};policy={POLICY}")
    t2 = time.time()
    gate = _gate()
    emit(
        "engine_hotpath_gate", gate["rows"], t2,
        derived=(
            f"fastpath_vs_reference={gate['speedup']:.2f}x"
            f"(floor {GATE_FLOOR}x);policies={len(GATE_POLICIES)};"
            "subprocess-isolated"
        ),
    )
    write_bench_json("engine", {
        "unit": "accesses_per_sec",
        "app": APP,
        "policy": POLICY,
        "intervals": INTERVALS,
        "accesses_per_interval": ACCESSES,
        "rows": out["rows"],
        "scanned_vs_host_speedup": round(out["speedup"], 3),
        "profile": prof["profile"],
        "gate": {
            "floor": GATE_FLOOR,
            "speedup": gate["speedup"],
            "per_policy": gate["per_policy"],
            "bit_identical": True,
        },
        "headline": (
            f"fastpath {gate['speedup']:.2f}x reference warm engine_run "
            f"(floor {GATE_FLOOR}x), bit-identical on "
            f"{len(GATE_POLICIES)} policies"
        ),
    })


if __name__ == "__main__":
    if len(sys.argv) >= 4 and sys.argv[1] == "--gate-child":
        _gate_child(sys.argv[2], sys.argv[3])
    else:
        run()
