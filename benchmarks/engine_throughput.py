"""Microbenchmark: interval-control-loop throughput (accesses/sec).

Compares three ways of driving the same Rainbow simulation:

  looped-host     — the pre-refactor path: per-interval host trace generation +
                    one device dispatch per interval + eager (unjitted) Python
                    controller round-trips (sim.runner.simulate_eager).
  scanned-device  — the MemoryEngine: traces pre-generated and staged once,
                    the full simulation runs as a single lax.scan jit
                    (engine.simloop.engine_run); steady-state scan time.
  scanned+fused   — same scan with the fused one-pass counting kernel path
                    ("ref" oracle off-TPU, the Pallas kernel on TPU).

Run: PYTHONPATH=src python -m benchmarks.engine_throughput
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import QUICK, emit
from repro.sim.config import MachineConfig
from repro.sim.runner import simulate_eager

APP = "streamcluster"
POLICY = "rainbow"
INTERVALS = 6 if QUICK else 10
ACCESSES = 20_000 if QUICK else 120_000
SEED = 7


def _bench(fn, reps: int = 3) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _measure() -> dict:
    from repro.engine import simloop

    mc = MachineConfig()
    total_accesses = INTERVALS * ACCESSES

    # --- looped host (one interval per dispatch; includes per-interval
    # trace generation, exactly as the pre-refactor runner executed) ---
    simulate_eager(APP, POLICY, mc, intervals=1, accesses=ACCESSES, seed=SEED)  # warm caches
    t_host = _bench(
        lambda: simulate_eager(
            APP, POLICY, mc, intervals=INTERVALS, accesses=ACCESSES, seed=SEED
        ),
        reps=1 if QUICK else 2,
    )

    rows = [{
        "mode": "looped-host",
        "intervals": INTERVALS,
        "accesses_per_interval": ACCESSES,
        "seconds": round(t_host, 4),
        "accesses_per_sec": round(total_accesses / t_host, 1),
    }]

    # --- scanned device engine (counting backends) ---
    backends = ["jax", "ref"] + (["pallas"] if jax.default_backend() == "tpu" else [])
    results = {"looped-host": total_accesses / t_host}
    chunks, meta = simloop.make_chunks(APP, POLICY, mc, SEED, INTERVALS, ACCESSES)
    for backend in backends:
        spec = simloop.EngineSpec(
            policy=POLICY, mc=mc,
            num_superpages=meta["num_superpages"],
            footprint_pages=meta["footprint_pages"],
            counter_backend=backend,
        )
        state0 = simloop.engine_init(spec)
        out = simloop.engine_run(spec, state0, chunks)  # compile + warm
        jax.block_until_ready(out)

        def scan_once():
            jax.block_until_ready(simloop.engine_run(spec, state0, chunks))

        t_scan = _bench(scan_once)
        mode = "scanned-device" if backend == "jax" else f"scanned+fused({backend})"
        rows.append({
            "mode": mode,
            "intervals": INTERVALS,
            "accesses_per_interval": ACCESSES,
            "seconds": round(t_scan, 4),
            "accesses_per_sec": round(total_accesses / t_scan, 1),
        })
        results[mode] = total_accesses / t_scan

    speedup = results["scanned-device"] / results["looped-host"]
    return {"rows": rows, "speedup": speedup}


def run() -> None:
    t0 = time.time()
    out = _measure()
    emit(
        "engine_throughput", out["rows"], t0,
        derived=f"scanned_vs_host_speedup={out['speedup']:.1f}x",
    )


if __name__ == "__main__":
    run()
