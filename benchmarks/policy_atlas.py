"""Policy atlas: which policy wins where, across the scenario space.

Memos (PAPERS.md) shows hybrid-memory policy rankings INVERT across access
patterns; the paper's Figs. 7-15 compare Rainbow vs the HSCC baselines on the
app table only. This benchmark generalizes that comparison to every
registered workload scenario (repro.workloads.scenarios): a
(scenario x policy-preset x ControlPolicy-knob x seed) grid streamed through
the fleet as FUSED cells (traces synthesized inside the sharded scan), with
journal resume — at full scale (BENCH_QUICK=0) all 19 scenarios x 6 policy
columns x 3 seeds.

The run leans on the whole atlas-scale fast path: every (scenario, preset)
pair is its own compile signature, so the CompileCache + persistent
compilation cache (REPRO_FLEET_CACHE_DIR) decide whether a repeat/resumed
atlas recompiles anything; the prefetch pipeline stages ahead; the journal
batches retirement I/O.

Outputs:
  - rendered which-policy-wins-where matrix (mean IPC per cell, winner
    starred) on stdout
  - BENCH_atlas.json: config, per-cell rows, matrix, winners, per-group
    GroupTiming rows (this run + everything the journal accumulated),
    compile-cache stats, cells/sec

CLI (ci.sh runs the 2x2x2 smoke):
  PYTHONPATH=src python -m benchmarks.policy_atlas \\
      --scenarios 2 --policies 2 --seeds 2 --journal /tmp/atlas.jsonl \\
      --out BENCH_atlas.json --resume-check
"""
from __future__ import annotations

import argparse
import json
import os
import sys

if (
    __name__ == "__main__"
    and "jax" not in sys.modules
    and "host_platform_device_count" not in os.environ.get("XLA_FLAGS", "")
):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=4"
    ).strip()

import time

import numpy as np

from benchmarks.common import QUICK, emit

# Quick-mode scenario picks: one skewed, one streaming, one drifting — the
# regimes where rankings are known to diverge. Full mode sweeps the registry.
QUICK_SCENARIOS = ["stress/zipf-hotspot", "stress/seq-scan",
                   "stress/phase-shift"]
INTERVALS = 2 if QUICK else 4
ACCESSES = 1200 if QUICK else 20_000
SEEDS = (0, 1) if QUICK else (0, 1, 2)


def _policy_columns(mc):
    """(column label, engine policy kind, ControlPolicy override | None).

    The first four are the paper's comparison (Rainbow vs HSCC 4KB/2MB vs the
    flat baseline); the knob variants probe the ControlPolicy axis the
    ISSUE's Memos motivation cares about (does doubling the hot-set monitor
    or retaining counter history change who wins?).
    """
    from repro.engine.policy import get_policy

    rb = get_policy("sim-rainbow", mc=mc)
    return [
        ("rainbow", "rainbow", None),
        ("hscc-4kb", "hscc-4kb-mig", None),
        ("hscc-2mb", "hscc-2mb-mig", None),
        ("flat-static", "flat-static", None),
        ("rainbow/top_n-x2", "rainbow", rb.replace(top_n=2 * mc.top_n)),
        ("rainbow/decay-0.5", "rainbow", rb.replace(counter_decay=0.5)),
    ]


def build_plan(scenarios, columns, seeds, intervals, accesses):
    """One SweepPlan for the whole atlas: one grid per policy column, added.

    Per-kind grids are REQUIRED by SweepPlan.grid (a single ControlPolicy
    override cannot span policy kinds whose knobs use different units);
    the column label rides on each cell as a ("variant", ...) tag.
    """
    from repro.engine import fleet

    plan = None
    for label, kind, control in columns:
        grid = fleet.SweepPlan.grid(
            policies=[kind], seeds=tuple(seeds), scenario=tuple(scenarios),
            intervals=intervals, accesses=accesses, policy=control,
            tags=(("variant", label),),
        )
        plan = grid if plan is None else plan + grid
    return plan


def _rows(cells_metrics):
    return [
        {
            "scenario": c.app,
            "variant": c.tag["variant"],
            "seed": c.seed,
            "ipc": m.ipc,
            "mpki": m.mpki,
            "total_cycles": m.total_cycles,
            "migrations": m.migrations,
            "mig_bytes": m.mig_bytes,
            "tlb_service_frac": m.tlb_service_frac,
        }
        for c, m in cells_metrics
    ]


def _matrix(rows, scenarios, columns):
    """{scenario: {column: mean IPC across seeds}} + per-scenario winner."""
    mat: dict[str, dict[str, float]] = {}
    for scen in scenarios:
        mat[scen] = {}
        for label, _, _ in columns:
            vals = [r["ipc"] for r in rows
                    if r["scenario"] == scen and r["variant"] == label]
            mat[scen][label] = float(np.mean(vals)) if vals else float("nan")
    winners = {scen: max(cols, key=cols.get) for scen, cols in mat.items()}
    return mat, winners


def render_matrix(mat, winners) -> str:
    """The which-policy-wins-where table (winner starred per scenario row)."""
    cols = list(next(iter(mat.values())))
    w0 = max(len("scenario"), *(len(s) for s in mat))
    widths = [max(len(c), 10) for c in cols]
    lines = [
        " | ".join(["scenario".ljust(w0)]
                   + [c.rjust(w) for c, w in zip(cols, widths)]),
        "-+-".join(["-" * w0] + ["-" * w for w in widths]),
    ]
    for scen, by_col in mat.items():
        cells = []
        for c, w in zip(cols, widths):
            star = "*" if winners[scen] == c else " "
            cells.append(f"{star}{by_col[c]:.4f}".rjust(w))
        lines.append(" | ".join([scen.ljust(w0)] + cells))
    return "\n".join(lines)


def run_atlas(scenarios=None, n_policies=None, seeds=None, intervals=None,
              accesses=None, journal=None, out_path="BENCH_atlas.json",
              resume_check=False, quiet=False) -> dict:
    import jax

    from repro.engine import fleet
    from repro.sim.config import MachineConfig
    from repro.workloads.scenarios import available_scenarios

    mc = MachineConfig()
    if scenarios is None:
        scenarios = QUICK_SCENARIOS if QUICK else list(available_scenarios())
    columns = _policy_columns(mc)
    if n_policies is not None:
        columns = columns[:n_policies]
    seeds = tuple(seeds if seeds is not None else SEEDS)
    intervals = intervals or INTERVALS
    accesses = accesses or ACCESSES

    plan = build_plan(scenarios, columns, seeds, intervals, accesses)
    runner = fleet.FleetRunner()
    t0 = time.perf_counter()
    pairs = list(runner.run_iter(plan, journal=journal))
    elapsed = time.perf_counter() - t0

    rows = _rows(pairs)
    mat, winners = _matrix(rows, scenarios, columns)
    executed = sum(t.cells for t in runner.timings)
    timings = [t.row() for t in runner.timings]
    journal_timings = (
        fleet.FleetJournal(journal).load_timings() if journal else []
    )

    if resume_check:
        # A fresh runner over the same plan+journal must replay EVERY cell
        # from disk (zero groups staged/executed) and reproduce the metrics.
        runner2 = fleet.FleetRunner()
        pairs2 = list(runner2.run_iter(plan, journal=journal))
        assert dict(pairs2) == dict(pairs), "resumed atlas diverged"
        assert not runner2.timings, (
            f"resume re-executed {len(runner2.timings)} groups instead of "
            "replaying the journal"
        )
        if not quiet:
            print(f"resume check OK: {len(pairs2)} cells replayed, "
                  "0 groups re-executed")

    result = {
        "config": {
            "scenarios": list(scenarios),
            "policies": [label for label, _, _ in columns],
            "seeds": list(seeds),
            "intervals": intervals,
            "accesses": accesses,
            "devices": len(jax.devices()),
            "journal": str(journal) if journal else None,
        },
        "rows": rows,
        "matrix": mat,
        "winners": winners,
        "timings": timings,
        "journal_timings": journal_timings,
        "compile_cache": runner.compile_cache.stats(),
        "elapsed_s": round(elapsed, 3),
        "cells": len(rows),
        "cells_executed": executed,
        "cells_per_sec": round(len(rows) / elapsed, 3),
    }
    if out_path:
        with open(out_path, "w") as f:
            json.dump(result, f, indent=1)
    if not quiet:
        print(render_matrix(mat, winners))
        print(f"winners: { {s: w for s, w in winners.items()} }")
    return result


def run() -> None:
    t0 = time.time()
    out = run_atlas(out_path="BENCH_atlas.json")
    flat = [
        {"scenario": s, **{c: round(v, 4) for c, v in cols.items()},
         "winner": out["winners"][s]}
        for s, cols in out["matrix"].items()
    ]
    inversions = len(set(out["winners"].values()))
    emit(
        "policy_atlas", flat, t0,
        derived=(
            f"cells={out['cells']};cells_per_sec={out['cells_per_sec']};"
            f"distinct_winners={inversions};"
            f"compile_hits={out['compile_cache']['hits']};"
            f"compile_misses={out['compile_cache']['misses']}"
        ),
    )


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    p.add_argument("--scenarios", default=None,
                   help="comma-separated scenario names, or a count to take "
                        "the first N registered")
    p.add_argument("--policies", type=int, default=None,
                   help="use the first N policy columns")
    p.add_argument("--seeds", type=int, default=None,
                   help="seeds 0..N-1")
    p.add_argument("--intervals", type=int, default=None)
    p.add_argument("--accesses", type=int, default=None)
    p.add_argument("--journal", default=None,
                   help="journal path: stream + checkpoint; resumable")
    p.add_argument("--out", default="BENCH_atlas.json")
    p.add_argument("--resume-check", action="store_true",
                   help="after the run, replay the journal with a fresh "
                        "runner and assert bit-identical, zero re-execution")
    args = p.parse_args(argv)

    scenarios = None
    if args.scenarios:
        if args.scenarios.isdigit():
            from repro.workloads.scenarios import available_scenarios

            scenarios = list(available_scenarios())[: int(args.scenarios)]
        else:
            scenarios = args.scenarios.split(",")
    seeds = tuple(range(args.seeds)) if args.seeds else None
    run_atlas(scenarios=scenarios, n_policies=args.policies, seeds=seeds,
              intervals=args.intervals, accesses=args.accesses,
              journal=args.journal, out_path=args.out,
              resume_check=args.resume_check)


if __name__ == "__main__":
    main()
