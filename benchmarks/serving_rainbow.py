"""Layer-B technique benchmark: Rainbow paged decode vs flat decode on CPU
(reduced config) — wall time + exactness + promotion stats. The roofline-level
comparison for the full configs lives in the dry-run artifacts (--kv paged)."""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs import get_reduced_config
from repro.engine.policy import get_policy
from repro.memory.kvcache import PagedConfig, paged_init
from repro.models import model as M
from repro.serving.rainbow_decode import rainbow_decode_step


def run():
    t0 = time.time()
    cfg = get_reduced_config("qwen3-4b")
    key = jax.random.PRNGKey(0)
    B, S = 4, 64
    # controller knobs from the registered preset, resized to this geometry
    # (the same ControlPolicy surface engine.autotune searches over)
    pcfg = PagedConfig(
        block_size=8, blocks_per_seq=S // 8,
        policy=get_policy("serving-default").replace(
            hot_slots=16, top_n=4, max_promotions=8, interval_steps=8),
    )
    params = M.init_params(cfg, key, tp=1)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    flat_step = jax.jit(lambda p, t, c: M.decode_step(cfg, p, t, c))
    rb_step = jax.jit(lambda p, t, k: rainbow_decode_step(cfg, pcfg, p, t, k))
    cache = M.init_cache(cfg, B, S, tp=1)
    kv = paged_init(cfg, pcfg, B, 1, cfg.num_layers)

    # warmup + timed loops
    fl, cache = flat_step(params, toks[:, :1], cache)
    rl, kv = rb_step(params, toks[:, :1], kv)
    jax.block_until_ready((fl, rl))

    tf = time.time()
    err = 0.0
    for t in range(1, S):
        fl, cache = flat_step(params, toks[:, t:t + 1], cache)
    jax.block_until_ready(fl)
    flat_s = time.time() - tf

    tr = time.time()
    for t in range(1, S):
        rl, kv = rb_step(params, toks[:, t:t + 1], kv)
    jax.block_until_ready(rl)
    rb_s = time.time() - tr
    err = float(jnp.abs(fl[..., :cfg.vocab_size] - rl[..., :cfg.vocab_size]).max())

    rows = [{
        "flat_ms_per_step": round(1000 * flat_s / (S - 1), 3),
        "rainbow_ms_per_step": round(1000 * rb_s / (S - 1), 3),
        "exactness_err": err,
        "promoted_blocks": int((kv.remap.remap >= 0).sum()),
        "steps": S - 1,
    }]
    emit("serving_rainbow", rows, t0, f"exact={err == 0.0}")
    return rows


if __name__ == "__main__":
    run()
