"""Fig. 9: breakdown of Rainbow's address-translation overhead
(split-TLB hits / bitmap cache / SPTWs / address remapping)."""
import time

from benchmarks.common import emit
from benchmarks.paper_policies import all_cells


def run():
    t0 = time.time()
    cells = all_cells()  # FleetResult: the sharded sweep-plan run
    apps = cells.apps()
    rows = []
    for app in apps:
        m = cells[(app, "rainbow")]
        b = m.breakdown
        trans = b["cycles_tlb"] + b["cycles_walk"] + b["cycles_bitmap"] + b["cycles_remap"]
        rows.append({
            "app": app,
            "translation_pct_of_cycles": round(100 * trans / m.total_cycles, 2),
            "split_tlb_pct": round(100 * b["cycles_tlb"] / max(trans, 1), 1),
            "bitmap_cache_pct": round(100 * b["cycles_bitmap"] / max(trans, 1), 1),
            "sptw_pct": round(100 * b["cycles_walk"] / max(trans, 1), 1),
            "remap_pct": round(100 * b["cycles_remap"] / max(trans, 1), 1),
            "bmc_misses": int(b["bmc_misses"]),
        })
    avg = sum(r["translation_pct_of_cycles"] for r in rows) / max(len(rows), 1)
    emit("paper_fig9_breakdown", rows, t0,
         f"avg_translation_overhead={avg:.1f}%_paper=12%")
    return rows


if __name__ == "__main__":
    run()
