"""Engine-in-the-loop serving autotune: record a real decode mass trace, tune
the ControlPolicy against it, and serve with the winner.

The closed loop of the API redesign: `serving.rainbow_decode.record_mass_trace`
captures the controller's access stream from a real (reduced-config) model run,
`engine.autotune` replays candidate policies through the SAME engine.control
path on zero-payload state, scores them with the "v5e-serving" cost model, and
the winning policy plugs straight back into the decode step. Also asserts the
vmap and mesh-sharded evaluation paths agree bit for bit.
"""
import time

import jax

from benchmarks.common import emit
from repro.configs import get_reduced_config
from repro.engine.autotune import TunePlan, autotune, evaluate
from repro.memory.kvcache import PagedConfig, paged_init
from repro.models import model as M
from repro.serving.rainbow_decode import rainbow_decode_step, record_mass_trace


def _timed_decode(cfg, pcfg, params, toks, S):
    step = jax.jit(lambda p, t, k: rainbow_decode_step(cfg, pcfg, p, t, k))
    kv = paged_init(cfg, pcfg, toks.shape[0], 1, cfg.num_layers)
    logits, kv = step(params, toks[:, :1], kv)  # warmup/compile
    jax.block_until_ready(logits)
    t = time.time()
    for i in range(1, S):
        logits, kv = step(params, toks[:, i:i + 1], kv)
    jax.block_until_ready(logits)
    return (time.time() - t) / (S - 1), int((kv.remap.remap >= 0).sum())


def run():
    t0 = time.time()
    cfg = get_reduced_config("qwen3-4b")
    key = jax.random.PRNGKey(0)
    B, S = 2, 32
    pcfg = PagedConfig(block_size=4, blocks_per_seq=S // 4, hot_slots=8,
                       top_n=4, max_promotions=8, interval_steps=8)
    params = M.init_params(cfg, key, tp=1)
    prompt = jax.random.randint(key, (B, S // 2), 0, cfg.vocab_size)

    trace, _ = record_mass_trace(cfg, pcfg, params, prompt, steps=S)
    plan = TunePlan.grid(
        pcfg.policy, interval_steps=(2, 4, 8), threshold_init=(0.0, 64.0)
    )
    res = autotune(plan, trace)
    assert res.improved, (
        f"tuned policy must beat the serving default on the recorded trace "
        f"(tuned {res.best_cost:.1f} vs default {res.baseline_cost:.1f})"
    )
    # bit-identity of the two evaluation paths on this real trace
    cands = plan.candidates()
    rows_v = evaluate(trace, cands, runner="vmap")
    rows_s = evaluate(trace, cands, runner="sharded")
    assert rows_v == rows_s, "vmap vs sharded evaluation diverged"

    tuned_pcfg = PagedConfig(block_size=pcfg.block_size,
                             blocks_per_seq=pcfg.blocks_per_seq,
                             policy=res.tuned_policy())
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    ms_def, prom_def = _timed_decode(cfg, pcfg, params, toks, S)
    ms_tuned, prom_tuned = _timed_decode(cfg, tuned_pcfg, params, toks, S)

    rows = [{
        "default_cost_per_step": round(res.baseline_cost, 1),
        "tuned_cost_per_step": round(res.best_cost, 1),
        "gain_pct": round(100 * (1 - res.best_cost / res.baseline_cost), 1),
        "tuned_interval_steps": res.best.interval_steps,
        "tuned_threshold_init": res.best.threshold_init,
        "candidates": len(cands),
        "default_ms_per_step": round(1000 * ms_def, 3),
        "tuned_ms_per_step": round(1000 * ms_tuned, 3),
        "default_promoted": prom_def,
        "tuned_promoted": prom_tuned,
    }]
    emit("autotune_serving", rows, t0,
         f"improved={res.improved} paths_bit_identical=True")
    return rows


if __name__ == "__main__":
    run()
