"""Fig. 11: page-migration traffic normalized to total memory footprint."""
import time

from benchmarks.common import emit
from benchmarks.paper_policies import all_cells


def run():
    t0 = time.time()
    cells = all_cells()  # FleetResult: the sharded sweep-plan run
    apps = cells.apps()
    rows = []
    reds = []
    for app in apps:
        row = {"app": app}
        for pol in ("hscc-4kb-mig", "hscc-2mb-mig", "rainbow"):
            row[pol] = round(cells[(app, pol)].traffic_ratio, 4)
        rows.append(row)
        if row["hscc-2mb-mig"] > 0:
            reds.append(1 - row["rainbow"] / row["hscc-2mb-mig"])
    avg = 100 * sum(reds) / max(len(reds), 1)
    emit("paper_fig11_traffic", rows, t0,
         f"rainbow_traffic_reduction_vs_2mb={avg:.1f}%_paper=50%")
    return rows


if __name__ == "__main__":
    run()
