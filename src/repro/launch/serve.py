"""Serving launcher: batched prefill + decode with flat or Rainbow-paged KV.

``PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --kv paged --tokens 64``

The paged path's controller knobs come from the unified ControlPolicy surface
(engine.policy): pick a registered preset with ``--policy`` and override
individual knobs with ``--interval-steps/--top-n/--hot-slots/--max-promotions``.
``--autotune`` records the decode attention-mass trace of a short pilot run,
searches (interval_steps, threshold_init) engine-in-the-loop against it
(engine.autotune), and serves with the winning policy.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced_config
from repro.engine.policy import available_policies, get_policy
from repro.memory.kvcache import PagedConfig, paged_init
from repro.models import model as M
from repro.serving.rainbow_decode import rainbow_decode_step, record_mass_trace
from repro.serving.steps import greedy_sample
from repro.timing import GEOMETRY_PRESETS, get_geometry


def resolve_timing(args, error):
    """Validated (timing_model, QueueGeometry | None) from the CLI flags.

    Mirrors EngineSpec.timing_geometry(): "flat" resolves to no geometry and
    REJECTS an explicit --queue-geometry (it would otherwise be silently
    dropped — the same loud-over-lossy rule the --kv flat audit applies to
    the controller knobs); "queueing" resolves the named preset through
    repro.timing.get_geometry, unknown names listed loudly.
    """
    if args.timing_model == "flat":
        if args.queue_geometry is not None:
            error(
                f"--queue-geometry {args.queue_geometry} has no effect under "
                "--timing-model flat; drop it or pass --timing-model queueing"
            )
        return "flat", None
    name = args.queue_geometry or "default"
    try:
        geom = get_geometry(name)
    except KeyError:
        error(
            f"unknown --queue-geometry preset {name!r}; registered: "
            f"{sorted(GEOMETRY_PRESETS)}"
        )
    geom.validate()
    return "queueing", geom


def build_paged_config(args, nblk: int) -> PagedConfig:
    """One PagedConfig from (preset, CLI overrides, geometry-aware defaults).

    Precedence: explicit CLI flags > the chosen --policy preset. Geometry-aware
    fallbacks (hot pool sized to the sequence) only apply to the generic
    "serving-default" preset — a named preset's knobs are exactly what its
    author registered.
    """
    policy = get_policy(args.policy)
    overrides = {
        k: v for k, v in {
            "hot_slots": args.hot_slots,
            "top_n": args.top_n,
            "max_promotions": args.max_promotions,
            "interval_steps": args.interval_steps,
        }.items() if v is not None
    }
    if args.policy == "serving-default":
        hot = overrides.get("hot_slots", max(8, nblk // 2))
        overrides.setdefault("hot_slots", hot)
        overrides.setdefault("top_n", min(8, nblk))
        overrides.setdefault("max_promotions", min(16, hot))
    return PagedConfig(
        block_size=args.block_size,
        blocks_per_seq=nblk,
        policy=policy.replace(**overrides) if overrides else policy,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--kv", choices=["flat", "paged"], default="paged")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--block-size", type=int, default=8)
    # -- unified ControlPolicy knobs (paged path) --
    ap.add_argument("--policy", default="serving-default",
                    help=f"registered preset, one of {available_policies()}")
    ap.add_argument("--interval-steps", type=int, default=None,
                    help="decode steps per monitoring interval")
    ap.add_argument("--top-n", type=int, default=None,
                    help="stage-2 monitored superblocks")
    ap.add_argument("--hot-slots", type=int, default=None,
                    help="hot-pool capacity in KV blocks")
    ap.add_argument("--max-promotions", type=int, default=None,
                    help="promotion-plan size per interval")
    ap.add_argument("--autotune", action="store_true",
                    help="tune (interval_steps, threshold_init) against a "
                         "recorded pilot decode trace before serving")
    # -- timing model (paged path) --
    ap.add_argument("--timing-model", choices=["flat", "queueing"],
                    default="flat",
                    help="cost model for reporting/tuning: flat event counts "
                         "or the per-channel/bank queueing model")
    ap.add_argument("--queue-geometry", default=None,
                    help="registered QueueGeometry preset, one of "
                         f"{sorted(GEOMETRY_PRESETS)} (queueing model only)")
    args = ap.parse_args()
    timing_model, queue_geom = resolve_timing(args, ap.error)

    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if args.prompt_len < 1 or args.tokens < 1:
        ap.error("--prompt-len and --tokens must be >= 1")
    if args.kv == "paged" and cfg.family not in ("dense", "vlm"):
        ap.error(
            f"--kv paged targets dense-family archs; --arch {args.arch} is "
            f"family {cfg.family!r} (use --kv flat)"
        )
    if args.kv == "flat":
        ignored = [
            flag for flag, v in [
                ("--autotune", args.autotune or None),
                ("--interval-steps", args.interval_steps),
                ("--top-n", args.top_n),
                ("--hot-slots", args.hot_slots),
                ("--max-promotions", args.max_promotions),
                ("--queue-geometry", args.queue_geometry),
                ("--timing-model",
                 None if timing_model == "flat" else timing_model),
            ] if v is not None
        ]
        if args.policy != "serving-default":
            ignored.append("--policy")
        if ignored:
            ap.error(
                f"{', '.join(ignored)} only appl{'y' if len(ignored) > 1 else 'ies'} "
                "to the Rainbow-paged cache; drop the flag(s) or use --kv paged"
            )
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key, tp=1)
    b = args.batch
    total = args.prompt_len + args.tokens
    prompt = jax.random.randint(key, (b, args.prompt_len), 0, cfg.vocab_size)

    t0 = time.time()
    if args.kv == "flat":
        cache = M.init_cache(cfg, b, total, tp=1)
        logits, cache = M.prefill(cfg, params, {"tokens": prompt}, cache, tp=1)
        step = jax.jit(lambda p, t, c: M.decode_step(cfg, p, t, c))
        tok = greedy_sample(logits[:, -1:], cfg.vocab_size)
        out = [tok]
        for _ in range(args.tokens - 1):
            logits, cache = step(params, tok, cache)
            tok = greedy_sample(logits, cfg.vocab_size)
            out.append(tok)
    else:
        nblk = (total + args.block_size - 1) // args.block_size
        try:
            pcfg = build_paged_config(args, nblk)
        except (ValueError, KeyError) as e:
            # impossible geometry / unknown preset -> clean CLI error
            ap.error(str(e.args[0]) if e.args else str(e))

        if timing_model == "queueing":
            print(f"timing model: queueing, geometry {queue_geom}")

        if args.autotune:
            from repro.engine.autotune import TunePlan, autotune

            pilot = args.prompt_len + min(args.tokens, 16)
            trace, _ = record_mass_trace(cfg, pcfg, params, prompt, steps=pilot)
            plan = TunePlan.grid(
                pcfg.policy,
                interval_steps=(2, 4, 8, 16),
                threshold_init=(0.0, 64.0),
            )
            res = autotune(plan, trace)
            print(f"autotune ({pilot}-step pilot trace): {res.summary()}")
            tuned = res.tuned_policy()
            # every knob outside the tuned axes (including the async-migration
            # family: async_window / abort_on_write / shadow_residency) must
            # ride through the tuner untouched — a tuned policy that silently
            # reset them would serve a different policy than requested
            tuned_axes = {name for name, _ in plan.space}
            drifted = {
                name
                for name in type(tuned).__dataclass_fields__
                if name not in tuned_axes
                and getattr(tuned, name) != getattr(pcfg.policy, name)
            }
            assert not drifted, (
                f"autotune dropped untuned ControlPolicy knobs: {sorted(drifted)}"
            )
            pcfg = PagedConfig(
                block_size=pcfg.block_size,
                blocks_per_seq=pcfg.blocks_per_seq,
                policy=tuned,
            )

        kv = paged_init(cfg, pcfg, b, 1, cfg.num_layers)
        step = jax.jit(lambda p, t, k: rainbow_decode_step(cfg, pcfg, p, t, k))
        # paged path consumes the prompt token-by-token (prefill-by-decode)
        tok = prompt[:, :1]
        for i in range(args.prompt_len):
            logits, kv = step(params, prompt[:, i:i + 1], kv)
        tok = greedy_sample(logits, cfg.vocab_size)
        out = [tok]
        for _ in range(args.tokens - 1):
            logits, kv = step(params, tok, kv)
            tok = greedy_sample(logits, cfg.vocab_size)
            out.append(tok)
        print(f"promoted hot blocks: {int((kv.remap.remap >= 0).sum())}")

    toks = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    print(f"decoded {args.tokens} tokens x {b} seqs in {dt:.2f}s "
          f"({1000 * dt / args.tokens:.1f} ms/step incl. compile)")
    print("first sequence:", toks[0].tolist()[:16], "...")


if __name__ == "__main__":
    main()
