"""Serving launcher: batched prefill + decode with flat or Rainbow-paged KV.

``PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --kv paged --tokens 64``
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced_config
from repro.memory.kvcache import PagedConfig, paged_init
from repro.models import model as M
from repro.serving.rainbow_decode import rainbow_decode_step
from repro.serving.steps import greedy_sample


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--kv", choices=["flat", "paged"], default="paged")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--block-size", type=int, default=8)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    assert cfg.family in ("dense", "vlm") or args.kv == "flat", \
        "paged serving targets dense-family archs"
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key, tp=1)
    b = args.batch
    total = args.prompt_len + args.tokens
    prompt = jax.random.randint(key, (b, args.prompt_len), 0, cfg.vocab_size)

    t0 = time.time()
    if args.kv == "flat":
        cache = M.init_cache(cfg, b, total, tp=1)
        logits, cache = M.prefill(cfg, params, {"tokens": prompt}, cache, tp=1)
        step = jax.jit(lambda p, t, c: M.decode_step(cfg, p, t, c))
        tok = greedy_sample(logits[:, -1:], cfg.vocab_size)
        out = [tok]
        for _ in range(args.tokens - 1):
            logits, cache = step(params, tok, cache)
            tok = greedy_sample(logits, cfg.vocab_size)
            out.append(tok)
    else:
        nblk = (total + args.block_size - 1) // args.block_size
        pcfg = PagedConfig(block_size=args.block_size, blocks_per_seq=nblk,
                           hot_slots=max(8, nblk // 2), top_n=8,
                           max_promotions=16, interval_steps=8)
        kv = paged_init(cfg, pcfg, b, 1, cfg.num_layers)
        step = jax.jit(lambda p, t, k: rainbow_decode_step(cfg, pcfg, p, t, k))
        # paged path consumes the prompt token-by-token (prefill-by-decode)
        tok = prompt[:, :1]
        for i in range(args.prompt_len):
            logits, kv = step(params, prompt[:, i:i + 1], kv)
        tok = greedy_sample(logits, cfg.vocab_size)
        out = [tok]
        for _ in range(args.tokens - 1):
            logits, kv = step(params, tok, kv)
            tok = greedy_sample(logits, cfg.vocab_size)
            out.append(tok)
        print(f"promoted hot blocks: {int((kv.remap.remap >= 0).sum())}")

    toks = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    print(f"decoded {args.tokens} tokens x {b} seqs in {dt:.2f}s "
          f"({1000 * dt / args.tokens:.1f} ms/step incl. compile)")
    print("first sequence:", toks[0].tolist()[:16], "...")


if __name__ == "__main__":
    main()
