"""Mesh axis conventions (DESIGN.md §5).

Production meshes: ("data", "model") single-pod, ("pod", "data", "model")
multi-pod. Batch/data-parallel dims shard over BATCH_AXES (the constrainer drops
axes absent from the active mesh, so model code is mesh-shape-agnostic).
"""
BATCH_AXES = ("pod", "data")
MODEL_AXIS = "model"
SEQ_AXIS = "data"  # sequence-parallel dims reuse the data axis (long_500k)
