"""ShapeDtypeStruct input stand-ins for every (arch x shape x step-kind) cell.

No device allocation: the dry-run lowers against these. Modality frontends are
stubs per the assignment: [audio] supplies precomputed frame embeddings, [vlm]
supplies patch embeddings.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch.axes import BATCH_AXES
from repro.models import model as M
from repro.models.config import ModelConfig, ShapeConfig

SDS = jax.ShapeDtypeStruct


def seq_layout(cfg: ModelConfig, seq_len: int) -> dict[str, int]:
    """How the assigned seq_len splits across modalities/enc-dec."""
    if cfg.is_encoder_decoder:
        enc = seq_len // cfg.encoder_seq_divisor
        return {"enc": enc, "dec": seq_len - enc, "text": seq_len - enc}
    if cfg.family == "vlm":
        nv = cfg.num_vision_tokens
        return {"vision": nv, "text": seq_len - nv, "dec": seq_len}
    return {"text": seq_len, "dec": seq_len}


def train_inputs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    lay = seq_layout(cfg, s)
    st = lay["text"]
    batch = {
        "tokens": SDS((b, st), jnp.int32),
        "targets": SDS((b, st), jnp.int32),
        "loss_mask": SDS((b, st), jnp.float32),
    }
    if cfg.family == "vlm":
        batch["vision_embeds"] = SDS((b, lay["vision"], cfg.d_model), jnp.bfloat16)
    if cfg.is_encoder_decoder:
        batch["frames"] = SDS((b, lay["enc"], cfg.d_model), jnp.bfloat16)
    return batch


def prefill_inputs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    batch = train_inputs(cfg, shape)
    batch.pop("targets")
    batch.pop("loss_mask")
    return batch


def decode_inputs(
    cfg: ModelConfig, shape: ShapeConfig, tp: int
) -> tuple[dict[str, Any], Any, int]:
    """Returns (token batch SDS, cache SDS tree, cache max_len)."""
    b, s = shape.global_batch, shape.seq_len
    max_len = s // cfg.encoder_seq_divisor if cfg.is_encoder_decoder else s
    tokens = {"tokens": SDS((b, 1), jnp.int32)}
    cache = jax.eval_shape(lambda: M.init_cache(cfg, b, max_len, tp=tp))
    return tokens, cache, max_len


def batch_is_replicated(shape: ShapeConfig, dp_size: int) -> bool:
    return shape.global_batch % dp_size != 0


def seq_axis_for(cfg: ModelConfig, shape: ShapeConfig, dp_size: int):
    """Shard the KV-cache sequence dim over 'data' when batch can't use it."""
    if batch_is_replicated(shape, dp_size) and not cfg.attn_free:
        return "data"
    return None
