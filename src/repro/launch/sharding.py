"""Sharding-constraint utilities: mesh-aware spec filtering.

Model code annotates activations with full logical specs (e.g. P(("pod","data"),
None, "model")). `make_constrainer(mesh)` drops axis names the mesh doesn't have,
so the same model runs on the single-pod (data, model) mesh, the multi-pod
(pod, data, model) mesh, or a 1-device CPU test mesh (sc=None skips entirely).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

Constrainer = Optional[Callable[[jax.Array, P], jax.Array]]


def filter_spec(spec: P, mesh: Mesh) -> P:
    """Drop axis names not present in `mesh` from a PartitionSpec."""
    names = set(mesh.axis_names)

    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, str):
            return entry if entry in names else None
        kept = tuple(a for a in entry if a in names)
        return kept if kept else None

    return P(*(keep(e) for e in spec))


def filter_tree(tree, mesh: Mesh):
    """Filter a pytree of PartitionSpecs against the mesh."""
    return jax.tree.map(
        lambda s: filter_spec(s, mesh), tree, is_leaf=lambda x: isinstance(x, P)
    )


def make_constrainer(mesh: Mesh, strip_batch: bool = False) -> Constrainer:
    """strip_batch: drop batch-axis entries (batch-replicated cells, e.g. B=1)."""
    from repro.launch.axes import BATCH_AXES

    def sc(x: jax.Array, spec: P) -> jax.Array:
        if strip_batch:
            spec = P(*(None if e == BATCH_AXES else e for e in spec))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, filter_spec(spec, mesh))
        )

    return sc


def sharding_tree(tree, mesh: Mesh):
    """PartitionSpec tree -> NamedSharding tree (for jit in/out_shardings)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, filter_spec(s, mesh)),
        tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_shardings(tree, mesh: Mesh, axis: str = "fleet"):
    """NamedSharding tree sharding every leaf's LEADING dim along `axis`.

    Used to device_put host-staged fleet batches (stacked states/traces)
    directly into their sharded layout — one transfer per leaf, no gather.
    """
    return sharding_tree(jax.tree.map(lambda _: P(axis), tree), mesh)
