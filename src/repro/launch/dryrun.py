import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run driver (deliverable (e)).

For each (arch x shape x mesh) cell: build the step function, jit with explicit
shardings, .lower().compile(), print memory_analysis + cost_analysis, parse the
optimized HLO for collective operand bytes, and write a JSON artifact consumed by
benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, applicable_shapes, get_config, get_shape
from repro.launch import hlo_analysis, inputs as IN
from repro.launch.mesh import make_production_mesh, mesh_dp_size, mesh_tp_size
from repro.launch.sharding import filter_tree, make_constrainer, sharding_tree
from repro.models import model as M
from repro.serving import steps as serve_steps
from repro.train.step import (
    TrainStepConfig,
    batch_specs,
    build_train_step,
    init_train_state,
    train_state_specs,
)


def _cost_get(cost: dict, key: str) -> float:
    if not cost:
        return 0.0
    return float(cost.get(key, 0.0))


def run_cell(
    arch: str,
    shape_id: str,
    multi_pod: bool,
    out_dir: str,
    attn_impl: str = "dense",
    kv_impl: str = "flat",
    remat: str = "full",
    quiet: bool = False,
    tag: str = "",
    resid: str = "tp",
) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_id)
    mesh = make_production_mesh(multi_pod=multi_pod)
    tp = mesh_tp_size(mesh)
    dp = mesh_dp_size(mesh)
    chips = mesh.devices.size
    replicated = IN.batch_is_replicated(shape, dp)
    sc = make_constrainer(mesh, strip_batch=replicated)
    seq_axis = IN.seq_axis_for(cfg, shape, dp)

    meta = {
        "arch": arch,
        "shape": shape_id,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(chips),
        "kind": shape.kind,
        "attn_impl": attn_impl,
        "kv_impl": kv_impl,
        "remat": remat,
        "batch_replicated": replicated,
        "cache_seq_axis": seq_axis,
        "tag": tag,
    }

    t0 = time.time()

    def build_lowered():
        if shape.kind == "train":
            tcfg = TrainStepConfig(tp=tp, remat=remat, attn_impl=attn_impl)
            state_sds = jax.eval_shape(
                lambda: init_train_state(cfg, jax.random.PRNGKey(0), tcfg)
            )
            state_sh = sharding_tree(train_state_specs(cfg, tcfg, dp_size=dp), mesh)
            batch_sds = IN.train_inputs(cfg, shape)
            batch_sh = sharding_tree(
                {k: v for k, v in batch_specs(cfg, replicated).items() if k in batch_sds},
                mesh,
            )
            step = build_train_step(cfg, tcfg, sc=sc)
            lowered = jax.jit(
                step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            ).lower(state_sds, batch_sds)
            fn_args = (step, (state_sds, batch_sds))
        elif shape.kind == "prefill":
            params_sds = jax.eval_shape(
                partial(M.init_params, cfg, jax.random.PRNGKey(0), tp)
            )
            params_sh = sharding_tree(M.param_specs(cfg, tp), mesh)
            batch_sds = IN.prefill_inputs(cfg, shape)
            batch_sh = sharding_tree(
                {
                    k: v
                    for k, v in serve_steps.prefill_batch_specs(cfg, replicated).items()
                    if k in batch_sds
                },
                mesh,
            )
            max_len = (
                shape.seq_len // cfg.encoder_seq_divisor
                if cfg.is_encoder_decoder
                else shape.seq_len
            )
            step = serve_steps.build_prefill_step(
                cfg, tp, max_len, sc=sc, attn_impl=attn_impl
            )
            lowered = jax.jit(step, in_shardings=(params_sh, batch_sh)).lower(
                params_sds, batch_sds
            )
            fn_args = (step, (params_sds, batch_sds))
        elif shape.kind == "decode" and kv_impl.startswith("paged"):
            # Rainbow paged decode (the paper's technique on the serving path)
            from jax.sharding import PartitionSpec as PS

            from repro.memory.kvcache import (
                PagedConfig, paged_cache_specs, paged_init, paged_scales_init,
            )
            from repro.serving.rainbow_decode import rainbow_decode_step

            assert cfg.family in ("dense", "vlm"), "paged decode: dense-family"
            b = shape.global_batch
            block = 16
            quant = kv_impl.endswith("-q8")
            pcfg = PagedConfig(
                block_size=block,
                blocks_per_seq=shape.seq_len // block,
                hot_slots=4096,
                top_n=128,
                max_promotions=256,
                interval_steps=8,
                quantize=quant,
            )
            params_sds = jax.eval_shape(
                partial(M.init_params, cfg, jax.random.PRNGKey(0), tp)
            )
            params_sh = sharding_tree(M.param_specs(cfg, tp), mesh)
            kv_sds = jax.eval_shape(
                lambda: paged_init(cfg, pcfg, b, tp, cfg.num_layers)
            )
            kv_sh = sharding_tree(paged_cache_specs(), mesh)
            tok_sh = sharding_tree(
                serve_steps.decode_batch_specs(replicated), mesh
            )["tokens"]
            mode = "sparse" if "sparse" in kv_impl else "full"
            step = partial(
                rainbow_decode_step, cfg, pcfg, tp=tp, sc=sc, mode=mode
            )
            tok_sds2 = jax.ShapeDtypeStruct((b, 1), jnp.int32)
            if quant:
                sc_sds = jax.eval_shape(
                    lambda: paged_scales_init(pcfg, b, cfg.kv_store(tp), cfg.num_layers)
                )
                cap_sc = PS(None, "data", None, "model")
                hot_sc = PS(None, None, None, "model")
                sc_sh = sharding_tree(
                    {"cap_k": cap_sc, "cap_v": cap_sc,
                     "hot_k": hot_sc, "hot_v": hot_sc},
                    mesh,
                )
                fn = lambda p, t, k, s: step(p, t, k, scales=s)
                lowered = jax.jit(
                    fn, in_shardings=(params_sh, tok_sh, kv_sh, sc_sh),
                    donate_argnums=(2, 3),
                ).lower(params_sds, tok_sds2, kv_sds, sc_sds)
                fn_args = (fn, (params_sds, tok_sds2, kv_sds, sc_sds))
            else:
                fn = lambda p, t, k: step(p, t, k)
                lowered = jax.jit(
                    fn, in_shardings=(params_sh, tok_sh, kv_sh), donate_argnums=(2,)
                ).lower(params_sds, tok_sds2, kv_sds)
                fn_args = (fn, (params_sds, tok_sds2, kv_sds))
        else:  # decode (flat cache)
            params_sds = jax.eval_shape(
                partial(M.init_params, cfg, jax.random.PRNGKey(0), tp)
            )
            params_sh = sharding_tree(M.param_specs(cfg, tp), mesh)
            tok_sds, cache_sds, _ = IN.decode_inputs(cfg, shape, tp)
            cache_specs = M.cache_specs(cfg, seq_axis=seq_axis)
            if replicated:
                # batch=1 cells: drop batch-dim sharding (cache batch replicates)
                def _strip_batch(spec: P) -> P:
                    return P(*(None if e == ("pod", "data") else e for e in spec))

                cache_specs = jax.tree.map(
                    _strip_batch, cache_specs, is_leaf=lambda x: isinstance(x, P)
                )
            cache_sh = sharding_tree(cache_specs, mesh)
            step = serve_steps.build_decode_step(cfg, tp, sc=sc)
            tok_sh = sharding_tree(
                serve_steps.decode_batch_specs(replicated), mesh
            )["tokens"]
            lowered = jax.jit(
                step, in_shardings=(params_sh, cache_sh, tok_sh), donate_argnums=(1,)
            ).lower(params_sds, cache_sds, tok_sds["tokens"])
            fn_args = (step, (params_sds, cache_sds, tok_sds["tokens"]))
        return lowered, fn_args

    from repro.models.unroll_flag import set_scan_unroll

    M.set_resid_seq_parallel(resid == "seq")
    meta["resid"] = resid
    # Production lowering (rolled scans): memory analysis + compile proof.
    with mesh:
        set_scan_unroll(False)
        lowered, fn_args = build_lowered()
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        # Cost lowering (unrolled scans): true flops/bytes/collective counts.
        # (HloCostAnalysis counts while bodies once — see models/unroll_flag.py.)
        # Multi-pod cells skip it: the roofline table is single-pod only.
        t0 = time.time()
        if multi_pod:
            cost_compiled = compiled
            meta["cost_from_rolled_hlo"] = True
        else:
            set_scan_unroll(True)
            try:
                cost_compiled = build_lowered()[0].compile()
            finally:
                set_scan_unroll(False)
        t_cost = time.time() - t0

    # ---- analyses ----
    try:
        mem = compiled.memory_analysis()
        mem_stats = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
        }
        mem_stats["peak_bytes_per_device"] = (
            mem_stats["argument_bytes"]
            + mem_stats["output_bytes"]
            + mem_stats["temp_bytes"]
            - mem_stats["alias_bytes"]
        )
    except Exception as e:  # pragma: no cover
        mem_stats = {"error": repr(e)}

    try:
        cost = cost_compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
    except Exception as e:  # pragma: no cover
        cost = {"error": repr(e)}

    hlo = cost_compiled.as_text()
    coll = hlo_analysis.collective_bytes(hlo)

    try:
        from repro.launch.jaxpr_flops import count_flops

        fn, fargs = fn_args
        jaxpr_total_flops = count_flops(fn, *fargs)
    except Exception as e:  # pragma: no cover
        jaxpr_total_flops = -1.0

    flops_dev = _cost_get(cost, "flops")
    bytes_dev = _cost_get(cost, "bytes accessed")
    terms = hlo_analysis.roofline_terms(flops_dev, bytes_dev, coll.total_bytes)
    mflops = hlo_analysis.model_flops(cfg, shape, shape.kind)
    useful_ratio = mflops / (flops_dev * chips) if flops_dev else 0.0

    result = {
        **meta,
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "cost_compile_s": round(t_cost, 2),
        "memory": mem_stats,
        "cost_analysis": {
            k: float(v) for k, v in cost.items() if isinstance(v, (int, float))
        },
        "collectives": {
            "bytes_by_op": coll.bytes_by_op,
            "count_by_op": coll.count_by_op,
            "total_bytes_per_device": coll.total_bytes,
        },
        "roofline": terms,
        "model_flops": mflops,
        "jaxpr_flops_global": jaxpr_total_flops,
        "useful_flops_ratio": useful_ratio,
        "hlo_bytes": len(hlo),
    }

    if not quiet:
        print(f"== {arch} x {shape_id} x {meta['mesh']} ({shape.kind}) ==")
        print(f"  memory_analysis: {mem_stats}")
        print(
            f"  cost_analysis: flops/device={flops_dev:.3e} bytes/device={bytes_dev:.3e}"
        )
        print(
            f"  collectives: {coll.bytes_by_op} total={coll.total_bytes:.3e} B/device"
        )
        print(
            f"  roofline: compute={terms['compute_s']:.4f}s memory={terms['memory_s']:.4f}s"
            f" collective={terms['collective_s']:.4f}s dominant={terms['dominant']}"
            f" useful_flops_ratio={useful_ratio:.3f}"
        )
        print(f"  lower={t_lower:.1f}s compile={t_compile:.1f}s")

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        fname = f"{arch}__{shape_id}__{meta['mesh']}{suffix}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(result, f, indent=2, default=str)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--attn", default="dense", choices=["dense", "chunked"])
    ap.add_argument("--kv", default="flat", choices=["flat", "paged", "paged-sparse", "paged-q8", "paged-sparse-q8"])
    ap.add_argument("--remat", default="full", choices=["none", "full", "dots"])
    ap.add_argument("--resid", default="tp", choices=["tp", "seq"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells: list[tuple[str, str]] = []
    if args.all:
        for arch in ARCH_IDS:
            for shape_id in applicable_shapes(arch):
                cells.append((arch, shape_id))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells.append((args.arch, args.shape))

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = []
    for arch, shape_id in cells:
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            suffix = f"__{args.tag}" if args.tag else ""
            fpath = os.path.join(
                args.out, f"{arch}__{shape_id}__{mesh_name}{suffix}.json"
            )
            if args.skip_existing and os.path.exists(fpath):
                print(f"skip existing {fpath}")
                continue
            try:
                run_cell(
                    arch, shape_id, mp, args.out,
                    attn_impl=args.attn, kv_impl=args.kv, remat=args.remat,
                    tag=args.tag, resid=args.resid,
                )
            except Exception as e:
                traceback.print_exc()
                failures.append((arch, shape_id, mesh_name, repr(e)))
    if failures:
        print("\nFAILED CELLS:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall requested cells passed")


if __name__ == "__main__":
    main()
