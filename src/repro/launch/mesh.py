"""Production mesh construction (multi-pod dry-run requirement).

Defined as functions (never module-level constants) so importing this module never
touches jax device state. The dry-run sets XLA_FLAGS host-device-count=512 before
any jax import; smoke tests and benches see the real single CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(devices: int | None = None, model: int = 1):
    """Small mesh over available devices (for CPU integration tests)."""
    n = devices or len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_fleet_mesh(devices: int | None = None, *, processes: int | None = None):
    """1-D mesh over all (or the first N) devices for homogeneous fleet axes.

    Sweep fleets (app x policy x seed x config cells of identical shape) are
    embarrassingly parallel, so a single "fleet" axis is the whole layout;
    engine.fleet pads the fleet to a multiple of the mesh size.

    `processes=N` scales the fleet past one process: jax.distributed is
    brought up first (launch.distributed — worker env / cluster detection;
    must happen before jax touches its backends) and the mesh then spans the
    GLOBAL device set of all N connected processes. Every process must build
    the mesh and run the same plan (SPMD); engine.fleet gathers per-group
    results to all processes on retire.
    """
    if processes is not None:
        from repro.launch import distributed

        distributed.ensure_initialized(processes)
    n = devices or len(jax.devices())
    return jax.make_mesh((n,), ("fleet",))


def mesh_dp_size(mesh) -> int:
    n = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            n *= mesh.shape[ax]
    return n


def mesh_tp_size(mesh) -> int:
    return mesh.shape.get("model", 1)
