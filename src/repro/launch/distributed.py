"""Multi-process fleet bootstrap: jax.distributed bring-up + worker spawning.

The fleet axis (engine.fleet) is embarrassingly parallel, so scaling a sweep
past one host is "just" a bigger 1-D mesh — the hard part is process bring-up
and getting results back:

  spawn(...)       subprocess-launches N copies of a worker command on THIS
                   host, each with REPRO_DIST_* env vars + a forced CPU device
                   count (--xla_force_host_platform_device_count), emulating an
                   N-host fleet for tests/CI. On real TPU pods the launcher is
                   the cluster scheduler and spawn() is not needed.
  initialize(...)  called by every worker (directly or via
                   launch.mesh.make_fleet_mesh(processes=N)): reads the worker
                   env, forces the local device count BEFORE jax touches its
                   backends, enables gloo cross-process CPU collectives, and
                   calls jax.distributed.initialize. Idempotent; a no-op
                   single-process run when no worker env is present.
  barrier/kv_*     thin wrappers over the jax coordination service used to
                   sequence workers and ship small host-side blobs (e.g.
                   verification rows) to the coordinator without touching the
                   filesystem.

jax.distributed can only be initialized ONCE per process (re-init raises), so
tests exercise this module through subprocesses — see docs/fleet.md for the
troubleshooting notes.

`python -m repro.launch.distributed --processes 2 --local-devices 2 --check`
is the self-contained smoke: the launcher runs a small single-device reference
sweep, spawns the workers (each re-runs this module with worker env set), and
asserts the multi-process FleetResult is bit-identical — the ci.sh
distributed leg and tests/test_fleet_distributed.py both drive it.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pathlib
import socket
import subprocess
import sys
import tempfile
import threading
import time

ENV_COORDINATOR = "REPRO_DIST_COORDINATOR"
ENV_NUM_PROCESSES = "REPRO_DIST_NUM_PROCESSES"
ENV_PROCESS_ID = "REPRO_DIST_PROCESS_ID"
ENV_LOCAL_DEVICES = "REPRO_DIST_LOCAL_DEVICES"

_DEVICE_COUNT_FLAG = "--xla_force_host_platform_device_count"


@dataclasses.dataclass(frozen=True)
class WorkerEnv:
    """One worker's slot in the process fleet (parsed from REPRO_DIST_*)."""

    coordinator: str
    num_processes: int
    process_id: int
    local_devices: int | None = None

    def environ(self) -> dict[str, str]:
        env = {
            ENV_COORDINATOR: self.coordinator,
            ENV_NUM_PROCESSES: str(self.num_processes),
            ENV_PROCESS_ID: str(self.process_id),
        }
        if self.local_devices is not None:
            env[ENV_LOCAL_DEVICES] = str(self.local_devices)
        return env


def worker_env() -> WorkerEnv | None:
    """The WorkerEnv of this process, or None outside a spawned fleet."""
    if ENV_COORDINATOR not in os.environ:
        return None
    local = os.environ.get(ENV_LOCAL_DEVICES)
    return WorkerEnv(
        coordinator=os.environ[ENV_COORDINATOR],
        num_processes=int(os.environ[ENV_NUM_PROCESSES]),
        process_id=int(os.environ[ENV_PROCESS_ID]),
        local_devices=int(local) if local else None,
    )


def free_port() -> int:
    """An OS-assigned free TCP port for the coordination service."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _force_local_devices(n: int) -> None:
    """Force the host-platform device count; must run before backend init."""
    flags = os.environ.get("XLA_FLAGS", "")
    if _DEVICE_COUNT_FLAG in flags:
        return  # the caller already pinned a count; respect it
    from jax._src import xla_bridge

    if xla_bridge.backends_are_initialized():
        raise RuntimeError(
            "distributed.initialize: jax backends are already initialized, "
            f"too late to force {n} local CPU devices — call initialize() "
            "(or make_fleet_mesh(processes=N)) before any jax.devices()/jit"
        )
    os.environ["XLA_FLAGS"] = f"{flags} {_DEVICE_COUNT_FLAG}={n}".strip()


def is_initialized() -> bool:
    from jax._src import distributed as jdist

    return jdist.global_state.client is not None


def initialize(
    env: WorkerEnv | None = None,
    *,
    collectives: str = "gloo",
    cluster_detect: bool = False,
) -> bool:
    """Bring up jax.distributed for this process; returns True if distributed.

    Reads the spawn() worker env when `env` is None; without one this is a
    single-process no-op (the zero-config path every test and CLI run takes)
    unless `cluster_detect=True`, which lets jax auto-detect a real cluster
    (TPU pods, SLURM, ...) from its own environment instead. Safe to call
    more than once — re-init of an already-connected process is skipped. CPU
    cross-process collectives (the retire path's all-gather) need gloo,
    which must be selected before the backends exist.
    """
    env = env or worker_env()
    if is_initialized():
        return True
    if env is None and not cluster_detect:
        return False
    import jax

    from jax._src import xla_bridge

    if env is not None and env.local_devices:
        _force_local_devices(env.local_devices)
    set_collectives = collectives and not xla_bridge.backends_are_initialized()
    if set_collectives:
        prev = xla_bridge.CPU_COLLECTIVES_IMPLEMENTATION.value
        jax.config.update("jax_cpu_collectives_implementation", collectives)
    try:
        if env is None:
            jax.distributed.initialize()  # cluster auto-detection
        else:
            jax.distributed.initialize(
                coordinator_address=env.coordinator,
                num_processes=env.num_processes,
                process_id=env.process_id,
            )
    except Exception:
        if set_collectives:
            # gloo without a coordination service poisons CPU backend
            # bring-up; restore so a failed probe leaves jax usable
            jax.config.update("jax_cpu_collectives_implementation", prev)
        raise
    return True


def ensure_initialized(processes: int) -> None:
    """make_fleet_mesh(processes=N)'s contract: N connected jax processes.

    Bring-up order: an already-connected process is a no-op; a spawn() worker
    env wins; otherwise jax's own cluster auto-detection is attempted — the
    real-host path, where the cluster scheduler launched the processes and
    no REPRO_DIST_* env exists.
    """
    if processes <= 1:
        return
    detect_err = None
    try:
        initialize(cluster_detect=worker_env() is None)
    except Exception as e:  # no spawn env and no detectable cluster
        detect_err = e
    import jax

    if jax.process_count() != processes:
        hint = (
            "spawn this program through launch.distributed.spawn (or set the "
            f"{ENV_COORDINATOR}/{ENV_NUM_PROCESSES}/{ENV_PROCESS_ID} worker "
            "env) so every process joins the coordination service; on real "
            "clusters, launch one process per host and jax auto-detection "
            "finds the coordinator"
        )
        raise RuntimeError(
            f"make_fleet_mesh(processes={processes}): jax sees "
            f"{jax.process_count()} process(es) — {hint}"
        ) from detect_err


# -- coordination-service helpers (barrier + tiny-blob KV) -------------------


def _client():
    from jax._src import distributed as jdist

    client = jdist.global_state.client
    if client is None:
        raise RuntimeError(
            "distributed coordination service not initialized — "
            "call launch.distributed.initialize() first"
        )
    return client


def barrier(name: str, timeout_s: int = 120) -> None:
    """Block until every process reaches `name` (coordination service)."""
    _client().wait_at_barrier(name, timeout_in_ms=timeout_s * 1000)


def kv_put(key: str, data: bytes) -> None:
    """Publish a small host-side blob to the coordination service KV store."""
    _client().key_value_set_bytes(key, data)


def kv_get(key: str, timeout_s: int = 120) -> bytes:
    """Blocking fetch of a KV blob (e.g. the coordinator collecting shards)."""
    return _client().blocking_key_value_get_bytes(key, timeout_s * 1000)


# -- local process-fleet spawning (CPU emulation of a multi-host fleet) ------


def spawn(
    argv: list[str],
    processes: int,
    *,
    local_devices: int | None = None,
    coordinator: str | None = None,
    env: dict[str, str] | None = None,
    timeout_s: int = 600,
) -> list[subprocess.CompletedProcess]:
    """Run `argv` as an N-process jax fleet on this host; wait for all.

    Every worker gets the same argv plus its REPRO_DIST_* slot; worker code
    calls initialize() (or make_fleet_mesh(processes=N)) to join. Raises on
    the first nonzero exit, with that worker's tail of stderr.
    """
    coordinator = coordinator or f"127.0.0.1:{free_port()}"
    procs = []
    for pid in range(processes):
        wenv = WorkerEnv(coordinator, processes, pid, local_devices)
        penv = {**os.environ, **(env or {}), **wenv.environ()}
        procs.append(subprocess.Popen(
            argv, env=penv, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
            text=True,
        ))
    # Drain every worker's pipes CONCURRENTLY: a chatty worker that fills its
    # OS pipe buffer would otherwise block mid-collective, stalling the whole
    # fleet until the sequential reader reached it (or the timeout fired).
    results: list = [None] * processes
    def drain(pid: int, p: subprocess.Popen) -> None:
        out, err = p.communicate()
        results[pid] = subprocess.CompletedProcess(argv, p.returncode, out, err)

    threads = [
        threading.Thread(target=drain, args=(pid, p), daemon=True)
        for pid, p in enumerate(procs)
    ]
    deadline = time.monotonic() + timeout_s
    try:
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=max(deadline - time.monotonic(), 0.0))
        if any(t.is_alive() for t in threads):
            raise RuntimeError(
                f"distributed fleet timed out after {timeout_s}s "
                f"({sum(t.is_alive() for t in threads)}/{processes} workers "
                "still running)"
            )
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
        for t in threads:
            t.join(timeout=10)
    done = results
    for pid, r in enumerate(done):
        if r.returncode != 0:
            raise RuntimeError(
                f"distributed worker {pid}/{processes} exited "
                f"{r.returncode}:\n{r.stderr[-4000:]}"
            )
    return done


# -- CLI: spawn-and-verify smoke ---------------------------------------------

#: The smoke plan: 2 compile signatures (streamcluster vs soplex shapes) and
#: group sizes (3, 2) that divide NO even mesh — every leg exercises padding.
_SMOKE = dict(intervals=2, accesses=2000)


def _smoke_plan():
    from repro.engine import fleet

    return fleet.SweepPlan.grid(
        ["streamcluster"], ["rainbow"], (0, 1, 2), **_SMOKE
    ) + fleet.SweepPlan.grid(["soplex"], ["rainbow"], (0, 1), **_SMOKE)


def _result_rows(res) -> list[dict]:
    return [
        {"label": c.label, "seed": c.seed, **{
            f: getattr(m, f)
            for f in ("ipc", "mpki", "migrations", "total_cycles", "mig_bytes")
        }}
        for c, m in res.items()
    ]


def _worker_main(args, wenv: WorkerEnv) -> list[dict]:
    """SPMD body every spawned process runs: sweep the smoke plan, stream it,
    and cross-check every process finalized the SAME rows (KV store)."""
    initialize(wenv)
    import jax

    from repro.engine import fleet
    from repro.launch.mesh import make_fleet_mesh

    mesh = make_fleet_mesh(processes=wenv.num_processes)
    spans = {d.process_index for d in mesh.devices.flat}
    assert len(spans) == wenv.num_processes, (
        f"fleet mesh spans processes {spans}, expected {wenv.num_processes}"
    )
    runner = fleet.FleetRunner(mesh=mesh)
    plan = _smoke_plan()
    res = runner.run(plan)
    streamed = dict(runner.run_iter(plan))
    assert {c: streamed[c] for c in res} == dict(res.items()), (
        "streamed run_iter diverged from barrier run"
    )
    # the prefetch pipeline must surface per-group timings on EVERY process
    assert len(runner.timings) == 2 and all(
        t.cells >= 1 and t.scan_s >= 0 for t in runner.timings
    ), f"per-group timings missing in the fleet: {runner.timings}"
    rows = _result_rows(res)
    # the retire all-gather promises every process the same bytes — verify it
    # for real: workers publish their rows, the coordinator compares.
    me = jax.process_index()
    if me != 0:
        kv_put(f"smoke/rows/{me}", json.dumps(rows).encode())
    else:
        for peer in range(1, wenv.num_processes):
            peer_rows = json.loads(kv_get(f"smoke/rows/{peer}"))
            assert peer_rows == rows, (
                f"process {peer} finalized different rows than process 0"
            )

    # journal leg: a multi-process sweep checkpoints (process 0 writes), then
    # a second run replays PURELY from the journal — workers adopt process
    # 0's synced view, so this exercises the cross-process resume path too.
    journal = pathlib.Path(tempfile.gettempdir()) / (
        f"repro-fleet-smoke-{wenv.coordinator.rsplit(':', 1)[-1]}.jsonl"
    )
    if me == 0 and journal.exists():
        journal.unlink()
    barrier("smoke/journal-clean")
    try:
        # batched retirement (flush_groups=2): both groups coalesce into one
        # write; the generator-finalize flush makes them durable for replay
        first = runner.run(
            plan, journal=fleet.FleetJournal(journal, flush_groups=2)
        )
        replay = runner.run(plan, journal=journal)
        assert dict(first.items()) == dict(res.items()), (
            "journaled sweep diverged from barrier run"
        )
        assert dict(replay.items()) == dict(res.items()), (
            "journal replay diverged from barrier run"
        )
    finally:
        barrier("smoke/journal-done")
        if me == 0 and journal.exists():
            journal.unlink()
    return rows


def _launcher_main(args) -> int:
    # the spawn path IS the CPU emulation mode (forced host devices only
    # exist on the CPU platform) — pin it for the workers and the oracle
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    port = free_port()
    coordinator = f"127.0.0.1:{port}"
    argv = [sys.executable, "-m", "repro.launch.distributed"]
    reference = None
    if args.check:
        # single-device oracle BEFORE spawning: this process never joins the
        # fleet, so its jax state is independent of the workers'.
        from repro.engine import fleet

        reference = _result_rows(fleet.FleetRunner().run(_smoke_plan()))
    results = spawn(
        argv, args.processes,
        local_devices=args.local_devices, coordinator=coordinator,
        timeout_s=args.timeout,
    )
    rows = None
    for r in results:
        for line in r.stdout.splitlines():
            if line.startswith("SMOKE_ROWS "):
                rows = json.loads(line[len("SMOKE_ROWS "):])
    if rows is None:
        raise RuntimeError("no SMOKE_ROWS line in worker stdout")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f)
    if args.check:
        if rows != reference:
            print("MISMATCH\n single-device:", reference, "\n fleet:", rows)
            return 1
        print(
            f"distributed smoke OK: {args.processes} processes x "
            f"{args.local_devices or 'native'} devices, "
            f"{len(rows)} cells bit-identical to single-device"
        )
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--processes", type=int, default=2)
    ap.add_argument("--local-devices", type=int, default=2,
                    help="forced CPU devices per worker (emulated hosts)")
    ap.add_argument("--check", action="store_true",
                    help="compare the fleet result to a single-device oracle")
    ap.add_argument("--out", default=None, help="write result rows JSON here")
    ap.add_argument("--timeout", type=int, default=600)
    args = ap.parse_args(argv)

    wenv = worker_env()
    if wenv is not None:  # spawned copy: run the SPMD worker body
        rows = _worker_main(args, wenv)
        if wenv.process_id == 0:
            print("SMOKE_ROWS " + json.dumps(rows), flush=True)
        return 0
    return _launcher_main(args)


if __name__ == "__main__":
    sys.exit(main())
