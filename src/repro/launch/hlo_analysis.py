"""HLO-text analysis: collective operand bytes + roofline terms (§Roofline).

`cost_analysis()` gives per-device HLO FLOPs/bytes; collective bytes are NOT in
cost_analysis, so we parse the optimized HLO: for every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute instruction we sum the byte sizes
of its operands (resolved through each operand's defining instruction).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "s4": 1, "u4": 1, "token": 0, "opaque": 0,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}\s\/#]+?)\s+([\w\-]+)(?:\.\d+)?\("
)


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string, e.g. 'f32[128,256]{1,0}' or a tuple."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: dict = field(default_factory=dict)
    count_by_op: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective instruction in the HLO module."""
    # result-shape table: instruction name -> bytes
    result_bytes: dict[str, int] = {}
    instrs: list[tuple[str, str, str]] = []  # (opcode, name, full line)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, opcode = m.group(1), m.group(2), m.group(3)
        result_bytes[name] = shape_bytes(type_str)
        base_op = opcode.rstrip("0123456789").rstrip(".")
        if any(base_op.startswith(c) for c in COLLECTIVES):
            instrs.append((base_op, name, line))

    stats = CollectiveStats()
    for opcode, name, line in instrs:
        # operands: %name refs inside the call parens
        call = line.split("(", 1)[1]
        call = call.split(")", 1)[0]
        ops = re.findall(r"%?([\w\.\-]+)", call)
        b = 0
        for o in ops:
            if o in result_bytes:
                b += result_bytes[o]
        if b == 0:
            # start-done pairs (e.g. all-reduce-start): charge result size
            b = result_bytes.get(name, 0)
        stats.bytes_by_op[opcode] = stats.bytes_by_op.get(opcode, 0) + b
        stats.count_by_op[opcode] = stats.count_by_op.get(opcode, 0) + 1
    return stats


# ---------------------------------------------------------------------------
# Roofline terms (TPU v5e-class constants from the assignment)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link


def roofline_terms(
    flops_per_device: float,
    bytes_per_device: float,
    collective_bytes_per_device: float,
) -> dict[str, float]:
    compute_s = flops_per_device / PEAK_FLOPS
    memory_s = bytes_per_device / HBM_BW
    collective_s = collective_bytes_per_device / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    bound = max(compute_s, memory_s, collective_s)
    terms["roofline_fraction"] = compute_s / bound if bound > 0 else 0.0
    return terms


def decode_bytes_global(cfg, shape) -> float:
    """Analytic per-step HBM traffic for decode cells (global bytes).

    XLA's HloCostAnalysis charges dynamic-update-slice as full-buffer
    read+write; on TPU the update is in-place and tiny, so for decode the raw
    'bytes accessed' is inflated by ~2*L*cache_bytes. This analytic model is
    the corrected memory-term source for decode cells (documented in
    EXPERIMENTS.md §Roofline): params + one full KV/state read + logits.
    """
    n_params = cfg.param_count()
    b, s = shape.global_batch, shape.seq_len
    bytes_total = 2.0 * n_params  # bf16 weights read once
    hd = cfg.head_dim
    kvs = cfg.kv_store(16)
    if cfg.is_encoder_decoder:
        s_eff = s // cfg.encoder_seq_divisor
        # decoder self KV + cross KV
        bytes_total += 2 * cfg.num_layers * b * s_eff * kvs * hd * 2 * 2
    elif not cfg.attn_free:
        window = cfg.sliding_window
        if window and cfg.global_attn_every:
            n_glob = (cfg.num_layers + cfg.global_attn_every - 1) // cfg.global_attn_every
            n_loc = cfg.num_layers - n_glob
            s_loc = min(window, s)
            bytes_total += 2 * b * hd * kvs * 2 * (n_glob * s + n_loc * s_loc)
        else:
            bytes_total += 2 * cfg.num_layers * b * s * kvs * hd * 2
    if cfg.ssm_state:
        h = cfg.ssm_d_inner // cfg.ssm_head_dim
        bytes_total += cfg.num_layers * b * h * cfg.ssm_head_dim * cfg.ssm_state * 4 * 2
    bytes_total += b * cfg.padded_vocab * 4  # logits
    return bytes_total


def model_flops(cfg, shape, kind: str) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); D = tokens this step."""
    n = cfg.active_param_count()
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens  # forward only
    tokens = shape.global_batch * 1
    return 2.0 * n * tokens  # decode: one token per sequence
