"""Training launcher: ``PYTHONPATH=src python -m repro.launch.train --arch <id>``.

Runs the fault-tolerant loop on the local mesh (CPU: 1 device; TPU pod: the
production mesh) with checkpointing + auto-resume. The e2e example
(examples/train_100m.py) drives this with a ~100M config for a few hundred steps.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, get_reduced_config
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_test_mesh
from repro.launch.sharding import make_constrainer, sharding_tree
from repro.optim import AdamWConfig, linear_warmup_cosine
from repro.train.loop import LoopConfig, Trainer
from repro.train.step import (
    TrainStepConfig, batch_specs, build_train_step, init_train_state,
    train_state_specs,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", help="smoke-scale config")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--attn", default="dense")
    ap.add_argument("--ckpt-dir", default="checkpoints/train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_test_mesh(model=args.model_parallel)
    sc = make_constrainer(mesh)
    tp = args.model_parallel

    tcfg = TrainStepConfig(
        tp=tp, remat=args.remat, attn_impl=args.attn,
        adamw=AdamWConfig(lr=args.lr),
    )
    schedule = linear_warmup_cosine(args.lr, args.steps // 10 + 1, args.steps)
    step = build_train_step(cfg, tcfg, sc=sc, lr_schedule=schedule)
    state_sh = sharding_tree(train_state_specs(cfg, tcfg, dp_size=1), mesh)

    with mesh:
        jit_step = jax.jit(step, donate_argnums=(0,), out_shardings=(state_sh, None))
        data = iter(SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=0))
        trainer = Trainer(jit_step, data, LoopConfig(
            total_steps=args.steps, checkpoint_every=args.ckpt_every,
            checkpoint_dir=args.ckpt_dir))
        state, start = trainer.ckpt.restore_or_init(
            lambda: init_train_state(cfg, jax.random.PRNGKey(0), tcfg),
            shardings=state_sh,
        )
        if start:
            print(f"resumed from step {start}")
        state, hist = trainer.run(state, start)
    if hist:
        print(f"final loss {hist[-1]['loss']:.4f} after {hist[-1]['step'] + 1} steps")
    if trainer.events:
        print("events:", trainer.events)


if __name__ == "__main__":
    main()
