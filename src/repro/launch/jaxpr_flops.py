"""Exact structural FLOP counting by jaxpr traversal.

Complements XLA's cost_analysis (which needs unrolled scans to count loop
bodies): walks the closed jaxpr, counts dot_general/conv FLOPs analytically,
and multiplies scan bodies by their trip count — exact for any nesting, zero
compile cost. Used as the §Roofline cross-check column and as the FLOP source
for cells whose unrolled cost-lowering is impractical (nested SSD scans).
"""
from __future__ import annotations

import math
from typing import Any

import jax
from jax import core


def _dot_flops(eqn) -> float:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    batch = math.prod(lhs.shape[i] for i in lb)
    contract = math.prod(lhs.shape[i] for i in lc)
    m = math.prod(
        lhs.shape[i] for i in range(len(lhs.shape)) if i not in set(lc) | set(lb)
    )
    n = math.prod(
        rhs.shape[i] for i in range(len(rhs.shape)) if i not in set(rc) | set(rb)
    )
    return 2.0 * batch * m * n * contract


def _conv_flops(eqn) -> float:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    out = eqn.outvars[0].aval
    kernel_spatial = math.prod(rhs.shape[:-2]) if len(rhs.shape) > 2 else 1
    # general estimate: out elements x kernel volume x in-features x 2
    cin = rhs.shape[eqn.params["dimension_numbers"].rhs_spec[1]]
    return 2.0 * math.prod(out.shape) * kernel_spatial * cin


def flops_of_jaxpr(jaxpr: core.Jaxpr, scale: float = 1.0) -> float:
    total = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            total += scale * _dot_flops(eqn)
        elif name == "conv_general_dilated":
            total += scale * _conv_flops(eqn)
        elif name == "scan":
            inner = eqn.params["jaxpr"].jaxpr
            total += flops_of_jaxpr(inner, scale * eqn.params["length"])
        elif name == "while":
            inner = eqn.params["body_jaxpr"].jaxpr
            total += flops_of_jaxpr(inner, scale)  # trip count unknown: 1x
        elif name == "cond":
            branches = eqn.params["branches"]
            if branches:
                total += max(
                    flops_of_jaxpr(b.jaxpr, scale) for b in branches
                )
        elif name in ("pjit", "custom_vjp_call", "custom_jvp_call",
                      "custom_vjp_call_jaxpr", "remat", "remat2", "checkpoint",
                      "custom_gradient", "closed_call"):
            inner = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            if inner is not None:
                inner_jaxpr = inner.jaxpr if hasattr(inner, "jaxpr") else inner
                total += flops_of_jaxpr(inner_jaxpr, scale)
        elif name == "custom_vjp_call_fwd":
            inner = eqn.params.get("fun_jaxpr")
            if inner is not None:
                total += flops_of_jaxpr(inner.jaxpr, scale)
    return total


def count_flops(fn, *args, **kwargs) -> float:
    """Trace fn abstractly and count its structural FLOPs."""
    closed = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    return flops_of_jaxpr(closed.jaxpr)
