"""Deterministic, resumable data pipeline.

Two sources:
  * SyntheticLM — seeded zipf-over-vocab token stream with induced bigram
    structure (so a 100M-param model's loss actually falls during the e2e
    example), generated on the fly from (seed, step) — resume == set the step.
  * PackedFile — memory-mapped token file (uint16/uint32) cut into fixed-length
    sequences; sharded across hosts by range; resume via (epoch, cursor).

Both yield the batch dict the train step consumes: tokens/targets/loss_mask.
State is an explicit small dict -> checkpointable next to the train state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.1
    step: int = 0  # resume cursor

    def state(self) -> dict[str, Any]:
        return {"step": self.step, "seed": self.seed}

    def load_state(self, st: dict[str, Any]) -> None:
        self.step = int(st["step"])
        self.seed = int(st["seed"])

    def _probs(self) -> np.ndarray:
        r = np.arange(1, self.vocab_size + 1, dtype=np.float64)
        p = r ** (-self.zipf_alpha)
        return p / p.sum()

    def next_batch(self) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed * 1_000_003 + self.step) & 0x7FFFFFFF)
        p = self._probs()
        b, s = self.global_batch, self.seq_len
        base = rng.choice(self.vocab_size, size=(b, s + 1), p=p)
        # induce learnable structure: token[t+1] is correlated with token[t]
        mix = rng.random((b, s + 1)) < 0.5
        shifted = (base + 7) % self.vocab_size
        seq = np.where(mix, base, np.roll(shifted, 1, axis=1))
        self.step += 1
        return {
            "tokens": seq[:, :-1].astype(np.int32),
            "targets": seq[:, 1:].astype(np.int32),
            "loss_mask": np.ones((b, s), np.float32),
        }

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()


@dataclasses.dataclass
class PackedFile:
    path: str
    seq_len: int
    global_batch: int
    dtype: str = "uint16"
    num_shards: int = 1  # data-parallel host count
    shard_index: int = 0
    epoch: int = 0
    cursor: int = 0  # sequence index within this shard's range

    def __post_init__(self):
        self._tokens = np.memmap(self.path, dtype=self.dtype, mode="r")
        n_seqs = len(self._tokens) // (self.seq_len + 1)
        per = n_seqs // self.num_shards
        self._lo = self.shard_index * per
        self._hi = self._lo + per

    def state(self) -> dict[str, Any]:
        return {"epoch": self.epoch, "cursor": self.cursor}

    def load_state(self, st: dict[str, Any]) -> None:
        self.epoch = int(st["epoch"])
        self.cursor = int(st["cursor"])

    def next_batch(self) -> dict[str, np.ndarray]:
        b, s = self.global_batch, self.seq_len
        # deterministic shuffled order per epoch
        order = np.random.default_rng(self.epoch).permutation(self._hi - self._lo)
        toks = np.empty((b, s + 1), np.int64)
        for i in range(b):
            if self.cursor >= len(order):
                self.epoch += 1
                self.cursor = 0
                order = np.random.default_rng(self.epoch).permutation(
                    self._hi - self._lo
                )
            seq_id = self._lo + order[self.cursor]
            off = seq_id * (s + 1)
            toks[i] = self._tokens[off : off + s + 1]
            self.cursor += 1
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "targets": toks[:, 1:].astype(np.int32),
            "loss_mask": np.ones((b, s), np.float32),
        }

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()
