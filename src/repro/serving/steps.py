"""Serving step builders: prefill and single-token decode (flat KV cache).

The Rainbow-paged decode path lives in repro.serving.rainbow_decode; this module
is the baseline (paper's "without technique" serving analogue).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch.axes import BATCH_AXES
from repro.models import model as M
from repro.models.config import ModelConfig


def decode_batch_specs(batch_replicated: bool = False) -> dict[str, Any]:
    dp = None if batch_replicated else BATCH_AXES
    return {"tokens": P(dp, None)}


def prefill_batch_specs(cfg: ModelConfig, batch_replicated: bool = False):
    dp = None if batch_replicated else BATCH_AXES
    specs = {"tokens": P(dp, None)}
    if cfg.family == "vlm":
        specs["vision_embeds"] = P(dp, None, None)
    if cfg.is_encoder_decoder:
        specs["frames"] = P(dp, None, None)
    return specs


def build_prefill_step(
    cfg: ModelConfig, tp: int, max_len: int, sc=None, attn_impl: str = "dense"
) -> Callable:
    """(params, batch) -> (logits [B,1,V], cache). Cache is created inside."""

    def step(params, batch):
        bsz = batch["tokens"].shape[0]
        cache = M.init_cache(cfg, bsz, max_len, tp=tp)
        return M.prefill(cfg, params, batch, cache, tp=tp, sc=sc, attn_impl=attn_impl)

    return step


def build_decode_step(cfg: ModelConfig, tp: int, sc=None) -> Callable:
    """(params, cache, tokens [B,1]) -> (logits [B,1,V], cache')."""

    def step(params, cache, tokens):
        logits, cache = M.decode_step(cfg, params, tokens, cache, tp=tp, sc=sc)
        return logits, cache

    return step


def greedy_sample(logits: jax.Array, vocab_size: int) -> jax.Array:
    return jnp.argmax(logits[..., :vocab_size], axis=-1).astype(jnp.int32)
