"""Rainbow-managed decode: paged KV with two-tier translation + hot-block stats.

Read modes:
  * full   — attend over every block through the translated (single-gather)
             pool read; numerically identical to flat-cache decode.
  * sparse — attend over hot-pool blocks + the trailing window only (stage-1
             screened). This is where tiering pays on real hardware: cold
             blocks stay in the capacity tier (host memory) untouched. The
             approximation (H2O/Quest-style) is opt-in; any block whose mass
             grows gets promoted and rejoins the read set.

Each decode step records per-block attention mass (the access stream of the
paper's memory controller); every `interval_steps`, end_interval_promote() runs
two-stage classification + utility admission and copies hot blocks.

The interval control loop here is the SAME engine as Layer A's simulator:
observe_block_mass feeds the shared weighted stage-1/2 counters and
end_interval_promote plans through repro.engine.control.plan_and_apply — only
the access semantics (attention mass) and the payload copy differ.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.remap import translate
from repro.memory.kvcache import (
    PagedConfig,
    RainbowKV,
    append_token,
    append_token_q8,
    dequantize_kv,
    end_interval_promote,
    observe_block_mass,
    paged_init,
    promote_scales,
)
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import model as M


def _attend_with_mass(q, k, v, valid, block_size, nblk):
    """decode_attend that also returns per-block softmax mass [B, nblk].

    valid: bool[S] or bool[B, S] mask of readable positions.
    """
    b, smax, kvs, hd = k.shape
    hp = q.shape[2]
    ke = attn._expand_kv(k, hp)
    ve = attn._expand_kv(v, hp)
    s = jnp.einsum("bqhk,bshk->bhqs", q, ke, preferred_element_type=jnp.float32)
    s = s / np.sqrt(hd)
    if valid.ndim == 1:
        valid = valid[None]
    s = jnp.where(valid[:, None, None, :], s, attn.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhqs,bshk->bqhk", p.astype(q.dtype), ve, preferred_element_type=jnp.float32
    ).astype(q.dtype)
    mass = p[:, :, 0, :].sum(axis=1)  # [B, S] summed over heads
    full = nblk * block_size
    blk_mass = mass[:, :full].reshape(b, nblk, block_size).sum(-1)
    return out, blk_mass


def pool_indices(kv: RainbowKV, pcfg: PagedConfig, batch: int):
    """Layer-invariant translated pool indices: (resident[B, nblk], vidx[B, nblk]).

    vidx indexes the virtually concatenated [capacity ++ hot] pool; resident
    blocks redirect to num_cap + slot (Fig. 6 cases via one indirection).
    """
    nblk = pcfg.blocks_per_seq
    blocks = jnp.arange(nblk)
    sp = jnp.arange(batch)[:, None].repeat(nblk, 1)
    resident, slot = translate(kv.remap, sp, blocks[None, :].repeat(batch, 0))
    home = (sp * nblk + blocks[None, :]).astype(jnp.int32)
    vidx = jnp.where(resident, batch * nblk + slot, home)  # [B, nblk]
    return resident, vidx


def sparse_read_set(
    kv: RainbowKV,
    pcfg: PagedConfig,
    batch: int,
    nwin: int = 8,
    precomputed: tuple[jax.Array, jax.Array] | None = None,
):
    """Sparse-mode read set: trailing-window home blocks ++ resident blocks.

    Returns (read_idx, read_valid, read_block): pool indices per read lane,
    the lane validity mask, and the seq-local block id each lane reads (-1 on
    invalid lanes). This is the promotion-rejoin surface: a cold block whose
    attention mass grows gets admitted by end_interval_promote, becomes
    resident, and re-enters this set on the next decode step.
    """
    resident, vidx = precomputed or pool_indices(kv, pcfg, batch)
    nblk = pcfg.blocks_per_seq
    cur_blk = kv.length // pcfg.block_size
    win = jnp.clip((cur_blk - jnp.arange(nwin))[None, :].repeat(batch, 0), 0, nblk - 1)
    win_idx = jnp.take_along_axis(vidx, win, axis=1)
    # Every block must appear in the read set at most ONCE: a duplicated key
    # does not split its softmax mass, it DOUBLES its share (both copies add
    # exp(s) to the numerator and denominator), skewing both the attention
    # output and the recorded per-block mass. Window lanes dedupe against
    # earlier window lanes (edge clipping repeats blocks early in decode)...
    lane = jnp.arange(nwin)
    win_dup = (win[:, :, None] == win[:, None, :]) & (lane[:, None] > lane[None, :])
    win_valid = ~win_dup.any(-1)
    # ...and hot lanes dedupe against the window. The hot pool is a GLOBAL
    # resource: one sequence may own every slot, so each sequence exposes up
    # to min(hot_slots, nblk) hot lanes. (A per-seq hot_slots // batch budget
    # would hide promoted blocks of an imbalanced batch from the read set —
    # breaking the promotion-rejoin invariant.)
    hot_rank = jnp.argsort(~resident, axis=1)[:, : min(pcfg.hot_slots, nblk)]
    hot_sel = jnp.take_along_axis(vidx, hot_rank, axis=1)
    hot_ok = jnp.take_along_axis(resident, hot_rank, axis=1)
    hot_ok &= ~(hot_rank[:, :, None] == win[:, None, :]).any(-1)
    read_idx = jnp.concatenate([win_idx, jnp.where(hot_ok, hot_sel, 0)], axis=1)
    read_valid = jnp.concatenate([win_valid, hot_ok], axis=1)
    read_block = jnp.concatenate(
        [jnp.where(win_valid, win, -1), jnp.where(hot_ok, hot_rank, -1)], axis=1
    ).astype(jnp.int32)
    return read_idx, read_valid, read_block


def rainbow_decode_step(
    cfg,
    pcfg: PagedConfig,
    params: Any,
    tokens: jax.Array,  # [B, 1]
    kv: RainbowKV,
    tp: int = 1,
    sc=None,
    mode: str = "full",
    scales: dict | None = None,  # int8 mode (pcfg.quantize): scale side pytree
    collect_mass: bool = False,  # also return this step's [B, nblk] block mass
):
    """One decode step for a dense-family LM over the Rainbow paged cache."""
    assert cfg.family in ("dense", "vlm"), "rainbow decode targets dense-family archs"
    b = tokens.shape[0]
    cur = kv.length
    x = L.embed_lookup(cfg, params["embed"], tokens)
    pos = jnp.full((b, 1), cur, jnp.int32)
    nblk = pcfg.blocks_per_seq

    seg = M.segments(cfg)[0]
    seg_params = params["segments"][seg.name]

    # Translation is layer-invariant: compute the virtual pool indices once.
    resident, vidx = pool_indices(kv, pcfg, b)

    if mode == "sparse":
        read_idx, read_valid, read_block = sparse_read_set(
            kv, pcfg, b, precomputed=(resident, vidx)
        )
    else:
        read_idx = vidx
        read_valid = None

    def body(carry, xs):
        h = carry
        if pcfg.quantize:
            pl, cap_k_l, cap_v_l, hot_k_l, hot_v_l, csk, csv, hsk, hsv = xs
        else:
            pl, cap_k_l, cap_v_l, hot_k_l, hot_v_l = xs
        hn = L.apply_norm(cfg, pl["ln1"], h)
        q, k_new, v_new = attn.qkv_project(cfg, pl["attn"], hn, pos, use_rope=True)

        pool_k = jnp.concatenate([cap_k_l, hot_k_l], axis=0)
        pool_v = jnp.concatenate([cap_v_l, hot_v_l], axis=0)
        kvs_, hd = pool_k.shape[-2], pool_k.shape[-1]
        if pcfg.quantize:
            sk_pool = jnp.concatenate([csk, hsk], axis=0)
            sv_pool = jnp.concatenate([csv, hsv], axis=0)
            k_r = dequantize_kv(pool_k[read_idx], sk_pool[read_idx], x.dtype)
            v_r = dequantize_kv(pool_v[read_idx], sv_pool[read_idx], x.dtype)
            k_r = k_r.reshape(b, -1, kvs_, hd)
            v_r = v_r.reshape(b, -1, kvs_, hd)
        else:
            k_r = pool_k[read_idx].reshape(b, -1, kvs_, hd)
            v_r = pool_v[read_idx].reshape(b, -1, kvs_, hd)
        k_r = jnp.concatenate([k_r, k_new], axis=1)  # fresh token attends itself
        v_r = jnp.concatenate([v_r, v_new], axis=1)

        smax = k_r.shape[1]
        if mode == "sparse":
            token_ok = jnp.repeat(read_valid, pcfg.block_size, axis=1)
            valid = jnp.concatenate(
                [token_ok, jnp.ones((b, 1), bool)], axis=1
            )  # fresh token always readable
            o, lane_mass = _attend_with_mass(
                q, k_r, v_r, valid, pcfg.block_size, read_idx.shape[1]
            )
            # Scatter read-lane mass back to home blocks so the controller
            # observes sparse reads too (lanes are deduplicated, so each
            # block's mass lands exactly once; invalid lanes drop). Without
            # this, sparse mode fed zero mass to observe_block_mass, nothing
            # ever promoted, and a hot block leaving the trailing window was
            # lost forever — the promotion-rejoin path existed only in full
            # mode.
            dest = jnp.where(read_block >= 0, read_block, nblk)
            blk_mass = jnp.zeros((b, nblk), jnp.float32).at[
                jnp.arange(b)[:, None], dest
            ].add(lane_mass, mode="drop")
        else:
            pos_ids = jnp.arange(smax)
            valid = (pos_ids < cur) | (pos_ids == smax - 1)  # history + fresh
            o, blk_mass = _attend_with_mass(
                q, k_r, v_r, valid, pcfg.block_size, nblk
            )

        h = h + attn.attn_output(pl["attn"], o)
        h2 = L.apply_norm(cfg, pl["ln2"], h)
        h = h + L.apply_mlp(cfg, pl["mlp"], h2, sc=sc)
        return h, (k_new[:, 0], v_new[:, 0], blk_mass)

    if pcfg.quantize:
        xs = (seg_params, kv.cap_k, kv.cap_v, kv.hot_k, kv.hot_v,
              scales["cap_k"], scales["cap_v"], scales["hot_k"], scales["hot_v"])
    else:
        xs = (seg_params, kv.cap_k, kv.cap_v, kv.hot_k, kv.hot_v)
    h, (k_all, v_all, mass_all) = jax.lax.scan(body, x, xs)

    if pcfg.quantize:
        kv, scales = append_token_q8(kv, pcfg, scales, k_all, v_all)
    else:
        kv = append_token(kv, pcfg, None, k_all, v_all)
    step_mass = mass_all.sum(axis=0)  # [B, nblk] — the controller's access stream
    kv = observe_block_mass(kv, pcfg, step_mass)
    kv = dataclasses.replace(kv, length=kv.length + 1)

    if pcfg.quantize:
        def do_promote(args):
            kv_, sc_ = args
            new, rep = end_interval_promote(kv_, pcfg)
            sc_ = promote_scales(sc_, pcfg, rep["plan"], rep["cand_sp"], rep["cand_pg"])
            return new, sc_

        kv, scales = jax.lax.cond(
            kv.step_in_interval >= pcfg.interval_steps, do_promote,
            lambda a: a, (kv, scales),
        )
    else:
        def do_promote(kv_):
            new, _ = end_interval_promote(kv_, pcfg)
            return new

        kv = jax.lax.cond(
            kv.step_in_interval >= pcfg.interval_steps, do_promote, lambda s: s, kv
        )

    h = L.apply_norm(cfg, params["final_norm"], h)
    logits = L.lm_logits(cfg, params["embed"], h)
    out = (logits, kv) + ((scales,) if pcfg.quantize else ())
    if collect_mass:
        out = out + (step_mass,)
    return out


def record_mass_trace(
    cfg,
    pcfg: PagedConfig,
    params: Any,
    prompt: jax.Array,  # int32[B, P] prompt tokens (consumed prefill-by-decode)
    steps: int,  # total decode steps recorded (>= prompt length)
    tp: int = 1,
):
    """Run a real model decode and record the controller's access stream.

    Returns (MassTrace, final RainbowKV). The trace holds one [B, nblk]
    attention-mass row per decode step — exactly what observe_block_mass saw —
    so `engine.autotune` can replay the observe/promote control loop against
    it for any candidate ControlPolicy without re-running the model.
    """
    from repro.engine.autotune import MassTrace
    from repro.serving.steps import greedy_sample

    assert not pcfg.quantize, "mass-trace recording targets the fp pools"
    b, plen = prompt.shape
    if steps < plen:
        raise ValueError(f"steps ({steps}) must cover the prompt ({plen})")
    step = jax.jit(
        lambda p, t, k: rainbow_decode_step(cfg, pcfg, p, t, k, tp=tp,
                                            collect_mass=True)
    )
    kv = paged_init(cfg, pcfg, b, tp, cfg.num_layers)
    rows = []
    tok = prompt[:, :1]
    for t in range(steps):
        if t < plen:
            tok = prompt[:, t:t + 1]
        logits, kv, mass = step(params, tok, kv)
        rows.append(np.asarray(mass, np.float32))
        tok = greedy_sample(logits, cfg.vocab_size)
    trace = MassTrace(
        mass=np.stack(rows), block_size=pcfg.block_size, start_length=0
    )
    return trace, kv
