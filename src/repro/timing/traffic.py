"""Per-tier migration traffic decomposition for the queueing timing model.

sim.policies.interval_costs is THE flat cost model: per interval it prices
migration activity as one `mig_cycles` scalar. The queueing model needs the
same cycles SPLIT BY TIER so each half lands on the queues it actually
occupies — a page copy reads one tier and writes the other, stealing
bandwidth from demand accesses on both.

Invariant (pinned by tests/test_timing.py): for every policy,

    dram_cycles + nvm_cycles == interval_costs(...)["mig_cycles"]

so the queueing model charges exactly the cycles the flat model already
accounts for — no double counting, only a different placement. The split is
half/half per transfer: each page move (either direction) and each dirty
writeback busies the source tier's read port and the destination tier's
write port for mig/writeback cost halves (the per-page constants already
lump read+write — see core.migration._SIM_PAGE_COST's `* 2`).

Kept free of repro.sim imports (engine -> timing must not cycle back through
sim.__init__); `mc` is duck-typed and PAGES_PER_SP is literal, the same
convention as workloads/generators.py.
"""
from __future__ import annotations

import jax.numpy as jnp

PAGES_PER_SP = 512  # == sim.config.PAGES_PER_SP (kept literal: no sim import)


def migration_cycles(policy: str, mc, migrations, evictions, dirty):
    """(dram_cycles, nvm_cycles) f32 scalars of one interval's migrations.

    Mirrors sim.policies.interval_costs case by case:

      flat-static / dram-only: no migration machinery at all -> (0, 0).
      hscc-4kb / hscc-2mb: every moved unit (migrations + evictions) costs
        mig_page_cost (x512 for superpages), dirty victims add a writeback;
        each transfer splits half to either tier.
      rainbow / nomad: only migrations pay the page copy and only dirty
        evictions pay a writeback — clean evictions write back the 8-byte
        remap pointer, which the flat model prices at zero cycles (§III-E),
        so the queues see zero too. Nomad plans the same generations as
        rainbow (identical per-generation cycles); the DIFFERENCE is purely
        the charging schedule — the engine spreads each generation's total
        over async_window installments and passes the per-interval
        installment to interval_step via bulk_dram/bulk_nvm, so this
        function prices a nomad generation at creation time exactly like a
        rainbow interval.

    migrations/evictions/dirty are int32 scalars (traced or concrete).
    """
    m = jnp.asarray(migrations, jnp.int32).astype(jnp.float32)
    e = jnp.asarray(evictions, jnp.int32).astype(jnp.float32)
    d = jnp.asarray(dirty, jnp.int32).astype(jnp.float32)
    if policy in ("hscc-4kb-mig", "hscc-2mb-mig"):
        scale = PAGES_PER_SP if policy == "hscc-2mb-mig" else 1
        half_mig = jnp.float32(mc.mig_page_cost * scale / 2.0)
        half_wb = jnp.float32(mc.writeback_page_cost * scale / 2.0)
        per_tier = (m + e) * half_mig + d * half_wb
        return per_tier, per_tier
    if policy in ("rainbow", "nomad"):
        half_mig = jnp.float32(mc.mig_page_cost / 2.0)
        half_wb = jnp.float32(mc.writeback_page_cost / 2.0)
        per_tier = m * half_mig + d * half_wb
        return per_tier, per_tier
    if policy in ("flat-static", "dram-only"):
        z = jnp.zeros((), jnp.float32)
        return z, z
    raise KeyError(f"unknown policy {policy!r}")
