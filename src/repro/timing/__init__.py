"""Queueing-fidelity timing subsystem: per-channel/bank contention model.

See docs/timing.md. The engine selects it via EngineSpec.timing_model
("flat" keeps the event-count cost model; "queueing" carries per-tier
per-server avail_cycle clocks in the scan state). The flat floor invariant —
flat == queueing with infinite banks, bitwise — is the differential anchor
every existing figure keeps.
"""
from repro.timing.queueing import (
    GEOMETRY_PRESETS,
    MIGRATING_POLICIES,
    IntervalTiming,
    QueueGeometry,
    QueueState,
    bulk_charge,
    charge_queues,
    charged_service_cycles,
    get_geometry,
    interval_step,
    interval_step_jit,
    queue_init,
    zero_timing,
)
from repro.timing.traffic import migration_cycles

__all__ = [
    "GEOMETRY_PRESETS",
    "MIGRATING_POLICIES",
    "IntervalTiming",
    "QueueGeometry",
    "QueueState",
    "bulk_charge",
    "charge_queues",
    "charged_service_cycles",
    "get_geometry",
    "interval_step",
    "interval_step_jit",
    "migration_cycles",
    "queue_init",
    "zero_timing",
]
