"""Per-channel/bank queueing timing: contention as scan-carried state.

The flat cost model (sim.policies.interval_costs) prices an interval as
event-counts x latencies — no access ever waits for another. This module
ports the tracehm `TimingObj.avail_cycle` idea device-side: each memory tier
exposes `channels x banks` servers, every access is dispatched to the server
`vpn % servers` of its tier, and a server busy until `avail_cycle` makes the
access WAIT. Migration/eviction traffic is charged to the same queues at
interval end, so background copies steal bandwidth from demand accesses —
the effect lightweight migration is supposed to relieve, now visible.

Design constraints (all load-bearing):

  * every op is vectorized jnp (stable argsort + segmented max-plus
    associative_scan + scatter) so the charge runs inside ``lax.scan``,
    under vmap-over-seeds, and in the shard_map fleet unchanged;
  * the FLAT FLOOR invariant: ``QueueGeometry.flat_floor()`` (infinite
    banks) is an explicit exact-zero path — every access finds an idle
    server, so stall/backlog contributions are literal ``0.0`` and
    ``timing_model="flat"`` stays bit-identical to queueing-with-infinite-
    banks (tests/test_timing.py sweeps every registered scenario x policy);
  * the demand service vector reuses EXACTLY the hoisted per-access memory
    cost of tlbsim.make_interval_runner (read/write x tier asymmetry), so
    the queue model prices the same accesses the counters already count.

Absolute queue clocks are f32: with issue_gap ~8 cycles and <= ~2M accesses
per simulation the clock stays ~1.6e7, where the f32 ulp is ~1-2 cycles —
fine for stall ESTIMATES, and irrelevant to the flat floor (exact zeros).

No repro.sim imports here (sim.__init__ -> runner -> policies -> engine ->
timing would cycle): MachineConfig is consumed duck-typed via its latency
attributes, and PAGES_PER_SP is kept literal in traffic.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.timing import traffic

#: Policies whose step programs emit migration traffic (everything else
#: charges zero bulk cycles, so the no-migration counterfactual chain is
#: skipped and mig_stall is an exact 0.0).
MIGRATING_POLICIES = ("rainbow", "hscc-4kb-mig", "hscc-2mb-mig", "nomad")


@dataclasses.dataclass(frozen=True)
class QueueGeometry:
    """Channel/bank geometry of both tiers (hashable; part of EngineSpec).

    ``servers = channels * banks`` independent FIFO queues per tier; accesses
    map to servers by ``vpn % servers`` (address-interleaved striping).
    ``issue_gap`` is the mean core-side issue spacing in cycles — arrivals of
    interval access i land at ``(t + i) * issue_gap`` where t is the running
    access clock, so queues drain (or back up) across interval boundaries.

    ``infinite=True`` (``flat_floor()``) models one idle server per access:
    no queueing ever, all contention metrics exactly 0.0 — the differential
    floor that keeps every flat-model figure unchanged.
    """

    dram_channels: int = 4
    dram_banks: int = 16
    nvm_channels: int = 2
    nvm_banks: int = 8
    issue_gap: float = 8.0
    infinite: bool = False

    def validate(self) -> None:
        for name in ("dram_channels", "dram_banks", "nvm_channels",
                     "nvm_banks"):
            v = getattr(self, name)
            if not isinstance(v, int) or v < 1:
                raise ValueError(
                    f"QueueGeometry.{name} must be a positive int, got {v!r}"
                )
        gap = self.issue_gap
        if not (isinstance(gap, (int, float)) and gap == gap and gap > 0):
            raise ValueError(
                f"QueueGeometry.issue_gap must be a positive finite number, "
                f"got {gap!r}"
            )

    @property
    def dram_servers(self) -> int:
        return self.dram_channels * self.dram_banks

    @property
    def nvm_servers(self) -> int:
        return self.nvm_channels * self.nvm_banks

    @classmethod
    def flat_floor(cls, issue_gap: float = 8.0) -> "QueueGeometry":
        """Infinite banks: the geometry whose metrics == the flat model."""
        return cls(issue_gap=issue_gap, infinite=True)


#: Named geometries every entry point (CLI flags, benchmarks) resolves from.
#: "constrained" is the scarce-bandwidth headline geometry of
#: benchmarks/timing_contention.py and benchmarks/nomad_async.py.
GEOMETRY_PRESETS: dict[str, QueueGeometry] = {
    "default": QueueGeometry(),
    "flat-floor": QueueGeometry.flat_floor(),
    "roomy": QueueGeometry(
        dram_channels=8, dram_banks=16, nvm_channels=4, nvm_banks=16),
    "constrained": QueueGeometry(
        dram_channels=1, dram_banks=2, nvm_channels=1, nvm_banks=2),
}


def get_geometry(name: str) -> QueueGeometry:
    """Resolve a named QueueGeometry preset, loudly rejecting unknowns."""
    try:
        return GEOMETRY_PRESETS[name]
    except KeyError:
        raise KeyError(
            f"unknown queue-geometry preset {name!r}; registered: "
            f"{sorted(GEOMETRY_PRESETS)}"
        ) from None


class QueueState(NamedTuple):
    """Scan-carried per-server ``avail_cycle`` clocks (f32, monotone).

    ``*_nomig`` is the counterfactual chain charged with demand traffic only
    (never the bulk migration charge) — the per-interval stall difference
    between the chains is the migration-induced stall attribution. For
    non-migrating policies (and the infinite floor) the chains are one and
    the same arrays.
    """

    dram_avail: jax.Array  # f32[dram_servers]
    nvm_avail: jax.Array  # f32[nvm_servers]
    dram_nomig: jax.Array  # f32[dram_servers]
    nvm_nomig: jax.Array  # f32[nvm_servers]


class IntervalTiming(NamedTuple):
    """One interval's contention metrics (f32 scalars; exact 0.0 on the
    flat floor)."""

    stall_dram: jax.Array  # demand bank-conflict wait cycles, DRAM tier
    stall_nvm: jax.Array  # demand bank-conflict wait cycles, NVM tier
    mig_stall: jax.Array  # stall attributable to migration traffic
    backlog_dram: jax.Array  # queue depth past interval end (cycles)
    backlog_nvm: jax.Array


def queue_init(geom: QueueGeometry) -> QueueState:
    """Idle queues (the infinite floor carries dummy length-1 clocks)."""
    geom.validate()
    if geom.infinite:
        z = jnp.zeros((1,), jnp.float32)
        return QueueState(z, z, z, z)
    zd = jnp.zeros((geom.dram_servers,), jnp.float32)
    zn = jnp.zeros((geom.nvm_servers,), jnp.float32)
    return QueueState(zd, zn, jnp.zeros_like(zd), jnp.zeros_like(zn))


def zero_timing() -> IntervalTiming:
    z = jnp.zeros((), jnp.float32)
    return IntervalTiming(z, z, z, z, z)


def charge_queues(avail, sid, arrivals, service, active):
    """FIFO-serve one tier's interval through its per-server queues.

    Vectorized segmented max-plus recurrence: completion of access k on its
    server is ``c_k = max(a_k, c_prev) + svc_k`` with the carried
    ``avail[s]`` folded into each segment's first arrival. Implemented as a
    stable argsort by server id (arrivals are already time-ordered, so each
    segment keeps FIFO order), one ``lax.associative_scan`` over the affine
    max-plus maps ``x -> max(x + svc, a_eff + svc)``, and a segment-last
    scatter back into the avail vector.

    Inactive lanes (accesses served by the OTHER tier) ride along on server 0
    with zero service: arrivals are non-decreasing, so they are transparent
    to every later completion and only ever advance avail[0] to an
    already-past arrival time.

    Returns ``(avail_new, stall_total)``; ``avail_new >= avail`` elementwise
    and ``stall_total`` sums active lanes' ``completion - service - arrival``.
    """
    n_servers = avail.shape[0]
    order = jnp.argsort(sid, stable=True)
    s = sid[order]
    a = arrivals[order]
    svc = service[order]
    act = active[order]

    first = jnp.concatenate(
        [jnp.ones((1,), bool), s[1:] != s[:-1]]
    )
    last = jnp.concatenate(
        [s[1:] != s[:-1], jnp.ones((1,), bool)]
    )
    a_eff = jnp.where(first, jnp.maximum(a, avail[s]), a)

    def combine(left, right):
        p1, q1, f1 = left
        p2, q2, f2 = right
        return (
            jnp.where(f2, p2, p1 + p2),
            jnp.where(f2, q2, jnp.maximum(q1 + p2, q2)),
            f1 | f2,
        )

    _, completion, _ = jax.lax.associative_scan(
        combine, (svc, a_eff + svc, first)
    )
    stall = jnp.where(act, completion - svc - a, jnp.float32(0.0))
    avail_new = avail.at[jnp.where(last, s, n_servers)].set(
        completion, mode="drop"
    )
    return avail_new, jnp.sum(stall)


def charged_service_cycles(sid, service, n_servers: int) -> jax.Array:
    """Total service cycles charged per server (conservation diagnostic:
    the vector permutes with any server relabeling; its sum is invariant)."""
    return jnp.zeros((n_servers,), jnp.float32).at[sid].add(service)


def bulk_charge(avail, cycles, t_end):
    """Spread `cycles` of background traffic evenly over a tier's servers,
    starting no earlier than the interval end it was planned at."""
    n_servers = avail.shape[0]
    return jnp.where(
        cycles > 0,
        jnp.maximum(avail, t_end) + cycles / jnp.float32(n_servers),
        avail,
    )


def interval_step(
    geom: QueueGeometry,
    mc,
    policy: str,
    q: QueueState,
    vpn,
    is_write,
    in_dram,
    t0,
    migrations,
    evictions,
    dirty,
    bulk_dram=None,
    bulk_nvm=None,
) -> tuple[QueueState, IntervalTiming]:
    """Charge one interval's demand + migration traffic through the queues.

    `mc` is duck-typed (t_dr/t_dw/t_nr/t_nw + the traffic-cost attributes);
    `t0` is the running access clock BEFORE this interval's accesses (the
    engine's SimState.t, int32); migrations/evictions/dirty are this
    interval's counts (int32 scalars, traced or concrete).

    `bulk_dram`/`bulk_nvm` (f32 scalars) override the per-tier bulk charge
    for migrating policies: the async (nomad) step programs pre-schedule each
    generation's traffic into per-interval INSTALLMENTS and pass this
    interval's installment here, instead of the whole generation landing at
    `t_end`. The counterfactual `*_nomig` chain stays demand-only either
    way, so `mig_stall` remains the exact per-interval (here: per-
    installment) attribution.

    The service vector is exactly the hoisted per-access mem_cost of
    tlbsim.make_interval_runner: ``where(write, t_?w, t_?r)`` per tier.
    """
    if geom.infinite:
        return q, zero_timing()

    accesses = vpn.shape[0]
    gap = jnp.float32(geom.issue_gap)
    t0f = jnp.asarray(t0, jnp.int32).astype(jnp.float32)
    arrivals = (t0f + jnp.arange(accesses, dtype=jnp.float32)) * gap
    t_end = (t0f + jnp.float32(accesses)) * gap

    vpn32 = jnp.asarray(vpn, jnp.int32)
    wr = jnp.asarray(is_write)
    dram = jnp.asarray(in_dram)
    svc_dram = jnp.where(
        dram,
        jnp.where(wr, jnp.float32(mc.t_dw), jnp.float32(mc.t_dr)),
        jnp.float32(0.0),
    )
    svc_nvm = jnp.where(
        dram,
        jnp.float32(0.0),
        jnp.where(wr, jnp.float32(mc.t_nw), jnp.float32(mc.t_nr)),
    )
    sid_dram = jnp.where(dram, vpn32 % geom.dram_servers, 0)
    sid_nvm = jnp.where(dram, 0, vpn32 % geom.nvm_servers)

    d_avail, d_stall = charge_queues(
        q.dram_avail, sid_dram, arrivals, svc_dram, dram
    )
    n_avail, n_stall = charge_queues(
        q.nvm_avail, sid_nvm, arrivals, svc_nvm, ~dram
    )

    if policy in MIGRATING_POLICIES:
        # counterfactual chain: demand only, never the bulk charge below
        d_nomig, d_stall0 = charge_queues(
            q.dram_nomig, sid_dram, arrivals, svc_dram, dram
        )
        n_nomig, n_stall0 = charge_queues(
            q.nvm_nomig, sid_nvm, arrivals, svc_nvm, ~dram
        )
        if bulk_dram is not None:
            dram_cycles, nvm_cycles = bulk_dram, bulk_nvm
        else:
            dram_cycles, nvm_cycles = traffic.migration_cycles(
                policy, mc, migrations, evictions, dirty
            )
        d_avail = bulk_charge(d_avail, dram_cycles, t_end)
        n_avail = bulk_charge(n_avail, nvm_cycles, t_end)
        mig_stall = jnp.maximum(
            jnp.float32(0.0), (d_stall + n_stall) - (d_stall0 + n_stall0)
        )
    else:
        # no bulk traffic ever: the actual chain IS the counterfactual
        d_nomig, n_nomig = d_avail, n_avail
        mig_stall = jnp.zeros((), jnp.float32)

    backlog_dram = jnp.sum(jnp.maximum(d_avail - t_end, 0.0))
    backlog_nvm = jnp.sum(jnp.maximum(n_avail - t_end, 0.0))
    q_new = QueueState(d_avail, n_avail, d_nomig, n_nomig)
    timing = IntervalTiming(
        stall_dram=d_stall,
        stall_nvm=n_stall,
        mig_stall=mig_stall,
        backlog_dram=backlog_dram,
        backlog_nvm=backlog_nvm,
    )
    return q_new, timing


@functools.partial(
    jax.jit, static_argnames=("geom", "mc", "policy")
)
def interval_step_jit(
    geom, mc, policy, q, vpn, is_write, in_dram, t0, migrations, evictions,
    dirty, bulk_dram=None, bulk_nvm=None,
):
    """Jitted interval_step: the eager oracle (sim.policies) dispatches the
    SAME program per interval that the engine scan inlines, so the two paths
    accumulate bit-identical per-interval stall floats."""
    return interval_step(
        geom, mc, policy, q, vpn, is_write, in_dram, t0, migrations,
        evictions, dirty, bulk_dram, bulk_nvm,
    )
