"""Sharded FleetRunner: mesh-parallel (app x policy x seed x config) sweeps.

The paper's evaluation (§V, Figs. 7-15) is a grid of (workload x policy x
machine-config) simulations. PR 1 fused ONE simulation into a single lax.scan
and vmapped the seed fleet; this module owns the grid itself:

  SweepPlan    declares the cells (apps x policies x seeds x MachineConfig
               overrides, each optionally tagged for later slicing);
  FleetRunner  groups cells that share a compile signature (EngineSpec +
               interval shape), pads each group's flattened fleet axis to the
               mesh size, shards it across a 1-D "fleet" device mesh via
               shard_map of the SAME vmapped body engine_run_batch jits
               (launch.mesh.make_fleet_mesh / launch.sharding.batch_shardings),
               and pipelines host-side staging against the in-flight device
               scans: a background prepare thread generates traces, stages
               them sharded, and resolves each group's compiled executable
               (CompileCache: AOT executables keyed by the compile-signature
               digest, optionally backed by jax's persistent compilation
               cache so resumed/repeated processes skip XLA entirely) up to
               `prefetch_depth` groups ahead of retirement, recycling pooled
               host staging buffers instead of reallocating per group
               (fleet-state buffers are donated, so device memory is bounded
               by the staged depth); `pipeline=False` preserves the
               pre-pipeline inline double-buffered path as the differential
               reference;
  FleetResult  maps every cell back to its SimMetrics, in plan order, with
               tag/field selection for figure scripts.

The mesh may span MULTIPLE jax processes (launch.mesh.make_fleet_mesh
(processes=N) / launch.distributed): staging then feeds each process's
addressable shards via make_array_from_callback and retire all-gathers each
group's (tiny) stats to every process, so the SPMD result is bit-identical
to the single-device path. `run_iter` streams (cell, metrics) pairs as each
group retires — reusing the same prefetch pipeline — and an optional
FleetJournal checkpoints retired groups (appends coalesced up to a watermark,
one fsync per flush) so a killed sweep resumes from the last *flushed* group
(docs/fleet.md). Per-group wall-clock timings (stage / compile / scan /
retire) land on `FleetRunner.timings` and in the journal records, so atlas
throughput regressions are attributable without a profiler.

One engine path from a single-CPU test to a multi-process parameter study:
every paper_fig* module, sim.runner.sweep, sensitivity sweeps, and future
autotuning searches declare a plan and render rows from the result.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import hashlib
import itertools
import json
import os
import pathlib
import queue
import threading
import time
from typing import Any, Iterator, Mapping

import jax
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

import repro.engine.simloop as simloop
from repro.engine.policy import (
    SIM_POLICY_PRESETS,
    ControlPolicy,
    resolve_policy,
)
from repro.launch.mesh import make_fleet_mesh
from repro.launch.sharding import batch_shardings
from repro.sim import trace as trace_mod
from repro.sim.config import MachineConfig
from repro.sim.runner import SimMetrics, finalize_metrics, totals_from_stats

Tags = tuple[tuple[str, Any], ...]


@dataclasses.dataclass(frozen=True)
class SweepCell:
    """One simulation of the sweep grid (hashable: it IS the result key).

    `control` overrides the controller knobs of the stateful policies with a
    ControlPolicy (the unified surface of engine.policy) — sweeps over
    (interval_steps, top_n, threshold_init, ...) declare policies natively
    instead of patching raw MachineConfig dicts.

    `app` may also name a registered scenario (repro.workloads.scenarios);
    `fused=True` then synthesizes its trace INSIDE the engine scan — no
    make_chunks_np staging at all — while `fused=False` materializes the
    same generator stream host-side (the staged differential oracle). A
    fused cell whose app is not a registered scenario fails loudly in
    plan_groups; there is no silent fallback to staged mode.
    """

    app: str
    policy: str
    seed: int = 7
    mc: MachineConfig = dataclasses.field(default_factory=MachineConfig)
    intervals: int = 5
    accesses: int | None = None
    counter_backend: str = "jax"
    control: ControlPolicy | None = None
    fused: bool = False
    tags: Tags = ()
    timing_model: str = "flat"
    queue_geometry: Any = None  # repro.timing.QueueGeometry | None

    @property
    def tag(self) -> dict[str, Any]:
        return dict(self.tags)

    @property
    def label(self) -> str:
        return f"{self.app}/{self.policy}/seed={self.seed}"

    def key(self) -> str:
        """The journal key: the human label + a digest of EVERY cell field.

        Two cells can share a label but differ in mc/intervals/control (e.g.
        sensitivity sweeps), so resume matches on the full identity — a
        journal recorded at one config can never satisfy another.
        """
        blob = repr((self.app, self.policy, self.seed, self.mc,
                     self.intervals, self.accesses, self.counter_backend,
                     self.control, self.fused, self.tags,
                     self.timing_model, self.queue_geometry))
        return f"{self.label}#{hashlib.sha1(blob.encode()).hexdigest()[:10]}"


@dataclasses.dataclass(frozen=True)
class SweepPlan:
    """An ordered set of SweepCells; the declarative input of FleetRunner."""

    cells: tuple[SweepCell, ...]

    @staticmethod
    def grid(
        apps=(),
        policies=(),
        seeds=(7,),
        *,
        mc: MachineConfig | None = None,
        intervals: int = 5,
        accesses: int | None = None,
        counter_backend: str = "jax",
        policy: ControlPolicy | str | None = None,
        scenario=None,
        tags: Tags = (),
        timing_model: str = "flat",
        queue_geometry=None,
    ) -> "SweepPlan":
        """The dense (apps x policies x seeds) grid at one machine config.

        `policy` (a ControlPolicy or a registered preset name) overrides the
        stateful policies' controller knobs for every cell of the grid — the
        native way to sweep (interval_steps, top_n, threshold_init, ...)
        without patching MachineConfig. Because one override's knobs are in
        one policy kind's units (e.g. hscc-2mb hot_slots counts superpages),
        grids mixing several stateful kinds reject an override; declare one
        grid per kind and `+` them. The override's counter_backend is
        authoritative over the `counter_backend` argument.

        `scenario` (a name or sequence of names from
        repro.workloads.scenarios) adds FUSED cells: their traces are
        synthesized inside the engine scan, so the runner never stages
        make_chunks_np arrays for them. Scenario names passed through `apps`
        instead run STAGED (host-materialized from the same generator stream
        — the differential oracle); unregistered scenario names are rejected
        here, loudly.
        """
        mc = mc or MachineConfig()
        apps, policies, seeds = tuple(apps), tuple(policies), tuple(seeds)
        if isinstance(scenario, str):
            scenario = (scenario,)
        scenario = tuple(scenario or ())
        if scenario:
            from repro.workloads import scenarios as scen

            unknown = [s for s in scenario if not scen.is_scenario(s)]
            if unknown:
                raise ValueError(
                    f"SweepPlan.grid: unregistered scenario(s) {unknown}; "
                    f"registered: {scen.available_scenarios()}"
                )
        control = None
        if policy is not None:
            stateful = {p for p in policies if p in SIM_POLICY_PRESETS}
            if len(stateful) > 1:
                raise ValueError(
                    "SweepPlan.grid: one `policy` override cannot apply to "
                    f"multiple stateful policy kinds {sorted(stateful)} — "
                    "their knobs use different units (hscc-2mb counts "
                    "superpage slots, rainbow/hscc-4kb count 4KB pages); "
                    "declare one grid per kind and add the plans"
                )
            control = resolve_policy(policy, "sim-rainbow", mc=mc)
            if counter_backend not in ("jax", control.counter_backend):
                raise ValueError(
                    "SweepPlan.grid: conflicting counter_backend "
                    f"({counter_backend!r} argument vs "
                    f"{control.counter_backend!r} on the policy override) — "
                    "set it on the ControlPolicy"
                )
        workloads = [(a, False) for a in apps] + [(n, True) for n in scenario]
        if bool(workloads) != bool(policies) or (workloads and not seeds):
            raise ValueError(
                "SweepPlan.grid: a lopsided grid (workloads without "
                f"policies/seeds, or vice versa: apps={apps!r}, "
                f"scenario={scenario!r}, policies={policies!r}, "
                f"seeds={seeds!r}) would silently declare ZERO cells — "
                "pass every axis, or none for an explicitly empty plan"
            )
        return SweepPlan(tuple(
            SweepCell(a, p, s, mc, intervals, accesses, counter_backend,
                      control, fused, tuple(tags),
                      timing_model=timing_model,
                      queue_geometry=queue_geometry)
            for a, fused in workloads for p in policies for s in seeds
        ))

    def __add__(self, other: "SweepPlan") -> "SweepPlan":
        return SweepPlan(self.cells + other.cells)

    def __len__(self) -> int:
        return len(self.cells)

    def __iter__(self) -> Iterator[SweepCell]:
        return iter(self.cells)


@dataclasses.dataclass(frozen=True)
class FleetGroup:
    """Cells sharing one compile signature -> one sharded device program."""

    spec: simloop.EngineSpec
    intervals: int
    cells: tuple[SweepCell, ...]
    meta: dict


def plan_groups(plan: SweepPlan) -> list[FleetGroup]:
    """Group plan cells by compile signature, preserving first-seen order.

    Apps change array shapes (footprint/superpage counts) and configs change
    the EngineSpec, so only (seed x same-shape app) cells fuse into one fleet
    axis; the signature is probed from profile metadata without generating a
    single access (trace.probe_meta).
    """
    buckets: dict[tuple, list[SweepCell]] = collections.defaultdict(list)
    metas: dict[tuple, dict] = {}
    seen: set[SweepCell] = set()
    for cell in plan.cells:
        if cell in seen:  # exact duplicates collapse to one run
            continue
        seen.add(cell)
        if cell.fused:
            # fused cells compile against the registered generator program;
            # an unregistered name must fail HERE, not fall back to staging
            from repro.workloads import scenarios as scen

            if not scen.is_scenario(cell.app):
                raise ValueError(
                    f"plan_groups: cell {cell.label!r} requests fused "
                    f"generation but {cell.app!r} is not a registered "
                    f"scenario (registered: {scen.available_scenarios()}); "
                    "fused cells never silently fall back to staged mode"
                )
        meta = trace_mod.probe_meta(cell.app, cell.accesses)
        spec = simloop.EngineSpec(
            policy=cell.policy,
            mc=cell.mc,
            num_superpages=meta["num_superpages"],
            footprint_pages=meta["footprint_pages"],
            counter_backend=cell.counter_backend,
            control=cell.control,
            source=(
                simloop.TraceSource(cell.app, cell.accesses)
                if cell.fused else None
            ),
            timing_model=cell.timing_model,
            queue_geometry=cell.queue_geometry,
        )
        key = (spec, cell.intervals, meta["accesses_per_interval"],
               meta["inst_per_access"])
        buckets[key].append(cell)
        metas[key] = meta
    return [
        FleetGroup(spec=key[0], intervals=key[1], cells=tuple(cells),
                   meta=metas[key])
        for key, cells in buckets.items()
    ]


@functools.lru_cache(maxsize=None)
def _sharded_fused_fn(spec: simloop.EngineSpec, intervals: int, mesh):
    """shard_map of the fused-generation engine body over the fleet mesh.

    Per-shard it is exactly engine_run_fused_batch's program
    (simloop.batch_run_fused): traces are synthesized inside each shard's
    scan, so the only staged inputs are the (tiny) seed vector and initial
    fleet states — nothing for the double buffer to generate host-side.
    """
    fn = shard_map(
        simloop.batch_run_fused(spec, intervals),
        mesh=mesh,
        in_specs=(P("fleet"), P("fleet")),
        out_specs=(P("fleet"), P("fleet")),
    )
    return jax.jit(fn, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _sharded_fleet_fn(spec: simloop.EngineSpec, mesh):
    """shard_map of the shared vmapped engine body over the fleet mesh.

    Per-shard it is exactly engine_run_batch's program (simloop.batch_run), so
    sharded results are bit-identical to the single-device vmap path. The
    fleet states are donated (the final states alias them); trace chunks are
    inputs-only to the scan so XLA cannot alias them into any output — their
    buffers are instead recycled when the group retires and the host drops its
    reference, bounding double-buffer memory at two staged groups.
    """
    fn = shard_map(
        simloop.batch_run(spec),
        mesh=mesh,
        in_specs=(P("fleet"), P("fleet")),
        out_specs=(P("fleet"), P("fleet")),
    )
    return jax.jit(fn, donate_argnums=(0,))


# ---------------------------------------------------------------------------
# Compile caching: skip retracing/re-XLA for repeated compile signatures
# ---------------------------------------------------------------------------

#: Point this env var at a directory to persist compiled fleet programs across
#: processes (resumed sweeps, repeated atlas runs): see
#: enable_persistent_compile_cache.
PERSISTENT_CACHE_ENV = "REPRO_FLEET_CACHE_DIR"
_persistent_cache_dir: str | None = None


def enable_persistent_compile_cache(path=None) -> str | None:
    """Back jax's compilation cache with an on-disk directory.

    `path` (or the REPRO_FLEET_CACHE_DIR env var when None) names a directory
    where XLA executables are persisted keyed by program fingerprint — a
    superset of the fleet compile signature, so a resumed or repeated sweep
    in a FRESH process skips the XLA compile of every signature it has seen
    before (the dominant cost of cold atlas-scale plans). Returns the active
    directory, or None when unset (no-op). Thresholds are dropped to zero so
    even fast-compiling groups persist.
    """
    global _persistent_cache_dir
    path = path if path is not None else os.environ.get(PERSISTENT_CACHE_ENV)
    if not path:
        return None
    path = str(path)
    if _persistent_cache_dir != path:
        jax.config.update("jax_compilation_cache_dir", path)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        # jax initializes its cache handle at most once, on the FIRST compile
        # of the process — which import-time jitted constants usually trigger
        # long before any runner exists, permanently latching "no cache
        # configured". Reset so the next compile re-reads the directory.
        from jax._src import compilation_cache as _cc

        _cc.reset_cache()
        _persistent_cache_dir = path
    return path


def group_signature(group: FleetGroup, fleet_size: int, mesh) -> str:
    """Digest of everything determining one group's compiled fleet program.

    The probe_meta dict (shapes), the EngineSpec (policy program + geometry +
    controller knobs), interval count, the PADDED fleet size (monitor state
    shapes and the shard extent depend on it), and the mesh devices. Two
    groups with equal signatures are guaranteed to lower to the same program,
    so one AOT executable serves both.
    """
    blob = repr((group.spec, group.intervals, sorted(group.meta.items()),
                 int(fleet_size), tuple(str(d) for d in mesh.devices.flat)))
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


class CompileCache:
    """AOT-compiled sharded fleet executables, keyed by group_signature.

    The pipelined runner lowers each group's program against the exact avals
    and shardings of its staged inputs and compiles it ahead of dispatch
    (jax.jit(...).lower(...).compile() — bit-identical to calling the jitted
    function, donation included). Repeated signatures across groups, plans,
    and runs of one process hit `_exes`; with
    enable_persistent_compile_cache, cache misses still skip the XLA backend
    work in any process that compiled the signature before.

    Thread-safe for the runner's single prepare thread + any number of
    readers; a module-level instance (COMPILE_CACHE) is shared by default so
    sequential FleetRunners reuse each other's compiles.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._exes: dict[str, Any] = {}
        self.hits = 0
        self.misses = 0
        self.compile_seconds = 0.0

    def stats(self) -> dict[str, Any]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "entries": len(self._exes),
                    "compile_seconds": self.compile_seconds}

    def clear(self) -> None:
        with self._lock:
            self._exes.clear()
            self.hits = self.misses = 0
            self.compile_seconds = 0.0

    def get_or_compile(self, group: FleetGroup, staged, mesh):
        """(executable, signature, compile_seconds, cached) for one group.

        `staged` is the group's sharded (states, batch) — its avals are the
        lowering inputs, so an executable can only ever be reused where
        shapes, dtypes, AND shardings agree (group_signature covers them).
        """
        fleet_size = int(jax.tree.leaves(staged)[0].shape[0])
        sig = group_signature(group, fleet_size, mesh)
        with self._lock:
            exe = self._exes.get(sig)
            if exe is not None:
                self.hits += 1
                return exe, sig, 0.0, True
        t0 = time.perf_counter()
        if group.spec.source is not None:
            body = simloop.batch_run_fused(group.spec, group.intervals)
        else:
            body = simloop.batch_run(group.spec)
        jitted = jax.jit(
            shard_map(body, mesh=mesh, in_specs=(P("fleet"), P("fleet")),
                      out_specs=(P("fleet"), P("fleet"))),
            donate_argnums=(0,),
        )
        sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype,
                                           sharding=x.sharding),
            staged,
        )
        exe = jitted.lower(*sds).compile()
        dt = time.perf_counter() - t0
        with self._lock:
            self.misses += 1
            self.compile_seconds += dt
            exe = self._exes.setdefault(sig, exe)
        return exe, sig, dt, False


#: Process-wide default cache; pass `compile_cache=` to FleetRunner to isolate.
COMPILE_CACHE = CompileCache()


@dataclasses.dataclass(frozen=True)
class GroupTiming:
    """Wall-clock breakdown of one retired group (FleetRunner.timings).

    stage_s    host trace generation + sharded device transfer
    compile_s  trace/lower/XLA compile (0.0 on a CompileCache hit)
    scan_s     host wall blocked on the group's device results at retire —
               an upper bound on the un-overlapped scan time
    retire_s   stats gather + per-cell metric finalization (journal I/O is
               batched separately and excluded)
    """

    label: str
    signature: str
    cells: int
    stage_s: float
    compile_s: float
    scan_s: float
    retire_s: float
    compile_cached: bool

    def row(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


class _StagingPool:
    """Recycled host staging buffers, keyed by padded batch geometry.

    Atlas-scale plans stage hundreds of groups with only a handful of
    distinct (fleet, intervals, accesses) geometries; reusing the padded
    TraceChunks buffers avoids reallocating (and re-faulting) hundreds of MB
    per group. A buffer is released back only after its group retires — by
    then the sharded scan has consumed the staged copy, so the next group may
    overwrite it even while earlier results are still being finalized.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._free: dict[tuple, list] = collections.defaultdict(list)
        self.allocated = 0
        self.reused = 0

    def acquire(self, key: tuple, alloc):
        with self._lock:
            free = self._free.get(key)
            if free:
                self.reused += 1
                return free.pop()
            self.allocated += 1
        return alloc()

    def release(self, key: tuple, bufs) -> None:
        with self._lock:
            self._free[key].append(bufs)


def _pad_fleet(arrs, pad: int):
    """Pad the leading fleet axis by repeating the last member `pad` times."""
    if pad == 0:
        return arrs
    return jax.tree.map(
        lambda x: np.concatenate([x, np.repeat(x[-1:], pad, axis=0)]), arrs
    )


def _mesh_is_multiprocess(mesh) -> bool:
    return len({d.process_index for d in mesh.devices.flat}) > 1


@functools.lru_cache(maxsize=None)
def _replicate_fn(mesh):
    """jit identity resharding fleet-sharded outputs to fully-replicated.

    The multi-process retire path: an all-gather over the fleet axis (gloo on
    CPU, native on TPU) makes every shard addressable on every process, so
    the per-group device_get and metric finalization stay SPMD-identical
    everywhere — each process sees the SAME bytes it would single-process.
    """
    return jax.jit(lambda t: t, out_shardings=NamedSharding(mesh, P()))


_journal_sync_ids = itertools.count()


def _sync_journal_view(recorded: dict[str, "SimMetrics"]):
    """Make process 0's journal view authoritative across a process fleet.

    Resume decisions must be SPMD-identical: if one process's filesystem view
    of the journal is stale (NFS attribute caches), it would stage groups its
    peers skip and the collectives would deadlock. Process 0 broadcasts its
    loaded records through the coordination-service KV store; everyone else
    adopts them verbatim (the KV key carries a per-call sequence number, and
    all processes call in the same order, so concurrent sweeps can't cross).
    """
    import jax

    from repro.launch import distributed

    key = f"fleet-journal/{next(_journal_sync_ids)}"
    if jax.process_index() == 0:
        distributed.kv_put(key, json.dumps(
            {k: dataclasses.asdict(m) for k, m in recorded.items()}
        ).encode())
        return recorded
    return {
        k: SimMetrics(**fields)
        for k, fields in json.loads(distributed.kv_get(key)).items()
    }


class FleetJournal:
    """Append-only JSONL checkpoint of retired groups (streamed sweeps).

    One header line, then one record per retired FleetGroup mapping each
    cell's `SweepCell.key()` to its SimMetrics fields (plus that group's
    GroupTiming, which load() ignores). Appends are COALESCED: records buffer
    in memory and hit the file — one write, one fsync — when `flush_groups`
    records or `flush_bytes` of JSON accumulate, on an explicit flush()/
    close(), or when the streaming generator finalizes (run_iter flushes in
    its `finally`, so even a close()d iterator persists what it retired).
    `flush_groups=1` restores the original fsync-per-group durability.

    A killed sweep loses at worst the unflushed buffer plus one torn tail
    line, which load() discards — resume re-runs those groups and appends to
    the same file. Only process 0 of a multi-process fleet writes; every
    process reads (the journal must live on a filesystem all workers share).
    """

    #: Journal schema version. v1 headers carried only the version number;
    #: v2 headers also record the SimMetrics field names (`schema`) so a
    #: resume against a journal written by a DIFFERENT build fails loudly at
    #: load() instead of deep inside SimMetrics(**fields) — or, worse,
    #: silently dropping fields the old build never wrote.
    VERSION = 2

    def __init__(self, path: str | os.PathLike, *, flush_groups: int = 8,
                 flush_bytes: int = 4 << 20):
        if flush_groups < 1:
            raise ValueError(
                f"FleetJournal: flush_groups must be >= 1 (got {flush_groups})"
            )
        self.path = pathlib.Path(path)
        self.flush_groups = flush_groups
        self.flush_bytes = flush_bytes
        self._buf: list[str] = []
        self._buf_bytes = 0

    @property
    def pending(self) -> int:
        """Buffered records not yet durable (0 right after a flush)."""
        return len(self._buf)

    def __enter__(self) -> "FleetJournal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def load(self) -> dict[str, SimMetrics]:
        """Completed cells keyed by SweepCell.key(); {} for a fresh journal."""
        if not self.path.exists():
            return {}
        done: dict[str, SimMetrics] = {}
        known = {f.name for f in dataclasses.fields(SimMetrics)}
        saw_header = False
        with self.path.open() as f:
            for line in f:
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    break  # torn tail write from a kill; earlier lines stand
                if rec.get("kind") == "fleet-journal":
                    if rec.get("version") != self.VERSION:
                        raise ValueError(
                            f"{self.path}: journal version {rec.get('version')}"
                            f" != {self.VERSION}; re-run with a fresh "
                            "--journal path (mixed-version journals cannot "
                            "be resumed)"
                        )
                    unknown = set(rec.get("schema", ())) - known
                    if unknown:
                        raise ValueError(
                            f"{self.path}: journal records SimMetrics fields "
                            f"unknown to this build: {sorted(unknown)}; "
                            "re-run with a fresh --journal path"
                        )
                    saw_header = True
                    continue
                if not saw_header:
                    raise ValueError(
                        f"{self.path}: cell record before any fleet-journal "
                        "header — a headerless (pre-versioning) or truncated "
                        "journal; re-run with a fresh --journal path"
                    )
                for key, fields in rec["cells"].items():
                    done[key] = SimMetrics(**fields)
        return done

    def load_timings(self) -> list[dict]:
        """GroupTiming rows of every flushed group, in retirement order.

        The atlas trajectory artifact: where a resumed run's wall-clock went,
        across every process that ever appended to this journal.
        """
        if not self.path.exists():
            return []
        rows: list[dict] = []
        with self.path.open() as f:
            for line in f:
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    break
                if "timing" in rec:
                    rows.append(rec["timing"])
        return rows

    def _drop_torn_tail(self) -> bool:
        """Truncate a partial last line (kill mid-write) before appending.

        load() already ignores the torn line; without this, the next append
        would glue its record onto the fragment and corrupt it too. Returns
        whether the file still has content (i.e. whether a header exists).
        """
        if not self.path.exists():
            return False
        with self.path.open("rb+") as f:
            data = f.read()
            if data and not data.endswith(b"\n"):
                keep = data.rfind(b"\n") + 1
                f.truncate(keep)
                data = data[:keep]
            return bool(data)

    def append(self, cells: dict[SweepCell, SimMetrics],
               timing: GroupTiming | None = None) -> None:
        """Record one retired group (coordinator only); durable at the next
        watermark flush — immediately when flush_groups == 1."""
        if jax.process_index() != 0:
            return
        rec: dict[str, Any] = {"cells": {
            c.key(): dataclasses.asdict(m) for c, m in cells.items()
        }}
        if timing is not None:
            rec["timing"] = timing.row()
        line = json.dumps(rec)
        self._buf.append(line)
        self._buf_bytes += len(line) + 1
        if len(self._buf) >= self.flush_groups \
                or self._buf_bytes >= self.flush_bytes:
            self.flush()

    def flush(self) -> None:
        """Write every buffered record in one append + one fsync.

        The whole coalesced batch lands in a single write() after the torn
        tail (if any) is truncated, so a kill during the flush still leaves
        at worst one torn LINE — the load()-side recovery contract is
        unchanged from the per-group-fsync journal.
        """
        if not self._buf or jax.process_index() != 0:
            return
        self.path.parent.mkdir(parents=True, exist_ok=True)
        lines = []
        if not self._drop_torn_tail():
            lines.append(json.dumps({
                "kind": "fleet-journal",
                "version": self.VERSION,
                "schema": sorted(
                    f.name for f in dataclasses.fields(SimMetrics)
                ),
            }))
        lines.extend(self._buf)
        with self.path.open("a") as f:
            f.write("".join(ln + "\n" for ln in lines))
            f.flush()
            os.fsync(f.fileno())
        self._buf.clear()
        self._buf_bytes = 0

    def close(self) -> None:
        self.flush()


class FleetRunner:
    """Run SweepPlans over a device mesh with pipelined trace staging.

    mesh            1-D "fleet" mesh (default: make_fleet_mesh over all
                    devices; built lazily so constructing a runner never
                    touches jax device state). A multi-process mesh
                    (make_fleet_mesh(processes=N)) works transparently: every
                    process stages the full host batch, owns its device
                    shards, and retire all-gathers each group's (tiny) stats
                    back to every process.
    prefetch_depth  how many groups may be staged-but-not-retired at once:
                    a background prepare thread generates traces, stages
                    them sharded, and resolves the compiled executable up to
                    this many groups ahead of retirement. 2 reproduces the
                    classic double buffer's memory bound; 1 is fully serial.
    double_buffer   legacy alias: False is prefetch_depth=1.
    pipeline        False disables the prepare thread, compile cache, and
                    staging pool, restoring the pre-pipeline inline path —
                    the differential reference the pipelined path is tested
                    against (bit-identical by tests/test_fleet*.py).
    compile_cache   CompileCache instance (default: the process-wide
                    COMPILE_CACHE, so sequential runners share compiles).

    Construction also arms jax's persistent compilation cache when
    REPRO_FLEET_CACHE_DIR is set (enable_persistent_compile_cache), so
    resumed or repeated sweeps in fresh processes skip XLA for every
    signature compiled before. After a run, `timings` holds one GroupTiming
    per retired group.
    """

    def __init__(self, mesh=None, double_buffer: bool = True, *,
                 pipeline: bool = True, prefetch_depth: int | None = None,
                 compile_cache: CompileCache | None = None):
        if prefetch_depth is None:
            prefetch_depth = 2 if double_buffer else 1
        if prefetch_depth < 1:
            raise ValueError(
                f"FleetRunner: prefetch_depth must be >= 1 (got "
                f"{prefetch_depth}); 1 is already the serial pipeline"
            )
        self._mesh = mesh
        self.pipeline = pipeline
        self.prefetch_depth = prefetch_depth
        self.compile_cache = compile_cache or COMPILE_CACHE
        self.timings: list[GroupTiming] = []
        self._staging_pool = _StagingPool()
        enable_persistent_compile_cache()

    @property
    def double_buffer(self) -> bool:
        return self.prefetch_depth > 1

    @property
    def mesh(self):
        if self._mesh is None:
            self._mesh = make_fleet_mesh()
        return self._mesh

    # -- staging ------------------------------------------------------------

    def _stage(self, group: FleetGroup):
        """Host trace generation + one sharded device_put per group.

        Runs concurrently with the previous group's device scan (the scan was
        dispatched asynchronously) — this host/device overlap is the whole
        point of the double buffer. Fused-generation groups
        (spec.source != None) stage only (states, seeds): their traces are
        synthesized inside the sharded scan itself.
        """
        mesh = self.mesh
        if group.spec.source is not None:
            simloop.require_uniform_meta(
                [trace_mod.probe_meta(c.app, c.accesses) for c in group.cells]
                + [group.meta],
                [c.label for c in group.cells] + ["probe"],
            )
            batch = np.asarray([c.seed for c in group.cells], np.int32)
        else:
            chunk_list, metas = [], []
            for cell in group.cells:
                chunks, meta = simloop.make_chunks_np(
                    cell.app, cell.policy, cell.mc, cell.seed,
                    cell.intervals, cell.accesses,
                )
                chunk_list.append(chunks)
                metas.append(meta)
            simloop.require_uniform_meta(
                metas + [group.meta], [c.label for c in group.cells] + ["probe"]
            )
            batch = jax.tree.map(lambda *xs: np.stack(xs), *chunk_list)
        pad = -len(group.cells) % mesh.devices.size
        batch = _pad_fleet(batch, pad)

        state0 = jax.tree.map(np.asarray, simloop.engine_init(group.spec))
        states = jax.tree.map(
            lambda x: np.broadcast_to(x, (len(group.cells) + pad,) + x.shape),
            state0,
        )
        target = (states, batch)
        shardings = batch_shardings(target, mesh)
        if _mesh_is_multiprocess(mesh):
            # device_put cannot target non-addressable devices; every process
            # staged the same full host batch, so each contributes exactly
            # the shards its local devices own.
            return jax.tree.map(
                lambda x, s: jax.make_array_from_callback(
                    np.shape(x), s,
                    lambda idx, _x=x: np.ascontiguousarray(_x[idx]),
                ),
                target, shardings,
            )
        return jax.device_put(target, shardings)

    def _stage_pooled(self, group: FleetGroup):
        """Pipelined staging: _stage, with pooled padded host chunk buffers.

        Returns (staged, pool_key, bufs); the caller releases (pool_key,
        bufs) back to the staging pool once the group retires. Per-cell
        chunks are written straight into the padded buffer (no np.stack +
        re-pad copies) and padding lanes repeat the last cell, exactly like
        _pad_fleet. Fused groups stage only the (tiny) seed vector — nothing
        to pool.
        """
        mesh = self.mesh
        if group.spec.source is not None:
            return self._stage(group), None, None
        pad = -len(group.cells) % mesh.devices.size
        n = len(group.cells) + pad
        ii = group.intervals
        aa = group.meta["accesses_per_interval"]
        pool_key = (n, ii, aa)
        bufs = self._staging_pool.acquire(pool_key, lambda: simloop.TraceChunks(
            sp=np.empty((n, ii, aa), np.int32),
            page=np.empty((n, ii, aa), np.int32),
            vpn=np.empty((n, ii, aa), np.int32),
            is_write=np.empty((n, ii, aa), bool),
            in_dram=np.empty((n, ii, aa), bool),
        ))
        metas = []
        for i, cell in enumerate(group.cells):
            chunks, meta = simloop.make_chunks_np(
                cell.app, cell.policy, cell.mc, cell.seed,
                cell.intervals, cell.accesses,
            )
            for dst, src in zip(bufs, chunks):
                dst[i] = src
            metas.append(meta)
        for j in range(len(group.cells), n):
            for dst in bufs:
                dst[j] = dst[len(group.cells) - 1]
        simloop.require_uniform_meta(
            metas + [group.meta], [c.label for c in group.cells] + ["probe"]
        )
        state0 = jax.tree.map(np.asarray, simloop.engine_init(group.spec))
        states = jax.tree.map(
            lambda x: np.broadcast_to(x, (n,) + x.shape), state0
        )
        target = (states, bufs)
        shardings = batch_shardings(target, mesh)
        if _mesh_is_multiprocess(mesh):
            staged = jax.tree.map(
                lambda x, s: jax.make_array_from_callback(
                    np.shape(x), s,
                    lambda idx, _x=x: np.ascontiguousarray(_x[idx]),
                ),
                target, shardings,
            )
        else:
            staged = jax.device_put(target, shardings)
        return staged, pool_key, bufs

    def _launch(self, group: FleetGroup):
        """Stage one group and dispatch its sharded scan (async) to the mesh."""
        states, batch = self._stage(group)
        if group.spec.source is not None:
            fn = _sharded_fused_fn(group.spec, group.intervals, self.mesh)
        else:
            fn = _sharded_fleet_fn(group.spec, self.mesh)
        return fn(states, batch)  # async dispatch: returns before the mesh finishes

    # -- retire -------------------------------------------------------------

    def _retire(self, group: FleetGroup, counters, stats, out: dict):
        """Block on one group's device results and finalize per-cell metrics."""
        if _mesh_is_multiprocess(self.mesh):
            counters, stats = _replicate_fn(self.mesh)((counters, stats))
        stats_h = jax.tree.map(np.asarray, stats)
        counters_h = jax.tree.map(np.asarray, counters)
        for i, cell in enumerate(group.cells):  # padding lanes are dropped
            per_cell = type(stats)(*(x[i] for x in stats_h))
            totals = totals_from_stats(
                cell.policy, cell.mc, per_cell,
                group.meta["accesses_per_interval"],
            )
            per_counters = type(counters)(*(x[i] for x in counters_h))
            out[cell] = finalize_metrics(
                cell.app, cell.policy, cell.mc, totals, per_counters,
                group.meta["inst_per_access"], group.meta["footprint_pages"],
            )

    # -- the sweep ----------------------------------------------------------

    def run(
        self,
        plan: SweepPlan,
        *,
        stream: bool = False,
        journal: str | os.PathLike | FleetJournal | None = None,
    ) -> "FleetResult":
        """Execute every cell of the plan; metrics come back in plan order.

        A pipelined runner (the default) always executes through `run_iter`'s
        prefetch pipeline; `stream`/`journal` only add incremental retirement
        semantics for the caller and checkpointing. With `pipeline=False` and
        neither, the pre-pipeline inline barrier loop runs instead — kept
        verbatim as the differential reference every pipelined path is tested
        against (all paths are bit-identical).
        """
        if stream or journal is not None or self.pipeline:
            metrics = dict(self.run_iter(plan, journal=journal))
            return FleetResult(
                cells=tuple(dict.fromkeys(plan.cells)), metrics=metrics
            )
        self.timings = []
        groups = plan_groups(plan)
        metrics: dict[SweepCell, SimMetrics] = {}
        in_flight: collections.deque = collections.deque()
        for group in groups:
            finals, stats = self._launch(group)
            in_flight.append((group, finals.sim.counters, stats))
            while len(in_flight) >= (2 if self.double_buffer else 1):
                self._retire(*in_flight.popleft(), metrics)
        while in_flight:
            self._retire(*in_flight.popleft(), metrics)
        return FleetResult(cells=tuple(dict.fromkeys(plan.cells)), metrics=metrics)

    def run_iter(
        self,
        plan: SweepPlan,
        *,
        journal: str | os.PathLike | FleetJournal | None = None,
    ) -> Iterator[tuple[SweepCell, SimMetrics]]:
        """Stream (cell, metrics) pairs as each compile-signature group
        retires, instead of blocking until the whole plan finishes.

        Staging and compilation run in the prefetch pipeline (or the legacy
        double buffer with `pipeline=False`), so consumers (figure renderers,
        CSV writers, progress bars) overlap with device work. With `journal`,
        every retired group is appended to the checkpoint (coalesced; durable
        at the journal's flush watermark and whenever this generator
        finalizes — including close()) and groups already recorded there are
        replayed from disk (yielded up front, in plan order) without staging
        a single trace. Per-group GroupTimings accumulate on `self.timings`.
        """
        if journal is not None and not isinstance(journal, FleetJournal):
            journal = FleetJournal(journal)
        self.timings = []
        groups = plan_groups(plan)
        pending: list[FleetGroup] = groups
        try:
            if journal is not None:
                recorded = journal.load()
                if _mesh_is_multiprocess(self.mesh):
                    recorded = _sync_journal_view(recorded)
                pending = []
                for group in groups:
                    got = {c: recorded.get(c.key()) for c in group.cells}
                    if all(m is not None for m in got.values()):
                        yield from got.items()  # resumed from the checkpoint
                    else:
                        pending.append(group)
            if self.pipeline:
                yield from self._pipeline_iter(pending, journal)
            else:
                yield from self._legacy_iter(pending, journal)
        finally:
            if journal is not None:
                journal.flush()

    def _legacy_iter(self, pending, journal):
        """The pre-pipeline inline double buffer (differential reference).

        Timings are attributed coarser than the pipeline's: _launch folds
        trace staging, any jit compile, and dispatch into stage_s (there is
        no compile cache on this path), and scan_s is the host wall blocked
        at retire.
        """
        in_flight: collections.deque = collections.deque()

        def retire_next():
            out: dict[SweepCell, SimMetrics] = {}
            group, counters, stats, stage_s = in_flight.popleft()
            t0 = time.perf_counter()
            jax.block_until_ready((counters, stats))
            t1 = time.perf_counter()
            self._retire(group, counters, stats, out)
            cell0 = group.cells[0]
            timing = GroupTiming(
                label=f"{cell0.app}/{cell0.policy}",
                signature=group_signature(
                    group, int(jax.tree.leaves(stats)[0].shape[0]), self.mesh
                ),
                cells=len(group.cells),
                stage_s=stage_s,
                compile_s=0.0,
                scan_s=t1 - t0,
                retire_s=time.perf_counter() - t1,
                compile_cached=False,
            )
            self.timings.append(timing)
            if journal is not None:
                journal.append(out, timing=timing)
            return out.items()

        for group in pending:
            t0 = time.perf_counter()
            finals, stats = self._launch(group)
            in_flight.append(
                (group, finals.sim.counters, stats, time.perf_counter() - t0)
            )
            while len(in_flight) >= (2 if self.double_buffer else 1):
                yield from retire_next()
        while in_flight:
            yield from retire_next()

    def _pipeline_iter(self, pending, journal):
        """The pipelined engine: a prepare thread stages + compiles ahead.

        One background thread walks the pending groups in plan order: for
        each it generates host traces into a pooled buffer, stages them
        sharded to the mesh, and resolves the group's compiled executable
        (CompileCache) — at most `prefetch_depth` groups ahead of
        retirement, so staged memory stays bounded. The MAIN thread alone
        dispatches the (async) sharded scans and retires them, in plan
        order, so on a multi-process mesh collectives issue in the same
        order on every process. On a multicore host the next group's trace
        generation and compile overlap the in-flight scan; either way,
        repeated signatures skip compilation entirely.
        """
        slots = threading.Semaphore(self.prefetch_depth)
        ready: queue.Queue = queue.Queue()
        stop = threading.Event()

        def prepare():
            try:
                for group in pending:
                    slots.acquire()
                    if stop.is_set():
                        return
                    t0 = time.perf_counter()
                    staged, pool_key, bufs = self._stage_pooled(group)
                    t1 = time.perf_counter()
                    exe, sig, compile_s, cached = \
                        self.compile_cache.get_or_compile(
                            group, staged, self.mesh)
                    ready.put((group, staged, exe, sig, pool_key, bufs,
                               t1 - t0, compile_s, cached))
                ready.put(None)
            except BaseException as e:  # re-raised on the consuming side
                ready.put(e)

        worker = threading.Thread(
            target=prepare, name="fleet-prepare", daemon=True
        )
        in_flight: collections.deque = collections.deque()

        def retire_next():
            (group, counters, stats, sig, pool_key, bufs,
             stage_s, compile_s, cached) = in_flight.popleft()
            t0 = time.perf_counter()
            jax.block_until_ready((counters, stats))
            t1 = time.perf_counter()
            out: dict[SweepCell, SimMetrics] = {}
            self._retire(group, counters, stats, out)
            if pool_key is not None:
                self._staging_pool.release(pool_key, bufs)
            slots.release()
            cell0 = group.cells[0]
            timing = GroupTiming(
                label=f"{cell0.app}/{cell0.policy}",
                signature=sig,
                cells=len(group.cells),
                stage_s=stage_s,
                compile_s=compile_s,
                scan_s=t1 - t0,
                retire_s=time.perf_counter() - t1,
                compile_cached=cached,
            )
            self.timings.append(timing)
            if journal is not None:
                journal.append(out, timing=timing)
            return out.items()

        worker.start()
        try:
            while True:
                item = ready.get()
                if item is None:
                    break
                if isinstance(item, BaseException):
                    raise item
                (group, staged, exe, sig, pool_key, bufs,
                 stage_s, compile_s, cached) = item
                finals, stats = exe(*staged)  # async dispatch
                del staged  # states were donated; drop the host reference
                in_flight.append((group, finals.sim.counters, stats, sig,
                                  pool_key, bufs, stage_s, compile_s, cached))
                while len(in_flight) >= self.prefetch_depth:
                    yield from retire_next()
            while in_flight:
                yield from retire_next()
        finally:
            stop.set()
            slots.release()  # unblock a prepare thread parked on acquire
            worker.join(timeout=60)

    # -- trace calibration (Fig. 1 / Tables I-II, no simulation) ------------

    def calibration(self, plan: SweepPlan) -> dict[SweepCell, dict]:
        """Per-cell trace-calibration statistics (host-only, no device work).

        Lets the trace-validation figures declare the same SweepPlan grid as
        the simulation figures and render rows from one API.
        """
        return {
            cell: trace_calibration_stats(
                trace_mod.generate(cell.app, cell.seed, interval=1,
                                   accesses=cell.accesses)
            )
            for cell in plan.cells
        }


def trace_calibration_stats(tr) -> dict[str, Any]:
    """Paper Fig. 1 / Tables I-II statistics of one generated trace."""
    sp_touched: dict[int, set] = {}
    for s, p in zip(tr.sp, tr.page):
        sp_touched.setdefault(int(s), set()).add(int(p))
    touched = np.array([len(v) for v in sp_touched.values()])
    counts = np.bincount(tr.vpn.astype(np.int64), minlength=tr.footprint_pages)
    order = np.argsort(-counts)
    csum = np.cumsum(counts[order])
    n_hot = int(np.searchsorted(csum, 0.70 * csum[-1])) + 1
    ws_pages = int((counts > 0).sum())
    return {
        "sp_with_le32_touched_pct": round(float((touched <= 32).mean() * 100), 1),
        "median_touched_per_sp": int(np.median(touched)),
        "hot_page_pct_measured": round(100 * n_hot / max(ws_pages, 1), 2),
        "working_set_pages": ws_pages,
    }


@dataclasses.dataclass(frozen=True)
class FleetResult:
    """Cell -> SimMetrics mapping in plan order, sliceable by field or tag."""

    cells: tuple[SweepCell, ...]
    metrics: Mapping[SweepCell, SimMetrics]

    def __len__(self) -> int:
        return len(self.cells)

    def __iter__(self) -> Iterator[SweepCell]:
        return iter(self.cells)

    def items(self):
        return [(c, self.metrics[c]) for c in self.cells]

    def __getitem__(self, key) -> SimMetrics:
        if isinstance(key, SweepCell):
            return self.metrics[key]
        app, policy, *rest = key
        return self.one(app=app, policy=policy,
                        **({"seed": rest[0]} if rest else {}))

    def apps(self) -> list[str]:
        return sorted({c.app for c in self.cells})

    def policies(self) -> list[str]:
        out: list[str] = []
        for c in self.cells:
            if c.policy not in out:
                out.append(c.policy)
        return out

    def select(self, **filters) -> list[tuple[SweepCell, SimMetrics]]:
        """Cells matching every filter; SweepCell field names match fields,
        anything else matches the cell's tags."""
        fields = {f.name for f in dataclasses.fields(SweepCell)}

        def ok(cell: SweepCell) -> bool:
            for k, v in filters.items():
                got = getattr(cell, k) if k in fields else cell.tag.get(k)
                if got != v:
                    return False
            return True

        return [(c, self.metrics[c]) for c in self.cells if ok(c)]

    def one(self, **filters) -> SimMetrics:
        hits = self.select(**filters)
        if len(hits) != 1:
            raise KeyError(
                f"{filters} matched {len(hits)} cells"
                + (f" (e.g. {[c.label for c, _ in hits[:4]]})" if hits else "")
            )
        return hits[0][1]

    def rows(self, **filters) -> list[dict[str, Any]]:
        """SimMetrics.row() per matching cell, annotated with seed + tags."""
        return [
            {**m.row(), "seed": c.seed, **c.tag}
            for c, m in self.select(**filters)
        ]
