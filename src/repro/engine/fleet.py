"""Sharded FleetRunner: mesh-parallel (app x policy x seed x config) sweeps.

The paper's evaluation (§V, Figs. 7-15) is a grid of (workload x policy x
machine-config) simulations. PR 1 fused ONE simulation into a single lax.scan
and vmapped the seed fleet; this module owns the grid itself:

  SweepPlan    declares the cells (apps x policies x seeds x MachineConfig
               overrides, each optionally tagged for later slicing);
  FleetRunner  groups cells that share a compile signature (EngineSpec +
               interval shape), pads each group's flattened fleet axis to the
               mesh size, shards it across a 1-D "fleet" device mesh via
               shard_map of the SAME vmapped body engine_run_batch jits
               (launch.mesh.make_fleet_mesh / launch.sharding.batch_shardings),
               and double-buffers host-side make_chunks_np staging against the
               in-flight device scan: while group i's sharded scan runs on the
               mesh, group i+1's traces are generated and device_put sharded
               (async dispatch; fleet-state buffers are donated and retired
               chunk buffers recycled, so staging reuses the previous group's
               memory);
  FleetResult  maps every cell back to its SimMetrics, in plan order, with
               tag/field selection for figure scripts.

The mesh may span MULTIPLE jax processes (launch.mesh.make_fleet_mesh
(processes=N) / launch.distributed): staging then feeds each process's
addressable shards via make_array_from_callback and retire all-gathers each
group's (tiny) stats to every process, so the SPMD result is bit-identical
to the single-device path. `run_iter` streams (cell, metrics) pairs as each
group retires — reusing the same double buffer — and an optional FleetJournal
checkpoints retired groups so a killed sweep resumes from the last retired
group (docs/fleet.md).

One engine path from a single-CPU test to a multi-process parameter study:
every paper_fig* module, sim.runner.sweep, sensitivity sweeps, and future
autotuning searches declare a plan and render rows from the result.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import hashlib
import itertools
import json
import os
import pathlib
from typing import Any, Iterator, Mapping

import jax
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

import repro.engine.simloop as simloop
from repro.engine.policy import (
    SIM_POLICY_PRESETS,
    ControlPolicy,
    resolve_policy,
)
from repro.launch.mesh import make_fleet_mesh
from repro.launch.sharding import batch_shardings
from repro.sim import trace as trace_mod
from repro.sim.config import MachineConfig
from repro.sim.runner import SimMetrics, finalize_metrics, totals_from_stats

Tags = tuple[tuple[str, Any], ...]


@dataclasses.dataclass(frozen=True)
class SweepCell:
    """One simulation of the sweep grid (hashable: it IS the result key).

    `control` overrides the controller knobs of the stateful policies with a
    ControlPolicy (the unified surface of engine.policy) — sweeps over
    (interval_steps, top_n, threshold_init, ...) declare policies natively
    instead of patching raw MachineConfig dicts.

    `app` may also name a registered scenario (repro.workloads.scenarios);
    `fused=True` then synthesizes its trace INSIDE the engine scan — no
    make_chunks_np staging at all — while `fused=False` materializes the
    same generator stream host-side (the staged differential oracle). A
    fused cell whose app is not a registered scenario fails loudly in
    plan_groups; there is no silent fallback to staged mode.
    """

    app: str
    policy: str
    seed: int = 7
    mc: MachineConfig = dataclasses.field(default_factory=MachineConfig)
    intervals: int = 5
    accesses: int | None = None
    counter_backend: str = "jax"
    control: ControlPolicy | None = None
    fused: bool = False
    tags: Tags = ()

    @property
    def tag(self) -> dict[str, Any]:
        return dict(self.tags)

    @property
    def label(self) -> str:
        return f"{self.app}/{self.policy}/seed={self.seed}"

    def key(self) -> str:
        """The journal key: the human label + a digest of EVERY cell field.

        Two cells can share a label but differ in mc/intervals/control (e.g.
        sensitivity sweeps), so resume matches on the full identity — a
        journal recorded at one config can never satisfy another.
        """
        blob = repr((self.app, self.policy, self.seed, self.mc,
                     self.intervals, self.accesses, self.counter_backend,
                     self.control, self.fused, self.tags))
        return f"{self.label}#{hashlib.sha1(blob.encode()).hexdigest()[:10]}"


@dataclasses.dataclass(frozen=True)
class SweepPlan:
    """An ordered set of SweepCells; the declarative input of FleetRunner."""

    cells: tuple[SweepCell, ...]

    @staticmethod
    def grid(
        apps=(),
        policies=(),
        seeds=(7,),
        *,
        mc: MachineConfig | None = None,
        intervals: int = 5,
        accesses: int | None = None,
        counter_backend: str = "jax",
        policy: ControlPolicy | str | None = None,
        scenario=None,
        tags: Tags = (),
    ) -> "SweepPlan":
        """The dense (apps x policies x seeds) grid at one machine config.

        `policy` (a ControlPolicy or a registered preset name) overrides the
        stateful policies' controller knobs for every cell of the grid — the
        native way to sweep (interval_steps, top_n, threshold_init, ...)
        without patching MachineConfig. Because one override's knobs are in
        one policy kind's units (e.g. hscc-2mb hot_slots counts superpages),
        grids mixing several stateful kinds reject an override; declare one
        grid per kind and `+` them. The override's counter_backend is
        authoritative over the `counter_backend` argument.

        `scenario` (a name or sequence of names from
        repro.workloads.scenarios) adds FUSED cells: their traces are
        synthesized inside the engine scan, so the runner never stages
        make_chunks_np arrays for them. Scenario names passed through `apps`
        instead run STAGED (host-materialized from the same generator stream
        — the differential oracle); unregistered scenario names are rejected
        here, loudly.
        """
        mc = mc or MachineConfig()
        apps, policies, seeds = tuple(apps), tuple(policies), tuple(seeds)
        if isinstance(scenario, str):
            scenario = (scenario,)
        scenario = tuple(scenario or ())
        if scenario:
            from repro.workloads import scenarios as scen

            unknown = [s for s in scenario if not scen.is_scenario(s)]
            if unknown:
                raise ValueError(
                    f"SweepPlan.grid: unregistered scenario(s) {unknown}; "
                    f"registered: {scen.available_scenarios()}"
                )
        control = None
        if policy is not None:
            stateful = {p for p in policies if p in SIM_POLICY_PRESETS}
            if len(stateful) > 1:
                raise ValueError(
                    "SweepPlan.grid: one `policy` override cannot apply to "
                    f"multiple stateful policy kinds {sorted(stateful)} — "
                    "their knobs use different units (hscc-2mb counts "
                    "superpage slots, rainbow/hscc-4kb count 4KB pages); "
                    "declare one grid per kind and add the plans"
                )
            control = resolve_policy(policy, "sim-rainbow", mc=mc)
            if counter_backend not in ("jax", control.counter_backend):
                raise ValueError(
                    "SweepPlan.grid: conflicting counter_backend "
                    f"({counter_backend!r} argument vs "
                    f"{control.counter_backend!r} on the policy override) — "
                    "set it on the ControlPolicy"
                )
        workloads = [(a, False) for a in apps] + [(n, True) for n in scenario]
        if bool(workloads) != bool(policies) or (workloads and not seeds):
            raise ValueError(
                "SweepPlan.grid: a lopsided grid (workloads without "
                f"policies/seeds, or vice versa: apps={apps!r}, "
                f"scenario={scenario!r}, policies={policies!r}, "
                f"seeds={seeds!r}) would silently declare ZERO cells — "
                "pass every axis, or none for an explicitly empty plan"
            )
        return SweepPlan(tuple(
            SweepCell(a, p, s, mc, intervals, accesses, counter_backend,
                      control, fused, tuple(tags))
            for a, fused in workloads for p in policies for s in seeds
        ))

    def __add__(self, other: "SweepPlan") -> "SweepPlan":
        return SweepPlan(self.cells + other.cells)

    def __len__(self) -> int:
        return len(self.cells)

    def __iter__(self) -> Iterator[SweepCell]:
        return iter(self.cells)


@dataclasses.dataclass(frozen=True)
class FleetGroup:
    """Cells sharing one compile signature -> one sharded device program."""

    spec: simloop.EngineSpec
    intervals: int
    cells: tuple[SweepCell, ...]
    meta: dict


def plan_groups(plan: SweepPlan) -> list[FleetGroup]:
    """Group plan cells by compile signature, preserving first-seen order.

    Apps change array shapes (footprint/superpage counts) and configs change
    the EngineSpec, so only (seed x same-shape app) cells fuse into one fleet
    axis; the signature is probed from profile metadata without generating a
    single access (trace.probe_meta).
    """
    buckets: dict[tuple, list[SweepCell]] = collections.defaultdict(list)
    metas: dict[tuple, dict] = {}
    seen: set[SweepCell] = set()
    for cell in plan.cells:
        if cell in seen:  # exact duplicates collapse to one run
            continue
        seen.add(cell)
        if cell.fused:
            # fused cells compile against the registered generator program;
            # an unregistered name must fail HERE, not fall back to staging
            from repro.workloads import scenarios as scen

            if not scen.is_scenario(cell.app):
                raise ValueError(
                    f"plan_groups: cell {cell.label!r} requests fused "
                    f"generation but {cell.app!r} is not a registered "
                    f"scenario (registered: {scen.available_scenarios()}); "
                    "fused cells never silently fall back to staged mode"
                )
        meta = trace_mod.probe_meta(cell.app, cell.accesses)
        spec = simloop.EngineSpec(
            policy=cell.policy,
            mc=cell.mc,
            num_superpages=meta["num_superpages"],
            footprint_pages=meta["footprint_pages"],
            counter_backend=cell.counter_backend,
            control=cell.control,
            source=(
                simloop.TraceSource(cell.app, cell.accesses)
                if cell.fused else None
            ),
        )
        key = (spec, cell.intervals, meta["accesses_per_interval"],
               meta["inst_per_access"])
        buckets[key].append(cell)
        metas[key] = meta
    return [
        FleetGroup(spec=key[0], intervals=key[1], cells=tuple(cells),
                   meta=metas[key])
        for key, cells in buckets.items()
    ]


@functools.lru_cache(maxsize=None)
def _sharded_fused_fn(spec: simloop.EngineSpec, intervals: int, mesh):
    """shard_map of the fused-generation engine body over the fleet mesh.

    Per-shard it is exactly engine_run_fused_batch's program
    (simloop.batch_run_fused): traces are synthesized inside each shard's
    scan, so the only staged inputs are the (tiny) seed vector and initial
    fleet states — nothing for the double buffer to generate host-side.
    """
    fn = shard_map(
        simloop.batch_run_fused(spec, intervals),
        mesh=mesh,
        in_specs=(P("fleet"), P("fleet")),
        out_specs=(P("fleet"), P("fleet")),
    )
    return jax.jit(fn, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _sharded_fleet_fn(spec: simloop.EngineSpec, mesh):
    """shard_map of the shared vmapped engine body over the fleet mesh.

    Per-shard it is exactly engine_run_batch's program (simloop.batch_run), so
    sharded results are bit-identical to the single-device vmap path. The
    fleet states are donated (the final states alias them); trace chunks are
    inputs-only to the scan so XLA cannot alias them into any output — their
    buffers are instead recycled when the group retires and the host drops its
    reference, bounding double-buffer memory at two staged groups.
    """
    fn = shard_map(
        simloop.batch_run(spec),
        mesh=mesh,
        in_specs=(P("fleet"), P("fleet")),
        out_specs=(P("fleet"), P("fleet")),
    )
    return jax.jit(fn, donate_argnums=(0,))


def _pad_fleet(arrs, pad: int):
    """Pad the leading fleet axis by repeating the last member `pad` times."""
    if pad == 0:
        return arrs
    return jax.tree.map(
        lambda x: np.concatenate([x, np.repeat(x[-1:], pad, axis=0)]), arrs
    )


def _mesh_is_multiprocess(mesh) -> bool:
    return len({d.process_index for d in mesh.devices.flat}) > 1


@functools.lru_cache(maxsize=None)
def _replicate_fn(mesh):
    """jit identity resharding fleet-sharded outputs to fully-replicated.

    The multi-process retire path: an all-gather over the fleet axis (gloo on
    CPU, native on TPU) makes every shard addressable on every process, so
    the per-group device_get and metric finalization stay SPMD-identical
    everywhere — each process sees the SAME bytes it would single-process.
    """
    return jax.jit(lambda t: t, out_shardings=NamedSharding(mesh, P()))


_journal_sync_ids = itertools.count()


def _sync_journal_view(recorded: dict[str, "SimMetrics"]):
    """Make process 0's journal view authoritative across a process fleet.

    Resume decisions must be SPMD-identical: if one process's filesystem view
    of the journal is stale (NFS attribute caches), it would stage groups its
    peers skip and the collectives would deadlock. Process 0 broadcasts its
    loaded records through the coordination-service KV store; everyone else
    adopts them verbatim (the KV key carries a per-call sequence number, and
    all processes call in the same order, so concurrent sweeps can't cross).
    """
    import jax

    from repro.launch import distributed

    key = f"fleet-journal/{next(_journal_sync_ids)}"
    if jax.process_index() == 0:
        distributed.kv_put(key, json.dumps(
            {k: dataclasses.asdict(m) for k, m in recorded.items()}
        ).encode())
        return recorded
    return {
        k: SimMetrics(**fields)
        for k, fields in json.loads(distributed.kv_get(key)).items()
    }


class FleetJournal:
    """Append-only JSONL checkpoint of retired groups (streamed sweeps).

    One header line, then one record per retired FleetGroup mapping each
    cell's `SweepCell.key()` to its SimMetrics fields. A killed sweep leaves
    at worst one torn tail line, which load() discards — resume re-runs that
    group and every group after it, and appends to the same file. Only
    process 0 of a multi-process fleet writes; every process reads (the
    journal must live on a filesystem all workers share).
    """

    VERSION = 1

    def __init__(self, path: str | os.PathLike):
        self.path = pathlib.Path(path)

    def load(self) -> dict[str, SimMetrics]:
        """Completed cells keyed by SweepCell.key(); {} for a fresh journal."""
        if not self.path.exists():
            return {}
        done: dict[str, SimMetrics] = {}
        with self.path.open() as f:
            for line in f:
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    break  # torn tail write from a kill; earlier lines stand
                if rec.get("kind") == "fleet-journal":
                    if rec.get("version") != self.VERSION:
                        raise ValueError(
                            f"{self.path}: journal version {rec.get('version')}"
                            f" != {self.VERSION}"
                        )
                    continue
                for key, fields in rec["cells"].items():
                    done[key] = SimMetrics(**fields)
        return done

    def _drop_torn_tail(self) -> bool:
        """Truncate a partial last line (kill mid-write) before appending.

        load() already ignores the torn line; without this, the next append
        would glue its record onto the fragment and corrupt it too. Returns
        whether the file still has content (i.e. whether a header exists).
        """
        if not self.path.exists():
            return False
        with self.path.open("rb+") as f:
            data = f.read()
            if data and not data.endswith(b"\n"):
                keep = data.rfind(b"\n") + 1
                f.truncate(keep)
                data = data[:keep]
            return bool(data)

    def append(self, cells: dict[SweepCell, SimMetrics]) -> None:
        """Durably record one retired group (coordinator only, fsynced)."""
        if jax.process_index() != 0:
            return
        self.path.parent.mkdir(parents=True, exist_ok=True)
        lines = []
        if not self._drop_torn_tail():
            lines.append(json.dumps(
                {"kind": "fleet-journal", "version": self.VERSION}
            ))
        lines.append(json.dumps({"cells": {
            c.key(): dataclasses.asdict(m) for c, m in cells.items()
        }}))
        with self.path.open("a") as f:
            f.write("".join(ln + "\n" for ln in lines))
            f.flush()
            os.fsync(f.fileno())


class FleetRunner:
    """Run SweepPlans over a device mesh with double-buffered trace staging.

    mesh           1-D "fleet" mesh (default: make_fleet_mesh over all
                   devices; built lazily so constructing a runner never
                   touches jax device state). A multi-process mesh
                   (make_fleet_mesh(processes=N)) works transparently: every
                   process stages the full host batch, owns its device
                   shards, and retire all-gathers each group's (tiny) stats
                   back to every process.
    double_buffer  keep one group's sharded scan in flight while the next
                   group's traces are generated host-side and staged to the
                   mesh; False retires each group before staging the next
                   (the serial reference behavior).
    """

    def __init__(self, mesh=None, double_buffer: bool = True):
        self._mesh = mesh
        self.double_buffer = double_buffer

    @property
    def mesh(self):
        if self._mesh is None:
            self._mesh = make_fleet_mesh()
        return self._mesh

    # -- staging ------------------------------------------------------------

    def _stage(self, group: FleetGroup):
        """Host trace generation + one sharded device_put per group.

        Runs concurrently with the previous group's device scan (the scan was
        dispatched asynchronously) — this host/device overlap is the whole
        point of the double buffer. Fused-generation groups
        (spec.source != None) stage only (states, seeds): their traces are
        synthesized inside the sharded scan itself.
        """
        mesh = self.mesh
        if group.spec.source is not None:
            simloop.require_uniform_meta(
                [trace_mod.probe_meta(c.app, c.accesses) for c in group.cells]
                + [group.meta],
                [c.label for c in group.cells] + ["probe"],
            )
            batch = np.asarray([c.seed for c in group.cells], np.int32)
        else:
            chunk_list, metas = [], []
            for cell in group.cells:
                chunks, meta = simloop.make_chunks_np(
                    cell.app, cell.policy, cell.mc, cell.seed,
                    cell.intervals, cell.accesses,
                )
                chunk_list.append(chunks)
                metas.append(meta)
            simloop.require_uniform_meta(
                metas + [group.meta], [c.label for c in group.cells] + ["probe"]
            )
            batch = jax.tree.map(lambda *xs: np.stack(xs), *chunk_list)
        pad = -len(group.cells) % mesh.devices.size
        batch = _pad_fleet(batch, pad)

        state0 = jax.tree.map(np.asarray, simloop.engine_init(group.spec))
        states = jax.tree.map(
            lambda x: np.broadcast_to(x, (len(group.cells) + pad,) + x.shape),
            state0,
        )
        target = (states, batch)
        shardings = batch_shardings(target, mesh)
        if _mesh_is_multiprocess(mesh):
            # device_put cannot target non-addressable devices; every process
            # staged the same full host batch, so each contributes exactly
            # the shards its local devices own.
            return jax.tree.map(
                lambda x, s: jax.make_array_from_callback(
                    np.shape(x), s,
                    lambda idx, _x=x: np.ascontiguousarray(_x[idx]),
                ),
                target, shardings,
            )
        return jax.device_put(target, shardings)

    def _launch(self, group: FleetGroup):
        """Stage one group and dispatch its sharded scan (async) to the mesh."""
        states, batch = self._stage(group)
        if group.spec.source is not None:
            fn = _sharded_fused_fn(group.spec, group.intervals, self.mesh)
        else:
            fn = _sharded_fleet_fn(group.spec, self.mesh)
        return fn(states, batch)  # async dispatch: returns before the mesh finishes

    # -- retire -------------------------------------------------------------

    def _retire(self, group: FleetGroup, counters, stats, out: dict):
        """Block on one group's device results and finalize per-cell metrics."""
        if _mesh_is_multiprocess(self.mesh):
            counters, stats = _replicate_fn(self.mesh)((counters, stats))
        stats_h = jax.tree.map(np.asarray, stats)
        counters_h = jax.tree.map(np.asarray, counters)
        for i, cell in enumerate(group.cells):  # padding lanes are dropped
            per_cell = type(stats)(*(x[i] for x in stats_h))
            totals = totals_from_stats(
                cell.policy, cell.mc, per_cell,
                group.meta["accesses_per_interval"],
            )
            per_counters = type(counters)(*(x[i] for x in counters_h))
            out[cell] = finalize_metrics(
                cell.app, cell.policy, cell.mc, totals, per_counters,
                group.meta["inst_per_access"], group.meta["footprint_pages"],
            )

    # -- the sweep ----------------------------------------------------------

    def run(
        self,
        plan: SweepPlan,
        *,
        stream: bool = False,
        journal: str | os.PathLike | FleetJournal | None = None,
    ) -> "FleetResult":
        """Execute every cell of the plan; metrics come back in plan order.

        `stream=True` (or any `journal`) routes through `run_iter` — groups
        are retired to the host as soon as their sharded scan completes and,
        with a journal, checkpointed so a killed sweep resumes from the last
        retired group. Both paths are bit-identical; the barrier path is kept
        as the differential reference the streamed path is tested against.
        """
        if stream or journal is not None:
            metrics = dict(self.run_iter(plan, journal=journal))
            return FleetResult(
                cells=tuple(dict.fromkeys(plan.cells)), metrics=metrics
            )
        groups = plan_groups(plan)
        metrics: dict[SweepCell, SimMetrics] = {}
        in_flight: collections.deque = collections.deque()
        for group in groups:
            finals, stats = self._launch(group)
            in_flight.append((group, finals.sim.counters, stats))
            while len(in_flight) >= (2 if self.double_buffer else 1):
                self._retire(*in_flight.popleft(), metrics)
        while in_flight:
            self._retire(*in_flight.popleft(), metrics)
        return FleetResult(cells=tuple(dict.fromkeys(plan.cells)), metrics=metrics)

    def run_iter(
        self,
        plan: SweepPlan,
        *,
        journal: str | os.PathLike | FleetJournal | None = None,
    ) -> Iterator[tuple[SweepCell, SimMetrics]]:
        """Stream (cell, metrics) pairs as each compile-signature group
        retires, instead of blocking until the whole plan finishes.

        The double buffer is reused: group i's results are device_get while
        group i+1's traces are being staged, so consumers (figure renderers,
        CSV writers, progress bars) overlap with device work. With `journal`,
        every retired group is appended to the checkpoint first and groups
        already recorded there are replayed from disk (yielded up front, in
        plan order) without staging a single trace.
        """
        if journal is not None and not isinstance(journal, FleetJournal):
            journal = FleetJournal(journal)
        groups = plan_groups(plan)
        pending: list[FleetGroup] = groups
        if journal is not None:
            recorded = journal.load()
            if _mesh_is_multiprocess(self.mesh):
                recorded = _sync_journal_view(recorded)
            pending = []
            for group in groups:
                got = {c: recorded.get(c.key()) for c in group.cells}
                if all(m is not None for m in got.values()):
                    yield from got.items()  # resumed from the checkpoint
                else:
                    pending.append(group)

        in_flight: collections.deque = collections.deque()

        def retire_next():
            out: dict[SweepCell, SimMetrics] = {}
            group, counters, stats = in_flight.popleft()
            self._retire(group, counters, stats, out)
            if journal is not None:
                journal.append(out)
            return out.items()

        for group in pending:
            finals, stats = self._launch(group)
            in_flight.append((group, finals.sim.counters, stats))
            while len(in_flight) >= (2 if self.double_buffer else 1):
                yield from retire_next()
        while in_flight:
            yield from retire_next()

    # -- trace calibration (Fig. 1 / Tables I-II, no simulation) ------------

    def calibration(self, plan: SweepPlan) -> dict[SweepCell, dict]:
        """Per-cell trace-calibration statistics (host-only, no device work).

        Lets the trace-validation figures declare the same SweepPlan grid as
        the simulation figures and render rows from one API.
        """
        return {
            cell: trace_calibration_stats(
                trace_mod.generate(cell.app, cell.seed, interval=1,
                                   accesses=cell.accesses)
            )
            for cell in plan.cells
        }


def trace_calibration_stats(tr) -> dict[str, Any]:
    """Paper Fig. 1 / Tables I-II statistics of one generated trace."""
    sp_touched: dict[int, set] = {}
    for s, p in zip(tr.sp, tr.page):
        sp_touched.setdefault(int(s), set()).add(int(p))
    touched = np.array([len(v) for v in sp_touched.values()])
    counts = np.bincount(tr.vpn.astype(np.int64), minlength=tr.footprint_pages)
    order = np.argsort(-counts)
    csum = np.cumsum(counts[order])
    n_hot = int(np.searchsorted(csum, 0.70 * csum[-1])) + 1
    ws_pages = int((counts > 0).sum())
    return {
        "sp_with_le32_touched_pct": round(float((touched <= 32).mean() * 100), 1),
        "median_touched_per_sp": int(np.median(touched)),
        "hot_page_pct_measured": round(100 * n_hot / max(ws_pages, 1), 2),
        "working_set_pages": ws_pages,
    }


@dataclasses.dataclass(frozen=True)
class FleetResult:
    """Cell -> SimMetrics mapping in plan order, sliceable by field or tag."""

    cells: tuple[SweepCell, ...]
    metrics: Mapping[SweepCell, SimMetrics]

    def __len__(self) -> int:
        return len(self.cells)

    def __iter__(self) -> Iterator[SweepCell]:
        return iter(self.cells)

    def items(self):
        return [(c, self.metrics[c]) for c in self.cells]

    def __getitem__(self, key) -> SimMetrics:
        if isinstance(key, SweepCell):
            return self.metrics[key]
        app, policy, *rest = key
        return self.one(app=app, policy=policy,
                        **({"seed": rest[0]} if rest else {}))

    def apps(self) -> list[str]:
        return sorted({c.app for c in self.cells})

    def policies(self) -> list[str]:
        out: list[str] = []
        for c in self.cells:
            if c.policy not in out:
                out.append(c.policy)
        return out

    def select(self, **filters) -> list[tuple[SweepCell, SimMetrics]]:
        """Cells matching every filter; SweepCell field names match fields,
        anything else matches the cell's tags."""
        fields = {f.name for f in dataclasses.fields(SweepCell)}

        def ok(cell: SweepCell) -> bool:
            for k, v in filters.items():
                got = getattr(cell, k) if k in fields else cell.tag.get(k)
                if got != v:
                    return False
            return True

        return [(c, self.metrics[c]) for c in self.cells if ok(c)]

    def one(self, **filters) -> SimMetrics:
        hits = self.select(**filters)
        if len(hits) != 1:
            raise KeyError(
                f"{filters} matched {len(hits)} cells"
                + (f" (e.g. {[c.label for c, _ in hits[:4]]})" if hits else "")
            )
        return hits[0][1]

    def rows(self, **filters) -> list[dict[str, Any]]:
        """SimMetrics.row() per matching cell, annotated with seed + tags."""
        return [
            {**m.row(), "seed": c.seed, **c.tag}
            for c, m in self.select(**filters)
        ]
