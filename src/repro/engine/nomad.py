"""Nomad-style transactional asynchronous migration (PAPERS.md: Nomad '24).

Rainbow's step program stops the world at interval end: the whole migration
plan's traffic lands on the queues as one bulk charge at `t_end`. Nomad
migrates *transactionally* — the copy proceeds concurrently with demand
access, writes to a page mid-copy abort the transaction, and a migrating
page is temporarily resident in both tiers. This module models that family
as a wrapper AROUND the unchanged rainbow controller: admission, selection,
remap install/evict, and threshold adaptation are `core.rainbow` verbatim;
what changes is (a) WHEN the planned traffic is charged and (b) what happens
to in-flight pages that get written.

State added on top of RainbowState (all scan-carried, fixed shapes):

  * an in-flight ring of the last `W-1` generations' migrated lanes
    (`tx_sp/tx_page/tx_slot`, each int32[W-1, K]; row 0 = newest), where
    `W = policy.async_window` and `K = policy.max_promotions`;
  * per-tier installment schedules `pend_dram/pend_nvm` (f32[W]): slot j
    holds the bulk cycles due at the j-th upcoming interval end. A
    generation planned at the end of interval t spreads its
    `timing.traffic.migration_cycles` total evenly over the ends of
    intervals t .. t+W-1 (the first installment lands exactly where rainbow
    lands its full charge);
  * `aborts_total` (int32), surfaced as SimMetrics.mig_aborts.

Interval close (`nomad_close`) runs, in order:

  1. abort detection: a ring lane whose page was WRITTEN this interval (and
     that still owns its DRAM slot) aborts — remap entry evicted, slot
     released, remaining installments (including this interval's) canceled
     at `(mig_page_cost / 2) / W` per tier per lane, lane cleared, the page
     shot down in the 4KB TLB like an eviction. A lane whose slot was
     reassigned by a later plan is implicitly terminated, NOT an abort
     (rolling it back would clobber the new occupant);
  2. the unchanged rainbow plan/apply on the rolled-back state;
  3. installment bookkeeping: add the new generation's per-tier total / W
     into all W pend slots, emit `pend[0]` as this interval's bulk charge,
     shift the schedule, and rotate the new generation into ring row 0
     (row W-2 — the generation whose last installment was just charged —
     completes and drops out).

Degenerate invariant (the differential gate, tests/test_nomad.py): with
`async_window == 1` the ring is empty (shape (0, K)) and every async code
path is STATICALLY skipped — the bulk charge is exactly
`migration_cycles(...)` (0.0 + C/1.0 is bitwise C in f32) — so the nomad
step program is bit-identical to the synchronous rainbow program.

Simplifications (documented, deliberate):
  * evictions triggered by an aborted generation's original plan are not
    rolled back (their writeback traffic already happened);
  * a mid-flight page evicted by a later plan keeps its installments (the
    copy bandwidth was already being consumed);
  * the flat cost model prices each generation in full at plan time even if
    it later aborts — pessimistic; the queueing model cancels installments.

Imports only core/timing/utils (never repro.sim): engine -> timing must not
cycle back through sim.__init__, same constraint as timing/traffic.py.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import migration, rainbow as rb
from repro.core.rainbow import IntervalReport, RainbowConfig, RainbowState
from repro.core.remap import remap_evict, translate
from repro.timing import traffic
from repro.utils import pytree_dataclass


@pytree_dataclass
class NomadState:
    """RainbowState + the transactional in-flight ring + installment plan."""

    rb: RainbowState
    tx_sp: jax.Array  # int32[W-1, K]; row 0 = newest in-flight generation
    tx_page: jax.Array  # int32[W-1, K]
    tx_slot: jax.Array  # int32[W-1, K]
    pend_dram: jax.Array  # f32[W]; slot j due at the j-th upcoming interval end
    pend_nvm: jax.Array  # f32[W]
    aborts_total: jax.Array  # int32 cumulative aborted transactions


class NomadReport(NamedTuple):
    """rainbow's IntervalReport + the async layer's outputs."""

    rb: IntervalReport
    bulk_dram: jax.Array  # f32: this interval's DRAM-tier installment
    bulk_nvm: jax.Array  # f32: this interval's NVM-tier installment
    n_aborts: jax.Array  # int32: transactions aborted this interval
    abort_vpn: jax.Array | None  # int32[(W-1)*K] vpns to shoot down, or None


def _window(cfg: RainbowConfig) -> int:
    return cfg.policy.async_window


def nomad_init(cfg: RainbowConfig) -> NomadState:
    w, k = _window(cfg), cfg.policy.max_promotions
    ring = jnp.full((w - 1, k), -1, jnp.int32)
    return NomadState(
        rb=rb.rainbow_init(cfg),
        tx_sp=ring,
        tx_page=ring,
        tx_slot=ring,
        pend_dram=jnp.zeros((w,), jnp.float32),
        pend_nvm=jnp.zeros((w,), jnp.float32),
        aborts_total=jnp.zeros((), jnp.int32),
    )


def nomad_observe(
    cfg: RainbowConfig,
    st: NomadState,
    sp: jax.Array,
    page: jax.Array,
    is_write: jax.Array,
    now: jax.Array,
) -> NomadState:
    """Counting is the unchanged rainbow observe: accesses to in-flight pages
    count on their DRAM slot (the remap is installed at plan time), so an
    aborted page loses that interval's heat with its slot and must re-earn
    admission — the retry is by re-election, not a queued redo."""
    return dataclasses.replace(
        st, rb=rb.observe(cfg, st.rb, sp, page, is_write, now)
    )


def _in_flight_map(cfg: RainbowConfig, st: NomadState) -> jax.Array:
    """bool[num_sp * pages_per_sp]: vpn currently mid-copy (any ring row)."""
    nvpn = cfg.num_superpages * cfg.pages_per_sp
    lane_vpn = st.tx_sp * cfg.pages_per_sp + st.tx_page
    idx = jnp.where(st.tx_sp >= 0, lane_vpn, nvpn).reshape(-1)
    return jnp.zeros((nvpn,), bool).at[idx].set(True, mode="drop")


def residency(
    cfg: RainbowConfig,
    st: NomadState,
    sp: jax.Array,
    page: jax.Array,
    is_write: jax.Array,
) -> jax.Array:
    """Per-access fast-tier residency under the transactional copy window.

    Exclusive residency (shadow_residency=False) is rainbow's: the remap
    flips at plan time, every access to an installed page prices as DRAM.
    Shadow residency serves READS from the cheaper tier (DRAM: t_dr < t_nr
    on every preset) but WRITES to an in-flight page from the source NVM
    copy — the destination copy is not yet consistent, which is exactly why
    abort_on_write kills the transaction.
    """
    base, _ = translate(st.rb.remap, sp, page)
    if _window(cfg) == 1 or not cfg.policy.shadow_residency:
        return base
    in_flight = _in_flight_map(cfg, st)[sp * cfg.pages_per_sp + page]
    return base & ~(is_write & in_flight)


def _detect_aborts(cfg: RainbowConfig, st: NomadState, sp, page, is_write,
                   mc):
    """(new_st, n_aborts, abort_vpn): roll back written in-flight lanes."""
    nvpn = cfg.num_superpages * cfg.pages_per_sp
    wr_vpn = jnp.where(is_write, sp * cfg.pages_per_sp + page, nvpn)
    written = jnp.zeros((nvpn,), bool).at[wr_vpn].set(True, mode="drop")

    lane_valid = st.tx_sp >= 0
    lane_vpn = jnp.where(
        lane_valid, st.tx_sp * cfg.pages_per_sp + st.tx_page, 0
    )
    dram = st.rb.dram
    slot = jnp.where(lane_valid, st.tx_slot, 0)
    # a later plan may have reassigned the slot: that lane is terminated,
    # not aborted (rolling back would clobber the new occupant)
    owns = (
        lane_valid
        & (st.tx_slot >= 0)
        & (dram.slot_sp[slot] == st.tx_sp)
        & (dram.slot_page[slot] == st.tx_page)
    )
    aborted = owns & written[lane_vpn]  # bool[W-1, K]

    ab_sp = jnp.where(aborted, st.tx_sp, -1)
    ab_page = jnp.where(aborted, st.tx_page, -1)
    ab_slot = jnp.where(aborted, st.tx_slot, -1)
    remap = remap_evict(st.rb.remap, ab_sp.reshape(-1), ab_page.reshape(-1))
    dram = migration.dram_release(dram, ab_slot.reshape(-1))

    # cancel the remaining installments: a lane in ring row r has
    # W-1-r installments outstanding (pend slots 0 .. W-2-r), each worth
    # (mig_page_cost / 2) / W cycles per tier
    w = _window(cfg)
    share = jnp.float32(mc.mig_page_cost / 2.0 / w)
    n_ab_row = aborted.sum(axis=1).astype(jnp.float32)  # f32[W-1]
    cums = jnp.cumsum(n_ab_row)
    cancel = jnp.concatenate([cums[::-1], jnp.zeros((1,), jnp.float32)])
    pend_dram = jnp.maximum(st.pend_dram - share * cancel, 0.0)
    pend_nvm = jnp.maximum(st.pend_nvm - share * cancel, 0.0)

    n_aborts = aborted.sum().astype(jnp.int32)
    new_st = dataclasses.replace(
        st,
        rb=dataclasses.replace(st.rb, remap=remap, dram=dram),
        tx_sp=jnp.where(aborted, -1, st.tx_sp),
        tx_page=jnp.where(aborted, -1, st.tx_page),
        tx_slot=jnp.where(aborted, -1, st.tx_slot),
        pend_dram=pend_dram,
        pend_nvm=pend_nvm,
        aborts_total=st.aborts_total + n_aborts,
    )
    abort_vpn = jnp.where(
        aborted, st.tx_sp * cfg.pages_per_sp + st.tx_page, -1
    ).reshape(-1)
    return new_st, n_aborts, abort_vpn


def nomad_close(
    cfg: RainbowConfig,
    st: NomadState,
    sp: jax.Array,
    page: jax.Array,
    is_write: jax.Array,
    timing,
    mc,
) -> tuple[NomadState, NomadReport]:
    """End-of-interval: aborts -> rainbow plan/apply -> installment roll."""
    w = _window(cfg)

    n_aborts = jnp.zeros((), jnp.int32)
    abort_vpn = None
    if w > 1 and cfg.policy.abort_on_write:
        st, n_aborts, abort_vpn = _detect_aborts(
            cfg, st, sp, page, is_write, mc
        )

    rb_st, rep = rb.end_interval(cfg, st.rb, timing)

    # generation traffic, priced exactly like a rainbow interval, spread
    # evenly over the next w interval ends (slot 0 = THIS interval's end)
    c_dram, c_nvm = traffic.migration_cycles(
        "nomad", mc, rep.n_migrated, rep.n_evicted, rep.n_dirty_evicted
    )
    pend_dram = st.pend_dram + c_dram / jnp.float32(w)
    pend_nvm = st.pend_nvm + c_nvm / jnp.float32(w)
    bulk_dram, bulk_nvm = pend_dram[0], pend_nvm[0]
    zero = jnp.zeros((1,), jnp.float32)
    pend_dram = jnp.concatenate([pend_dram[1:], zero])
    pend_nvm = jnp.concatenate([pend_nvm[1:], zero])

    if w > 1:
        # rotate the new generation into row 0; row w-2 (its last
        # installment just charged) completes and leaves the ring
        new_sp = jnp.where(rep.plan.migrate, rep.cand_sp, -1)
        new_page = jnp.where(rep.plan.migrate, rep.cand_page, -1)
        new_slot = jnp.where(rep.plan.migrate, rep.plan.dst_slot, -1)
        tx_sp = jnp.concatenate([new_sp[None], st.tx_sp[: w - 2]])
        tx_page = jnp.concatenate([new_page[None], st.tx_page[: w - 2]])
        tx_slot = jnp.concatenate([new_slot[None], st.tx_slot[: w - 2]])
    else:
        tx_sp, tx_page, tx_slot = st.tx_sp, st.tx_page, st.tx_slot

    new_st = NomadState(
        rb=rb_st,
        tx_sp=tx_sp,
        tx_page=tx_page,
        tx_slot=tx_slot,
        pend_dram=pend_dram,
        pend_nvm=pend_nvm,
        aborts_total=st.aborts_total,
    )
    report = NomadReport(
        rb=rep,
        bulk_dram=bulk_dram,
        bulk_nvm=bulk_nvm,
        n_aborts=n_aborts,
        abort_vpn=abort_vpn,
    )
    return new_st, report


def nomad_interval(
    cfg: RainbowConfig,
    st: NomadState,
    sp: jax.Array,
    page: jax.Array,
    is_write: jax.Array,
    timing,
    mc,
) -> tuple[NomadState, NomadReport]:
    """One full interval (observe batch + close), scannable — the nomad
    counterpart of core.rainbow.interval_step."""
    st = nomad_observe(cfg, st, sp, page, is_write, st.rb.interval)
    return nomad_close(cfg, st, sp, page, is_write, timing, mc)
