"""The interval controller of Rainbow (§III), shared by Layer A and Layer B.

Everything the paper's memory controller + OS do once per monitoring interval is
expressed here as three pure, jit/scan-compatible phases:

  observe_tiers    : translate a batch of accesses, count the NVM tier with the
                     two-stage counters (stage-1 superpage + stage-2 read/write
                     small-page), and record DRAM-tier slot stats for Eq. 2.
  plan_and_apply   : hot-page candidate extraction from the stage-2 counters,
                     utility admission (Eq. 1/2) against the free/clean/dirty
                     slot manager, remap/bitmap evict + install, adaptive
                     threshold update (§III-C).
  rotate_monitors  : top-N hot-superpage selection for the next interval and
                     per-interval counter reset.

Layer A's `core.rainbow.observe/end_interval` and Layer B's
`memory.kvcache.end_interval_promote` are thin compositions of these phases —
the control loop exists exactly once. `engine.simloop` fuses the phases into a
single `lax.scan` step so a whole simulation runs device-resident.

The stage-1/stage-2 counting path has two implementations behind
``ControlConfig.counter_backend``:

  "jax"                      — saturating scatter-adds (bit-identical baseline)
  "ref" | "pallas" |
  "interpret"                — the fused one-pass counting kernel under
                               kernels/page_counter (ref oracle, Pallas TPU
                               kernel, or Pallas interpret mode), merged into
                               the saturating counters. Bit-identical to "jax"
                               because both reduce the batch in uint32 before
                               saturating once.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.counting import (
    COUNTER_MAX,
    Stage1State,
    Stage2State,
    counter_value,
    saturating_merge,
    select_top_n,
    stage1_init,
    stage1_record,
    stage2_record_weighted,
)
from repro.core.migration import (
    DramState,
    MigrationPlan,
    TimingParams,
    adapt_threshold,
    dram_apply_plan,
    dram_new_interval,
    dram_record_access,
    migration_benefit,
    plan_migrations,
)
from repro.core.remap import RemapState, remap_evict, remap_install, translate
from repro.utils import pytree_dataclass, static_field


@pytree_dataclass
class ControlConfig:
    """Static geometry of one controller instance.

    Layer A: units = superpages, pages = 4 KB pages. Layer B: units = sequences
    (superblocks), pages = KV blocks. `max_moves` bounds the per-interval plan
    size K (fixed shapes under scan).
    """

    num_units: int = static_field(default=1024)
    pages_per_unit: int = static_field(default=512)
    top_n: int = static_field(default=100)
    max_moves: int = static_field(default=512)
    write_weight: int = static_field(default=2)
    counter_backend: str = static_field(default="jax")
    # Stage-1 retention across interval rotation: 0.0 is the paper's full
    # reset (bit-identical default); (0, 1) keeps a decayed heat history so
    # slowly-warming units survive the rotation (engine.policy.ControlPolicy
    # exposes this as `counter_decay`).
    counter_decay: float = static_field(default=0.0)


class PlanOutcome(NamedTuple):
    """Result of plan_and_apply (per-interval migration decision + new tables)."""

    remap: RemapState
    dram: DramState
    threshold: jax.Array
    plan: MigrationPlan
    cand_sp: jax.Array
    cand_page: jax.Array
    n_migrated: jax.Array  # int32
    n_evicted: jax.Array  # int32
    n_dirty: jax.Array  # int32


def observe_tiers(
    cfg: ControlConfig,
    s1: Stage1State,
    s2_reads: Stage2State,
    s2_writes: Stage2State,
    dram: DramState,
    remap: RemapState,
    sp: jax.Array,  # int32[B] unit id per access
    page: jax.Array,  # int32[B] page within unit
    is_write: jax.Array,  # bool[B]
    now: jax.Array,  # int32 logical time (LRU)
) -> tuple[Stage1State, Stage2State, Stage2State, DramState]:
    """Record one access batch: NVM-tier two-stage counting + DRAM-tier stats.

    Accesses to migrated pages are DRAM-tier hits (counted on the slot for
    Eq. 2 victims); the rest feed the stage-1/stage-2 NVM counters.
    """
    in_dram, slot = translate(remap, sp, page)
    nvm_sp = jnp.where(in_dram, -1, sp)

    if cfg.counter_backend == "jax":
        s1 = stage1_record(s1, nvm_sp, is_write, cfg.write_weight)
        s2_reads = stage2_record_weighted(
            s2_reads, nvm_sp, page, (~is_write).astype(jnp.uint32)
        )
        s2_writes = stage2_record_weighted(
            s2_writes, nvm_sp, page, is_write.astype(jnp.uint32)
        )
    else:
        from repro.kernels.page_counter.ops import observe_counts

        h1, h2r, h2w = observe_counts(
            nvm_sp,
            page,
            is_write,
            s2_reads.psn,
            cfg.num_units,
            cfg.pages_per_unit,
            write_weight=cfg.write_weight,
            force=cfg.counter_backend,
        )
        s1 = Stage1State(counts=saturating_merge(s1.counts, h1))
        s2_reads = Stage2State(
            psn=s2_reads.psn, counts=saturating_merge(s2_reads.counts, h2r)
        )
        s2_writes = Stage2State(
            psn=s2_writes.psn, counts=saturating_merge(s2_writes.counts, h2w)
        )

    dram = dram_record_access(dram, jnp.where(in_dram, slot, -1), is_write, now)
    return s1, s2_reads, s2_writes, dram


def plan_and_apply(
    cfg: ControlConfig,
    reads: jax.Array,  # [N, P] effective read counts of monitored units
    writes: jax.Array,  # [N, P] effective write counts (zeros for Layer B)
    psn: jax.Array,  # int32[N] monitored unit per row (-1 unused)
    remap: RemapState,
    dram: DramState,
    threshold: jax.Array,
    timing: TimingParams,
    now: jax.Array,
    extra_exclude: jax.Array | None = None,  # bool[N, P] extra candidate mask
) -> PlanOutcome:
    """Close the interval's decision: classify hot pages and admit migrations.

    Candidates are the K best (Eq. 1) monitored pages not already resident (and
    not excluded by `extra_exclude`, e.g. Layer B's beyond-sequence-length
    blocks); admission runs Eq. 1/2 against the slot manager best-first into
    victims cheapest-first, then the remap/bitmap tables evict + install.
    """
    # Counters stay in their native monitor dtypes (uint16 stage-2 ->
    # int32 counter_value) until this single float32 conversion at Eq. 1;
    # the conversion is exact (saturating counters cap at 32767 << 2**24).
    reads = reads.astype(jnp.float32)
    writes = writes.astype(jnp.float32)
    n, p = reads.shape

    # Score in [N, P] directly (same elementwise values as the former
    # repeat/tile flattening) and recover candidate coordinates from the
    # row-major top_k index — no [N*P] repeat/tile index materialization.
    valid_row = psn >= 0
    score = migration_benefit(reads, writes, timing)
    score = jnp.where(valid_row[:, None], score, -jnp.inf)
    # Exclude pages already resident in the performance tier.
    already, _ = translate(
        remap, jnp.maximum(psn, 0)[:, None], jnp.arange(p, dtype=jnp.int32)[None, :]
    )
    score = jnp.where(already & valid_row[:, None], -jnp.inf, score)
    if extra_exclude is not None:
        score = jnp.where(extra_exclude, -jnp.inf, score)
    score = score.reshape(-1)

    k = min(cfg.max_moves, score.shape[0])
    top_score, top_idx = jax.lax.top_k(score, k)
    cand_sp = jnp.where(top_score > -jnp.inf, psn[top_idx // p], -1)
    cand_page = (top_idx % p).astype(jnp.int32)
    cand_r = reads.reshape(-1)[top_idx]
    cand_w = writes.reshape(-1)[top_idx]

    plan = plan_migrations(cand_sp, cand_page, cand_r, cand_w, dram, timing, threshold)
    dram = dram_apply_plan(dram, plan, cand_sp, cand_page, now)

    rm = remap_evict(remap, plan.evict_sp, plan.evict_page)
    rm = remap_install(
        rm, jnp.where(plan.migrate, cand_sp, -1), cand_page, plan.dst_slot
    )

    n_migrated = plan.migrate.sum().astype(jnp.int32)
    n_evicted = (plan.evict_sp >= 0).sum().astype(jnp.int32)
    n_dirty = plan.evict_dirty.sum().astype(jnp.int32)
    threshold = adapt_threshold(threshold, n_evicted)

    return PlanOutcome(
        remap=rm,
        dram=dram,
        threshold=threshold,
        plan=plan,
        cand_sp=cand_sp,
        cand_page=cand_page,
        n_migrated=n_migrated,
        n_evicted=n_evicted,
        n_dirty=n_dirty,
    )


def rotate_monitors(
    cfg: ControlConfig, s1: Stage1State, dram: DramState
) -> tuple[Stage1State, jax.Array, DramState]:
    """Rotate to the next interval: (fresh stage-1, new monitor set, reset slots).

    The next interval's stage-2 monitors are this interval's stage-1 top-N
    (history-based, paper step (2)); DRAM per-interval slot stats are zeroed.
    With `counter_decay` > 0 stage-1 keeps a decayed heat history instead of a
    full reset (the overflow bit is re-derived from the decayed value, so a
    "definitely hot" unit cools off over idle intervals).
    """
    new_psn, _ = select_top_n(s1, cfg.top_n)
    if cfg.counter_decay > 0.0:
        kept = counter_value(s1.counts).astype(jnp.float32) * cfg.counter_decay
        new_s1 = Stage1State(
            counts=jnp.minimum(kept, COUNTER_MAX).astype(jnp.uint16)
        )
    else:
        new_s1 = stage1_init(cfg.num_units)
    return new_s1, new_psn, dram_new_interval(dram)
