"""Layer-A MemoryEngine: the whole simulation as ONE device-resident lax.scan.

The eager reference path (sim.policies / sim.runner's `simulate_eager`) steps
intervals from the host: one `run_interval` dispatch + one policy-migrate
round-trip per interval. At fleet scale the control loop itself becomes the
bottleneck (cf. Nomad '24, Memos '17) — so the engine fuses the interval loop:

  EngineStep = residency -> per-access translation scan -> policy migrate
               (counting + utility admission + remap install/evict) ->
               TLB shootdowns

and `engine_run` executes `lax.scan(EngineStep)` over pre-generated trace
chunks, so a full (intervals x accesses) simulation is a single XLA program
with zero host<->device traffic inside the loop. `sweep_seeds` vmaps the same
step across seeds for fleet sweeps.

All five §IV-A policies are ported as policy-parameterized step programs:

  flat-static / dram-only : residency is state-free, precomputed per chunk
  hscc-4kb / hscc-2mb     : fixed-shape JAX ports of the HSCC utility loop
  rainbow                 : core.rainbow.interval_step (the shared controller)

The engine is bit-identical to the eager path for the state-free policies and
for rainbow (same ops, same order). The HSCC ports could in principle differ
from the old numpy reference in f32 benefit ties, but were re-validated EXACT
over the full workload table, after which the numpy host loops were deleted —
scripts/validate_hscc_parity.py regresses them against the recorded snapshot.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rainbow as rb
from repro.core.remap import translate
from repro.engine import nomad as nomad_mod
from repro.core.tlb import SplitTLB, split_tlb_invalidate_many, tlb_invalidate
from repro.engine.policy import ControlPolicy, sim_policy_for
from repro.sim import tlbsim
from repro.sim import trace as trace_mod
from repro.sim.config import PAGES_PER_SP, MachineConfig
from repro.sim.policies import machine_timing
from repro.timing import QueueGeometry
from repro.timing import queueing as qtiming
from repro.utils import pytree_dataclass, static_field

#: TranslationKind used by the per-access scan, per policy (§IV-A table).
POLICY_KINDS = {
    "flat-static": "flat4k",
    "hscc-4kb-mig": "flat4k",
    "hscc-2mb-mig": "sp2m",
    "rainbow": "rainbow",
    "nomad": "rainbow",
    "dram-only": "sp2m",
}


@dataclasses.dataclass(frozen=True)
class TraceSource:
    """A device-resident trace program as an engine input (hashable).

    Carries the registered scenario NAME (repro.workloads.scenarios) plus the
    per-interval access-count override — everything the fused scan needs to
    synthesize each interval's chunk on device. Registration is import-time
    (the registry rejects rebinding) so a name can never alias two programs
    across the jit cache.
    """

    scenario: str
    accesses: int | None = None


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """Static configuration of one engine compile (hashable; jit static arg).

    `control` overrides the machine-derived ControlPolicy of the stateful
    policies (rainbow / HSCC ports) — the hook SweepPlan cells and the serving
    autotuner use to sweep controller knobs without touching MachineConfig.

    `source` switches the engine to FUSED trace generation: instead of
    consuming pre-staged TraceChunks, the scan body synthesizes each
    interval's chunk from the named scenario program (engine_run_fused /
    batch_run_fused take a seed where the staged entry points take chunks).
    """

    policy: str
    mc: MachineConfig
    num_superpages: int
    footprint_pages: int
    counter_backend: str = "jax"  # rainbow counting: "jax"|"ref"|"pallas"|"interpret"
    max_invalidate: int = 256  # 4KB-TLB shootdowns applied per interval (eager cap)
    control: ControlPolicy | None = None
    source: TraceSource | None = None
    # fastpath=True routes the hot path through the vectorized/fused interval
    # runner (tlbsim.make_interval_runner), batch shootdowns, and cumsum-based
    # first-k selection. fastpath=False keeps the pre-overhaul reference ops
    # (serial make_access_step scan, argsort selection, per-vpn shootdown
    # scan). Both compiles are bit-identical — the reference path exists as
    # the subprocess-isolated speedup baseline and as the differential anchor
    # for tests (tests/test_hotpath.py, tests/test_engine.py).
    fastpath: bool = True
    # timing_model="queueing" carries per-tier per-server avail_cycle clocks
    # (repro.timing) in the scan state and fills the contention fields of
    # IntervalStats; "flat" (default) keeps the event-count cost model and a
    # None queue carry. The two are bit-identical when queue_geometry is the
    # infinite flat floor (tests/test_timing.py).
    timing_model: str = "flat"
    queue_geometry: QueueGeometry | None = None

    def control_policy(self) -> ControlPolicy:
        """The effective ControlPolicy of this compile (stateful policies)."""
        return sim_policy_for(
            self.policy, self.mc, self.control, self.counter_backend
        )

    def timing_geometry(self) -> QueueGeometry | None:
        """The effective QueueGeometry (validated), or None under "flat"."""
        if self.timing_model == "flat":
            return None
        if self.timing_model != "queueing":
            raise ValueError(
                f"EngineSpec.timing_model must be 'flat' or 'queueing', "
                f"got {self.timing_model!r}"
            )
        geom = self.queue_geometry or QueueGeometry()
        geom.validate()
        return geom


class TraceChunks(NamedTuple):
    """Pre-generated device trace: [intervals, accesses] per field.

    `in_dram` carries the state-free residency of flat-static / dram-only
    (zeros for stateful policies, which derive residency on device).
    """

    sp: jax.Array  # int32[I, A]
    page: jax.Array  # int32[I, A]
    vpn: jax.Array  # int32[I, A]
    is_write: jax.Array  # bool[I, A]
    in_dram: jax.Array  # bool[I, A]


@pytree_dataclass
class HsccPolicyState:
    """DRAM residency of the HSCC ports (per 4KB page or per superpage)."""

    resident: jax.Array  # bool[num_units]
    dirty: jax.Array  # bool[num_units]
    slots_used: jax.Array  # int32 (4KB variant; the 2MB port recounts residency)


@pytree_dataclass
class EngineState:
    sim: tlbsim.SimState
    pol: Any  # policy-program state (structure is static per EngineSpec)
    q: Any = None  # timing.QueueState under timing_model="queueing"


class IntervalStats(NamedTuple):
    """Per-interval migration activity (host finalize derives bytes/cycles)
    plus the queueing model's contention metrics — f32 scalars that are
    EXACT zeros under timing_model="flat" AND under the infinite-bank floor,
    so the flat floor holds bitwise through every accumulation."""

    migrations: jax.Array  # int32
    evictions: jax.Array  # int32
    dirty_evictions: jax.Array  # int32
    shootdowns: jax.Array  # int32
    stall_dram: jax.Array  # f32: demand bank-conflict wait cycles, DRAM tier
    stall_nvm: jax.Array  # f32: demand bank-conflict wait cycles, NVM tier
    mig_stall: jax.Array  # f32: stall attributable to migration traffic
    backlog_dram: jax.Array  # f32: queue depth past interval end (cycles)
    backlog_nvm: jax.Array  # f32
    aborts: jax.Array = None  # int32: transactional migration aborts (nomad)


def _zero_stats() -> IntervalStats:
    z = jnp.zeros((), jnp.int32)
    f = jnp.zeros((), jnp.float32)
    return IntervalStats(z, z, z, z, f, f, f, f, f, z)


# ---------------------------------------------------------------------------
# Host-side trace pre-generation (outside the loop; the scan never leaves HBM)
# ---------------------------------------------------------------------------

# flat-static residency hash: (vpn * KNUTH) % MOD < MOD * dram_ratio.  The
# staged path evaluates it on int64 vpns; the fused in-scan path reduces
# KNUTH mod MOD first so the whole product fits int32 — mathematically the
# same residue, so both paths agree bit for bit.
_FLAT_HASH_KNUTH = 2654435761
_FLAT_HASH_MOD = 997


def _flat_static_threshold(mc: MachineConfig) -> int:
    return int(_FLAT_HASH_MOD * (mc.dram_bytes / (mc.dram_bytes + mc.nvm_bytes)))


def make_chunks_np(
    app: str,
    policy: str,
    mc: MachineConfig,
    seed: int,
    intervals: int,
    accesses: int | None = None,
) -> tuple[TraceChunks, dict]:
    """Generate + stack all interval traces HOST-SIDE (numpy TraceChunks).

    The fleet runner stacks many of these along a fleet axis and stages them
    to the mesh in one sharded device_put, so generation stays off-device.
    """
    if policy not in POLICY_KINDS:
        raise KeyError(
            f"unknown policy {policy!r}; expected one of {sorted(POLICY_KINDS)}"
        )
    traces = [
        trace_mod.generate(app, seed, i, accesses) for i in range(intervals)
    ]
    t0 = traces[0]
    vpn64 = np.stack([t.vpn for t in traces])
    wr = np.stack([t.is_write for t in traces])
    if policy == "flat-static":
        in_dram = (
            (vpn64 * _FLAT_HASH_KNUTH) % _FLAT_HASH_MOD
        ) < _flat_static_threshold(mc)
    elif policy == "dram-only":
        in_dram = np.ones_like(wr)
    else:
        in_dram = np.zeros_like(wr)
    chunks = TraceChunks(
        sp=np.stack([t.sp for t in traces]),
        page=np.stack([t.page for t in traces]),
        vpn=vpn64.astype(np.int32),
        is_write=wr,
        in_dram=in_dram,
    )
    meta = {
        "num_superpages": int(t0.num_superpages),
        "footprint_pages": int(t0.footprint_pages),
        "inst_per_access": float(t0.inst_per_access),
        "accesses_per_interval": int(t0.sp.shape[0]),
    }
    return chunks, meta


def make_chunks(
    app: str,
    policy: str,
    mc: MachineConfig,
    seed: int,
    intervals: int,
    accesses: int | None = None,
) -> tuple[TraceChunks, dict]:
    """Generate + stack all interval traces for one (app, policy, seed) run."""
    chunks, meta = make_chunks_np(app, policy, mc, seed, intervals, accesses)
    return jax.tree.map(jnp.asarray, chunks), meta


def require_uniform_meta(metas: list[dict], labels: list[str]) -> dict:
    """Assert every fleet member produced identical trace meta.

    Batching silently trusts member 0's shapes, so any disagreement in
    footprint / superpage count / interval length would corrupt the whole
    fleet — fail loudly, naming the offending members, instead.
    """
    keys = (
        "num_superpages", "footprint_pages",
        "accesses_per_interval", "inst_per_access",
    )
    base = metas[0]
    for lbl, m in zip(labels, metas):
        bad = [k for k in keys if m[k] != base[k]]
        if bad:
            detail = "; ".join(
                f"{k}: {labels[0]}={base[k]} vs {lbl}={m[k]}" for k in bad
            )
            raise ValueError(
                f"fleet members disagree on trace meta ({detail}) — "
                "cells with different shapes cannot share one batched compile"
            )
    return base


# ---------------------------------------------------------------------------
# Shared fixed-shape helpers
# ---------------------------------------------------------------------------


def _first_k_valid(
    values: jax.Array, valid: jax.Array, k: int, fastpath: bool = True
) -> jax.Array:
    """First k `values` whose lane is valid, in lane order; -1 padding.

    One shared implementation for engine + eager oracle (utils.select): the
    fast path is the sort-free masked-cumsum scatter, the reference the
    pre-overhaul stable argsort; tests/test_hotpath.py pins them
    bit-identical across masks and edge floors.
    """
    from repro.utils.select import first_k_valid, first_k_valid_ref

    if not fastpath:
        return first_k_valid_ref(values, valid, k)
    return first_k_valid(values, valid, k)


def _invalidate_4k(
    sim: tlbsim.SimState, vpns: jax.Array, fastpath: bool = True
) -> tlbsim.SimState:
    """Shoot down a fixed-length vpn list in the 4KB split TLB.

    -1 lanes are exact no-ops (they only rewrite already-invalid entries), so
    this matches the eager Policy._invalidate_4k host path bit for bit.

    Fast path: the shared vectorized batch shootdown
    (core.tlb.split_tlb_invalidate_many — one broadcast membership test per
    level). The reference path keeps the pre-overhaul per-vpn sequential
    scan; tests/test_hotpath.py pins the two bit-identical.
    """
    if not fastpath:

        def body(tlb4: SplitTLB, v):
            return SplitTLB(
                l1=tlb_invalidate(tlb4.l1, v), l2=tlb_invalidate(tlb4.l2, v)
            ), None

        tlb4, _ = jax.lax.scan(body, sim.tlb4, vpns)
        return sim._replace(tlb4=tlb4)

    return sim._replace(tlb4=split_tlb_invalidate_many(sim.tlb4, vpns))


def _histograms(idx: jax.Array, is_write: jax.Array, n: int, fastpath: bool = True):
    """Per-unit read/write counts as float32 histograms.

    Fast path: accumulate in int32 and convert once — scatter-adds of 0/1 in
    int32 are cheaper than float32 and the conversion is exact while per-unit
    counts stay below 2**24 (see docs/engine.md; accesses per interval are
    ~1e4-1e6, so the bound has ~16x headroom even if every access hits one
    unit). The reference path scatters float32 ones directly.
    """
    if fastpath:
        ones = jnp.ones_like(idx, dtype=jnp.int32)
        zeros = jnp.zeros_like(ones)
        reads = (
            jnp.zeros((n,), jnp.int32)
            .at[idx]
            .add(jnp.where(is_write, zeros, ones))
            .astype(jnp.float32)
        )
        writes = (
            jnp.zeros((n,), jnp.int32)
            .at[idx]
            .add(jnp.where(is_write, ones, zeros))
            .astype(jnp.float32)
        )
        return reads, writes
    reads = jnp.zeros((n,), jnp.float32).at[idx].add(
        jnp.where(is_write, 0.0, 1.0)
    )
    writes = jnp.zeros((n,), jnp.float32).at[idx].add(
        jnp.where(is_write, 1.0, 0.0)
    )
    return reads, writes


# ---------------------------------------------------------------------------
# Policy programs: init / residency / migrate
# ---------------------------------------------------------------------------


def _rainbow_cfg(spec: EngineSpec) -> rb.RainbowConfig:
    return rb.RainbowConfig(
        num_superpages=spec.num_superpages,
        pages_per_sp=PAGES_PER_SP,
        policy=spec.control_policy(),
    )


def engine_init(spec: EngineSpec) -> EngineState:
    sim = tlbsim.init_state(spec.mc)
    if spec.policy == "rainbow":
        # threshold comes from the policy's threshold_init (mc.mig_threshold
        # for the default preset; an EngineSpec.control override wins)
        pol: Any = rb.rainbow_init(_rainbow_cfg(spec))
    elif spec.policy == "nomad":
        pol = nomad_mod.nomad_init(_rainbow_cfg(spec))
    elif spec.policy == "hscc-4kb-mig":
        pol = HsccPolicyState(
            resident=jnp.zeros((spec.footprint_pages,), bool),
            dirty=jnp.zeros((spec.footprint_pages,), bool),
            slots_used=jnp.zeros((), jnp.int32),
        )
    elif spec.policy == "hscc-2mb-mig":
        pol = HsccPolicyState(
            resident=jnp.zeros((spec.num_superpages,), bool),
            dirty=jnp.zeros((spec.num_superpages,), bool),
            slots_used=jnp.zeros((), jnp.int32),
        )
    else:  # flat-static / dram-only: state-free
        pol = None
    geom = spec.timing_geometry()
    q = qtiming.queue_init(geom) if geom is not None else None
    return EngineState(sim=sim, pol=pol, q=q)


def _rainbow_finish(spec: EngineSpec, rep) -> tuple[IntervalStats, jax.Array]:
    """Shootdown list + interval stats from a rainbow IntervalReport."""
    # NVM->DRAM migration needs NO shootdown (superpage mapping unchanged);
    # only DRAM->NVM writeback shoots down the 4KB entries (paper §III-F).
    ev_valid = rep.plan.evict_sp >= 0
    ev_vpn = rep.plan.evict_sp * PAGES_PER_SP + rep.plan.evict_page
    inval = _first_k_valid(ev_vpn, ev_valid, spec.max_invalidate, spec.fastpath)
    stats = _zero_stats()._replace(
        migrations=rep.n_migrated,
        evictions=rep.n_evicted,
        dirty_evictions=rep.n_dirty_evicted,
        shootdowns=rep.n_evicted,
    )
    return stats, inval


def _rainbow_migrate(spec: EngineSpec, pol, chunk):
    cfg = _rainbow_cfg(spec)
    pol, rep = rb.interval_step(
        cfg, pol, chunk.sp, chunk.page, chunk.is_write, machine_timing(spec.mc)
    )
    stats, inval = _rainbow_finish(spec, rep)
    return pol, stats, inval


def _nomad_finish(spec: EngineSpec, rep) -> tuple[IntervalStats, jax.Array]:
    """Shootdown list + interval stats from a NomadReport.

    Aborted pages move back to NVM, so their 4KB entries are shot down like
    evictions (aborts first: they were rolled back before the plan ran).
    With async_window == 1 (or aborts disabled) rep.abort_vpn is None and
    this reduces STATICALLY to _rainbow_finish — the degenerate gate's
    bitwise anchor.
    """
    r = rep.rb
    ev_valid = r.plan.evict_sp >= 0
    ev_vpn = r.plan.evict_sp * PAGES_PER_SP + r.plan.evict_page
    if rep.abort_vpn is not None:
        vals = jnp.concatenate([rep.abort_vpn, ev_vpn])
        valid = jnp.concatenate([rep.abort_vpn >= 0, ev_valid])
    else:
        vals, valid = ev_vpn, ev_valid
    inval = _first_k_valid(vals, valid, spec.max_invalidate, spec.fastpath)
    stats = _zero_stats()._replace(
        migrations=r.n_migrated,
        evictions=r.n_evicted,
        dirty_evictions=r.n_dirty_evicted,
        shootdowns=r.n_evicted + rep.n_aborts,
        aborts=rep.n_aborts,
    )
    return stats, inval


def _nomad_migrate(spec: EngineSpec, pol, chunk):
    """pol', stats, shootdowns, (bulk_dram, bulk_nvm) — the bulk pair is the
    interval's installment for the queueing model's bulk_charge."""
    cfg = _rainbow_cfg(spec)
    pol, rep = nomad_mod.nomad_interval(
        cfg, pol, chunk.sp, chunk.page, chunk.is_write,
        machine_timing(spec.mc), spec.mc,
    )
    stats, inval = _nomad_finish(spec, rep)
    return pol, stats, inval, (rep.bulk_dram, rep.bulk_nvm)


def _hscc_admit(
    mc: MachineConfig,
    resident: jax.Array,
    dirty: jax.Array,
    reads: jax.Array,
    writes: jax.Array,
    free: jax.Array,
    cand_k: int,
    unit_mig_cost: float,
    unit_writeback: float,
    threshold: float,
):
    """Fixed-shape HSCC admission: free slots best-first, then swap vs coldest.

    Faithful port of the numpy Hscc4K/Hscc2M.migrate reference (validated
    exact over the full workload table, then deleted — see
    scripts/validate_hscc_parity.py): candidates are the top-`cand_k`
    non-resident units by Eq. 1 benefit above the threshold; the first `free`
    fill free slots, the rest are paired rank-for-rank with the coldest
    residents and admitted when the (double-counted, as in the reference)
    swap gain clears the threshold.
    """
    n = resident.shape[0]
    benefit = (
        (mc.t_nr - mc.t_dr) * reads + (mc.t_nw - mc.t_dw) * writes - unit_mig_cost
    )
    benefit = jnp.where(resident, -jnp.inf, benefit)
    k = min(cand_k, n)
    b_top, cand = jax.lax.top_k(benefit, k)
    ok = b_top > threshold

    rank = jnp.cumsum(ok.astype(jnp.int32)) - 1  # rank among admitted lanes
    admit_free = ok & (rank < free)
    resident = resident.at[jnp.where(admit_free, cand, n)].set(True, mode="drop")
    n_free = admit_free.sum().astype(jnp.int32)

    # Swap path: pair overflow candidates with the coldest residents
    # (residency measured after the free admissions, as in the reference).
    rest = ok & (rank >= free)
    rrank = jnp.clip(rank - free, 0, k - 1)
    hotness = reads + writes
    cold_score = jnp.where(resident, hotness, jnp.inf)
    _, victims = jax.lax.top_k(-cold_score, k)
    vic = victims[rrank]
    vic_ok = resident[vic] & rest
    gain_out = (mc.t_nr - mc.t_dr) * reads[vic] + (mc.t_nw - mc.t_dw) * writes[vic]
    wb = jnp.where(dirty[vic], unit_writeback, 0.0)
    ok2 = vic_ok & (b_top - gain_out - unit_mig_cost - wb > threshold)

    resident = resident.at[jnp.where(ok2, vic, n)].set(False, mode="drop")
    resident = resident.at[jnp.where(ok2, cand, n)].set(True, mode="drop")
    dirty_ev = (ok2 & dirty[vic]).sum().astype(jnp.int32)
    dirty = dirty.at[jnp.where(ok2, vic, n)].set(False, mode="drop")

    n_swap = ok2.sum().astype(jnp.int32)
    stats = _zero_stats()._replace(
        migrations=n_free + n_swap,
        evictions=n_swap,
        dirty_evictions=dirty_ev,
        shootdowns=n_free + 2 * n_swap,
    )
    return resident, dirty, n_free, stats, cand, ok


def _hscc4k_migrate(spec: EngineSpec, pol: HsccPolicyState, chunk):
    mc, fp = spec.mc, spec.footprint_pages
    cpol = spec.control_policy()  # "hscc-4kb" preset unless overridden
    vpn = jnp.minimum(chunk.vpn, fp - 1)
    reads, writes = _histograms(vpn, chunk.is_write, fp, spec.fastpath)
    dirty = pol.dirty | (pol.resident & (writes > 0))
    free = jnp.maximum(cpol.hot_slots - pol.slots_used, 0)
    resident, dirty, n_free, stats, cand, ok = _hscc_admit(
        mc, pol.resident, dirty, reads, writes, free,
        cand_k=cpol.max_promotions, unit_mig_cost=mc.mig_page_cost,
        unit_writeback=mc.writeback_page_cost,
        threshold=cpol.threshold_init,
    )
    pol = HsccPolicyState(
        resident=resident, dirty=dirty, slots_used=pol.slots_used + n_free
    )
    inval = _first_k_valid(cand, ok, 64, spec.fastpath)  # eager: _invalidate_4k(cand[:64])
    return pol, stats, inval


def _hscc2m_migrate(spec: EngineSpec, pol: HsccPolicyState, chunk):
    mc, nsp = spec.mc, spec.num_superpages
    cpol = spec.control_policy()  # "hscc-2mb" preset unless overridden
    reads, writes = _histograms(chunk.sp, chunk.is_write, nsp, spec.fastpath)
    dirty = pol.dirty | (pol.resident & (writes > 0))
    free = jnp.maximum(cpol.hot_slots - pol.resident.sum().astype(jnp.int32), 0)
    resident, dirty, _, stats, _, _ = _hscc_admit(
        mc, pol.resident, dirty, reads, writes, free,
        cand_k=cpol.max_promotions, unit_mig_cost=mc.mig_page_cost * PAGES_PER_SP,
        unit_writeback=mc.writeback_page_cost * PAGES_PER_SP,
        threshold=cpol.threshold_init,
    )
    return HsccPolicyState(resident=resident, dirty=dirty, slots_used=pol.slots_used), stats, None


# ---------------------------------------------------------------------------
# EngineStep + scanned run
# ---------------------------------------------------------------------------


def _residency(
    spec: EngineSpec, state: EngineState, chunk: TraceChunks
) -> jax.Array:
    """Per-access fast-tier residency at interval start (policy-specific)."""
    if spec.policy == "rainbow":
        in_dram, _ = translate(state.pol.remap, chunk.sp, chunk.page)
    elif spec.policy == "nomad":
        in_dram = nomad_mod.residency(
            _rainbow_cfg(spec), state.pol, chunk.sp, chunk.page, chunk.is_write
        )
    elif spec.policy == "hscc-4kb-mig":
        in_dram = state.pol.resident[
            jnp.minimum(chunk.vpn, spec.footprint_pages - 1)
        ]
    elif spec.policy == "hscc-2mb-mig":
        in_dram = state.pol.resident[chunk.sp]
    else:
        in_dram = chunk.in_dram
    return in_dram


def _access_scan(
    spec: EngineSpec, sim: tlbsim.SimState, chunk: TraceChunks, in_dram: jax.Array
) -> tlbsim.SimState:
    """The per-access translation walk (fast interval runner or reference scan)."""
    if spec.fastpath:
        run = tlbsim.make_interval_runner(POLICY_KINDS[spec.policy], spec.mc)
        return run(sim, chunk.vpn, chunk.sp, in_dram, chunk.is_write)
    step = tlbsim.make_access_step(POLICY_KINDS[spec.policy], spec.mc)
    sim, _ = jax.lax.scan(
        step, sim, (chunk.vpn, chunk.sp, in_dram, chunk.is_write)
    )
    return sim


def engine_step(
    spec: EngineSpec, state: EngineState, chunk: TraceChunks
) -> tuple[EngineState, IntervalStats]:
    """One interval, device-resident: residency -> access scan -> migrate."""
    policy = spec.policy
    in_dram = _residency(spec, state, chunk)
    t0 = state.sim.t  # access clock BEFORE this interval's walk
    sim = _access_scan(spec, state.sim, chunk, in_dram)

    inval = None
    bulk = None
    if policy == "rainbow":
        pol, stats, inval = _rainbow_migrate(spec, state.pol, chunk)
    elif policy == "nomad":
        pol, stats, inval, bulk = _nomad_migrate(spec, state.pol, chunk)
    elif policy == "hscc-4kb-mig":
        pol, stats, inval = _hscc4k_migrate(spec, state.pol, chunk)
    elif policy == "hscc-2mb-mig":
        pol, stats, _ = _hscc2m_migrate(spec, state.pol, chunk)
    else:
        pol, stats = state.pol, _zero_stats()
    if inval is not None:
        sim = _invalidate_4k(sim, inval, spec.fastpath)
    q = state.q
    geom = spec.timing_geometry()
    if geom is not None:
        extra = {} if bulk is None else {
            "bulk_dram": bulk[0], "bulk_nvm": bulk[1],
        }
        q, tm = qtiming.interval_step(
            geom, spec.mc, policy, state.q,
            chunk.vpn, chunk.is_write, in_dram, t0,
            stats.migrations, stats.evictions, stats.dirty_evictions,
            **extra,
        )
        stats = stats._replace(
            stall_dram=tm.stall_dram,
            stall_nvm=tm.stall_nvm,
            mig_stall=tm.mig_stall,
            backlog_dram=tm.backlog_dram,
            backlog_nvm=tm.backlog_nvm,
        )
    return EngineState(sim=sim, pol=pol, q=q), stats


@functools.partial(jax.jit, static_argnames=("spec",))
def _engine_run_jit(
    spec: EngineSpec, state: EngineState, chunks: TraceChunks
) -> tuple[EngineState, IntervalStats]:
    return jax.lax.scan(
        lambda st, ch: engine_step(spec, st, ch), state, chunks
    )


@functools.partial(jax.jit, static_argnames=("spec",), donate_argnums=(1,))
def _engine_run_donated(
    spec: EngineSpec, state: EngineState, chunks: TraceChunks
) -> tuple[EngineState, IntervalStats]:
    return jax.lax.scan(
        lambda st, ch: engine_step(spec, st, ch), state, chunks
    )


def _dealias(state):
    """Copy leaves that repeat a buffer, so the pytree is safe to donate.

    Init helpers legitimately reuse one device array across fields
    (zero_counters' 14 scalars, dram_init's zeros) — XLA rejects donating
    the same buffer twice, so duplicates get a one-off copy here. First
    occurrence keeps the original buffer and still donates in place.
    """
    seen: set[int] = set()

    def one(x):
        if isinstance(x, jax.Array):
            if id(x) in seen:
                return jnp.array(x)
            seen.add(id(x))
        return x

    return jax.tree.map(one, state)


def engine_run(
    spec: EngineSpec,
    state: EngineState,
    chunks: TraceChunks,
    *,
    donate: bool = False,
    profile: bool = False,
):
    """The whole simulation as one lax.scan over interval chunks.

    donate=True donates the input EngineState's buffers to the scan carry
    (the caller must not reuse `state` afterwards — sim.runner.simulate
    qualifies, benchmarks that re-run from one state0 do not).

    profile=True instead drives the intervals from the host through
    phase-split compiles and returns (state, stats, EngineProfile) — same
    ops in the same order, so the results are bit-identical to the scanned
    run (asserted in tests/test_hotpath.py); see engine.profile.
    """
    if profile:
        from repro.engine.profile import run_profiled

        return run_profiled(spec, state, chunks)
    if donate:
        return _engine_run_donated(spec, _dealias(state), chunks)
    return _engine_run_jit(spec, state, chunks)


@functools.lru_cache(maxsize=None)
def batch_run(spec: EngineSpec):
    """Unjitted whole-sim runner vmapped over a leading fleet axis.

    The single body shared by `engine_run_batch` (one-device vmap) and
    `engine.fleet`'s shard_map partitions — so the sharded fleet is the same
    program per shard, bit for bit, as the PR 1 vmap path.

    Memoized per spec: callers wrap the body in jit/shard_map, whose tracing
    caches key on function identity — a fresh closure per call would retrace
    every group dispatch even when the compile signature repeats. (Entries
    are closures, a few hundred bytes per distinct spec.)
    """

    def run(states: EngineState, chunks: TraceChunks):
        return jax.vmap(
            lambda st, ch: jax.lax.scan(
                lambda s, c: engine_step(spec, s, c), st, ch
            )
        )(states, chunks)

    return run


@functools.partial(jax.jit, static_argnames=("spec",))
def engine_run_batch(
    spec: EngineSpec, states: EngineState, chunks: TraceChunks
) -> tuple[EngineState, IntervalStats]:
    """vmap of engine_run over a leading batch dim (fleet sweeps over seeds)."""
    return batch_run(spec)(states, chunks)


# ---------------------------------------------------------------------------
# Fused in-scan trace generation (EngineSpec.source)
# ---------------------------------------------------------------------------


def _fused_program(spec: EngineSpec):
    """(setup, emit) of the spec's scenario, shape-checked against the spec.

    Raises loudly when the spec is staged or the scenario's static shapes
    disagree with the compile signature — a fused cell must never silently
    fall back to (or group with) a different shape than it emits.
    """
    from repro.workloads import scenarios  # lazy: workloads -> sim.config

    if spec.source is None:
        raise ValueError(
            "EngineSpec.source is None: this is a staged compile — feed it "
            "TraceChunks via engine_run/engine_run_batch, or set source="
            "TraceSource(scenario, accesses) for fused in-scan generation"
        )
    setup, emit, meta = scenarios.trace_program(
        spec.source.scenario, spec.source.accesses
    )
    if (meta["num_superpages"] != spec.num_superpages
            or meta["footprint_pages"] != spec.footprint_pages):
        raise ValueError(
            f"EngineSpec/{spec.source.scenario!r} shape mismatch: spec has "
            f"(num_superpages={spec.num_superpages}, footprint_pages="
            f"{spec.footprint_pages}) but the scenario program emits "
            f"(num_superpages={meta['num_superpages']}, footprint_pages="
            f"{meta['footprint_pages']})"
        )
    return setup, emit


def synth_chunk(spec: EngineSpec, emit, aux, seed, interval) -> TraceChunks:
    """One interval's TraceChunks synthesized on device (inside the scan).

    Field-for-field what make_chunks_np stages for the same workload: vpn is
    the emitted page index, sp/page its superpage split, and `in_dram`
    carries the state-free residency of flat-static / dram-only.
    """
    vpn, is_write = emit(aux, seed, interval)
    if spec.policy == "flat-static":
        in_dram = (
            (vpn % _FLAT_HASH_MOD) * (_FLAT_HASH_KNUTH % _FLAT_HASH_MOD)
            % _FLAT_HASH_MOD
        ) < _flat_static_threshold(spec.mc)
    elif spec.policy == "dram-only":
        in_dram = jnp.ones_like(is_write)
    else:
        in_dram = jnp.zeros_like(is_write)
    return TraceChunks(
        sp=vpn // PAGES_PER_SP,
        page=vpn % PAGES_PER_SP,
        vpn=vpn,
        is_write=is_write,
        in_dram=in_dram,
    )


def _fused_scan(
    spec: EngineSpec, state: EngineState, seed, intervals: int
) -> tuple[EngineState, IntervalStats]:
    """The whole simulation as one lax.scan, chunks synthesized in the body.

    The scenario's seed-dependent setup (e.g. hot-page placement) runs ONCE,
    outside the scan; each scan step folds the interval index into the seed's
    key stream and emits that interval's chunk right where engine_step
    consumes it — zero staging, zero host<->device trace traffic.
    """
    setup, emit = _fused_program(spec)
    seed = jnp.asarray(seed, jnp.int32)
    aux = setup(seed)

    def body(st, i):
        return engine_step(spec, st, synth_chunk(spec, emit, aux, seed, i))

    return jax.lax.scan(body, state, jnp.arange(intervals, dtype=jnp.int32))


@functools.partial(jax.jit, static_argnames=("spec", "intervals"))
def _engine_run_fused_jit(
    spec: EngineSpec, state: EngineState, seed, intervals: int
) -> tuple[EngineState, IntervalStats]:
    return _fused_scan(spec, state, seed, intervals)


@functools.partial(
    jax.jit, static_argnames=("spec", "intervals"), donate_argnums=(1,)
)
def _engine_run_fused_donated(
    spec: EngineSpec, state: EngineState, seed, intervals: int
) -> tuple[EngineState, IntervalStats]:
    return _fused_scan(spec, state, seed, intervals)


def engine_run_fused(
    spec: EngineSpec,
    state: EngineState,
    seed,
    intervals: int,
    *,
    donate: bool = False,
    profile: bool = False,
):
    """Fused counterpart of engine_run: a seed in, a full simulation out.

    donate/profile behave as in engine_run (the profiled run synthesizes each
    interval's chunk host-driven via the same scenario program and reports it
    as a separate "synth" phase).
    """
    if profile:
        from repro.engine.profile import run_profiled

        return run_profiled(spec, state, None, seed=seed, intervals=intervals)
    if donate:
        return _engine_run_fused_donated(spec, _dealias(state), seed, intervals)
    return _engine_run_fused_jit(spec, state, seed, intervals)


@functools.lru_cache(maxsize=None)
def batch_run_fused(spec: EngineSpec, intervals: int):
    """Unjitted fused whole-sim runner vmapped over a leading fleet axis.

    The single body shared by `engine_run_fused_batch` (one-device vmap) and
    `engine.fleet`'s fused shard_map partitions — same program per shard,
    bit for bit, as the single-device fused path.

    Memoized per (spec, intervals) so repeated group dispatches reuse one
    function identity (see batch_run).
    """
    _fused_program(spec)  # staged/mismatched specs fail HERE, not at trace

    def run(states: EngineState, seeds):
        return jax.vmap(
            lambda st, sd: _fused_scan(spec, st, sd, intervals)
        )(states, seeds)

    return run


@functools.partial(jax.jit, static_argnames=("spec", "intervals"))
def engine_run_fused_batch(
    spec: EngineSpec, states: EngineState, seeds, intervals: int
) -> tuple[EngineState, IntervalStats]:
    """vmap of engine_run_fused over a seed fleet (one batched compile)."""
    return batch_run_fused(spec, intervals)(states, seeds)


def sweep_seeds(
    app: str,
    policy: str,
    mc: MachineConfig,
    seeds: list[int],
    intervals: int = 5,
    accesses: int | None = None,
    counter_backend: str = "jax",
    timing_model: str = "flat",
    queue_geometry=None,
) -> tuple[EngineState, IntervalStats, dict]:
    """Run one (app, policy) across a seed fleet in a single batched compile.

    Returns (final states, per-interval stats [S, I], meta). Apps/policies
    change array shapes and scan structure, so the host shell loops over them
    and vmaps the homogeneous axis (seeds) here.
    """
    chunk_list, meta = zip(
        *(make_chunks(app, policy, mc, s, intervals, accesses) for s in seeds)
    )
    chunks = jax.tree.map(lambda *xs: jnp.stack(xs), *chunk_list)
    meta0 = require_uniform_meta(list(meta), [f"seed={s}" for s in seeds])
    spec = EngineSpec(
        policy=policy,
        mc=mc,
        num_superpages=meta0["num_superpages"],
        footprint_pages=meta0["footprint_pages"],
        counter_backend=counter_backend,
        timing_model=timing_model,
        queue_geometry=queue_geometry,
    )
    state0 = engine_init(spec)
    states = jax.tree.map(
        lambda x: jnp.stack([x] * len(seeds)), state0
    )
    finals, stats = engine_run_batch(spec, states, chunks)
    return finals, stats, meta0
