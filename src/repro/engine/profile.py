"""Per-phase engine profiler: `engine_run(..., profile=True)`.

The scanned engine is one fused XLA program — great for throughput, opaque
for attribution. This module re-runs the SAME per-interval ops (same
functions, same order, so the final EngineState / IntervalStats are
bit-identical to `engine_run`; asserted by tests/test_hotpath.py) but drives
the intervals from the host through phase-split compiles, timing each phase
with `block_until_ready` and attaching XLA's compiled-cost analysis
(flops / bytes accessed) per phase.

Phases (per-policy anatomy; see docs/engine.md):

  synth    fused specs only: on-device chunk synthesis from the scenario
  tlb      residency translate + the per-access TLB/bitmap walk
  observe  rainbow: stage-1/stage-2/DRAM-tier counting (rb.observe)
  plan     rainbow: classify + admit (control.plan_and_apply);
           HSCC ports: the whole fixed-shape utility-admission program
  apply    rainbow: monitor rotation + controller-state commit + shootdowns;
           HSCC 4K: shootdowns
  queue    timing_model="queueing" only: the per-channel/bank contention
           charge (repro.timing.interval_step)

The first call of each phase compiles; that wall time is reported separately
as `compile_s` so `wall_s` stays a clean per-interval execution cost (with a
1-interval run every phase therefore shows wall_s == 0).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import rainbow as rb
from repro.engine import nomad as nomad_mod
from repro.sim.policies import machine_timing
from repro.timing import queueing as qtiming


@dataclasses.dataclass
class PhaseCost:
    wall_s: float = 0.0  # execution wall time, compile excluded
    compile_s: float = 0.0  # first-call (trace + compile + run) wall time
    calls: int = 0  # timed executions contributing to wall_s
    flops: float = 0.0  # XLA cost analysis, per call (0.0 when unavailable)
    bytes_accessed: float = 0.0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class EngineProfile:
    intervals: int
    total_wall_s: float
    phases: dict[str, PhaseCost]

    def as_dict(self) -> dict:
        return {
            "intervals": self.intervals,
            "total_wall_s": self.total_wall_s,
            "phases": {k: v.as_dict() for k, v in self.phases.items()},
        }


def _cost_analysis(compiled) -> dict[str, float]:
    """Normalized {flops, bytes accessed} from a Compiled, {} when absent."""
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    if not isinstance(ca, dict):
        return {}
    return {
        k: float(v) for k, v in ca.items() if isinstance(v, (int, float))
    }


class _Phase:
    """One jitted phase: compile-on-first-use, then timed dispatches."""

    def __init__(self, name: str, fn):
        self.name = name
        self._jit = jax.jit(fn)
        self._compiled = None
        self.cost = PhaseCost()

    def __call__(self, *args):
        if self._compiled is None:
            t0 = time.perf_counter()
            self._compiled = self._jit.lower(*args).compile()
            out = self._compiled(*args)
            jax.block_until_ready(out)
            self.cost.compile_s = time.perf_counter() - t0
            ca = _cost_analysis(self._compiled)
            self.cost.flops = ca.get("flops", 0.0)
            self.cost.bytes_accessed = ca.get("bytes accessed", 0.0)
            return out
        t0 = time.perf_counter()
        out = self._compiled(*args)
        jax.block_until_ready(out)
        self.cost.wall_s += time.perf_counter() - t0
        self.cost.calls += 1
        return out


def run_profiled(spec, state, chunks, *, seed=None, intervals: int | None = None):
    """Host-driven, phase-timed equivalent of engine_run / engine_run_fused.

    Staged mode: pass `chunks` (TraceChunks [I, A]). Fused mode: pass
    chunks=None plus seed/intervals (spec.source must be set). Returns
    (final EngineState, IntervalStats [I], EngineProfile) with state/stats
    bit-identical to the scanned run.
    """
    from repro.engine import simloop

    t_start = time.perf_counter()
    fused = chunks is None
    if fused:
        if intervals is None:
            raise ValueError("profiled fused run needs intervals=")
        setup, emit = simloop._fused_program(spec)
        seed = jnp.asarray(seed, jnp.int32)
        n_intervals = intervals
    else:
        n_intervals = int(jax.tree_util.tree_leaves(chunks)[0].shape[0])

    mt = machine_timing(spec.mc)
    policy = spec.policy
    phases: dict[str, _Phase] = {}

    def phase(name, fn):
        phases[name] = _Phase(name, fn)
        return phases[name]

    if fused:
        p_synth = phase(
            "synth",
            lambda aux, sd, i: simloop.synth_chunk(spec, emit, aux, sd, i),
        )

    p_tlb = phase(
        "tlb",
        lambda st, ch: simloop._access_scan(
            spec, st.sim, ch, simloop._residency(spec, st, ch)
        ),
    )

    if policy == "rainbow":
        cfg = simloop._rainbow_cfg(spec)
        p_observe = phase(
            "observe",
            lambda pol, ch: rb.observe(
                cfg, pol, ch.sp, ch.page, ch.is_write, pol.interval
            ),
        )
        p_plan = phase("plan", lambda pol: rb.plan_interval(cfg, pol, mt))

        def _apply(sim, pol, out):
            pol, rep = rb.apply_interval(cfg, pol, out)
            stats, inval = simloop._rainbow_finish(spec, rep)
            return simloop._invalidate_4k(sim, inval, spec.fastpath), pol, stats

        p_apply = phase("apply", _apply)
    elif policy == "nomad":
        cfg = simloop._rainbow_cfg(spec)
        p_observe = phase(
            "observe",
            lambda pol, ch: nomad_mod.nomad_observe(
                cfg, pol, ch.sp, ch.page, ch.is_write, pol.rb.interval
            ),
        )

        def _nomad_plan(pol, ch):
            pol, rep = nomad_mod.nomad_close(
                cfg, pol, ch.sp, ch.page, ch.is_write, mt, spec.mc
            )
            stats, inval = simloop._nomad_finish(spec, rep)
            return pol, stats, inval, (rep.bulk_dram, rep.bulk_nvm)

        p_plan = phase("plan", _nomad_plan)
        p_apply = phase(
            "apply",
            lambda sim, inval: simloop._invalidate_4k(sim, inval, spec.fastpath),
        )
    elif policy == "hscc-4kb-mig":
        p_plan = phase(
            "plan", lambda pol, ch: simloop._hscc4k_migrate(spec, pol, ch)
        )
        p_apply = phase(
            "apply",
            lambda sim, inval: simloop._invalidate_4k(sim, inval, spec.fastpath),
        )
    elif policy == "hscc-2mb-mig":
        p_plan = phase(
            "plan", lambda pol, ch: simloop._hscc2m_migrate(spec, pol, ch)
        )

    geom = spec.timing_geometry()
    if geom is not None:
        def _queue(st, ch, stats, *bulk):
            in_dram = simloop._residency(spec, st, ch)
            extra = (
                {"bulk_dram": bulk[0], "bulk_nvm": bulk[1]} if bulk else {}
            )
            q, tm = qtiming.interval_step(
                geom, spec.mc, policy, st.q,
                ch.vpn, ch.is_write, in_dram, st.sim.t,
                stats.migrations, stats.evictions, stats.dirty_evictions,
                **extra,
            )
            return q, stats._replace(
                stall_dram=tm.stall_dram,
                stall_nvm=tm.stall_nvm,
                mig_stall=tm.mig_stall,
                backlog_dram=tm.backlog_dram,
                backlog_nvm=tm.backlog_nvm,
            )

        p_queue = phase("queue", _queue)

    if fused:
        t0 = time.perf_counter()
        aux = setup(seed)
        jax.block_until_ready(aux)
        phases["synth"].cost.compile_s += time.perf_counter() - t0

    stats_per_interval: list[Any] = []
    for i in range(n_intervals):
        if fused:
            chunk = p_synth(aux, seed, jnp.asarray(i, jnp.int32))
        else:
            chunk = jax.tree.map(lambda x: x[i], chunks)
        sim = p_tlb(state, chunk)
        bulk = ()
        if policy == "rainbow":
            pol = p_observe(state.pol, chunk)
            out = p_plan(pol)
            sim, pol, stats = p_apply(sim, pol, out)
        elif policy == "nomad":
            pol = p_observe(state.pol, chunk)
            pol, stats, inval, bulk = p_plan(pol, chunk)
            sim = p_apply(sim, inval)
        elif policy == "hscc-4kb-mig":
            pol, stats, inval = p_plan(state.pol, chunk)
            sim = p_apply(sim, inval)
        elif policy == "hscc-2mb-mig":
            pol, stats, _ = p_plan(state.pol, chunk)
        else:
            pol, stats = state.pol, simloop._zero_stats()
        q = state.q
        if geom is not None:
            # consumes PRE-interval state (residency + access clock), like
            # the in-scan engine_step
            q, stats = p_queue(state, chunk, stats, *bulk)
        state = simloop.EngineState(sim=sim, pol=pol, q=q)
        stats_per_interval.append(stats)

    stats = jax.tree.map(lambda *xs: jnp.stack(xs), *stats_per_interval)
    profile = EngineProfile(
        intervals=n_intervals,
        total_wall_s=time.perf_counter() - t_start,
        phases={name: p.cost for name, p in phases.items()},
    )
    return state, stats, profile
