"""ControlPolicy: the ONE declarative policy surface of the interval controller.

Before this module the controller's knobs lived on three disjoint surfaces —
`core.rainbow.RainbowConfig` (Layer A), `memory.kvcache.PagedConfig` (Layer B),
and hand-rolled argparse in `launch/serve.py` — so every consumer redeclared
(interval_steps, top_n, max_promotions, ...) with its own names and defaults.
`ControlPolicy` is the single frozen pytree-dataclass holding exactly the
interval-controller knobs of §III-B/C; both layers' configs are thin
compositions of a ControlPolicy plus layer-specific geometry:

  RainbowConfig = ControlPolicy + (num_superpages, pages_per_sp)
  PagedConfig   = ControlPolicy + (block_size, blocks_per_seq, quantize)

and `engine.autotune` searches over ControlPolicy fields directly.

A tiny registry (`@register_policy` / `get_policy`) names the presets every
entry point constructs its controller from: the paper's §IV-F simulator
parameters, the HSCC baselines' admission shapes, and the v5e-class serving
defaults. Factories may consume a `MachineConfig` (Layer A knobs are machine
properties there); `get_policy(name, **kw)` resolves either form and validates.

Field mapping to the old surfaces (kept as deprecation-shim properties):

  hot_slots       <- RainbowConfig.dram_slots / PagedConfig.hot_slots
  max_promotions  <- RainbowConfig.max_migrations_per_interval /
                     PagedConfig.max_promotions / the HSCC ports' cand_k
  threshold_init  <- the `threshold` argument of rainbow_init /
                     MachineConfig.mig_threshold
  interval_steps  <- PagedConfig.interval_steps (Layer A runs one controller
                     close per trace chunk, i.e. interval_steps = 1)
  counter_decay   <- new (§III-B extension): fraction of each stage-1 counter
                     retained across interval rotation (0.0 = the paper's full
                     reset; bit-identical default)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.utils import pytree_dataclass, static_field

#: Counting backends accepted by engine.control (see its module docstring).
COUNTER_BACKENDS = ("jax", "ref", "pallas", "interpret")


@pytree_dataclass
class ControlPolicy:
    """Interval-controller knobs, layer-agnostic (all static: a policy is part
    of the compile signature, like the geometry it composes with).

    interval_steps   observe batches per monitoring interval (Layer B decode
                     steps; Layer A closes every chunk, i.e. 1)
    top_n            stage-2 monitor rows (hot superpages / superblocks)
    max_promotions   per-interval migration-plan size K (fixed shapes)
    hot_slots        performance-tier capacity in pages/blocks (DRAM slots /
                     HBM hot-pool blocks)
    write_weight     stage-1 weighting of NVM writes vs reads (§III-B)
    threshold_init   initial adaptive admission threshold (§III-C)
    counter_decay    stage-1 retention across interval rotation in [0, 1);
                     0.0 reproduces the paper's per-interval counter reset
    counter_backend  "jax" scatter-adds or the fused page_counter kernel
                     ("ref" | "pallas" | "interpret")
    async_window     intervals each migration generation's traffic is spread
                     over (Nomad-style transactional migration, docs/policy.md);
                     1 = the synchronous programs, charged in full at interval
                     end — BITWISE identical to the pre-async step programs
    abort_on_write   abort the in-flight copy of a page written mid-migration
                     (Nomad's transactional abort); requires async_window > 1
                     to have any effect — a window-1 copy completes before the
                     next interval can write to it
    shadow_residency during the copy window reads hit whichever tier is
                     cheaper (the page is temporarily resident in both);
                     False = exclusive residency (the remap flips at plan time)
    """

    interval_steps: int = static_field(default=8)
    top_n: int = static_field(default=16)
    max_promotions: int = static_field(default=64)
    hot_slots: int = static_field(default=256)
    write_weight: int = static_field(default=2)
    threshold_init: float = static_field(default=0.0)
    counter_decay: float = static_field(default=0.0)
    counter_backend: str = static_field(default="jax")
    async_window: int = static_field(default=1)
    abort_on_write: bool = static_field(default=False)
    shadow_residency: bool = static_field(default=False)

    # -- validation (satellite: impossible geometries fail loudly) ----------

    def validate(self, context: str = "ControlPolicy") -> "ControlPolicy":
        """Reject impossible knob settings with a clear error, returning self.

        Geometry-dependent checks (e.g. top_n vs blocks_per_seq) live on the
        composing config's validate; everything knowable here is checked here.
        """
        if self.interval_steps < 1:
            raise ValueError(
                f"{context}: interval_steps must be >= 1 (got "
                f"{self.interval_steps}); the controller closes an interval "
                "after that many observe batches"
            )
        if self.top_n < 1:
            raise ValueError(f"{context}: top_n must be >= 1 (got {self.top_n})")
        if self.max_promotions < 1:
            raise ValueError(
                f"{context}: max_promotions must be >= 1 (got "
                f"{self.max_promotions})"
            )
        if self.hot_slots < 1:
            raise ValueError(
                f"{context}: hot_slots must be >= 1 (got {self.hot_slots})"
            )
        if self.write_weight < 1:
            raise ValueError(
                f"{context}: write_weight must be >= 1 (got {self.write_weight})"
            )
        if not 0.0 <= self.counter_decay < 1.0:
            raise ValueError(
                f"{context}: counter_decay must be in [0, 1) (got "
                f"{self.counter_decay}); 1.0 would never forget stage-1 heat"
            )
        if self.counter_backend not in COUNTER_BACKENDS:
            raise ValueError(
                f"{context}: unknown counter_backend "
                f"{self.counter_backend!r}; expected one of {COUNTER_BACKENDS}"
            )
        if not 1 <= self.async_window <= 64:
            raise ValueError(
                f"{context}: async_window must be in [1, 64] (got "
                f"{self.async_window}); the in-flight ring is carried in the "
                "scan state, so the window is part of the compile signature"
            )
        if not isinstance(self.abort_on_write, bool):
            raise ValueError(
                f"{context}: abort_on_write must be a bool (got "
                f"{self.abort_on_write!r})"
            )
        if not isinstance(self.shadow_residency, bool):
            raise ValueError(
                f"{context}: shadow_residency must be a bool (got "
                f"{self.shadow_residency!r})"
            )
        return self

    # -- composition --------------------------------------------------------

    def replace(self, **overrides: Any) -> "ControlPolicy":
        """dataclasses.replace + validate (the idiom TunePlan candidates use)."""
        return dataclasses.replace(self, **overrides).validate()

    def control_config(self, num_units: int, pages_per_unit: int):
        """The engine-internal ControlConfig for one controller instance.

        This is THE construction both layers go through: Layer A passes
        (num_superpages, pages_per_sp), Layer B (batch, blocks_per_seq).
        """
        from repro.engine.control import ControlConfig

        return ControlConfig(
            num_units=num_units,
            pages_per_unit=pages_per_unit,
            top_n=self.top_n,
            max_moves=self.max_promotions,
            write_weight=self.write_weight,
            counter_backend=self.counter_backend,
            counter_decay=self.counter_decay,
        )


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

PolicyFactory = Callable[..., ControlPolicy]
_REGISTRY: dict[str, PolicyFactory] = {}


def register_policy(name: str) -> Callable[[PolicyFactory], PolicyFactory]:
    """Register a named ControlPolicy factory (decorator).

    Factories take keyword arguments only (commonly `mc=` for Layer A presets
    whose knobs are MachineConfig properties) and return a ControlPolicy.
    """

    def deco(fn: PolicyFactory) -> PolicyFactory:
        if name in _REGISTRY:
            raise ValueError(f"policy {name!r} is already registered")
        _REGISTRY[name] = fn
        return fn

    return deco


def get_policy(name: str, **kwargs: Any) -> ControlPolicy:
    """Resolve a registered preset to a validated ControlPolicy."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown policy preset {name!r}; registered: {available_policies()}"
        ) from None
    return factory(**kwargs).validate()


def resolve_policy(policy: "ControlPolicy | str | None", default: str,
                   **kwargs: Any) -> ControlPolicy:
    """Accept a ControlPolicy, a preset name, or None (-> `default` preset)."""
    if policy is None:
        return get_policy(default, **kwargs)
    if isinstance(policy, str):
        return get_policy(policy, **kwargs)
    return policy.validate()


def available_policies() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# Presets: every entry point's controller comes from one of these
# ---------------------------------------------------------------------------


@register_policy("serving-default")
def _serving_default(**_: Any) -> ControlPolicy:
    """Layer B defaults (the former PagedConfig field defaults)."""
    return ControlPolicy()


@register_policy("sim-rainbow")
def _sim_rainbow(mc=None) -> ControlPolicy:
    """Paper §IV-F simulator parameters, read off a MachineConfig.

    Layer A closes the controller once per trace chunk -> interval_steps = 1.
    """
    mc = mc or _machine_config()
    return ControlPolicy(
        interval_steps=1,
        top_n=mc.top_n,
        max_promotions=512,
        hot_slots=mc.dram_pages,
        write_weight=mc.write_weight,
        threshold_init=mc.mig_threshold,
    )


@register_policy("hscc-4kb")
def _hscc_4kb(mc=None) -> ControlPolicy:
    """HSCC 4KB-migration baseline: per-page admission, cand_k = 512."""
    mc = mc or _machine_config()
    return ControlPolicy(
        interval_steps=1,
        top_n=mc.top_n,
        max_promotions=512,
        hot_slots=mc.dram_pages,
        write_weight=1,
        threshold_init=mc.mig_threshold,
    )


@register_policy("hscc-2mb")
def _hscc_2mb(mc=None) -> ControlPolicy:
    """HSCC 2MB-migration baseline: per-superpage admission, cand_k = 64."""
    mc = mc or _machine_config()
    return ControlPolicy(
        interval_steps=1,
        top_n=mc.top_n,
        max_promotions=64,
        hot_slots=mc.dram_superpages,
        write_weight=1,
        threshold_init=mc.mig_threshold,
    )


@register_policy("nomad-sim")
def _nomad_sim(mc=None) -> ControlPolicy:
    """Nomad-style transactional async migration on the sim-rainbow knobs.

    Same admission/selection as sim-rainbow; migration traffic is spread over
    async_window intervals, writes to in-flight pages abort the transaction,
    and reads during the copy hit whichever tier is cheaper (shadow residency).
    """
    mc = mc or _machine_config()
    return dataclasses.replace(
        _sim_rainbow(mc=mc),
        async_window=4, abort_on_write=True, shadow_residency=True,
    )


@register_policy("nomad-sync")
def _nomad_sync(mc=None) -> ControlPolicy:
    """The degenerate window-1 Nomad: BITWISE identical to sim-rainbow.

    Kept registered as the live anchor of the sync-degenerate invariant
    (docs/policy.md): async_window=1 completes each copy inside its own
    interval, so no aborts, no shadow window, no installments.
    """
    mc = mc or _machine_config()
    return dataclasses.replace(_sim_rainbow(mc=mc), async_window=1)


@register_policy("nomad-exclusive")
def _nomad_exclusive(mc=None) -> ControlPolicy:
    """Async installment charging only: exclusive residency, no aborts.

    Isolates the traffic-spreading axis from the transactional axis — the
    controller decisions stay bitwise equal to sim-rainbow; only the queue
    charging schedule differs.
    """
    mc = mc or _machine_config()
    return dataclasses.replace(_sim_rainbow(mc=mc), async_window=4)


def _machine_config():
    # Lazy: repro.sim imports sim.runner -> sim.policies -> repro.engine, so a
    # module-level sim.config import here would cycle on `import repro.engine`.
    from repro.sim.config import MachineConfig

    return MachineConfig()


#: EngineSpec.policy -> registry preset for the simulator's stateful policies.
SIM_POLICY_PRESETS = {
    "rainbow": "sim-rainbow",
    "hscc-4kb-mig": "hscc-4kb",
    "hscc-2mb-mig": "hscc-2mb",
    "nomad": "nomad-sim",
}


def sim_policy_for(policy: str, mc, control: ControlPolicy | None = None,
                   counter_backend: str | None = None) -> ControlPolicy:
    """The effective ControlPolicy of one simulator cell.

    An explicit `control` override (SweepCell / autotune) is authoritative,
    INCLUDING its counter_backend — the cell-level `counter_backend` axis only
    applies to machine-preset policies (otherwise a cell's default "jax" would
    silently clobber a backend the caller set on the override).
    """
    if control is not None:
        return control.validate()
    pol = get_policy(SIM_POLICY_PRESETS[policy], mc=mc)
    if counter_backend is not None and counter_backend != pol.counter_backend:
        pol = dataclasses.replace(pol, counter_backend=counter_backend)
    return pol.validate()
