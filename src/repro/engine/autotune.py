"""Engine-in-the-loop serving autotuner over the ControlPolicy surface.

The ROADMAP's open item: Layer B's interval controller is the same jitted
`engine.control` path as Layer A, so its knobs (interval_steps, top_n,
threshold, ...) can be searched *against live decode traffic* instead of being
hand-set. This module closes that loop:

  MassTrace      a recorded decode attention-mass stream — one [B, nblk] row
                 per decode step, captured from a real model run by
                 `serving.rainbow_decode.record_mass_trace` (the exact array
                 observe_block_mass saw);
  TunePlan       a declarative search space over ControlPolicy fields with
                 successive-halving refinement (short trace prefixes eliminate
                 weak candidates before anyone pays for the full trace);
  evaluate       engine-in-the-loop replay: for each candidate policy the
                 controller itself (observe_block_mass -> end_interval_promote,
                 i.e. the SAME engine.control path serving runs) is replayed
                 over the trace on zero-payload KV state, and the serving cost
                 model (migration.TimingParams, "v5e-serving" preset) scores
                 the access stream it produces — mass-weighted reads at t_dr
                 (hot pool) vs t_nr (capacity pool) plus t_mig per promotion;
  autotune       the search driver; its TuneResult.tuned_policy() plugs
                 straight back into PagedConfig / launch.serve --autotune.

Candidates that share static shapes (top_n, max_promotions, hot_slots, ...)
fuse into one compiled group; interval_steps and threshold_init are *traced*
inside the replay, so a whole group evaluates as one vmap. Like engine.fleet,
the same vmapped body can instead be shard_mapped over the 1-D "fleet" device
mesh (`runner="sharded"`) — per shard it is the identical program, so the two
paths are bit-identical, padding included.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import counting, migration
from repro.core.migration import TimingParams, preset_timing
from repro.core.remap import remap_init, translate
from repro.engine.policy import ControlPolicy
from repro.memory.kvcache import (
    PagedConfig,
    RainbowKV,
    end_interval_promote,
    observe_block_mass,
    quantize_mass,
)

# ---------------------------------------------------------------------------
# Recorded decode traffic
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MassTrace:
    """A recorded per-block attention-mass stream (host-side numpy).

    mass[t, b, j] is the softmax mass KV block j of sequence b received at
    decode step t, summed over layers and heads — the access stream of the
    paper's memory controller in Layer B units. `start_length` is the sequence
    length before step 0 (0 when recording covers the prompt).
    """

    mass: np.ndarray  # float32[T, B, nblk]
    block_size: int
    start_length: int = 0

    @property
    def steps(self) -> int:
        return self.mass.shape[0]

    @property
    def batch(self) -> int:
        return self.mass.shape[1]

    @property
    def blocks_per_seq(self) -> int:
        return self.mass.shape[2]

    def prefix(self, steps: int) -> "MassTrace":
        """The first `steps` decode steps (successive-halving rungs)."""
        return MassTrace(
            mass=self.mass[:steps],
            block_size=self.block_size,
            start_length=self.start_length,
        )


# ---------------------------------------------------------------------------
# Search space
# ---------------------------------------------------------------------------

_POLICY_FIELDS = {f.name for f in dataclasses.fields(ControlPolicy)}


@dataclasses.dataclass(frozen=True)
class TunePlan:
    """A declarative search space over ControlPolicy fields.

    space  ((field, (value, ...)), ...) — the cartesian grid, applied over
           `base` with ControlPolicy.replace (so every candidate re-validates)
    rungs  successive-halving rounds; rung r evaluates survivors on the first
           T // eta**(rungs-1-r) trace steps and keeps the best 1/eta
    eta    halving factor
    """

    space: tuple[tuple[str, tuple[Any, ...]], ...]
    base: ControlPolicy = dataclasses.field(default_factory=ControlPolicy)
    rungs: int = 2
    eta: int = 2

    def __post_init__(self):
        bad = [k for k, _ in self.space if k not in _POLICY_FIELDS]
        if bad:
            raise ValueError(
                f"TunePlan: unknown ControlPolicy fields {bad}; "
                f"searchable: {sorted(_POLICY_FIELDS)}"
            )
        if self.rungs < 1 or self.eta < 2:
            raise ValueError(
                f"TunePlan: need rungs >= 1 and eta >= 2 "
                f"(got rungs={self.rungs}, eta={self.eta})"
            )

    @staticmethod
    def grid(base: ControlPolicy | None = None, *, rungs: int = 2,
             eta: int = 2, **space: Sequence[Any]) -> "TunePlan":
        """`TunePlan.grid(interval_steps=(2, 8), threshold_init=(0.0, 64.0))`."""
        return TunePlan(
            space=tuple(sorted((k, tuple(v)) for k, v in space.items())),
            base=base if base is not None else ControlPolicy(),
            rungs=rungs,
            eta=eta,
        )

    def candidates(self) -> tuple[ControlPolicy, ...]:
        """The full candidate grid, base-first ordering within each field."""
        if not self.space:
            return (self.base.validate(),)
        names = [k for k, _ in self.space]
        grids = [v for _, v in self.space]
        return tuple(
            self.base.replace(**dict(zip(names, combo)))
            for combo in itertools.product(*grids)
        )


# ---------------------------------------------------------------------------
# Engine-in-the-loop replay
# ---------------------------------------------------------------------------


def _group_signature(pol: ControlPolicy) -> ControlPolicy:
    """Candidates equal under this signature share one compiled replay group
    (interval_steps and threshold_init are traced inside the replay)."""
    return dataclasses.replace(pol, interval_steps=1, threshold_init=0.0)


def _replay_pcfg(trace: MassTrace, signature: ControlPolicy) -> PagedConfig:
    return PagedConfig(
        block_size=trace.block_size,
        blocks_per_seq=trace.blocks_per_seq,
        policy=signature,
    )


def _controller_kv(pcfg: PagedConfig, batch: int, start_length: int) -> RainbowKV:
    """Controller-only KV state: the full RainbowKV pytree with ZERO-layer
    pools, so end_interval_promote runs the exact serving control path
    (plan_and_apply, remap install/evict, monitor rotation) with free payload
    copies — the replay is the controller, not a model of it."""
    nblk = pcfg.blocks_per_seq
    cap = jnp.zeros((0, batch * nblk, pcfg.block_size, 1, 1), jnp.float32)
    hot = jnp.zeros((0, pcfg.hot_slots, pcfg.block_size, 1, 1), jnp.float32)
    return RainbowKV(
        cap_k=cap, cap_v=cap, hot_k=hot, hot_v=hot,
        remap=remap_init(batch, nblk),
        s1=counting.stage1_init(batch),
        s2=counting.stage2_init(pcfg.top_n, nblk),
        dram=migration.dram_init(pcfg.hot_slots),
        threshold=jnp.zeros((), jnp.float32),
        length=jnp.asarray(start_length, jnp.int32),
        step_in_interval=jnp.zeros((), jnp.int32),
    )


def _replay_one(pcfg: PagedConfig, kv: RainbowKV, interval_steps: jax.Array,
                mass: jax.Array, timing: TimingParams):
    """Replay the interval controller over one trace; return modeled cost.

    Per step: every valid block's quantized mass (the same 64x quantization
    observe_block_mass applies) is served from the tier the remap table says
    it lives in (t_dr hot pool vs t_nr capacity pool); each admitted promotion
    pays t_mig. Evicted KV blocks are clean (writes mirror into the capacity
    copy), so eviction costs only the remap-pointer write — §III-E's fast
    path — and is not charged.
    """
    nblk = pcfg.blocks_per_seq
    batch = kv.s1.counts.shape[0]
    sp_grid = jnp.arange(batch, dtype=jnp.int32)[:, None].repeat(nblk, 1)
    pg_grid = jnp.arange(nblk, dtype=jnp.int32)[None, :].repeat(batch, 0)

    def step(carry, mass_t):
        kv, cost = carry
        q = quantize_mass(mass_t).astype(jnp.float32)  # the counters' stream
        valid = pg_grid <= (kv.length // pcfg.block_size)
        resident, _ = translate(kv.remap, sp_grid, pg_grid)
        lat = jnp.where(resident, timing.t_dr, timing.t_nr)
        cost = cost + jnp.sum(jnp.where(valid, q * lat, 0.0))

        kv = observe_block_mass(kv, pcfg, mass_t)
        kv = dataclasses.replace(kv, length=kv.length + 1)

        def do_promote(kv_):
            new, rep = end_interval_promote(kv_, pcfg, timing)
            return new, rep["promoted"], rep["evicted"]

        def skip(kv_):
            return kv_, jnp.int32(0), jnp.int32(0)

        kv, n_prom, n_ev = jax.lax.cond(
            kv.step_in_interval >= interval_steps, do_promote, skip, kv
        )
        cost = cost + n_prom.astype(jnp.float32) * timing.t_mig
        return (kv, cost), (n_prom, n_ev)

    (kv, cost), (proms, evs) = jax.lax.scan(step, (kv, jnp.float32(0.0)), mass)
    return cost, proms.sum(), evs.sum()


def _vmapped_replay(pcfg: PagedConfig):
    return jax.vmap(
        lambda kv, iv, mass, timing: _replay_one(pcfg, kv, iv, mass, timing),
        in_axes=(0, 0, None, None),
    )


@functools.partial(jax.jit, static_argnames=("pcfg",))
def _eval_group_vmap(pcfg: PagedConfig, states: RainbowKV, ivals: jax.Array,
                     mass: jax.Array, timing: TimingParams):
    return _vmapped_replay(pcfg)(states, ivals, mass, timing)


@functools.lru_cache(maxsize=None)
def _sharded_replay_fn(pcfg: PagedConfig, mesh):
    """shard_map of the SAME vmapped replay body over the fleet mesh — per
    shard it is exactly _eval_group_vmap's program, so sharded evaluation is
    bit-identical to the one-device vmap path (cf. engine.fleet)."""
    fn = shard_map(
        _vmapped_replay(pcfg),
        mesh=mesh,
        in_specs=(P("fleet"), P("fleet"), P(), P()),
        out_specs=(P("fleet"), P("fleet"), P("fleet")),
    )
    return jax.jit(fn)


def _group_states(pcfg: PagedConfig, batch: int, start_length: int,
                  thresholds: np.ndarray) -> RainbowKV:
    kv0 = _controller_kv(pcfg, batch, start_length)
    states = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (len(thresholds),) + x.shape), kv0
    )
    return dataclasses.replace(
        states, threshold=jnp.asarray(thresholds, jnp.float32)
    )


def evaluate(
    trace: MassTrace,
    policies: Sequence[ControlPolicy],
    *,
    timing: TimingParams | None = None,
    runner: str = "vmap",
    mesh=None,
) -> list[dict[str, float]]:
    """Replay every candidate policy against the trace; one row per policy
    (plan order): modeled cost per decode step, promotions, evictions.

    runner="vmap" evaluates each static-shape group as one vmap on the default
    device; runner="sharded" shard_maps the same body over the fleet mesh.
    """
    if runner not in ("vmap", "sharded"):
        raise ValueError(f"unknown runner {runner!r}; use 'vmap' or 'sharded'")
    timing = timing if timing is not None else preset_timing("v5e-serving")
    mass = jnp.asarray(trace.mass, jnp.float32)

    # group candidates by static replay signature (first-seen order)
    groups: dict[ControlPolicy, list[int]] = {}
    for i, pol in enumerate(policies):
        # per-candidate validation against the trace geometry, loudly
        _replay_pcfg(trace, pol.validate())
        groups.setdefault(_group_signature(pol), []).append(i)

    if runner == "sharded" and mesh is None:
        from repro.launch.mesh import make_fleet_mesh

        mesh = make_fleet_mesh()

    rows: list[dict[str, float] | None] = [None] * len(policies)
    for sig, idxs in groups.items():
        pcfg = _replay_pcfg(trace, sig)
        ivals = np.asarray([policies[i].interval_steps for i in idxs], np.int32)
        thrs = np.asarray([policies[i].threshold_init for i in idxs], np.float32)
        if runner == "vmap":
            states = _group_states(pcfg, trace.batch, trace.start_length, thrs)
            cost, prom, ev = _eval_group_vmap(
                pcfg, states, jnp.asarray(ivals), mass, timing
            )
        else:
            pad = -len(idxs) % mesh.devices.size
            if pad:
                ivals = np.concatenate([ivals, np.repeat(ivals[-1:], pad)])
                thrs = np.concatenate([thrs, np.repeat(thrs[-1:], pad)])
            states = _group_states(pcfg, trace.batch, trace.start_length, thrs)
            cost, prom, ev = _sharded_replay_fn(pcfg, mesh)(
                states, jnp.asarray(ivals), mass, timing
            )
        cost, prom, ev = map(np.asarray, (cost, prom, ev))
        for j, i in enumerate(idxs):  # padding lanes are dropped
            rows[i] = {
                "cost_per_step": float(cost[j]) / max(trace.steps, 1),
                "total_cost": float(cost[j]),
                "promotions": int(prom[j]),
                "evictions": int(ev[j]),
            }
    return rows  # type: ignore[return-value]


# ---------------------------------------------------------------------------
# Search driver
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Outcome of one autotune run; `tuned_policy()` is the serving plug-in."""

    plan: TunePlan
    best: ControlPolicy
    best_cost: float  # modeled cost per decode step on the full trace
    baseline: ControlPolicy
    baseline_cost: float
    table: tuple[dict[str, Any], ...]  # per (rung, candidate) evaluation rows

    def tuned_policy(self) -> ControlPolicy:
        return self.best

    @property
    def improved(self) -> bool:
        return self.best_cost < self.baseline_cost

    def summary(self) -> str:
        gain = 100.0 * (1.0 - self.best_cost / max(self.baseline_cost, 1e-12))
        return (
            f"tuned {self.best_cost:.1f} vs baseline {self.baseline_cost:.1f} "
            f"ns/step ({gain:+.1f}%) with interval_steps="
            f"{self.best.interval_steps}, top_n={self.best.top_n}, "
            f"threshold_init={self.best.threshold_init}"
        )


def autotune(
    plan: TunePlan,
    trace: MassTrace,
    *,
    timing: TimingParams | None = None,
    runner: str = "vmap",
    mesh=None,
    baseline: ControlPolicy | None = None,
) -> TuneResult:
    """Successive-halving search of `plan` against a recorded mass trace.

    Rung r evaluates the surviving candidates on the first
    T // eta**(rungs-1-r) steps and keeps the best ceil(n/eta); the final rung
    runs the full trace and the argmin (ties broken by candidate index, so
    vmap and sharded runs pick the identical winner) becomes the result.
    """
    timing = timing if timing is not None else preset_timing("v5e-serving")
    cands = list(plan.candidates())
    baseline = (baseline or plan.base).validate()
    survivors = list(range(len(cands)))
    table: list[dict[str, Any]] = []

    for r in range(plan.rungs):
        steps = max(1, trace.steps // (plan.eta ** (plan.rungs - 1 - r)))
        sub = trace.prefix(steps)
        rows = evaluate(sub, [cands[i] for i in survivors],
                        timing=timing, runner=runner, mesh=mesh)
        ranked = sorted(
            zip((row["total_cost"] for row in rows), survivors, rows),
            key=lambda t: (t[0], t[1]),
        )
        for c, i, row in ranked:
            table.append({
                "rung": r, "steps": steps, "candidate": i,
                "policy": cands[i], **row,
            })
        keep = 1 if r == plan.rungs - 1 else max(
            1, math.ceil(len(survivors) / plan.eta)
        )
        survivors = [i for _, i, _ in ranked[:keep]]
        final_rows = {i: row for _, i, row in ranked}

    best_idx = survivors[0]
    best_cost = final_rows[best_idx]["cost_per_step"]
    # reuse the final (full-trace) rung when the baseline was a candidate there
    base_row = next(
        (final_rows[i] for i in final_rows if cands[i] == baseline), None
    )
    if base_row is None:
        [base_row] = evaluate(trace, [baseline],
                              timing=timing, runner=runner, mesh=mesh)
    return TuneResult(
        plan=plan,
        best=cands[best_idx],
        best_cost=best_cost,
        baseline=baseline,
        baseline_cost=base_row["cost_per_step"],
        table=tuple(table),
    )
