"""Unified device-resident MemoryEngine.

One interval controller (`engine.control`) and one scanned interval loop
(`engine.simloop`) drive both layers of the reproduction:

  * Layer A — the memory-system simulator (`sim.runner` is a thin host shell
    over `simloop.MemoryEngine`; `core.rainbow` delegates its observe /
    end_interval bodies to `control`).
  * Layer B — the serving runtime (`memory.kvcache.end_interval_promote` plans
    promotions through the same `control.plan_and_apply`).

Import discipline: `control` only depends on `repro.core` leaf modules and is
imported eagerly; `simloop` depends on `repro.sim` and is loaded lazily (PEP
562) so that `repro.sim.__init__` -> `sim.runner` -> engine does not cycle.
"""
from __future__ import annotations

from repro.engine.control import (
    ControlConfig,
    PlanOutcome,
    observe_tiers,
    plan_and_apply,
    rotate_monitors,
)

__all__ = [
    "ControlConfig",
    "PlanOutcome",
    "observe_tiers",
    "plan_and_apply",
    "rotate_monitors",
    "simloop",
    "fleet",
]


def __getattr__(name):  # lazy: these pull in repro.sim (see module docstring)
    if name in ("simloop", "fleet"):
        import importlib

        return importlib.import_module(f"repro.engine.{name}")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
