"""Unified device-resident MemoryEngine.

One interval controller (`engine.control`) and one scanned interval loop
(`engine.simloop`) drive both layers of the reproduction:

  * Layer A — the memory-system simulator (`sim.runner` is a thin host shell
    over `simloop.MemoryEngine`; `core.rainbow` delegates its observe /
    end_interval bodies to `control`).
  * Layer B — the serving runtime (`memory.kvcache.end_interval_promote` plans
    promotions through the same `control.plan_and_apply`).

The knobs of that controller live on ONE declarative surface —
`engine.policy.ControlPolicy` plus its `@register_policy` preset registry —
which `RainbowConfig` (Layer A) and `PagedConfig` (Layer B) compose with their
layer-specific geometry, and which `engine.autotune` searches over with
engine-in-the-loop evaluation against recorded decode attention-mass traces.

Import discipline: `control` and `policy` only depend on `repro.core` leaf
modules / `repro.utils` and are imported eagerly; `simloop`, `fleet`, and
`autotune` depend on `repro.sim` / `repro.memory` and are loaded lazily (PEP
562) so that `repro.sim.__init__` -> `sim.runner` -> engine does not cycle.
"""
from __future__ import annotations

from repro.engine.control import (
    ControlConfig,
    PlanOutcome,
    observe_tiers,
    plan_and_apply,
    rotate_monitors,
)
from repro.engine.policy import (
    ControlPolicy,
    available_policies,
    get_policy,
    register_policy,
    resolve_policy,
)

__all__ = [
    "ControlConfig",
    "ControlPolicy",
    "PlanOutcome",
    "available_policies",
    "get_policy",
    "observe_tiers",
    "plan_and_apply",
    "register_policy",
    "resolve_policy",
    "rotate_monitors",
    "simloop",
    "fleet",
    "autotune",
]


def __getattr__(name):  # lazy: these pull in repro.sim (see module docstring)
    if name in ("simloop", "fleet", "autotune"):
        import importlib

        return importlib.import_module(f"repro.engine.{name}")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
