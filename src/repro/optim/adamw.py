"""AdamW from scratch (no optax): fp32 master weights + moments, bf16 params.

Moments and master copies are additionally sharded over the data axes (ZeRO-style)
via `adamw_specs`; gradients arrive from the backward pass sharded like the params
and the update runs on the ZeRO shards (GSPMD inserts the reduce-scatter/all-gather
pair around the elementwise update).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch.axes import BATCH_AXES


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    zero_sharding: bool = True  # shard moments/master over data axes


def adamw_init(params: Any) -> dict[str, Any]:
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def _zero_spec(spec: P, shape: tuple[int, ...] | None, dp_size: int) -> P:
    """Shard the first unsharded, dp-divisible dim over the data axes (ZeRO)."""
    entries = list(spec)
    for i, e in enumerate(entries):
        if e is None and (shape is None or shape[i] % max(dp_size, 1) == 0):
            entries[i] = BATCH_AXES
            return P(*entries)
    return spec


def adamw_specs(
    param_specs: Any, cfg: AdamWConfig, param_shapes: Any = None, dp_size: int = 1
) -> dict[str, Any]:
    """param_shapes: matching tree of array/SDS leaves (for divisibility checks).
    Without shapes, ZeRO sharding is skipped (small/test meshes)."""
    is_spec = lambda x: isinstance(x, P)
    if cfg.zero_sharding and param_shapes is not None:
        opt_spec = jax.tree.map(
            lambda s, p: _zero_spec(s, tuple(p.shape), dp_size),
            param_specs,
            param_shapes,
            is_leaf=lambda x: isinstance(x, P),
        )
    else:
        opt_spec = param_specs
    return {"step": P(), "master": opt_spec, "m": opt_spec, "v": opt_spec}


def global_norm(grads: Any) -> jax.Array:
    leaves = jax.tree.leaves(grads)
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))


def adamw_update(
    cfg: AdamWConfig,
    grads: Any,
    opt_state: dict[str, Any],
    params: Any,
    lr: jax.Array | float | None = None,
) -> tuple[Any, dict[str, Any], dict[str, jax.Array]]:
    """Returns (new params in original dtype, new opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = cfg.lr if lr is None else lr
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd_m(g, m):
        return b1 * m + (1 - b1) * g.astype(jnp.float32) * scale

    def upd_v(g, v):
        gs = g.astype(jnp.float32) * scale
        return b2 * v + (1 - b2) * gs * gs

    ms = jax.tree.map(upd_m, grads, opt_state["m"])
    vs = jax.tree.map(upd_v, grads, opt_state["v"])

    def upd_p(m, v, p):
        return p - lr * ((m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps) + cfg.weight_decay * p)

    masters = jax.tree.map(upd_p, ms, vs, opt_state["master"])
    new_params = jax.tree.map(lambda mp, p: mp.astype(p.dtype), masters, params)
    new_state = {"step": step, "master": masters, "m": ms, "v": vs}
    return new_params, new_state, {"grad_norm": gnorm, "clip_scale": scale}
