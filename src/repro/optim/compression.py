"""Gradient compression for cross-pod all-reduce (distributed-optimization trick).

Two schemes, both with error feedback so compression error accumulates locally
instead of biasing the trajectory:

  * int8 stochastic-rounding quantization (8x traffic reduction)
  * top-k magnitude sparsification (k as a fraction; indices+values traffic)

Applied inside the train step *before* the gradient mean over the "pod" axis when
enabled — inside shard_map the all-reduce then moves int8/sparse payloads. On the
CPU dry-run the effect is visible as reduced all-reduce operand bytes in the HLO.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array, key: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor scale + int8 payload with stochastic rounding."""
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    scaled = g.astype(jnp.float32) / scale
    noise = jax.random.uniform(key, g.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads_int8(
    grads: Any, error: Any, key: jax.Array
) -> tuple[Any, Any, Any]:
    """Error-feedback int8: returns (quantized tree, scales tree, new error tree)."""
    leaves, treedef = jax.tree.flatten(grads)
    err_leaves = jax.tree.leaves(error)
    keys = jax.random.split(key, len(leaves))
    qs, scales, errs = [], [], []
    for g, e, k in zip(leaves, err_leaves, keys):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected, k)
        deq = dequantize_int8(q, s)
        qs.append(q)
        scales.append(s)
        errs.append(corrected - deq)
    return (
        treedef.unflatten(qs),
        treedef.unflatten(scales),
        treedef.unflatten(errs),
    )


def decompress_grads_int8(qs: Any, scales: Any) -> Any:
    return jax.tree.map(dequantize_int8, qs, scales)


def error_init(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def topk_sparsify(g: jax.Array, frac: float, error: jax.Array) -> tuple:
    """Error-feedback top-|g| sparsification. Returns (values, idx, new_error)."""
    flat = g.astype(jnp.float32).reshape(-1) + error.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    chosen = flat[idx]
    dense = jnp.zeros_like(flat).at[idx].set(chosen)
    return chosen, idx, (flat - dense).reshape(g.shape)


def topk_densify(vals: jax.Array, idx: jax.Array, shape) -> jax.Array:
    size = 1
    for s in shape:
        size *= s
    return jnp.zeros((size,), jnp.float32).at[idx].set(vals).reshape(shape)
