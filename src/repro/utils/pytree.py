"""Tiny pytree-dataclass helper (no flax available)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax


def static_field(**kwargs: Any) -> Any:
    """Mark a dataclass field as static (part of the pytree treedef, not a leaf)."""
    metadata = dict(kwargs.pop("metadata", {}) or {})
    metadata["static"] = True
    return dataclasses.field(metadata=metadata, **kwargs)


def pytree_dataclass(cls=None, /, **kwargs):
    """Decorator: make a (frozen) dataclass registered as a JAX pytree.

    Fields declared with ``static_field()`` become aux data; everything else is a
    child. Works with jit/scan/vmap and keeps attribute access.
    """

    def wrap(c):
        c = dataclasses.dataclass(frozen=True, **kwargs)(c)
        data_fields = []
        meta_fields = []
        for f in dataclasses.fields(c):
            if f.metadata.get("static", False):
                meta_fields.append(f.name)
            else:
                data_fields.append(f.name)
        jax.tree_util.register_dataclass(
            c, data_fields=data_fields, meta_fields=meta_fields
        )
        return c

    if cls is None:
        return wrap
    return wrap(cls)
