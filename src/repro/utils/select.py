"""Shared fixed-shape selection primitives (engine + eager oracle).

One implementation, imported by both the scanned engine (engine.simloop) and
the eager reference policies (sim.policies), so the differential suites
exercise a single selection code path instead of two copies that can drift
(PR 7 satellite). The pre-overhaul argsort form is kept as
`first_k_valid_ref` — the fastpath=False engine compiles against it and
tests/test_hotpath.py pins both bit-identical across masks and edge floors.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import Array


def first_k_valid_ref(values: Array, valid: Array, k: int) -> Array:
    """Reference: stable full argsort (the pre-overhaul hot path)."""
    order = jnp.argsort(~valid, stable=True)
    vals = jnp.where(valid[order], values[order], -1).astype(jnp.int32)
    if vals.shape[0] >= k:
        return vals[:k]
    return jnp.concatenate([vals, jnp.full((k - vals.shape[0],), -1, jnp.int32)])


def first_k_valid(values: Array, valid: Array, k: int) -> Array:
    """First k `values` whose lane is valid, in lane order; -1 padding.

    A masked cumsum ranks the valid lanes (each rank is unique, so the
    scatter is conflict-free) and the first k scatter into place — no sort.
    Bit-identical to `first_k_valid_ref` for every mask and floor
    (all-valid, all-invalid, k > n-valid, duplicate values).
    """
    rank = jnp.cumsum(valid.astype(jnp.int32)) - 1
    dst = jnp.where(valid & (rank < k), rank, k)
    return (
        jnp.full((k,), -1, jnp.int32)
        .at[dst]
        .set(values.astype(jnp.int32), mode="drop")
    )
