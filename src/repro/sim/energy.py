"""Energy model (paper Table IV): DRAM current-based dynamic + capacity-scaled
static (standby + refresh), PCM pJ/bit dynamic.

Scaling: the simulator runs 1/SCALE_DOWN of the real per-interval work, so
dynamic/migration energies are multiplied back by SCALE_DOWN, and static power
uses the *unscaled* DRAM capacity over the scaled-up wall time. This keeps the
static-vs-dynamic balance of the paper's full-size system (Fig. 12: DRAM-only
pays 8x standby+refresh; misused hybrids pay PCM write energy)."""
from __future__ import annotations

from repro.sim.config import CPU_GHZ, SCALE_DOWN, MachineConfig


def energy_joules(
    mc: MachineConfig,
    dram_reads: float,
    dram_writes: float,
    nvm_reads: float,
    nvm_writes: float,
    mig_bytes: float,
    total_cycles: float,
    dram_capacity_factor: float = 1.0,
) -> dict[str, float]:
    """dram_capacity_factor: 1 for the 4GB hybrid tiers, 8 for DRAM-only (32GB)."""
    t_dr_s = mc.t_dr / (CPU_GHZ * 1e9)
    t_dw_s = mc.t_dw / (CPU_GHZ * 1e9)
    e_dr = mc.dram_volt * (mc.dram_read_ma * 1e-3) * t_dr_s
    e_dw = mc.dram_volt * (mc.dram_write_ma * 1e-3) * t_dw_s
    line_bits = mc.line_bytes * 8
    e_nr = mc.pcm_read_pj_bit * line_bits * 1e-12
    e_nw = mc.pcm_write_pj_bit * line_bits * 1e-12

    dyn = (
        dram_reads * e_dr
        + dram_writes * e_dw
        + nvm_reads * e_nr
        + nvm_writes * e_nw
    ) * SCALE_DOWN
    # migration traffic: PCM read + DRAM write per line moved
    lines_moved = mig_bytes / mc.line_bytes
    mig = lines_moved * (e_nr + e_dw) * SCALE_DOWN

    # static: Table IV currents are per 4GB module; wall time scaled back up
    wall_s = total_cycles * SCALE_DOWN / (CPU_GHZ * 1e9)
    static_ma = (mc.dram_standby_ma + mc.dram_refresh_ma) * dram_capacity_factor
    static = mc.dram_volt * static_ma * 1e-3 * wall_s

    return {
        "dynamic_j": dyn,
        "migration_j": mig,
        "static_j": static,
        "total_j": dyn + mig + static,
    }
