"""Layer-A simulator configuration: machine model (paper Table IV) + per-app
trace calibration (paper Tables I/II).

Scaling: memory capacities, footprints, and TLB entry counts are scaled by
1/SCALE_DOWN (default 16) so the simulator runs at laptop scale while preserving
the *ratios* that drive the paper's effects (working set vs TLB coverage, DRAM:NVM
= 1:8, hot-page fractions). Latency/energy parameters are per-access and unscaled.
"""
from __future__ import annotations

import dataclasses

from repro.core.migration import SIM_CPU_GHZ, SIM_PAGE_BYTES, TIMING_PRESETS

CPU_GHZ = SIM_CPU_GHZ
NS = CPU_GHZ  # cycles per nanosecond

# Memory latencies + page-migration costs come from the shared preset table
# (core.migration.TIMING_PRESETS, built from the SAME clock/page constants) so
# the simulator and serving cost models are never two divergent copies.
_T4 = TIMING_PRESETS["paper-table4-sim"]

SCALE_DOWN = 16

PAGE_BYTES = SIM_PAGE_BYTES
SP_BYTES = 2 << 20
PAGES_PER_SP = SP_BYTES // PAGE_BYTES  # 512


@dataclasses.dataclass(frozen=True)
class MachineConfig:
    # --- split TLBs (Table IV), entries scaled by SCALE_DOWN ---
    l1_tlb_entries: int = 32 // SCALE_DOWN or 1
    l1_tlb_ways: int = 2
    l1_tlb_lat: float = 1.0
    l2_tlb_entries: int = 512 // SCALE_DOWN
    l2_tlb_ways: int = 8
    l2_tlb_lat: float = 8.0

    # --- memory latencies (cycles @ 3.2 GHz, from the shared preset table) ---
    t_dr: float = _T4["t_dr"]  # DRAM read  = 43.2
    t_dw: float = _T4["t_dw"]  # DRAM write = 91.2
    t_nr: float = _T4["t_nr"]  # PCM read   = 62.4
    t_nw: float = _T4["t_nw"]  # PCM write  = 547.2

    # --- translation structures ---
    bitmap_cache_lat: float = 9.0
    bitmap_cache_entries: int = 4000 // SCALE_DOWN
    bitmap_cache_ways: int = 8
    ptw_refs_4k: int = 4  # x86-64 4-level walk
    ptw_refs_2m: int = 3  # superpage walk: 3 levels
    remap_read_lat: float = 19.5 * NS  # read 8B pointer from NVM (t_nr)

    # --- consistency / migration costs (cycles) ---
    shootdown_cost: float = 4000.0  # per TLB shootdown event (IPI + inval)
    clflush_per_line: float = 40.0  # per 64B line flushed on migration
    mig_page_cost: float = _T4["t_mig"]  # rd PCM + wr DRAM, one 4 KB page
    writeback_page_cost: float = _T4["t_writeback"]

    # --- capacities (scaled) ---
    dram_bytes: int = (4 << 30) // SCALE_DOWN
    nvm_bytes: int = (32 << 30) // SCALE_DOWN

    # --- energy (per access / per bit, from Table IV) ---
    dram_volt: float = 1.5
    dram_read_ma: float = 237.0  # row-buffer miss (conservative)
    dram_write_ma: float = 242.0
    dram_standby_ma: float = 77.0
    dram_refresh_ma: float = 160.0
    pcm_read_pj_bit: float = 81.2  # row-buffer miss
    pcm_write_pj_bit: float = 1684.8
    pcm_hit_pj_bit: float = 1.616
    line_bytes: int = 64

    # --- Rainbow policy knobs (paper §IV-F) ---
    interval_cycles: float = 1e8
    top_n: int = 100
    write_weight: int = 2
    mig_threshold: float = 0.0
    # Eq. 1/2 admission amortizes T_mig over the expected DRAM residency of a
    # migrated page (pages persist across intervals; measured residency >> 1
    # interval). Full T_mig is still charged to cycles/traffic. Calibration
    # choice documented in EXPERIMENTS.md §Repro.
    t_mig_amortize: float = 8.0

    @property
    def dram_pages(self) -> int:
        return self.dram_bytes // PAGE_BYTES

    @property
    def dram_superpages(self) -> int:
        return self.dram_bytes // SP_BYTES

    @property
    def nvm_superpages(self) -> int:
        return self.nvm_bytes // SP_BYTES


@dataclasses.dataclass(frozen=True)
class AppProfile:
    """Synthetic-trace calibration from paper Tables I/II (unscaled MB)."""

    name: str
    footprint_mb: float  # Table I total memory footprint
    working_set_mb: float  # Table I working set per 1e8-cycle interval
    hot_page_pct: float  # Table I hot page percent of working set
    hot_min_access: int  # Table I min accesses of a hot page per interval
    # Table II: % of superpages covered by N hot 4KB pages, bucket upper bounds
    # (32, 64, 128, 256, 384, 512)
    sp_hot_dist: tuple[float, ...]
    write_ratio: float = 0.25
    zipf_alpha: float = 1.1  # skew of accesses over the hot set
    inst_per_access: float = 12.0  # instructions per memory-controller access
    accesses_per_interval: int = 120_000


APPS: dict[str, AppProfile] = {
    "cactusADM": AppProfile("cactusADM", 776, 74.6, 4.71, 64,
                            (28.01, 34.1, 29.32, 0.65, 7.45, 0.47), 0.35, 1.2, 18.0),
    "mcf": AppProfile("mcf", 1698, 1089, 2.36, 30,
                      (57.56, 16.48, 10.84, 9.95, 4.78, 0.39), 0.2, 1.05, 6.0, 260_000),
    "soplex": AppProfile("soplex", 1888, 70.9, 19.63, 51,
                         (45.69, 10.88, 22.76, 9.28, 6.77, 4.62), 0.25, 1.15, 8.0),
    "canneal": AppProfile("canneal", 972, 891.6, 8.52, 2,
                          (62.18, 15.86, 8.9, 11.57, 0.91, 0.58), 0.2, 0.8, 7.0, 240_000),
    "bodytrack": AppProfile("bodytrack", 620, 16.2, 1.0, 19,
                            (83.19, 6.01, 7.66, 2.18, 0.63, 0.33), 0.3, 1.3, 20.0),
    "streamcluster": AppProfile("streamcluster", 150, 105.5, 27.6, 10,
                                (23.77, 30.55, 14.38, 13.71, 17.5, 0.09), 0.15, 1.0, 9.0),
    "DICT": AppProfile("DICT", 384, 20.3, 37.2, 53,
                       (23.86, 14.53, 28.27, 22.14, 11.06, 0.14), 0.3, 1.2, 10.0),
    "BFS": AppProfile("BFS", 3718, 404.1, 20.51, 30,
                      (3.94, 18.19, 57.42, 6.35, 5.6, 8.5), 0.2, 1.0, 7.0, 200_000),
    "setCover": AppProfile("setCover", 2520, 49.8, 37.53, 34,
                           (16.26, 24.28, 27.58, 17.36, 7.5, 7.02), 0.25, 1.1, 9.0, 150_000),
    "MST": AppProfile("MST", 6660, 121.2, 32.42, 35,
                      (13.44, 21.28, 21.77, 25.8, 16.31, 1.4), 0.25, 1.05, 8.0, 160_000),
    "Graph500": AppProfile("Graph500", 27.4 * 1024, 7.2, 6.35, 64,
                           (61.48, 38.46, 0.06, 0.0, 0.0, 0.0), 0.2, 1.2, 5.0),
    "Linpack": AppProfile("Linpack", 23.9 * 1024, 40, 21.19, 63,
                          (22.21, 14.71, 29.18, 16.3, 9.64, 7.96), 0.35, 1.25, 15.0),
    "NPB-CG": AppProfile("NPB-CG", 22.9 * 1024, 40.9, 24.7, 64,
                         (0.05, 96.29, 2.66, 1.0, 0.0, 0.0), 0.25, 1.2, 10.0),
    "GUPS": AppProfile("GUPS", 8.06 * 1024, 7.6 * 1024, 5.8, 4,
                       (95.5, 4.5, 0.0, 0.0, 0.0, 0.0), 0.5, 0.6, 4.0, 320_000),
}

MIXES: dict[str, tuple[str, ...]] = {
    "mix1": ("cactusADM", "soplex", "setCover", "MST"),
    "mix2": ("setCover", "BFS", "DICT", "mcf"),
    "mix3": ("canneal", "DICT", "MST", "soplex"),
}

POLICIES = ("flat-static", "hscc-4kb-mig", "hscc-2mb-mig", "rainbow", "dram-only")
