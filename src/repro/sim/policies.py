"""The eager reference policies of §IV-A, driven interval by interval.

Each policy owns: residency state (which 4KB pages / superpages are DRAM-cached),
a migration routine run at interval boundaries, and the translation kind used by
the per-access scan (tlbsim). Rainbow reuses the core library (two-stage counting,
utility admission, remap/bitmap) — Layer A and Layer B share that code.

This module is the SLIM equivalence oracle for the scanned engine
(engine.simloop): flat-static / dram-only / rainbow, which the engine matches
bit for bit (tests/test_engine.py). The numpy HSCC host loops were deleted
after the engine ports were re-validated EXACT (rel-err 0.0 on migrations /
evictions / MPKI / IPC / mig_bytes) over the full workload table — all apps +
mixes x {hscc-4kb-mig, hscc-2mb-mig}; scripts/validate_hscc_parity.py keeps
that check alive against the recorded snapshot.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rainbow as rb
from repro.core.migration import TimingParams, make_timing
from repro.engine import nomad as nomad_mod
from repro.core.tlb import split_tlb_invalidate_many
from repro.engine.policy import sim_policy_for
from repro.sim import tlbsim
from repro.sim.config import PAGES_PER_SP, MachineConfig
from repro.sim.trace import Trace
from repro.timing import queueing as qtiming
from repro.utils.select import first_k_valid


def machine_timing(mc: MachineConfig) -> TimingParams:
    """Admission-test timing: T_mig amortized over expected residency."""
    a = max(mc.t_mig_amortize, 1.0)
    return make_timing(
        t_nr=mc.t_nr, t_nw=mc.t_nw, t_dr=mc.t_dr, t_dw=mc.t_dw,
        t_mig=mc.mig_page_cost / a, t_writeback=mc.writeback_page_cost / a,
    )


@dataclasses.dataclass
class IntervalResult:
    counters: tlbsim.SimCounters
    migrations: int = 0
    evictions: int = 0
    dirty_evictions: int = 0
    shootdowns: int = 0
    mig_bytes: float = 0.0
    mig_cycles: float = 0.0
    shootdown_cycles: float = 0.0
    clflush_cycles: float = 0.0
    # queueing timing model (repro.timing); stay 0.0 under timing_model="flat"
    stall_dram: float = 0.0
    stall_nvm: float = 0.0
    mig_stall: float = 0.0
    backlog_dram: float = 0.0
    backlog_nvm: float = 0.0
    # transactional async migration (engine.nomad): writes that hit an
    # in-flight page abort its transaction; 0 for every synchronous policy
    aborts: int = 0


def interval_costs(
    policy: str, mc: MachineConfig, migrations: int, evictions: int,
    dirty: int, shootdowns: int,
) -> dict[str, float]:
    """Per-interval traffic/cycle costs derived from migration counts.

    THE single source of each policy's cost model: the eager policies build
    their IntervalResult from it and the engine path (sim.runner) accumulates
    the same floats from the scanned per-interval counts.
    """
    if policy in ("flat-static", "dram-only"):
        return {"mig_bytes": 0.0, "mig_cycles": 0.0,
                "shootdown_cycles": 0.0, "clflush_cycles": 0.0}
    if policy == "hscc-4kb-mig":
        moved = migrations + evictions
        return {
            "mig_bytes": moved * 4096.0,
            "mig_cycles": moved * mc.mig_page_cost
            + dirty * mc.writeback_page_cost,
            "shootdown_cycles": shootdowns * mc.shootdown_cost,
            "clflush_cycles": moved * (4096 / mc.line_bytes) * mc.clflush_per_line,
        }
    if policy == "hscc-2mb-mig":
        moved = migrations + evictions
        sp_mig_cost = mc.mig_page_cost * PAGES_PER_SP
        return {
            "mig_bytes": moved * float(PAGES_PER_SP * 4096),
            "mig_cycles": moved * sp_mig_cost
            + dirty * mc.writeback_page_cost * PAGES_PER_SP,
            "shootdown_cycles": shootdowns * mc.shootdown_cost,
            "clflush_cycles": moved
            * (PAGES_PER_SP * 4096 / mc.line_bytes)
            * mc.clflush_per_line,
        }
    if policy in ("rainbow", "nomad"):
        # clean evictions write back only the 8-byte remap pointer (§III-E).
        # nomad prices a migration generation identically at creation time;
        # only the queue-charging SCHEDULE differs (installments over
        # async_window intervals — repro.timing.traffic).
        moved = migrations + evictions
        return {
            "mig_bytes": migrations * 4096.0 + dirty * 4096.0
            + (evictions - dirty) * 8.0,
            "mig_cycles": migrations * mc.mig_page_cost
            + dirty * mc.writeback_page_cost,
            "shootdown_cycles": shootdowns * mc.shootdown_cost,
            "clflush_cycles": moved * (4096 / mc.line_bytes) * mc.clflush_per_line,
        }
    raise KeyError(f"unknown policy {policy!r}")


class Policy:
    name = "base"
    kind = "flat4k"

    def __init__(
        self,
        mc: MachineConfig,
        trace0: Trace,
        seed: int = 0,
        timing_model: str = "flat",
        queue_geometry=None,
    ):
        self.mc = mc
        self.sim = tlbsim.init_state(mc)
        self.timing = machine_timing(mc)
        self.num_sp = trace0.num_superpages
        self.fp_pages = trace0.footprint_pages
        # queueing timing model: mirror EngineSpec.timing_geometry()
        if timing_model == "flat":
            self._geom = None
        elif timing_model == "queueing":
            self._geom = queue_geometry or qtiming.QueueGeometry()
            self._geom.validate()
        else:
            raise ValueError(
                f"timing_model must be 'flat' or 'queueing', "
                f"got {timing_model!r}"
            )
        self._q = (
            qtiming.queue_init(self._geom) if self._geom is not None else None
        )
        # async policies (Nomad) set this per interval to the pre-scheduled
        # installment charge; synchronous policies leave it None and the
        # queue model derives the lump from the interval's counts itself
        self._bulk = None

    def residency(self, trace: Trace) -> jax.Array:
        raise NotImplementedError

    def migrate(self, trace: Trace, in_dram: np.ndarray) -> IntervalResult:
        raise NotImplementedError

    def run_interval(self, trace: Trace) -> IntervalResult:
        in_dram = self.residency(trace)
        before = self.sim.counters
        t_before = self.sim.t  # access clock BEFORE this interval's walk
        self.sim = tlbsim.run_interval(
            self.kind,
            self.mc,
            self.sim,
            jnp.asarray(trace.vpn.astype(np.int32)),
            jnp.asarray(trace.sp),
            jnp.asarray(in_dram),
            jnp.asarray(trace.is_write),
        )
        delta = jax.tree.map(lambda a, b: a - b, self.sim.counters, before)
        res = self.migrate(trace, np.asarray(in_dram))
        res.counters = delta
        if self._geom is not None:
            extra = (
                {}
                if self._bulk is None
                else {"bulk_dram": self._bulk[0], "bulk_nvm": self._bulk[1]}
            )
            # the SAME jitted program the engine scan inlines per interval
            self._q, tm = qtiming.interval_step_jit(
                self._geom, self.mc, self.name, self._q,
                jnp.asarray(trace.vpn.astype(np.int32)),
                jnp.asarray(trace.is_write),
                jnp.asarray(in_dram),
                t_before,
                jnp.int32(res.migrations),
                jnp.int32(res.evictions),
                jnp.int32(res.dirty_evictions),
                **extra,
            )
            res.stall_dram = float(tm.stall_dram)
            res.stall_nvm = float(tm.stall_nvm)
            res.mig_stall = float(tm.mig_stall)
            res.backlog_dram = float(tm.backlog_dram)
            res.backlog_nvm = float(tm.backlog_nvm)
        return res

    def _invalidate_4k(self, vpns: np.ndarray) -> None:
        # Shared vectorized batch shootdown (same helper the engine's
        # fast path uses; bit-identical to the former per-vpn host loop —
        # -1 / duplicate lanes are no-ops, lru is untouched).
        vpns = jnp.asarray(vpns, jnp.int32)[:256]
        self.sim = self.sim._replace(
            tlb4=split_tlb_invalidate_many(self.sim.tlb4, vpns)
        )


# ---------------------------------------------------------------------------


class FlatStatic(Policy):
    """4 KB pages, static placement by capacity ratio (1:8), no migration."""

    name = "flat-static"
    kind = "flat4k"

    def residency(self, trace: Trace) -> np.ndarray:
        ratio = self.mc.dram_bytes / (self.mc.dram_bytes + self.mc.nvm_bytes)
        # deterministic hash placement
        return ((trace.vpn * 2654435761) % 997) < int(997 * ratio)

    def migrate(self, trace: Trace, in_dram) -> IntervalResult:
        return IntervalResult(counters=tlbsim.zero_counters())


class DramOnly(Policy):
    """32 GB DRAM, 2 MB superpages, no migration (upper bound)."""

    name = "dram-only"
    kind = "sp2m"

    def residency(self, trace: Trace) -> np.ndarray:
        return np.ones_like(trace.sp, bool)

    def migrate(self, trace: Trace, in_dram) -> IntervalResult:
        return IntervalResult(counters=tlbsim.zero_counters())


class Rainbow(Policy):
    """The paper's system, driven by the shared core library."""

    name = "rainbow"
    kind = "rainbow"

    def __init__(self, mc, trace0, seed=0, **kw):
        super().__init__(mc, trace0, seed, **kw)
        # the controller knobs come from the registered "sim-rainbow" preset —
        # the same ControlPolicy surface the engine, fleet sweeps, and the
        # serving autotuner consume (no duplicated knob definitions)
        self.cfg = rb.RainbowConfig(
            num_superpages=self.num_sp,
            pages_per_sp=PAGES_PER_SP,
            policy=sim_policy_for("rainbow", mc),
        )
        self.state = rb.rainbow_init(self.cfg)

    def residency(self, trace: Trace) -> np.ndarray:
        in_dram, _ = rb.translate_accesses(
            self.state, jnp.asarray(trace.sp), jnp.asarray(trace.page)
        )
        return np.asarray(in_dram)

    def migrate(self, trace: Trace, in_dram) -> IntervalResult:
        mc = self.mc
        self.state = rb.observe(
            self.cfg,
            self.state,
            jnp.asarray(trace.sp),
            jnp.asarray(trace.page),
            jnp.asarray(trace.is_write),
            self.state.interval,
        )
        self.state, rep = rb.end_interval(self.cfg, self.state, self.timing)
        migrations = int(rep.n_migrated)
        evictions = int(rep.n_evicted)
        dirty_ev = int(rep.n_dirty_evicted)
        # NVM->DRAM migration needs NO shootdown (superpage mapping unchanged);
        # only DRAM->NVM writeback shoots down the 4KB entries (paper §III-F).
        shootdowns = evictions
        # Same first-k selection the engine's shootdown step uses (shared
        # helper; -1-padded lanes are exact no-ops in the batch invalidate).
        ev_vpn = rep.plan.evict_sp * PAGES_PER_SP + rep.plan.evict_page
        self._invalidate_4k(first_k_valid(ev_vpn, rep.plan.evict_sp >= 0, 256))
        return IntervalResult(
            counters=tlbsim.zero_counters(),
            migrations=migrations,
            evictions=evictions,
            dirty_evictions=dirty_ev,
            shootdowns=shootdowns,
            **interval_costs(self.name, mc, migrations, evictions, dirty_ev,
                             shootdowns),
        )


class Nomad(Policy):
    """Transactional asynchronous migration (engine.nomad), eager oracle.

    Drives the SAME pure functions the engine step program inlines
    (nomad_interval / residency), one host round-trip per interval — the
    equivalence anchor for the async family, exactly as Rainbow anchors the
    synchronous program.
    """

    name = "nomad"
    kind = "rainbow"

    def __init__(self, mc, trace0, seed=0, **kw):
        super().__init__(mc, trace0, seed, **kw)
        self.cfg = rb.RainbowConfig(
            num_superpages=self.num_sp,
            pages_per_sp=PAGES_PER_SP,
            policy=sim_policy_for("nomad", mc),
        )
        self.state = nomad_mod.nomad_init(self.cfg)

    def residency(self, trace: Trace) -> np.ndarray:
        return np.asarray(
            nomad_mod.residency(
                self.cfg, self.state,
                jnp.asarray(trace.sp), jnp.asarray(trace.page),
                jnp.asarray(trace.is_write),
            )
        )

    def migrate(self, trace: Trace, in_dram) -> IntervalResult:
        mc = self.mc
        self.state, rep = nomad_mod.nomad_interval(
            self.cfg, self.state,
            jnp.asarray(trace.sp), jnp.asarray(trace.page),
            jnp.asarray(trace.is_write),
            self.timing, mc,
        )
        r = rep.rb
        migrations = int(r.n_migrated)
        evictions = int(r.n_evicted)
        dirty_ev = int(r.n_dirty_evicted)
        aborts = int(rep.n_aborts)
        # aborts roll back an installed remap entry, so they shoot down the
        # 4KB TLB exactly like evictions (aborts first — same concat order
        # as the engine's _nomad_finish)
        shootdowns = evictions + aborts
        ev_vpn = r.plan.evict_sp * PAGES_PER_SP + r.plan.evict_page
        ev_valid = r.plan.evict_sp >= 0
        if rep.abort_vpn is not None:
            vals = jnp.concatenate([rep.abort_vpn, ev_vpn])
            valid = jnp.concatenate([rep.abort_vpn >= 0, ev_valid])
        else:
            vals, valid = ev_vpn, ev_valid
        self._invalidate_4k(first_k_valid(vals, valid, 256))
        self._bulk = (rep.bulk_dram, rep.bulk_nvm)
        return IntervalResult(
            counters=tlbsim.zero_counters(),
            migrations=migrations,
            evictions=evictions,
            dirty_evictions=dirty_ev,
            shootdowns=shootdowns,
            aborts=aborts,
            **interval_costs(self.name, mc, migrations, evictions, dirty_ev,
                             shootdowns),
        )


#: The eager oracle set. The HSCC policies exist ONLY as engine step
#: programs (engine.simloop) — see the module docstring for the deletion
#: rationale and scripts/validate_hscc_parity.py for the durable parity check.
POLICY_CLASSES = {
    "flat-static": FlatStatic,
    "rainbow": Rainbow,
    "dram-only": DramOnly,
    "nomad": Nomad,
}
