"""The five memory-management policies of §IV-A, driven interval by interval.

Each policy owns: residency state (which 4KB pages / superpages are DRAM-cached),
a migration routine run at interval boundaries, and the translation kind used by
the per-access scan (tlbsim). Rainbow reuses the core library (two-stage counting,
utility admission, remap/bitmap) — Layer A and Layer B share that code.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import counting, migration
from repro.core import rainbow as rb
from repro.core.migration import TimingParams, make_timing
from repro.core.tlb import tlb_invalidate
from repro.sim import tlbsim
from repro.sim.config import PAGES_PER_SP, MachineConfig
from repro.sim.trace import Trace


def machine_timing(mc: MachineConfig) -> TimingParams:
    """Admission-test timing: T_mig amortized over expected residency."""
    a = max(mc.t_mig_amortize, 1.0)
    return make_timing(
        t_nr=mc.t_nr, t_nw=mc.t_nw, t_dr=mc.t_dr, t_dw=mc.t_dw,
        t_mig=mc.mig_page_cost / a, t_writeback=mc.writeback_page_cost / a,
    )


@dataclasses.dataclass
class IntervalResult:
    counters: tlbsim.SimCounters
    migrations: int = 0
    evictions: int = 0
    dirty_evictions: int = 0
    shootdowns: int = 0
    mig_bytes: float = 0.0
    mig_cycles: float = 0.0
    shootdown_cycles: float = 0.0
    clflush_cycles: float = 0.0


def interval_costs(
    policy: str, mc: MachineConfig, migrations: int, evictions: int,
    dirty: int, shootdowns: int,
) -> dict[str, float]:
    """Per-interval traffic/cycle costs derived from migration counts.

    THE single source of each policy's cost model: the eager policies build
    their IntervalResult from it and the engine path (sim.runner) accumulates
    the same floats from the scanned per-interval counts.
    """
    if policy in ("flat-static", "dram-only"):
        return {"mig_bytes": 0.0, "mig_cycles": 0.0,
                "shootdown_cycles": 0.0, "clflush_cycles": 0.0}
    if policy == "hscc-4kb-mig":
        moved = migrations + evictions
        return {
            "mig_bytes": moved * 4096.0,
            "mig_cycles": moved * mc.mig_page_cost
            + dirty * mc.writeback_page_cost,
            "shootdown_cycles": shootdowns * mc.shootdown_cost,
            "clflush_cycles": moved * (4096 / mc.line_bytes) * mc.clflush_per_line,
        }
    if policy == "hscc-2mb-mig":
        moved = migrations + evictions
        sp_mig_cost = mc.mig_page_cost * PAGES_PER_SP
        return {
            "mig_bytes": moved * float(PAGES_PER_SP * 4096),
            "mig_cycles": moved * sp_mig_cost
            + dirty * mc.writeback_page_cost * PAGES_PER_SP,
            "shootdown_cycles": shootdowns * mc.shootdown_cost,
            "clflush_cycles": moved
            * (PAGES_PER_SP * 4096 / mc.line_bytes)
            * mc.clflush_per_line,
        }
    if policy == "rainbow":
        # clean evictions write back only the 8-byte remap pointer (§III-E)
        moved = migrations + evictions
        return {
            "mig_bytes": migrations * 4096.0 + dirty * 4096.0
            + (evictions - dirty) * 8.0,
            "mig_cycles": migrations * mc.mig_page_cost
            + dirty * mc.writeback_page_cost,
            "shootdown_cycles": shootdowns * mc.shootdown_cost,
            "clflush_cycles": moved * (4096 / mc.line_bytes) * mc.clflush_per_line,
        }
    raise KeyError(f"unknown policy {policy!r}")


class Policy:
    name = "base"
    kind = "flat4k"

    def __init__(self, mc: MachineConfig, trace0: Trace, seed: int = 0):
        self.mc = mc
        self.sim = tlbsim.init_state(mc)
        self.timing = machine_timing(mc)
        self.num_sp = trace0.num_superpages
        self.fp_pages = trace0.footprint_pages

    def residency(self, trace: Trace) -> jax.Array:
        raise NotImplementedError

    def migrate(self, trace: Trace, in_dram: np.ndarray) -> IntervalResult:
        raise NotImplementedError

    def run_interval(self, trace: Trace) -> IntervalResult:
        in_dram = self.residency(trace)
        before = self.sim.counters
        self.sim = tlbsim.run_interval(
            self.kind,
            self.mc,
            self.sim,
            jnp.asarray(trace.vpn.astype(np.int32)),
            jnp.asarray(trace.sp),
            jnp.asarray(in_dram),
            jnp.asarray(trace.is_write),
        )
        delta = jax.tree.map(lambda a, b: a - b, self.sim.counters, before)
        res = self.migrate(trace, np.asarray(in_dram))
        res.counters = delta
        return res

    def _invalidate_4k(self, vpns: np.ndarray) -> None:
        from repro.core.tlb import SplitTLB

        tlb4 = self.sim.tlb4
        for v in vpns[:256]:
            tlb4 = SplitTLB(
                l1=tlb_invalidate(tlb4.l1, jnp.asarray(v)),
                l2=tlb_invalidate(tlb4.l2, jnp.asarray(v)),
            )
        self.sim = self.sim._replace(tlb4=tlb4)


# ---------------------------------------------------------------------------


class FlatStatic(Policy):
    """4 KB pages, static placement by capacity ratio (1:8), no migration."""

    name = "flat-static"
    kind = "flat4k"

    def residency(self, trace: Trace) -> np.ndarray:
        ratio = self.mc.dram_bytes / (self.mc.dram_bytes + self.mc.nvm_bytes)
        # deterministic hash placement
        return ((trace.vpn * 2654435761) % 997) < int(997 * ratio)

    def migrate(self, trace: Trace, in_dram) -> IntervalResult:
        return IntervalResult(counters=tlbsim.zero_counters())


class DramOnly(Policy):
    """32 GB DRAM, 2 MB superpages, no migration (upper bound)."""

    name = "dram-only"
    kind = "sp2m"

    def residency(self, trace: Trace) -> np.ndarray:
        return np.ones_like(trace.sp, bool)

    def migrate(self, trace: Trace, in_dram) -> IntervalResult:
        return IntervalResult(counters=tlbsim.zero_counters())


class Hscc4K(Policy):
    """HSCC: flat space, utility migration at 4 KB granularity, 4 KB TLBs."""

    name = "hscc-4kb-mig"
    kind = "flat4k"

    def __init__(self, mc, trace0, seed=0):
        super().__init__(mc, trace0, seed)
        self.resident = np.zeros(self.fp_pages, bool)  # DRAM residency per page
        self.dirty = np.zeros(self.fp_pages, bool)
        self.slots_used = 0
        self.max_slots = mc.dram_pages

    def residency(self, trace: Trace) -> np.ndarray:
        return self.resident[np.minimum(trace.vpn, self.fp_pages - 1)]

    def migrate(self, trace: Trace, in_dram) -> IntervalResult:
        mc = self.mc
        vpn = np.minimum(trace.vpn, self.fp_pages - 1)
        reads = np.bincount(vpn[~trace.is_write], minlength=self.fp_pages)
        writes = np.bincount(vpn[trace.is_write], minlength=self.fp_pages)
        self.dirty |= self.resident & (writes > 0)
        benefit = (
            (mc.t_nr - mc.t_dr) * reads
            + (mc.t_nw - mc.t_dw) * writes
            - mc.mig_page_cost
        )
        benefit[self.resident] = -np.inf  # already cached
        cand = np.argsort(-benefit)[:512]
        cand = cand[benefit[cand] > mc.mig_threshold]

        migrations = evictions = dirty_ev = 0
        free = self.max_slots - self.slots_used
        admit_free = cand[: max(free, 0)]
        self.resident[admit_free] = True
        self.slots_used += len(admit_free)
        migrations += len(admit_free)

        # evict coldest resident pages for the remainder (clean first)
        rest = cand[max(free, 0):]
        if len(rest):
            res_idx = np.flatnonzero(self.resident)
            cold_order = res_idx[np.argsort(reads[res_idx] + writes[res_idx])]
            k = min(len(rest), len(cold_order))
            victims = cold_order[:k]
            gain_in = benefit[rest[:k]]
            gain_out = (
                (mc.t_nr - mc.t_dr) * reads[victims]
                + (mc.t_nw - mc.t_dw) * writes[victims]
            )
            wb = np.where(self.dirty[victims], mc.writeback_page_cost, 0.0)
            ok = gain_in - gain_out - mc.mig_page_cost - wb > mc.mig_threshold
            victims, incoming = victims[ok], rest[:k][ok]
            self.resident[victims] = False
            self.resident[incoming] = True
            dirty_ev = int(self.dirty[victims].sum())
            self.dirty[victims] = False
            evictions = len(victims)
            migrations += len(incoming)

        # every migration / eviction remaps a page -> shootdown + clflush
        shootdowns = migrations + evictions
        self._invalidate_4k(cand[:64])
        return IntervalResult(
            counters=tlbsim.zero_counters(),
            migrations=migrations,
            evictions=evictions,
            dirty_evictions=dirty_ev,
            shootdowns=shootdowns,
            **interval_costs(self.name, mc, migrations, evictions, dirty_ev,
                             shootdowns),
        )


class Hscc2M(Policy):
    """HSCC modified for 2 MB superpage migration (costly; paper's foil)."""

    name = "hscc-2mb-mig"
    kind = "sp2m"

    def __init__(self, mc, trace0, seed=0):
        super().__init__(mc, trace0, seed)
        self.resident = np.zeros(self.num_sp, bool)
        self.dirty = np.zeros(self.num_sp, bool)
        self.max_slots = mc.dram_superpages

    def residency(self, trace: Trace) -> np.ndarray:
        return self.resident[trace.sp]

    def migrate(self, trace: Trace, in_dram) -> IntervalResult:
        mc = self.mc
        reads = np.bincount(trace.sp[~trace.is_write], minlength=self.num_sp)
        writes = np.bincount(trace.sp[trace.is_write], minlength=self.num_sp)
        self.dirty |= self.resident & (writes > 0)
        sp_mig_cost = mc.mig_page_cost * PAGES_PER_SP
        benefit = (
            (mc.t_nr - mc.t_dr) * reads + (mc.t_nw - mc.t_dw) * writes - sp_mig_cost
        )
        benefit[self.resident] = -np.inf
        cand = np.argsort(-benefit)[:64]
        cand = cand[benefit[cand] > mc.mig_threshold]

        migrations = evictions = dirty_ev = 0
        used = int(self.resident.sum())
        free = self.max_slots - used
        admit = cand[: max(free, 0)]
        self.resident[admit] = True
        migrations += len(admit)
        rest = cand[max(free, 0):]
        if len(rest):
            res_idx = np.flatnonzero(self.resident)
            cold = res_idx[np.argsort(reads[res_idx] + writes[res_idx])]
            k = min(len(rest), len(cold))
            victims = cold[:k]
            gain_in = benefit[rest[:k]]
            gain_out = (mc.t_nr - mc.t_dr) * reads[victims] + (
                mc.t_nw - mc.t_dw
            ) * writes[victims]
            wb = np.where(self.dirty[victims], mc.writeback_page_cost * PAGES_PER_SP, 0)
            ok = gain_in - gain_out - sp_mig_cost - wb > mc.mig_threshold
            victims, incoming = victims[ok], rest[:k][ok]
            self.resident[victims] = False
            self.resident[incoming] = True
            dirty_ev = int(self.dirty[victims].sum())
            self.dirty[victims] = False
            evictions = len(victims)
            migrations += len(incoming)

        shootdowns = migrations + evictions
        return IntervalResult(
            counters=tlbsim.zero_counters(),
            migrations=migrations,
            evictions=evictions,
            dirty_evictions=dirty_ev,
            shootdowns=shootdowns,
            **interval_costs(self.name, mc, migrations, evictions, dirty_ev,
                             shootdowns),
        )


class Rainbow(Policy):
    """The paper's system, driven by the shared core library."""

    name = "rainbow"
    kind = "rainbow"

    def __init__(self, mc, trace0, seed=0):
        super().__init__(mc, trace0, seed)
        self.cfg = rb.RainbowConfig(
            num_superpages=self.num_sp,
            pages_per_sp=PAGES_PER_SP,
            top_n=mc.top_n,
            dram_slots=mc.dram_pages,
            write_weight=mc.write_weight,
            max_migrations_per_interval=512,
        )
        self.state = rb.rainbow_init(self.cfg, threshold=mc.mig_threshold)

    def residency(self, trace: Trace) -> np.ndarray:
        in_dram, _ = rb.translate_accesses(
            self.state, jnp.asarray(trace.sp), jnp.asarray(trace.page)
        )
        return np.asarray(in_dram)

    def migrate(self, trace: Trace, in_dram) -> IntervalResult:
        mc = self.mc
        self.state = rb.observe(
            self.cfg,
            self.state,
            jnp.asarray(trace.sp),
            jnp.asarray(trace.page),
            jnp.asarray(trace.is_write),
            self.state.interval,
        )
        self.state, rep = rb.end_interval(self.cfg, self.state, self.timing)
        migrations = int(rep.n_migrated)
        evictions = int(rep.n_evicted)
        dirty_ev = int(rep.n_dirty_evicted)
        # NVM->DRAM migration needs NO shootdown (superpage mapping unchanged);
        # only DRAM->NVM writeback shoots down the 4KB entries (paper §III-F).
        shootdowns = evictions
        ev = np.asarray(rep.plan.evict_sp)
        evp = np.asarray(rep.plan.evict_page)
        evicted_vpn = (ev[ev >= 0].astype(np.int64) * PAGES_PER_SP + evp[ev >= 0])
        self._invalidate_4k(evicted_vpn.astype(np.int32))
        return IntervalResult(
            counters=tlbsim.zero_counters(),
            migrations=migrations,
            evictions=evictions,
            dirty_evictions=dirty_ev,
            shootdowns=shootdowns,
            **interval_costs(self.name, mc, migrations, evictions, dirty_ev,
                             shootdowns),
        )


POLICY_CLASSES = {
    "flat-static": FlatStatic,
    "hscc-4kb-mig": Hscc4K,
    "hscc-2mb-mig": Hscc2M,
    "rainbow": Rainbow,
    "dram-only": DramOnly,
}
