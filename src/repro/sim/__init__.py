from repro.sim import config, energy, policies, runner, tlbsim, trace
