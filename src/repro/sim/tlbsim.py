"""Per-access translation+memory simulation (the sequential core of Layer A).

One lax.scan over the interval's accesses carries the TLB/bitmap-cache LRU state
and accumulates cycle/miss counters. Residency (which pages are DRAM-cached) is
fixed within an interval — migrations happen at interval boundaries (the paper's
history-based policy) — so residency arrives as a precomputed per-access vector.

Covers all five policies via static TranslationKind:
  flat4k  : single 4KB TLB, 4-ref PTW          (Flat-static, HSCC-4KB-mig)
  sp2m    : single 2MB TLB, 3-ref PTW          (HSCC-2MB-mig, DRAM-only)
  rainbow : split TLBs + bitmap cache + remap  (Fig. 6 four cases)
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.bitmap import BitmapCache, bitmap_cache_init, bitmap_cache_lookup
from repro.core.tlb import SplitTLB, split_tlb_init, split_tlb_lookup
from repro.sim.config import MachineConfig


class SimCounters(NamedTuple):
    cycles_tlb: jax.Array
    cycles_walk: jax.Array
    cycles_bitmap: jax.Array
    cycles_remap: jax.Array
    cycles_mem: jax.Array
    miss4_l1: jax.Array
    miss4_l2: jax.Array
    miss2m_l1: jax.Array
    miss2m_l2: jax.Array
    bmc_miss: jax.Array
    dram_reads: jax.Array
    dram_writes: jax.Array
    nvm_reads: jax.Array
    nvm_writes: jax.Array


def zero_counters() -> SimCounters:
    z = jnp.zeros((), jnp.float32)
    return SimCounters(*([z] * 14))


class SimState(NamedTuple):
    tlb4: SplitTLB
    tlb2m: SplitTLB
    bmc: BitmapCache
    t: jax.Array
    counters: SimCounters


def init_state(mc: MachineConfig) -> SimState:
    mk = lambda: split_tlb_init(
        mc.l1_tlb_entries, mc.l1_tlb_ways, mc.l2_tlb_entries, mc.l2_tlb_ways
    )
    return SimState(
        tlb4=mk(),
        tlb2m=mk(),
        bmc=bitmap_cache_init(mc.bitmap_cache_entries, mc.bitmap_cache_ways),
        t=jnp.zeros((), jnp.int32),
        counters=zero_counters(),
    )


def _acc(c: SimCounters, **kw) -> SimCounters:
    return c._replace(**{k: getattr(c, k) + v for k, v in kw.items()})


@functools.lru_cache(maxsize=None)
def make_access_step(kind: str, mc: MachineConfig):
    """Build the per-access scan step for one TranslationKind.

    Returned step: (SimState, (vpn, sp, in_dram, is_write)) -> (SimState, None).
    `run_interval` scans it over one interval's accesses; engine.simloop embeds
    the same step inside its whole-simulation scan so the device-resident
    engine is bit-identical to the host-looped path.
    """

    l1l, l2l = mc.l1_tlb_lat, mc.l2_tlb_lat

    def step(st: SimState, xs):
        v, s, dram, wr = xs
        c = st.counters
        now = st.t
        mem_rd = jnp.where(dram, mc.t_dr, mc.t_nr)
        mem_wr = jnp.where(dram, mc.t_dw, mc.t_nw)
        mem_cost = jnp.where(wr, mem_wr, mem_rd)

        if kind == "flat4k":
            tlb4, h1, h2 = split_tlb_lookup(st.tlb4, v, now)
            walk = (~h1) & (~h2)
            c = _acc(
                c,
                cycles_tlb=l1l + jnp.where(~h1, l2l, 0.0),
                cycles_walk=jnp.where(walk, mc.ptw_refs_4k * mc.t_dr, 0.0),
                cycles_mem=mem_cost,
                miss4_l1=(~h1).astype(jnp.float32),
                miss4_l2=walk.astype(jnp.float32),
                dram_reads=(dram & ~wr).astype(jnp.float32),
                dram_writes=(dram & wr).astype(jnp.float32),
                nvm_reads=(~dram & ~wr).astype(jnp.float32),
                nvm_writes=(~dram & wr).astype(jnp.float32),
            )
            return SimState(tlb4, st.tlb2m, st.bmc, now + 1, c), None

        if kind == "sp2m":
            tlb2m, h1, h2 = split_tlb_lookup(st.tlb2m, s, now)
            walk = (~h1) & (~h2)
            c = _acc(
                c,
                cycles_tlb=l1l + jnp.where(~h1, l2l, 0.0),
                cycles_walk=jnp.where(walk, mc.ptw_refs_2m * mc.t_dr, 0.0),
                cycles_mem=mem_cost,
                miss2m_l1=(~h1).astype(jnp.float32),
                miss2m_l2=walk.astype(jnp.float32),
                dram_reads=(dram & ~wr).astype(jnp.float32),
                dram_writes=(dram & wr).astype(jnp.float32),
                nvm_reads=(~dram & ~wr).astype(jnp.float32),
                nvm_writes=(~dram & wr).astype(jnp.float32),
            )
            return SimState(st.tlb4, tlb2m, st.bmc, now + 1, c), None

        # ---- rainbow: Fig. 6 four cases ----
        # 4KB TLB holds only DRAM-cached pages; consulted in parallel with the
        # superpage TLB. Fill 4KB TLB only when the access resolves to DRAM.
        tlb4, h41, h42 = split_tlb_lookup(st.tlb4, v, now, fill=dram)
        hit4 = (h41 | h42) & dram  # stale-proof: entry implies residency
        tlb2m, h21, h22 = split_tlb_lookup(st.tlb2m, s, now)
        sp_hit = h21 | h22
        sptw = ~sp_hit

        # Cases 3/4: 4KB miss -> consult bitmap (cache) for the home superpage.
        need_bitmap = ~hit4
        bmc, bmc_hit = bitmap_cache_lookup(st.bmc, s, now)
        bmc_miss = need_bitmap & ~bmc_hit
        cost_bitmap = jnp.where(
            need_bitmap, mc.bitmap_cache_lat + jnp.where(bmc_miss, mc.t_nr, 0.0), 0.0
        )
        # migrated & 4KB-missed -> remap pointer read from NVM (one t_nr)
        remap_read = need_bitmap & dram
        cost_remap = jnp.where(remap_read, mc.remap_read_lat, 0.0)

        cost_tlb = l1l + jnp.where(~h41 & ~h21, l2l, 0.0)
        cost_walk = jnp.where(need_bitmap & sptw, mc.ptw_refs_2m * mc.t_dr, 0.0)

        c = _acc(
            c,
            cycles_tlb=cost_tlb,
            cycles_walk=cost_walk,
            cycles_bitmap=cost_bitmap,
            cycles_remap=cost_remap,
            cycles_mem=mem_cost,
            miss4_l1=(dram & ~h41).astype(jnp.float32),
            miss4_l2=(dram & ~hit4).astype(jnp.float32),
            miss2m_l1=(~h21).astype(jnp.float32),
            miss2m_l2=sptw.astype(jnp.float32),
            bmc_miss=bmc_miss.astype(jnp.float32),
            dram_reads=(dram & ~wr).astype(jnp.float32),
            dram_writes=(dram & wr).astype(jnp.float32),
            nvm_reads=(~dram & ~wr).astype(jnp.float32),
            nvm_writes=(~dram & wr).astype(jnp.float32),
        )
        return SimState(tlb4, tlb2m, bmc, now + 1, c), None

    return step


@functools.partial(jax.jit, static_argnames=("kind", "mc"))
def run_interval(
    kind: str,
    mc: MachineConfig,
    state: SimState,
    vpn: jax.Array,  # int32[A] 4KB page id (global)
    sp: jax.Array,  # int32[A] superpage id
    in_dram: jax.Array,  # bool[A] residency at interval start
    is_write: jax.Array,  # bool[A]
) -> SimState:
    """Scan the interval's accesses; returns state with accumulated counters."""
    state, _ = jax.lax.scan(
        make_access_step(kind, mc), state, (vpn, sp, in_dram, is_write)
    )
    return state


# ---------------------------------------------------------------------------
# Fast per-interval hot path (bit-identical to scanning make_access_step)
# ---------------------------------------------------------------------------
#
# The reference scan above carries the full SimState (TLB tables + all 14
# float32 counters) and re-derives every per-access quantity inside the scan
# body. Most of that work is provably order-independent:
#
#   * tier classification + memory cost per access depend only on the chunk
#     (in_dram, is_write), never on TLB state -> hoisted out of the scan and
#     computed vectorized. Elementwise ops in the same dtype are bitwise
#     equal wherever they run.
#   * COUNT-like counters (miss counts, tier read/write counts, bmc misses)
#     accumulate +0.0/+1.0 in float32. Every partial sum is an integer, and
#     integers are exact in float32 below 2**24 — so summing the batch as
#     int32 and adding the total once yields the SAME final float32 value as
#     the reference's one-add-per-access, for any access order. (Invariant:
#     cumulative per-counter totals stay < 2**24 ≈ 16.7M accesses; current
#     workloads peak around 1M. Documented in docs/engine.md.)
#
# What stays serial — and why:
#
#   * CYCLE counters (cycles_tlb/walk/bitmap/remap/mem) accumulate
#     NON-integer float32 values (e.g. t_dr = 43.2), and float addition is
#     not associative: any reordering changes low bits, which the HSCC
#     parity snapshot (rel-err 0.0 on IPC) would catch. They remain
#     sequential adds, in reference order, inside the scan.
#   * The set-associative LRU TLB/bitmap-cache state is genuinely
#     order-dependent (each lookup's hit and victim depend on every prior
#     access in the same set), so the tag/lru updates remain a scan.
#
# The scan body itself is slimmed two ways: the split-TLB L1 probe +
# conditional L1 back-fill pair collapses into ONE combined update
# (_fused_split_lookup below — provably the same final state), and the scan
# is unrolled (structural only: same ops, same order, same results).

INTERVAL_UNROLL = 4


def _probe(tags: jax.Array, lru: jax.Array, sets: int, v: jax.Array):
    """Read one set's line once. Returns (s, line, lru_line, hit_way, hit)."""
    if sets == 1:
        s = jnp.int32(0)
        line, lru_line = tags[0], lru[0]
    else:
        s = (v % sets).astype(jnp.int32)
        line = jax.lax.dynamic_index_in_dim(tags, s, keepdims=False)
        lru_line = jax.lax.dynamic_index_in_dim(lru, s, keepdims=False)
    hit_way = line == v
    return s, line, lru_line, hit_way, hit_way.any()


def _way_of(hit, hit_way, lru_line) -> jax.Array:
    return jnp.where(hit, jnp.argmax(hit_way), jnp.argmin(lru_line)).astype(
        jnp.int32
    )


def _write_entry(tags, lru, s, way, tag_v, lru_v):
    """Single-entry (s, way) update via dynamic_update_slice (no scatter)."""
    tags = jax.lax.dynamic_update_slice(tags, tag_v.reshape(1, 1), (s, way))
    lru = jax.lax.dynamic_update_slice(lru, lru_v.reshape(1, 1), (s, way))
    return tags, lru


def _pick(line: jax.Array, way: jax.Array) -> jax.Array:
    return jax.lax.dynamic_index_in_dim(line, way, keepdims=False)


def _fused_split_lookup(
    st: SplitTLB, vpn: jax.Array, now: jax.Array, fill: bool | jax.Array = True
) -> tuple[SplitTLB, jax.Array, jax.Array]:
    """split_tlb_lookup with the two L1 touches fused into one write.

    The reference does three tlb_lookup calls: an L1 probe (fill=False, which
    writes lru=now only on hit), the L2 lookup, then a conditional L1
    back-fill. Because the probe writes nothing on a miss, the back-fill's
    victim (argmin lru) is computed on unchanged state — so both L1 touches
    write the same (tag=vpn, lru=now) at the same way under the combined
    condition h1 | h2 | fill. One probe + one conditional single-entry write
    replaces two full lookups; final state and (h1, h2) are bit-identical.
    Set lines are gathered once and reused for the keep-old branch of the
    conditional write (the reference re-gathers `tags[s, way]`; same values).
    """
    from repro.core.tlb import TLBState

    v = vpn.astype(jnp.int32)
    now32 = now.astype(jnp.int32)
    fill = jnp.asarray(fill)
    l1, l2 = st.l1, st.l2

    s1, line1, lrul1, hw1, h1 = _probe(l1.tags, l1.lru, l1.sets, v)
    s2, line2, lrul2, hw2, h2 = _probe(l2.tags, l2.lru, l2.sets, v)

    way2 = _way_of(h2, hw2, lrul2)
    do2 = h2 | fill
    t2, r2 = _write_entry(
        l2.tags, l2.lru, s2, way2,
        jnp.where(do2, v, _pick(line2, way2)),
        jnp.where(do2, now32, _pick(lrul2, way2)),
    )

    way1 = _way_of(h1, hw1, lrul1)
    do1 = h1 | h2 | fill
    t1, r1 = _write_entry(
        l1.tags, l1.lru, s1, way1,
        jnp.where(do1, v, _pick(line1, way1)),
        jnp.where(do1, now32, _pick(lrul1, way1)),
    )

    return (
        SplitTLB(
            l1=TLBState(tags=t1, lru=r1, sets=l1.sets, ways=l1.ways),
            l2=TLBState(tags=t2, lru=r2, sets=l2.sets, ways=l2.ways),
        ),
        h1,
        h2,
    )


def _fast_bmc_lookup(bmc, psn: jax.Array, now: jax.Array):
    """bitmap_cache_lookup with one probe + dynamic_update_slice writes."""
    from repro.core.bitmap import BitmapCache

    p = psn.astype(jnp.int32)
    s, _, lrul, hw, hit = _probe(bmc.tags, bmc.lru, bmc.tags.shape[0], p)
    way = _way_of(hit, hw, lrul)
    tags, lru = _write_entry(
        bmc.tags, bmc.lru, s, way, p, now.astype(jnp.int32)
    )
    return BitmapCache(tags=tags, lru=lru), hit


def _count(x: jax.Array) -> jax.Array:
    """Batch count of a bool vector, as the float32 the reference accumulates."""
    return x.sum(dtype=jnp.int32).astype(jnp.float32)


@functools.lru_cache(maxsize=None)
def make_interval_runner(kind: str, mc: MachineConfig, unroll: int = INTERVAL_UNROLL):
    """Build the fast-path interval executor for one TranslationKind.

    Same signature as scanning `make_access_step` over the interval:
    (SimState, vpn, sp, in_dram, is_write) -> SimState, and bit-identical to
    it (tests/test_hotpath.py pins the equivalence property-wise; the
    engine-vs-eager suite pins it end-to-end). Memoized per (kind, mc) so jit
    tracing caches see one function identity.
    """

    l1l, l2l = mc.l1_tlb_lat, mc.l2_tlb_lat
    walk4 = mc.ptw_refs_4k * mc.t_dr
    walk2m = mc.ptw_refs_2m * mc.t_dr

    def run(st: SimState, vpn, sp, in_dram, is_write) -> SimState:
        c = st.counters
        # --- hoisted: order-independent per-access quantities (vectorized) ---
        mem_rd = jnp.where(in_dram, mc.t_dr, mc.t_nr)
        mem_wr = jnp.where(in_dram, mc.t_dw, mc.t_nw)
        mem_cost = jnp.where(is_write, mem_wr, mem_rd)
        dram_reads = c.dram_reads + _count(in_dram & ~is_write)
        dram_writes = c.dram_writes + _count(in_dram & is_write)
        nvm_reads = c.nvm_reads + _count(~in_dram & ~is_write)
        nvm_writes = c.nvm_writes + _count(~in_dram & is_write)

        zi = jnp.zeros((), jnp.int32)

        if kind in ("flat4k", "sp2m"):
            tlb0 = st.tlb4 if kind == "flat4k" else st.tlb2m
            key = vpn if kind == "flat4k" else sp
            walk_cost = walk4 if kind == "flat4k" else walk2m

            def body(carry, xs):
                tlb, t, ctlb, cwalk, cmem, m1, m2 = carry
                v, mcost = xs
                tlb, h1, h2 = _fused_split_lookup(tlb, v, t)
                walk = (~h1) & (~h2)
                ctlb = ctlb + (l1l + jnp.where(~h1, l2l, 0.0))
                cwalk = cwalk + jnp.where(walk, walk_cost, 0.0)
                cmem = cmem + mcost
                m1 = m1 + (~h1).astype(jnp.int32)
                m2 = m2 + walk.astype(jnp.int32)
                return (tlb, t + 1, ctlb, cwalk, cmem, m1, m2), None

            (tlb, t, ctlb, cwalk, cmem, m1, m2), _ = jax.lax.scan(
                body,
                (tlb0, st.t, c.cycles_tlb, c.cycles_walk, c.cycles_mem, zi, zi),
                (key, mem_cost),
                unroll=unroll,
            )
            if kind == "flat4k":
                counters = c._replace(
                    cycles_tlb=ctlb, cycles_walk=cwalk, cycles_mem=cmem,
                    miss4_l1=c.miss4_l1 + m1.astype(jnp.float32),
                    miss4_l2=c.miss4_l2 + m2.astype(jnp.float32),
                    dram_reads=dram_reads, dram_writes=dram_writes,
                    nvm_reads=nvm_reads, nvm_writes=nvm_writes,
                )
                return SimState(tlb, st.tlb2m, st.bmc, t, counters)
            counters = c._replace(
                cycles_tlb=ctlb, cycles_walk=cwalk, cycles_mem=cmem,
                miss2m_l1=c.miss2m_l1 + m1.astype(jnp.float32),
                miss2m_l2=c.miss2m_l2 + m2.astype(jnp.float32),
                dram_reads=dram_reads, dram_writes=dram_writes,
                nvm_reads=nvm_reads, nvm_writes=nvm_writes,
            )
            return SimState(st.tlb4, tlb, st.bmc, t, counters)

        # ---- rainbow: Fig. 6 four cases, slim carry ----
        def body(carry, xs):
            tlb4, tlb2m, bmc, t, ctlb, cwalk, cbmp, crmp, cmem, m41, m42, m21, m22, mb = carry
            v, s, dram, mcost = xs
            tlb4, h41, h42 = _fused_split_lookup(tlb4, v, t, fill=dram)
            hit4 = (h41 | h42) & dram
            tlb2m, h21, h22 = _fused_split_lookup(tlb2m, s, t)
            sptw = ~(h21 | h22)
            need_bitmap = ~hit4
            bmc, bmc_hit = _fast_bmc_lookup(bmc, s, t)
            bmc_miss = need_bitmap & ~bmc_hit
            ctlb = ctlb + (l1l + jnp.where(~h41 & ~h21, l2l, 0.0))
            cwalk = cwalk + jnp.where(need_bitmap & sptw, walk2m, 0.0)
            cbmp = cbmp + jnp.where(
                need_bitmap,
                mc.bitmap_cache_lat + jnp.where(bmc_miss, mc.t_nr, 0.0),
                0.0,
            )
            crmp = crmp + jnp.where(need_bitmap & dram, mc.remap_read_lat, 0.0)
            cmem = cmem + mcost
            m41 = m41 + (dram & ~h41).astype(jnp.int32)
            m42 = m42 + (dram & ~hit4).astype(jnp.int32)
            m21 = m21 + (~h21).astype(jnp.int32)
            m22 = m22 + sptw.astype(jnp.int32)
            mb = mb + bmc_miss.astype(jnp.int32)
            return (
                tlb4, tlb2m, bmc, t + 1,
                ctlb, cwalk, cbmp, crmp, cmem, m41, m42, m21, m22, mb,
            ), None

        carry0 = (
            st.tlb4, st.tlb2m, st.bmc, st.t,
            c.cycles_tlb, c.cycles_walk, c.cycles_bitmap, c.cycles_remap,
            c.cycles_mem, zi, zi, zi, zi, zi,
        )
        (
            tlb4, tlb2m, bmc, t,
            ctlb, cwalk, cbmp, crmp, cmem, m41, m42, m21, m22, mb,
        ), _ = jax.lax.scan(
            body, carry0, (vpn, sp, in_dram, mem_cost), unroll=unroll
        )
        counters = c._replace(
            cycles_tlb=ctlb, cycles_walk=cwalk, cycles_bitmap=cbmp,
            cycles_remap=crmp, cycles_mem=cmem,
            miss4_l1=c.miss4_l1 + m41.astype(jnp.float32),
            miss4_l2=c.miss4_l2 + m42.astype(jnp.float32),
            miss2m_l1=c.miss2m_l1 + m21.astype(jnp.float32),
            miss2m_l2=c.miss2m_l2 + m22.astype(jnp.float32),
            bmc_miss=c.bmc_miss + mb.astype(jnp.float32),
            dram_reads=dram_reads, dram_writes=dram_writes,
            nvm_reads=nvm_reads, nvm_writes=nvm_writes,
        )
        return SimState(tlb4, tlb2m, bmc, t, counters)

    return run


@functools.partial(jax.jit, static_argnames=("kind", "mc"))
def run_interval_fast(
    kind: str,
    mc: MachineConfig,
    state: SimState,
    vpn: jax.Array,
    sp: jax.Array,
    in_dram: jax.Array,
    is_write: jax.Array,
) -> SimState:
    """Jitted fast-path counterpart of run_interval (bit-identical)."""
    return make_interval_runner(kind, mc)(state, vpn, sp, in_dram, is_write)
