"""Per-access translation+memory simulation (the sequential core of Layer A).

One lax.scan over the interval's accesses carries the TLB/bitmap-cache LRU state
and accumulates cycle/miss counters. Residency (which pages are DRAM-cached) is
fixed within an interval — migrations happen at interval boundaries (the paper's
history-based policy) — so residency arrives as a precomputed per-access vector.

Covers all five policies via static TranslationKind:
  flat4k  : single 4KB TLB, 4-ref PTW          (Flat-static, HSCC-4KB-mig)
  sp2m    : single 2MB TLB, 3-ref PTW          (HSCC-2MB-mig, DRAM-only)
  rainbow : split TLBs + bitmap cache + remap  (Fig. 6 four cases)
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.bitmap import BitmapCache, bitmap_cache_init, bitmap_cache_lookup
from repro.core.tlb import SplitTLB, split_tlb_init, split_tlb_lookup
from repro.sim.config import MachineConfig


class SimCounters(NamedTuple):
    cycles_tlb: jax.Array
    cycles_walk: jax.Array
    cycles_bitmap: jax.Array
    cycles_remap: jax.Array
    cycles_mem: jax.Array
    miss4_l1: jax.Array
    miss4_l2: jax.Array
    miss2m_l1: jax.Array
    miss2m_l2: jax.Array
    bmc_miss: jax.Array
    dram_reads: jax.Array
    dram_writes: jax.Array
    nvm_reads: jax.Array
    nvm_writes: jax.Array


def zero_counters() -> SimCounters:
    z = jnp.zeros((), jnp.float32)
    return SimCounters(*([z] * 14))


class SimState(NamedTuple):
    tlb4: SplitTLB
    tlb2m: SplitTLB
    bmc: BitmapCache
    t: jax.Array
    counters: SimCounters


def init_state(mc: MachineConfig) -> SimState:
    mk = lambda: split_tlb_init(
        mc.l1_tlb_entries, mc.l1_tlb_ways, mc.l2_tlb_entries, mc.l2_tlb_ways
    )
    return SimState(
        tlb4=mk(),
        tlb2m=mk(),
        bmc=bitmap_cache_init(mc.bitmap_cache_entries, mc.bitmap_cache_ways),
        t=jnp.zeros((), jnp.int32),
        counters=zero_counters(),
    )


def _acc(c: SimCounters, **kw) -> SimCounters:
    return c._replace(**{k: getattr(c, k) + v for k, v in kw.items()})


def make_access_step(kind: str, mc: MachineConfig):
    """Build the per-access scan step for one TranslationKind.

    Returned step: (SimState, (vpn, sp, in_dram, is_write)) -> (SimState, None).
    `run_interval` scans it over one interval's accesses; engine.simloop embeds
    the same step inside its whole-simulation scan so the device-resident
    engine is bit-identical to the host-looped path.
    """

    l1l, l2l = mc.l1_tlb_lat, mc.l2_tlb_lat

    def step(st: SimState, xs):
        v, s, dram, wr = xs
        c = st.counters
        now = st.t
        mem_rd = jnp.where(dram, mc.t_dr, mc.t_nr)
        mem_wr = jnp.where(dram, mc.t_dw, mc.t_nw)
        mem_cost = jnp.where(wr, mem_wr, mem_rd)

        if kind == "flat4k":
            tlb4, h1, h2 = split_tlb_lookup(st.tlb4, v, now)
            walk = (~h1) & (~h2)
            c = _acc(
                c,
                cycles_tlb=l1l + jnp.where(~h1, l2l, 0.0),
                cycles_walk=jnp.where(walk, mc.ptw_refs_4k * mc.t_dr, 0.0),
                cycles_mem=mem_cost,
                miss4_l1=(~h1).astype(jnp.float32),
                miss4_l2=walk.astype(jnp.float32),
                dram_reads=(dram & ~wr).astype(jnp.float32),
                dram_writes=(dram & wr).astype(jnp.float32),
                nvm_reads=(~dram & ~wr).astype(jnp.float32),
                nvm_writes=(~dram & wr).astype(jnp.float32),
            )
            return SimState(tlb4, st.tlb2m, st.bmc, now + 1, c), None

        if kind == "sp2m":
            tlb2m, h1, h2 = split_tlb_lookup(st.tlb2m, s, now)
            walk = (~h1) & (~h2)
            c = _acc(
                c,
                cycles_tlb=l1l + jnp.where(~h1, l2l, 0.0),
                cycles_walk=jnp.where(walk, mc.ptw_refs_2m * mc.t_dr, 0.0),
                cycles_mem=mem_cost,
                miss2m_l1=(~h1).astype(jnp.float32),
                miss2m_l2=walk.astype(jnp.float32),
                dram_reads=(dram & ~wr).astype(jnp.float32),
                dram_writes=(dram & wr).astype(jnp.float32),
                nvm_reads=(~dram & ~wr).astype(jnp.float32),
                nvm_writes=(~dram & wr).astype(jnp.float32),
            )
            return SimState(st.tlb4, tlb2m, st.bmc, now + 1, c), None

        # ---- rainbow: Fig. 6 four cases ----
        # 4KB TLB holds only DRAM-cached pages; consulted in parallel with the
        # superpage TLB. Fill 4KB TLB only when the access resolves to DRAM.
        tlb4, h41, h42 = split_tlb_lookup(st.tlb4, v, now, fill=dram)
        hit4 = (h41 | h42) & dram  # stale-proof: entry implies residency
        tlb2m, h21, h22 = split_tlb_lookup(st.tlb2m, s, now)
        sp_hit = h21 | h22
        sptw = ~sp_hit

        # Cases 3/4: 4KB miss -> consult bitmap (cache) for the home superpage.
        need_bitmap = ~hit4
        bmc, bmc_hit = bitmap_cache_lookup(st.bmc, s, now)
        bmc_miss = need_bitmap & ~bmc_hit
        cost_bitmap = jnp.where(
            need_bitmap, mc.bitmap_cache_lat + jnp.where(bmc_miss, mc.t_nr, 0.0), 0.0
        )
        # migrated & 4KB-missed -> remap pointer read from NVM (one t_nr)
        remap_read = need_bitmap & dram
        cost_remap = jnp.where(remap_read, mc.remap_read_lat, 0.0)

        cost_tlb = l1l + jnp.where(~h41 & ~h21, l2l, 0.0)
        cost_walk = jnp.where(need_bitmap & sptw, mc.ptw_refs_2m * mc.t_dr, 0.0)

        c = _acc(
            c,
            cycles_tlb=cost_tlb,
            cycles_walk=cost_walk,
            cycles_bitmap=cost_bitmap,
            cycles_remap=cost_remap,
            cycles_mem=mem_cost,
            miss4_l1=(dram & ~h41).astype(jnp.float32),
            miss4_l2=(dram & ~hit4).astype(jnp.float32),
            miss2m_l1=(~h21).astype(jnp.float32),
            miss2m_l2=sptw.astype(jnp.float32),
            bmc_miss=bmc_miss.astype(jnp.float32),
            dram_reads=(dram & ~wr).astype(jnp.float32),
            dram_writes=(dram & wr).astype(jnp.float32),
            nvm_reads=(~dram & ~wr).astype(jnp.float32),
            nvm_writes=(~dram & wr).astype(jnp.float32),
        )
        return SimState(tlb4, tlb2m, bmc, now + 1, c), None

    return step


@functools.partial(jax.jit, static_argnames=("kind", "mc"))
def run_interval(
    kind: str,
    mc: MachineConfig,
    state: SimState,
    vpn: jax.Array,  # int32[A] 4KB page id (global)
    sp: jax.Array,  # int32[A] superpage id
    in_dram: jax.Array,  # bool[A] residency at interval start
    is_write: jax.Array,  # bool[A]
) -> SimState:
    """Scan the interval's accesses; returns state with accumulated counters."""
    state, _ = jax.lax.scan(
        make_access_step(kind, mc), state, (vpn, sp, in_dram, is_write)
    )
    return state
