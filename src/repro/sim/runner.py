"""Layer-A experiment runner: a thin host shell over the device-resident
MemoryEngine (engine.simloop), aggregating the paper's metrics (MPKI,
TLB-service cycles, IPC, migration traffic, energy, translation breakdown).

Two execution paths produce SimMetrics:

  simulate(...)              — default: pre-generate all interval traces, run
                               the whole simulation as one lax.scan on device
                               (engine.simloop.engine_run), finalize on host.
  simulate(..., engine=False)— the pre-refactor eager reference: one host
                               round-trip per interval through sim.policies.
                               Kept as the equivalence oracle (tests/test_engine
                               asserts bit-identical metrics) and as the
                               baseline of benchmarks/engine_throughput.py.

`sweep` declares the (app x policy x seed) grid as an engine.fleet.SweepPlan
and runs it through the mesh-sharded FleetRunner — same-shape cells fuse into
one sharded fleet axis, trace staging double-buffers against the device scan.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.sim import trace as trace_mod
from repro.sim.config import APPS, MIXES, MachineConfig
from repro.sim.energy import energy_joules
from repro.sim.policies import POLICY_CLASSES, interval_costs

BASE_CPI = 0.6  # out-of-order core CPI on non-memory work

_ZERO_TOTALS = {
    "migrations": 0, "evictions": 0, "dirty": 0, "shootdowns": 0,
    "mig_bytes": 0.0, "mig_cycles": 0.0, "shootdown_cycles": 0.0,
    "clflush_cycles": 0.0, "accesses": 0,
    # queueing timing model (repro.timing); exact 0.0 under timing_model="flat"
    "stall_dram": 0.0, "stall_nvm": 0.0, "mig_stall": 0.0,
    "backlog_dram": 0.0, "backlog_nvm": 0.0, "intervals": 0,
    # transactional async migration (engine.nomad); 0 for synchronous policies
    "aborts": 0,
}


@dataclasses.dataclass
class SimMetrics:
    app: str
    policy: str
    instructions: float
    total_cycles: float
    ipc: float
    mpki: float
    tlb_service_cycles: float
    tlb_service_frac: float
    breakdown: dict[str, float]
    migrations: int
    evictions: int
    shootdowns: int
    mig_bytes: float
    footprint_bytes: float
    traffic_ratio: float
    energy: dict[str, float]
    # queueing timing model (EngineSpec.timing_model="queueing"); trailing
    # with defaults so journaled SimMetrics(**fields) round-trips from before
    # the timing subsystem existed. All exact 0.0 under "flat".
    bank_stall_cycles: float = 0.0
    mig_stall_cycles: float = 0.0
    queue_occupancy_dram: float = 0.0
    queue_occupancy_nvm: float = 0.0
    # transactional async migration (engine.nomad): writes to in-flight pages
    # that aborted the copy; exactly 0 for every synchronous policy
    mig_aborts: int = 0

    def row(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d.update(d.pop("breakdown"))
        d.update({f"energy_{k}": v for k, v in d.pop("energy").items()})
        return d


def finalize_metrics(
    app: str,
    policy: str,
    mc: MachineConfig,
    totals: dict,
    counters,
    inst_per_access: float,
    footprint_pages: int,
) -> SimMetrics:
    """Metrics from accumulated per-interval totals + final scan counters."""
    c = counters
    f = lambda x: float(np.asarray(x))
    cycles_trans = (
        f(c.cycles_tlb) + f(c.cycles_walk) + f(c.cycles_bitmap) + f(c.cycles_remap)
    )
    instructions = totals["accesses"] * inst_per_access
    bank_stall = totals["stall_dram"] + totals["stall_nvm"]
    total_cycles = (
        instructions * BASE_CPI
        + cycles_trans
        + f(c.cycles_mem)
        + totals["mig_cycles"]
        + totals["shootdown_cycles"]
        + totals["clflush_cycles"]
        + bank_stall  # exact 0.0 under "flat": total_cycles bitwise unchanged
    )
    # the TLB miss count that matters for MPKI: walks actually taken
    if policy in ("flat-static", "hscc-4kb-mig"):
        tlb_misses = f(c.miss4_l2)
    elif policy in ("hscc-2mb-mig", "dram-only"):
        tlb_misses = f(c.miss2m_l2)
    else:  # rainbow: walks happen only when the superpage TLB misses
        tlb_misses = f(c.miss2m_l2)

    dram_cap = 8.0 if policy == "dram-only" else 1.0
    energy = energy_joules(
        mc,
        f(c.dram_reads), f(c.dram_writes), f(c.nvm_reads), f(c.nvm_writes),
        totals["mig_bytes"], total_cycles, dram_capacity_factor=dram_cap,
    )

    fp_bytes = footprint_pages * 4096.0
    return SimMetrics(
        app=app,
        policy=policy,
        instructions=instructions,
        total_cycles=total_cycles,
        ipc=instructions / total_cycles,
        mpki=tlb_misses / (instructions / 1000.0),
        tlb_service_cycles=cycles_trans,
        tlb_service_frac=cycles_trans / total_cycles,
        breakdown={
            "cycles_tlb": f(c.cycles_tlb),
            "cycles_walk": f(c.cycles_walk),
            "cycles_bitmap": f(c.cycles_bitmap),
            "cycles_remap": f(c.cycles_remap),
            "cycles_mem": f(c.cycles_mem),
            "cycles_mig": totals["mig_cycles"],
            "cycles_shootdown": totals["shootdown_cycles"],
            "cycles_clflush": totals["clflush_cycles"],
            "cycles_bank_stall": bank_stall,
            "bmc_misses": f(c.bmc_miss),
        },
        migrations=totals["migrations"],
        evictions=totals["evictions"],
        shootdowns=totals["shootdowns"],
        mig_bytes=totals["mig_bytes"],
        footprint_bytes=fp_bytes,
        traffic_ratio=totals["mig_bytes"] / fp_bytes,
        energy=energy,
        bank_stall_cycles=bank_stall,
        mig_stall_cycles=totals["mig_stall"],
        queue_occupancy_dram=totals["backlog_dram"] / max(totals["intervals"], 1),
        queue_occupancy_nvm=totals["backlog_nvm"] / max(totals["intervals"], 1),
        mig_aborts=totals["aborts"],
    )


def totals_from_stats(
    policy: str, mc: MachineConfig, stats, accesses_per_interval: int
) -> dict:
    """Accumulate engine per-interval stats in the eager path's order/dtypes."""
    totals = dict(_ZERO_TOTALS)
    m_i = np.asarray(stats.migrations)
    e_i = np.asarray(stats.evictions)
    d_i = np.asarray(stats.dirty_evictions)
    s_i = np.asarray(stats.shootdowns)
    a_i = (
        np.asarray(stats.aborts)
        if stats.aborts is not None
        else np.zeros_like(m_i)
    )
    cols = zip(
        m_i.tolist(), e_i.tolist(), d_i.tolist(), s_i.tolist(),
        np.asarray(stats.stall_dram).tolist(),
        np.asarray(stats.stall_nvm).tolist(),
        np.asarray(stats.mig_stall).tolist(),
        np.asarray(stats.backlog_dram).tolist(),
        np.asarray(stats.backlog_nvm).tolist(),
        a_i.tolist(),
    )
    for m, e, d, s, sd, sn, ms, bd, bn, ab in cols:
        costs = interval_costs(policy, mc, m, e, d, s)
        totals["migrations"] += m
        totals["evictions"] += e
        totals["dirty"] += d
        totals["shootdowns"] += s
        totals["mig_bytes"] += costs["mig_bytes"]
        totals["mig_cycles"] += costs["mig_cycles"]
        totals["shootdown_cycles"] += costs["shootdown_cycles"]
        totals["clflush_cycles"] += costs["clflush_cycles"]
        totals["accesses"] += accesses_per_interval
        totals["stall_dram"] += sd
        totals["stall_nvm"] += sn
        totals["mig_stall"] += ms
        totals["backlog_dram"] += bd
        totals["backlog_nvm"] += bn
        totals["aborts"] += ab
        totals["intervals"] += 1
    return totals


def simulate(
    app: str,
    policy: str,
    mc: MachineConfig | None = None,
    intervals: int = 5,
    accesses: int | None = None,
    seed: int = 7,
    engine: bool = True,
    counter_backend: str = "jax",
    fused: bool = False,
    fastpath: bool = True,
    timing_model: str = "flat",
    queue_geometry=None,
) -> SimMetrics:
    """Simulate (app x policy) over N intervals and aggregate SimMetrics.

    `app` may be a numpy app profile, a mix, or a registered scenario
    (repro.workloads). `fused=True` (scenarios only) synthesizes each
    interval's chunk INSIDE the engine scan instead of staging host-generated
    arrays — bit-identical to the staged path by the workloads differential
    gate (tests/test_workloads.py). `fastpath=False` compiles the engine
    against the pre-overhaul reference ops (EngineSpec.fastpath) — the
    differential anchor for the vectorized hot path.

    `timing_model="queueing"` (+ an optional repro.timing.QueueGeometry)
    charges every interval through the per-channel/bank contention model
    (docs/timing.md); "flat" keeps the event-count cost model bit-identical
    to queueing-with-infinite-banks.
    """
    if not engine:
        if fused:
            raise ValueError("fused generation requires the engine path")
        return simulate_eager(
            app, policy, mc, intervals, accesses, seed,
            timing_model=timing_model, queue_geometry=queue_geometry,
        )
    from repro.engine import simloop  # lazy: sim.__init__ imports this module

    mc = mc or MachineConfig()
    if fused:
        from repro.workloads import scenarios as scen

        if not scen.is_scenario(app):
            raise ValueError(
                f"fused generation needs a registered scenario, got {app!r} "
                f"(registered: {scen.available_scenarios()}); numpy app "
                "profiles/mixes run staged"
            )
        meta = trace_mod.probe_meta(app, accesses)
        source = simloop.TraceSource(scenario=app, accesses=accesses)
        chunks = None
    else:
        chunks, meta = simloop.make_chunks(
            app, policy, mc, seed, intervals, accesses
        )
        source = None
    spec = simloop.EngineSpec(
        policy=policy,
        mc=mc,
        num_superpages=meta["num_superpages"],
        footprint_pages=meta["footprint_pages"],
        counter_backend=counter_backend,
        source=source,
        fastpath=fastpath,
        timing_model=timing_model,
        queue_geometry=queue_geometry,
    )
    # The freshly built engine_init state is never reused, so its buffers are
    # donated to the scan — the carry updates in place instead of copying.
    if fused:
        state, stats = simloop.engine_run_fused(
            spec, simloop.engine_init(spec), seed, intervals, donate=True
        )
    else:
        state, stats = simloop.engine_run(
            spec, simloop.engine_init(spec), chunks, donate=True
        )
    totals = totals_from_stats(policy, mc, stats, meta["accesses_per_interval"])
    return finalize_metrics(
        app, policy, mc, totals, state.sim.counters,
        meta["inst_per_access"], meta["footprint_pages"],
    )


def simulate_eager(
    app: str,
    policy: str,
    mc: MachineConfig | None = None,
    intervals: int = 5,
    accesses: int | None = None,
    seed: int = 7,
    timing_model: str = "flat",
    queue_geometry=None,
) -> SimMetrics:
    """Pre-refactor host-looped reference path (one round-trip per interval)."""
    if policy not in POLICY_CLASSES:
        raise KeyError(
            f"no eager reference for {policy!r}: the numpy HSCC host loops "
            "were deleted after the engine ports passed exact full-table "
            "parity (scripts/validate_hscc_parity.py); use the engine path"
        )
    mc = mc or MachineConfig()
    trace0 = trace_mod.generate(app, seed, 0, accesses)
    pol = POLICY_CLASSES[policy](
        mc, trace0, seed,
        timing_model=timing_model, queue_geometry=queue_geometry,
    )

    totals = dict(_ZERO_TOTALS)
    tr = trace0
    for i in range(intervals):
        if i > 0:
            tr = trace_mod.generate(app, seed, i, accesses)
        res = pol.run_interval(tr)
        totals["migrations"] += res.migrations
        totals["evictions"] += res.evictions
        totals["dirty"] += res.dirty_evictions
        totals["shootdowns"] += res.shootdowns
        totals["mig_bytes"] += res.mig_bytes
        totals["mig_cycles"] += res.mig_cycles
        totals["shootdown_cycles"] += res.shootdown_cycles
        totals["clflush_cycles"] += res.clflush_cycles
        totals["accesses"] += tr.sp.shape[0]
        totals["stall_dram"] += res.stall_dram
        totals["stall_nvm"] += res.stall_nvm
        totals["mig_stall"] += res.mig_stall
        totals["backlog_dram"] += res.backlog_dram
        totals["backlog_nvm"] += res.backlog_nvm
        totals["aborts"] += res.aborts
        totals["intervals"] += 1

    return finalize_metrics(
        app, policy, mc, totals, pol.sim.counters,
        tr.inst_per_access, tr.footprint_pages,
    )


def sweep(
    apps: list[str],
    policies: list[str],
    seeds: list[int],
    mc: MachineConfig | None = None,
    intervals: int = 5,
    accesses: int | None = None,
    counter_backend: str = "jax",
    stream: bool = False,
    journal=None,
    scenarios: list[str] = (),
    runner=None,
    timing_model: str = "flat",
    queue_geometry=None,
) -> dict[tuple[str, str, int], SimMetrics]:
    """Fleet sweep: the (app x policy x seed) grid as ONE FleetRunner plan.

    Cells sharing a compile signature are fused onto the fleet axis, sharded
    across the device mesh, and double-buffered against host trace staging
    (engine.fleet). Returns {(app, policy, seed): metrics}.

    `scenarios` adds registered workload scenarios (repro.workloads) as
    FUSED cells: their traces are synthesized inside the sharded engine scan,
    so the runner stages nothing host-side for them (apps named in `apps`,
    scenario names included, run staged).

    `stream=True` retires groups through the incremental FleetRunner.run_iter
    path and `journal` (a path) checkpoints retired groups so a killed sweep
    resumes where it stopped — both bit-identical to the barrier path.

    `runner` substitutes a configured FleetRunner (prefetch depth, compile
    cache, pipeline=False reference mode); callers can read per-group
    wall-clock breakdowns off `runner.timings` afterwards.
    """
    from repro.engine import fleet  # lazy: sim.__init__ imports this module

    plan = fleet.SweepPlan.grid(
        apps, policies, tuple(seeds), mc=mc or MachineConfig(),
        intervals=intervals, accesses=accesses,
        counter_backend=counter_backend, scenario=tuple(scenarios),
        timing_model=timing_model, queue_geometry=queue_geometry,
    )
    runner = runner or fleet.FleetRunner()
    result = runner.run(plan, stream=stream, journal=journal)
    return {(c.app, c.policy, c.seed): m for c, m in result.items()}


def workloads(include_mixes: bool = True) -> list[str]:
    w = list(APPS)
    if include_mixes:
        w += list(MIXES)
    return w
