"""Layer-A experiment runner: simulate (app x policy) over N intervals, aggregate
the paper's metrics (MPKI, TLB-service cycles, IPC, migration traffic, energy,
translation breakdown)."""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.sim import trace as trace_mod
from repro.sim.config import APPS, MIXES, CPU_GHZ, MachineConfig
from repro.sim.energy import energy_joules
from repro.sim.policies import POLICY_CLASSES

BASE_CPI = 0.6  # out-of-order core CPI on non-memory work


@dataclasses.dataclass
class SimMetrics:
    app: str
    policy: str
    instructions: float
    total_cycles: float
    ipc: float
    mpki: float
    tlb_service_cycles: float
    tlb_service_frac: float
    breakdown: dict[str, float]
    migrations: int
    evictions: int
    shootdowns: int
    mig_bytes: float
    footprint_bytes: float
    traffic_ratio: float
    energy: dict[str, float]

    def row(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d.update(d.pop("breakdown"))
        d.update({f"energy_{k}": v for k, v in d.pop("energy").items()})
        return d


def simulate(
    app: str,
    policy: str,
    mc: MachineConfig | None = None,
    intervals: int = 5,
    accesses: int | None = None,
    seed: int = 7,
) -> SimMetrics:
    mc = mc or MachineConfig()
    trace0 = trace_mod.generate(app, seed, 0, accesses)
    pol = POLICY_CLASSES[policy](mc, trace0, seed)

    totals = {
        "migrations": 0, "evictions": 0, "dirty": 0, "shootdowns": 0,
        "mig_bytes": 0.0, "mig_cycles": 0.0, "shootdown_cycles": 0.0,
        "clflush_cycles": 0.0, "accesses": 0,
    }
    tr = trace0
    for i in range(intervals):
        if i > 0:
            tr = trace_mod.generate(app, seed, i, accesses)
        res = pol.run_interval(tr)
        totals["migrations"] += res.migrations
        totals["evictions"] += res.evictions
        totals["dirty"] += res.dirty_evictions
        totals["shootdowns"] += res.shootdowns
        totals["mig_bytes"] += res.mig_bytes
        totals["mig_cycles"] += res.mig_cycles
        totals["shootdown_cycles"] += res.shootdown_cycles
        totals["clflush_cycles"] += res.clflush_cycles
        totals["accesses"] += tr.sp.shape[0]

    c = pol.sim.counters
    f = lambda x: float(np.asarray(x))
    cycles_trans = (
        f(c.cycles_tlb) + f(c.cycles_walk) + f(c.cycles_bitmap) + f(c.cycles_remap)
    )
    instructions = totals["accesses"] * tr.inst_per_access
    total_cycles = (
        instructions * BASE_CPI
        + cycles_trans
        + f(c.cycles_mem)
        + totals["mig_cycles"]
        + totals["shootdown_cycles"]
        + totals["clflush_cycles"]
    )
    # the TLB miss count that matters for MPKI: walks actually taken
    if policy in ("flat-static", "hscc-4kb-mig"):
        tlb_misses = f(c.miss4_l2)
    elif policy in ("hscc-2mb-mig", "dram-only"):
        tlb_misses = f(c.miss2m_l2)
    else:  # rainbow: walks happen only when the superpage TLB misses
        tlb_misses = f(c.miss2m_l2)

    dram_cap = 8.0 if policy == "dram-only" else 1.0
    energy = energy_joules(
        mc,
        f(c.dram_reads), f(c.dram_writes), f(c.nvm_reads), f(c.nvm_writes),
        totals["mig_bytes"], total_cycles, dram_capacity_factor=dram_cap,
    )

    fp_bytes = tr.footprint_pages * 4096.0
    return SimMetrics(
        app=app,
        policy=policy,
        instructions=instructions,
        total_cycles=total_cycles,
        ipc=instructions / total_cycles,
        mpki=tlb_misses / (instructions / 1000.0),
        tlb_service_cycles=cycles_trans,
        tlb_service_frac=cycles_trans / total_cycles,
        breakdown={
            "cycles_tlb": f(c.cycles_tlb),
            "cycles_walk": f(c.cycles_walk),
            "cycles_bitmap": f(c.cycles_bitmap),
            "cycles_remap": f(c.cycles_remap),
            "cycles_mem": f(c.cycles_mem),
            "cycles_mig": totals["mig_cycles"],
            "cycles_shootdown": totals["shootdown_cycles"],
            "cycles_clflush": totals["clflush_cycles"],
            "bmc_misses": f(c.bmc_miss),
        },
        migrations=totals["migrations"],
        evictions=totals["evictions"],
        shootdowns=totals["shootdowns"],
        mig_bytes=totals["mig_bytes"],
        footprint_bytes=fp_bytes,
        traffic_ratio=totals["mig_bytes"] / fp_bytes,
        energy=energy,
    )


def workloads(include_mixes: bool = True) -> list[str]:
    w = list(APPS)
    if include_mixes:
        w += list(MIXES)
    return w
