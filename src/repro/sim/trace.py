"""Synthetic memory-access trace generation, calibrated to paper Tables I/II.

SPEC/Parsec/PBBS binaries are not available offline; the paper's own measured
statistics are the calibration targets instead (DESIGN.md Layer A):

  * footprint  -> page population size (scaled by SCALE_DOWN)
  * working set per interval -> pages touched per interval
  * hot-page % + CHOP 70%-rule -> fraction of accesses on the hot set
  * Table II  -> how hot pages cluster inside superpages
  * zipf alpha -> skew of accesses across hot pages

Traces are numpy (generation is host-side), consumed by jax scans.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sim.config import APPS, MIXES, PAGES_PER_SP, SCALE_DOWN, AppProfile

HOT_TRAFFIC_FRACTION = 0.70  # CHOP: hot pages receive 70% of references


@dataclasses.dataclass
class Trace:
    """One interval's accesses. sp/page identify the 4KB page; vpn = sp*512+page."""

    sp: np.ndarray  # int32[A] superpage id
    page: np.ndarray  # int32[A] page-in-superpage
    is_write: np.ndarray  # bool[A]
    num_superpages: int
    footprint_pages: int
    inst_per_access: float

    @property
    def vpn(self) -> np.ndarray:
        return self.sp.astype(np.int64) * PAGES_PER_SP + self.page


def _mb_to_pages(mb: float) -> int:
    return max(64, int(mb * 1024 * 1024 / 4096 / SCALE_DOWN))


def _pick_hot_pages(
    rng: np.random.Generator, prof: AppProfile, ws_pages: np.ndarray
) -> np.ndarray:
    """Choose hot pages inside the working set so their clustering across
    superpages follows the Table II bucket distribution."""
    n_hot = max(1, int(len(ws_pages) * prof.hot_page_pct / 100.0))
    sp_of = ws_pages // PAGES_PER_SP
    sps, counts = np.unique(sp_of, return_counts=True)
    probs = np.asarray(prof.sp_hot_dist, np.float64)
    probs = probs / probs.sum()
    uppers = np.array([32, 64, 128, 256, 384, 512])
    lowers = np.array([1, 33, 65, 129, 257, 385])

    hot: list[np.ndarray] = []
    order = rng.permutation(len(sps))
    budget = n_hot
    for i in order:
        if budget <= 0:
            break
        b = rng.choice(6, p=probs)
        lo, hi = lowers[b], uppers[b]
        want = int(rng.integers(lo, hi + 1)) // SCALE_DOWN or 1
        pages_here = ws_pages[sp_of == sps[i]]
        take = min(want, len(pages_here), budget)
        hot.append(rng.choice(pages_here, size=take, replace=False))
        budget -= take
    if not hot:
        return ws_pages[:1]
    return np.concatenate(hot)


HOT_CHURN = 0.08  # fraction of the hot set replaced per interval (phase drift)
WS_CHURN = 0.10


def generate_interval(
    prof: AppProfile, seed: int, interval: int, accesses: int | None = None
) -> Trace:
    """Generate one monitoring interval of accesses for an app.

    Hot/working sets are *persistent with slow churn* across intervals (derived
    deterministically from (seed, interval) so history-based policies see the
    temporal locality the paper measures; churn models phase drift).
    """
    rng0 = np.random.default_rng(seed & 0x7FFFFFFF)  # interval-invariant choices
    rng = np.random.default_rng((seed * 1000003 + interval * 7919) & 0x7FFFFFFF)
    fp_pages = _mb_to_pages(prof.footprint_mb)
    ws_pages_n = min(_mb_to_pages(prof.working_set_mb), fp_pages)
    a = accesses or prof.accesses_per_interval

    # Base working set (stable): contiguous block + scattered tail.
    ws_start = int(rng0.integers(0, max(fp_pages - ws_pages_n, 1)))
    ws_pages = np.arange(ws_start, ws_start + ws_pages_n, dtype=np.int64)
    # scattered tail clusters inside a few superpages (Table II: references
    # concentrate within superpages even for irregular apps)
    n_scatter = ws_pages_n // 4
    if n_scatter:
        n_sp = max(1, fp_pages // PAGES_PER_SP)
        n_scatter_sp = max(1, n_scatter // (PAGES_PER_SP // 4))
        homes = rng0.integers(0, n_sp, n_scatter_sp) * PAGES_PER_SP
        offs = rng0.integers(0, min(PAGES_PER_SP, fp_pages), n_scatter)
        ws_pages[-n_scatter:] = np.minimum(
            homes[rng0.integers(0, n_scatter_sp, n_scatter)] + offs, fp_pages - 1
        )
    # Hot pages are selected from the STABLE base working set (before churn)
    # so the rng0 stream — and therefore the hot set and its zipf rank order —
    # is identical across intervals (the paper's history-based premise).
    hot = _pick_hot_pages(rng0, prof, ws_pages.copy())

    # churn: replace a slice of the ws per interval (phase drift)
    n_churn = int(ws_pages_n * WS_CHURN)
    if n_churn and interval:
        idx = (np.arange(n_churn) + interval * n_churn) % ws_pages_n
        ws_pages[idx] = rng.integers(0, fp_pages, n_churn)
    n_hot_churn = int(len(hot) * HOT_CHURN)
    if n_hot_churn and interval:
        idx = (np.arange(n_hot_churn) + interval * n_hot_churn) % len(hot)
        hot = hot.copy()
        hot[idx] = rng.choice(ws_pages, size=n_hot_churn)

    n_hot_acc = int(a * HOT_TRAFFIC_FRACTION)
    n_cold_acc = a - n_hot_acc

    # zipf-ranked hot accesses (rank order stable across intervals)
    ranks = np.arange(1, len(hot) + 1, dtype=np.float64)
    w = ranks ** (-prof.zipf_alpha)
    w /= w.sum()
    hot_idx = rng.choice(len(hot), size=n_hot_acc, p=w)
    hot_acc = hot[hot_idx]
    cold_acc = rng.choice(ws_pages, size=n_cold_acc)

    pages = np.concatenate([hot_acc, cold_acc])
    rng.shuffle(pages)
    is_write = rng.random(a) < prof.write_ratio

    num_sp = (fp_pages + PAGES_PER_SP - 1) // PAGES_PER_SP
    return Trace(
        sp=(pages // PAGES_PER_SP).astype(np.int32),
        page=(pages % PAGES_PER_SP).astype(np.int32),
        is_write=is_write,
        num_superpages=int(num_sp),
        footprint_pages=int(fp_pages),
        inst_per_access=prof.inst_per_access,
    )


def generate_mix(
    mix: str, seed: int, interval: int, accesses_per_app: int | None = None
) -> Trace:
    """Interleave member apps' traces in a shared (offset) address space."""
    members = MIXES[mix]
    traces = []
    sp_base = 0
    for i, name in enumerate(members):
        t = generate_interval(APPS[name], seed + i, interval, accesses_per_app)
        traces.append((t, sp_base))
        sp_base += t.num_superpages
    a = sum(t.sp.shape[0] for t, _ in traces)
    sp = np.concatenate([t.sp + base for t, base in traces])
    page = np.concatenate([t.page for t, _ in traces])
    wr = np.concatenate([t.is_write for t, _ in traces])
    # round-robin interleave by shuffling with a fixed permutation
    rng = np.random.default_rng(seed)
    perm = rng.permutation(a)
    ipa = float(np.mean([t.inst_per_access for t, _ in traces]))
    return Trace(
        sp=sp[perm],
        page=page[perm],
        is_write=wr[perm],
        num_superpages=sp_base,
        footprint_pages=sum(t.footprint_pages for t, _ in traces),
        inst_per_access=ipa,
    )


def generate(name: str, seed: int, interval: int, accesses: int | None = None) -> Trace:
    """One interval of any workload: numpy app profile, mix, or scenario.

    Registered scenario names (repro.workloads.scenarios) dispatch to a thin
    host materialization of the SAME jitted generator stream the engine fuses
    into its interval scan — so feeding this Trace through the staged engine
    path is the exact differential oracle of fused in-scan generation.
    """
    if name in MIXES:
        per_app = (accesses // len(MIXES[name])) if accesses else None
        return generate_mix(name, seed, interval, per_app)
    if name in APPS:
        return generate_interval(APPS[name], seed, interval, accesses)
    return _materialize_scenario(name, seed, interval, accesses)


def _materialize_scenario(
    name: str, seed: int, interval: int, accesses: int | None
) -> Trace:
    """Host Trace from a registered scenario's device generator stream."""
    from repro.workloads import scenarios  # lazy: keeps trace.py numpy-first

    pages, is_write, meta = scenarios.materialize(name, seed, interval, accesses)
    return Trace(
        sp=(pages // PAGES_PER_SP).astype(np.int32),
        page=(pages % PAGES_PER_SP).astype(np.int32),
        is_write=is_write,
        num_superpages=int(meta["num_superpages"]),
        footprint_pages=int(meta["footprint_pages"]),
        inst_per_access=float(meta["inst_per_access"]),
    )


def probe_meta(name: str, accesses: int | None = None) -> dict:
    """Shape metadata of `generate(name, ...)` WITHOUT materializing accesses.

    Seed/interval-invariant by construction (footprints and access counts are
    profile-derived), so fleet schedulers can group compatible cells before any
    trace generation happens. Keys match engine.simloop.make_chunks meta.

    Scenario names report the registered generator program's static shapes —
    identical whether the cell later runs staged or fused, so both modes of
    one scenario land in consistent compile-signature groups (never a silent
    shape mismatch between probe and emission: materialize() re-asserts it).
    """
    if name not in APPS and name not in MIXES:
        from repro.workloads import scenarios  # lazy: keeps trace.py numpy-first

        return scenarios.probe_meta(name, accesses)

    def one(prof: AppProfile, a: int | None) -> tuple[int, int, int, float]:
        fp = _mb_to_pages(prof.footprint_mb)
        nsp = (fp + PAGES_PER_SP - 1) // PAGES_PER_SP
        return fp, nsp, a or prof.accesses_per_interval, prof.inst_per_access

    if name in MIXES:
        per_app = (accesses // len(MIXES[name])) if accesses else None
        parts = [one(APPS[m], per_app) for m in MIXES[name]]
        fp = sum(p[0] for p in parts)
        nsp = sum(p[1] for p in parts)
        a = sum(p[2] for p in parts)
        ipa = float(np.mean([p[3] for p in parts]))
    else:
        fp, nsp, a, ipa = one(APPS[name], accesses)
    return {
        "num_superpages": nsp,
        "footprint_pages": fp,
        "inst_per_access": ipa,
        "accesses_per_interval": a,
    }
