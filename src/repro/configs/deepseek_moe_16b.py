"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained, first layer
dense FFN [arXiv:2401.06066; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=11264,  # dense first layer: (top_k + shared) * moe_d_ff
    vocab_size=102400,
    head_dim=128,
    moe_num_experts=64,
    moe_top_k=6,
    moe_num_shared=2,
    moe_d_ff=1408,
    moe_first_dense=1,
    rope_theta=1e4,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b-reduced",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=192,
        vocab_size=256,
        head_dim=16,
        moe_num_experts=8,
        moe_top_k=2,
        moe_num_shared=1,
        moe_d_ff=48,
        moe_first_dense=1,
        vocab_pad_multiple=8,
        rope_theta=1e4,
    )
