"""granite-8b [dense] — llama-arch, code [arXiv:2405.04324; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
    head_dim=128,
    rope_theta=1e4,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-8b-reduced",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=160,
        vocab_size=256,
        head_dim=16,
        vocab_pad_multiple=8,
        rope_theta=1e4,
    )
