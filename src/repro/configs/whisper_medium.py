"""whisper-medium [audio] — enc-dec, conv frontend STUB: input_specs() provides
precomputed frame embeddings [arXiv:2212.04356; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    head_dim=64,
    is_encoder_decoder=True,
    num_encoder_layers=24,
    encoder_seq_divisor=2,  # enc frames = seq_len // 2, dec tokens = seq_len // 2
    norm="layernorm",
    act="gelu",
    gated_mlp=False,
    rope_theta=1e4,  # decoder self-attn uses rope in our port (orig: learned pos)
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium-reduced",
        family="audio",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        is_encoder_decoder=True,
        num_encoder_layers=2,
        encoder_seq_divisor=2,
        norm="layernorm",
        act="gelu",
        gated_mlp=False,
        vocab_pad_multiple=8,
    )
