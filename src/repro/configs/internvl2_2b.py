"""internvl2-2b [vlm] — InternViT frontend STUB (input_specs() provides patch
embeddings) + InternLM2 backbone [arXiv:2404.16821; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    head_dim=128,
    num_vision_tokens=256,
    rope_theta=1e6,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b-reduced",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=160,
        vocab_size=256,
        head_dim=16,
        num_vision_tokens=8,
        vocab_pad_multiple=8,
    )
