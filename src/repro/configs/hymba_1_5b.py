"""hymba-1.5b [hybrid] — parallel attn+mamba heads per layer; sliding-window
attention with periodic global layers [arXiv:2411.13676; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,  # 1600 / 25
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    sliding_window=2048,
    global_attn_every=16,  # layers 0 and 16 use full attention
    rope_theta=1e4,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b-reduced",
        family="hybrid",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        ssm_state=8,
        ssm_head_dim=16,
        ssm_expand=2,
        ssm_chunk=16,
        sliding_window=32,
        global_attn_every=2,
        vocab_pad_multiple=8,
    )
