"""Assigned-architecture registry: ``get_config("<arch-id>")``."""
from __future__ import annotations

import importlib

from repro.models.config import SHAPES, ModelConfig, ShapeConfig

ARCH_IDS = [
    "qwen3-4b",
    "qwen3-0.6b",
    "smollm-360m",
    "granite-8b",
    "deepseek-moe-16b",
    "qwen2-moe-a2.7b",
    "whisper-medium",
    "hymba-1.5b",
    "internvl2-2b",
    "mamba2-1.3b",
]


def _module(arch_id: str):
    return importlib.import_module(f"repro.configs.{arch_id.replace('-', '_').replace('.', '_')}")


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return _module(arch_id).CONFIG


def get_reduced_config(arch_id: str) -> ModelConfig:
    """Small same-family config for CPU smoke tests."""
    return _module(arch_id).reduced()


def get_shape(shape_id: str) -> ShapeConfig:
    return SHAPES[shape_id]


def applicable_shapes(arch_id: str) -> list[str]:
    """Assigned shape cells actually runnable for this arch (DESIGN.md §4).

    long_500k requires sub-quadratic sequence mixing: only the SSM/hybrid archs
    qualify; the 8 pure full-attention archs record a 'skip' cell.
    """
    cfg = get_config(arch_id)
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in ("ssm", "hybrid"):
        shapes.append("long_500k")
    return shapes
