"""smollm-360m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM-135M; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    head_dim=64,  # 960 / 15
    rope_theta=1e4,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m-reduced",
        family="dense",
        num_layers=2,
        d_model=60,
        num_heads=3,
        num_kv_heads=1,
        d_ff=112,
        vocab_size=256,
        head_dim=20,
        vocab_pad_multiple=8,
        rope_theta=1e4,
    )
