"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=0,  # all layers MoE
    vocab_size=151936,
    head_dim=128,
    moe_num_experts=60,
    moe_top_k=4,
    moe_num_shared=4,
    moe_d_ff=1408,
    rope_theta=1e6,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b-reduced",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=256,
        head_dim=16,
        moe_num_experts=6,
        moe_top_k=2,
        moe_num_shared=1,
        moe_d_ff=48,
        vocab_pad_multiple=8,
    )
