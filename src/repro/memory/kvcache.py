"""RainbowKVCache: the paper's two-tier page management applied to KV caches.

Mapping (DESIGN.md §2): per-sequence KV is stored in a *capacity pool* (the NVM
analogue — host DRAM on a real deployment) at superblock granularity; hot KV
blocks are cached in a small *hot pool* (the DRAM analogue — HBM). A residency
bitmap + remap table (core.remap) redirect block reads; superblocks are never
re-laid-out, so promotion/demotion never touches the block table (the
"no-splinter / no-shootdown" property).

"Access" = attention mass a block receives during decode (strictly more precise
than the paper's post-LLC reference counts — adaptation note 3). Two-stage
counting (core.counting) runs at superblock then block granularity; admission is
the utility test (core.migration) with (HBM bw, host-link bw) timings.

The pure-JAX read path realizes translation as ONE gather into a virtually
concatenated [capacity ++ hot] pool — the TPU-idiomatic form of Fig. 6's
indirection. kernels/rainbow_attention implements the same recurrence tiled.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import counting, migration
from repro.core.migration import TimingParams, preset_timing
from repro.core.remap import RemapState, remap_init, translate
from repro.engine.policy import ControlPolicy, get_policy
from repro.utils import pytree_dataclass, static_field


@pytree_dataclass(init=False)
class PagedConfig:
    """Layer-B cache config: ControlPolicy + block-pool geometry.

    The interval-controller knobs (`hot_slots`, `top_n`, `max_promotions`,
    `interval_steps`, ...) live on `policy` — the same ControlPolicy surface
    Layer A's RainbowConfig composes and engine.autotune searches over. The
    pre-redesign flat kwargs are kept as deprecation shims (init kwargs +
    read-only properties), so `PagedConfig(hot_slots=8, ...)` and
    `dataclasses.replace(pcfg, interval_steps=2)` keep working.
    """

    block_size: int = static_field(default=16)  # tokens per block (4KB-page analogue)
    blocks_per_seq: int = static_field(default=512)  # blocks per superblock run
    quantize: bool = static_field(default=False)  # int8 pools + bf16 scales
                                                  # (beyond-paper §Perf A3)
    policy: ControlPolicy = static_field(default=None)

    def __init__(
        self,
        block_size: int = 16,
        blocks_per_seq: int = 512,
        hot_slots: int | None = None,
        top_n: int | None = None,
        max_promotions: int | None = None,
        interval_steps: int | None = None,
        quantize: bool = False,
        policy: ControlPolicy | str | None = None,
    ):
        if policy is None:
            policy = get_policy("serving-default")
        elif isinstance(policy, str):
            policy = get_policy(policy)
        legacy = {
            "hot_slots": hot_slots,
            "top_n": top_n,
            "max_promotions": max_promotions,
            "interval_steps": interval_steps,
        }
        overrides = {k: v for k, v in legacy.items() if v is not None}
        if overrides:
            policy = dataclasses.replace(policy, **overrides)
        object.__setattr__(self, "block_size", block_size)
        object.__setattr__(self, "blocks_per_seq", blocks_per_seq)
        object.__setattr__(self, "quantize", quantize)
        object.__setattr__(self, "policy", policy.validate("PagedConfig"))
        self.validate()

    def validate(self) -> "PagedConfig":
        """Reject impossible serving geometries loudly (satellite fix) — the
        old flat config let these flow into engine.control and silently
        miscount (e.g. stage-2 monitor rows wider than the superblock)."""
        pol = self.policy
        if self.block_size < 1 or self.blocks_per_seq < 1:
            raise ValueError(
                "PagedConfig: block_size and blocks_per_seq must be >= 1 "
                f"(got {self.block_size}, {self.blocks_per_seq})"
            )
        if pol.top_n > self.blocks_per_seq:
            # Conservative guard: top_n counts monitored stage-2 units
            # (sequences), and each monitor row carries blocks_per_seq
            # counters — a top_n beyond the per-sequence block count is
            # almost always a swapped or mistyped knob, so fail loudly.
            raise ValueError(
                f"PagedConfig: top_n ({pol.top_n}) > blocks_per_seq "
                f"({self.blocks_per_seq}) — each stage-2 monitor row holds "
                "blocks_per_seq counters; a monitor table wider than one "
                "superblock's block count is a mis-sized config (shrink "
                "top_n or pass a larger blocks_per_seq)"
            )
        if pol.max_promotions > pol.hot_slots:
            raise ValueError(
                f"PagedConfig: max_promotions ({pol.max_promotions}) > "
                f"hot_slots ({pol.hot_slots}) — one interval can never admit "
                "more blocks than the hot pool holds"
            )
        return self

    # -- deprecation shims (old flat-knob surface) --------------------------

    @property
    def hot_slots(self) -> int:
        return self.policy.hot_slots

    @property
    def top_n(self) -> int:
        return self.policy.top_n

    @property
    def max_promotions(self) -> int:
        return self.policy.max_promotions

    @property
    def interval_steps(self) -> int:
        return self.policy.interval_steps


def default_timing() -> TimingParams:
    """The "v5e-serving" preset of core.migration.TIMING_PRESETS (ns-per-block
    HBM vs host-link costs) — one shared table with the simulator's machine
    model instead of a second hand-maintained copy."""
    return preset_timing("v5e-serving")


@pytree_dataclass
class RainbowKV:
    """Per-layer-stacked paged KV state for a decode batch.

    cap_k/cap_v: [L, B*blocks_per_seq, block, KVS, hd]  capacity pool
    hot_k/hot_v: [L, hot_slots, block, KVS, hd]         hot pool
    remap:       RemapState over (superblock=seq, page=block) — shared by layers
                 (hotness is measured summed over layers; per-layer remap is a
                 config away but multiplies table traffic for little gain)
    s1/s2:       two-stage counters (stage 1 per superblock, stage 2 per block)
    dram:        hot-pool slot manager (free/clean/dirty; KV blocks are clean)
    length:      int32 current sequence length (uniform across batch)
    step_in_interval: int32
    """

    cap_k: jax.Array
    cap_v: jax.Array
    hot_k: jax.Array
    hot_v: jax.Array
    remap: RemapState
    s1: counting.Stage1State
    s2: counting.Stage2State
    dram: migration.DramState
    threshold: jax.Array
    length: jax.Array
    step_in_interval: jax.Array


def paged_init(cfg, pcfg: PagedConfig, batch: int, tp: int, layers: int) -> RainbowKV:
    kvs = cfg.kv_store(tp)
    hd = cfg.head_dim
    dt = jnp.int8 if pcfg.quantize else jnp.dtype(cfg.dtype)
    nb = batch * pcfg.blocks_per_seq
    shape_cap = (layers, nb, pcfg.block_size, kvs, hd)
    shape_hot = (layers, pcfg.hot_slots, pcfg.block_size, kvs, hd)
    kv = RainbowKV(
        cap_k=jnp.zeros(shape_cap, dt),
        cap_v=jnp.zeros(shape_cap, dt),
        hot_k=jnp.zeros(shape_hot, dt),
        hot_v=jnp.zeros(shape_hot, dt),
        remap=remap_init(batch, pcfg.blocks_per_seq),
        s1=counting.stage1_init(batch),
        s2=counting.stage2_init(pcfg.top_n, pcfg.blocks_per_seq),
        dram=migration.dram_init(pcfg.hot_slots),
        threshold=jnp.asarray(pcfg.policy.threshold_init, jnp.float32),
        length=jnp.zeros((), jnp.int32),
        step_in_interval=jnp.zeros((), jnp.int32),
    )
    return kv


def paged_scales_init(pcfg: PagedConfig, batch: int, kvs: int, layers: int):
    """int8 mode: per-token, per-kv-head scale side pytree (1 fp32 per head_dim
    payload — 1/64 the pool bytes at hd=128 with fp32 scales)."""
    nb = batch * pcfg.blocks_per_seq
    return {
        "cap_k": jnp.zeros((layers, nb, pcfg.block_size, kvs), jnp.float32),
        "cap_v": jnp.zeros((layers, nb, pcfg.block_size, kvs), jnp.float32),
        "hot_k": jnp.zeros((layers, pcfg.hot_slots, pcfg.block_size, kvs), jnp.float32),
        "hot_v": jnp.zeros((layers, pcfg.hot_slots, pcfg.block_size, kvs), jnp.float32),
    }


def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: [..., hd] -> (int8[..., hd], scale[...]) per-channel symmetric."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) + 1e-8
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def paged_cache_specs(batch_axes="data", model_axis="model") -> RainbowKV:
    """PartitionSpec tree matching paged_init's structure (for pjit shardings).

    Capacity pools shard over the flattened (seq x block) dim (batch-major) and
    kv-head slots; hot pools shard kv-heads only (the hot set is a global
    resource); tables/counters are tiny and replicate.
    """
    from jax.sharding import PartitionSpec as P

    from repro.core.counting import Stage1State, Stage2State
    from repro.core.migration import DramState

    cap = P(None, batch_axes, None, model_axis, None)
    hot = P(None, None, None, model_axis, None)
    return RainbowKV(
        cap_k=cap, cap_v=cap, hot_k=hot, hot_v=hot,
        remap=RemapState(bitmap=P(None, None), remap=P(None, None)),
        s1=Stage1State(counts=P(None)),
        s2=Stage2State(psn=P(None), counts=P(None, None)),
        dram=DramState(*([P(None)] * 6)),
        threshold=P(), length=P(), step_in_interval=P(),
    )


def block_of(pcfg: PagedConfig, pos: jax.Array) -> jax.Array:
    return pos // pcfg.block_size


def append_token(
    kv: RainbowKV, pcfg: PagedConfig, layer_slice: None, k_new: jax.Array, v_new: jax.Array
) -> RainbowKV:
    """Write one token's K/V into the capacity pool (all layers at once).

    k_new/v_new: [L, B, KVS, hd]. New tokens go to their home capacity block —
    DRAM-preferred placement happens via promotion (fresh blocks are usually
    hot and get promoted at the next interval).
    """
    lyr, b, kvs, hd = k_new.shape
    pos = kv.length
    blk = pos // pcfg.block_size
    off = pos % pcfg.block_size
    seq_ids = jnp.arange(b)
    flat_block = seq_ids * pcfg.blocks_per_seq + blk  # [B]
    cap_k = kv.cap_k.at[:, flat_block, off].set(k_new.astype(kv.cap_k.dtype))
    cap_v = kv.cap_v.at[:, flat_block, off].set(v_new.astype(kv.cap_v.dtype))
    # Paper §III-E cases 1/2: writes to a migrated page must land on the fast
    # copy too, else reads through the remap see stale data. (We also keep the
    # capacity copy fresh, so evictions are always "clean" — KV blocks never
    # pay T_writeback; exactly the clean-list fast path the paper optimizes.)
    resident, slot = translate(kv.remap, seq_ids, jnp.full((b,), blk))
    slot_safe = jnp.where(resident, slot, kv.hot_k.shape[1])  # OOB -> dropped
    hot_k = kv.hot_k.at[:, slot_safe, off].set(
        k_new.astype(kv.hot_k.dtype), mode="drop"
    )
    hot_v = kv.hot_v.at[:, slot_safe, off].set(
        v_new.astype(kv.hot_v.dtype), mode="drop"
    )
    return _replace(kv, cap_k=cap_k, cap_v=cap_v, hot_k=hot_k, hot_v=hot_v)


def append_token_q8(
    kv: RainbowKV, pcfg: PagedConfig, scales: dict, k_new: jax.Array, v_new: jax.Array
) -> tuple[RainbowKV, dict]:
    """int8-mode append: quantize per (layer, seq, kv-head), write pools+scales."""
    lyr, b, kvs, hd = k_new.shape
    pos = kv.length
    blk = pos // pcfg.block_size
    off = pos % pcfg.block_size
    seq_ids = jnp.arange(b)
    flat_block = seq_ids * pcfg.blocks_per_seq + blk
    qk, sk = quantize_kv(k_new)
    qv, sv = quantize_kv(v_new)
    cap_k = kv.cap_k.at[:, flat_block, off].set(qk)
    cap_v = kv.cap_v.at[:, flat_block, off].set(qv)
    scales = dict(scales)
    scales["cap_k"] = scales["cap_k"].at[:, flat_block, off].set(sk)
    scales["cap_v"] = scales["cap_v"].at[:, flat_block, off].set(sv)
    # mirror writes into promoted blocks (paper case 1/2, as in append_token)
    resident, slot = translate(kv.remap, seq_ids, jnp.full((b,), blk))
    slot_safe = jnp.where(resident, slot, kv.hot_k.shape[1])
    hot_k = kv.hot_k.at[:, slot_safe, off].set(qk, mode="drop")
    hot_v = kv.hot_v.at[:, slot_safe, off].set(qv, mode="drop")
    scales["hot_k"] = scales["hot_k"].at[:, slot_safe, off].set(sk, mode="drop")
    scales["hot_v"] = scales["hot_v"].at[:, slot_safe, off].set(sv, mode="drop")
    return _replace(kv, cap_k=cap_k, cap_v=cap_v, hot_k=hot_k, hot_v=hot_v), scales


def promote_scales(scales: dict, pcfg: PagedConfig, plan, cand_sp, cand_pg) -> dict:
    """Mirror end_interval_promote's block copies on the scale side pytree."""
    src = jnp.where(plan.migrate, cand_sp * pcfg.blocks_per_seq + cand_pg, 0).astype(jnp.int32)
    dst = jnp.where(plan.migrate, plan.dst_slot, pcfg.hot_slots).astype(jnp.int32)
    out = dict(scales)
    out["hot_k"] = scales["hot_k"].at[:, dst].set(scales["cap_k"][:, src], mode="drop")
    out["hot_v"] = scales["hot_v"].at[:, dst].set(scales["cap_v"][:, src], mode="drop")
    return out


def _replace(kv: RainbowKV, **kw) -> RainbowKV:
    return dataclasses.replace(kv, **kw)


def gather_layer_kv(
    kv: RainbowKV, pcfg: PagedConfig, layer: jax.Array, batch: int
) -> tuple[jax.Array, jax.Array]:
    """Translated read of one layer's KV: [B, blocks_per_seq, block, KVS, hd].

    Single-gather translation: virtual pool = capacity ++ hot; resident blocks
    redirect to num_cap + slot (Fig. 6 cases via one indirection).
    """
    nb = batch * pcfg.blocks_per_seq
    blocks = jnp.arange(pcfg.blocks_per_seq)
    seqs = jnp.arange(batch)
    sp = seqs[:, None].repeat(pcfg.blocks_per_seq, 1)
    pg = blocks[None, :].repeat(batch, 0)
    resident, slot = translate(kv.remap, sp, pg)
    home = (sp * pcfg.blocks_per_seq + pg).astype(jnp.int32)
    vidx = jnp.where(resident, nb + slot, home)  # [B, blocks_per_seq]

    pool_k = jnp.concatenate([kv.cap_k[layer], kv.hot_k[layer]], axis=0)
    pool_v = jnp.concatenate([kv.cap_v[layer], kv.hot_v[layer]], axis=0)
    return pool_k[vidx], pool_v[vidx]


def quantize_mass(mass: jax.Array) -> jax.Array:
    """Attention mass -> uint32 access counts for the 15-bit counters.

    THE single quantization of Layer B's access stream: observe_block_mass
    counts with it and engine.autotune's replay prices the same counts, so the
    tuner's cost model scores exactly the stream the controller sees.
    """
    return jnp.clip(mass * 64.0, 0, 1024).astype(jnp.uint32)


def observe_block_mass(
    kv: RainbowKV, pcfg: PagedConfig, mass: jax.Array
) -> RainbowKV:
    """Record per-block attention mass for this decode step.

    mass: float32[B, blocks_per_seq] — summed softmax mass per KV block
    (aggregated over layers/heads by the caller). Quantized to integer counts
    for the paper's 15-bit counters; the floor of 1 keeps every monitored
    block's counter warm. NOTE: this deliberately CHANGES the pre-refactor
    accounting, which computed the extra weight as uint32 `(q - 1).clip(0)` —
    at q = 0 that underflows to 2^32-1 and saturated zero-mass blocks straight
    to "definitely hot", letting cold blocks win promotions. max(q, 1) is the
    intended semantics.
    """
    b, nblk = mass.shape
    q = quantize_mass(mass)
    seq_ids = jnp.arange(b, dtype=jnp.int32)
    s1 = counting.stage1_record_weighted(kv.s1, seq_ids, q.sum(axis=1))
    # stage 2: only monitored superblocks count at block grain, mass-weighted
    flat_sp = seq_ids[:, None].repeat(nblk, 1).reshape(-1)
    flat_pg = jnp.arange(nblk, dtype=jnp.int32)[None].repeat(b, 0).reshape(-1)
    s2 = counting.stage2_record_weighted(
        kv.s2, flat_sp, flat_pg, jnp.maximum(q.reshape(-1), 1)
    )
    return _replace(kv, s1=s1, s2=s2, step_in_interval=kv.step_in_interval + 1)


def end_interval_promote(
    kv: RainbowKV, pcfg: PagedConfig, timing: TimingParams | None = None
) -> tuple[RainbowKV, dict]:
    """Close the interval: pick hot blocks (two-stage), admit into the hot pool
    (utility test), copy block payloads, update remap.

    Layer B's end-interval IS the engine controller: candidate extraction,
    Eq. 1/2 admission, remap evict+install, threshold adaptation, and monitor
    rotation all run through repro.engine.control (the same code Layer A's
    rainbow.end_interval composes); only the block payload copy onto the KV
    pools is serving-specific.
    """
    from repro.engine import control

    timing = timing or default_timing()
    b = kv.s1.counts.shape[0]
    # the controller instance comes straight from the unified policy surface
    ctrl = pcfg.policy.control_config(
        num_units=b, pages_per_unit=pcfg.blocks_per_seq
    )
    reads = counting.counter_value(kv.s2.counts)
    # never promote blocks beyond the current sequence length
    out_of_range = (
        jnp.arange(pcfg.blocks_per_seq, dtype=jnp.int32)[None, :]
        > (kv.length // pcfg.block_size)
    )
    out = control.plan_and_apply(
        ctrl, reads, jnp.zeros_like(reads), kv.s2.psn,
        kv.remap, kv.dram, kv.threshold, timing, now=jnp.int32(0),
        extra_exclude=jnp.broadcast_to(out_of_range, reads.shape),
    )
    plan, cand_sp, cand_pg = out.plan, out.cand_sp, out.cand_page

    # ---- block payload copies (the block_gather kernel's reference path) ----
    src = jnp.where(
        plan.migrate, cand_sp * pcfg.blocks_per_seq + cand_pg, 0
    ).astype(jnp.int32)
    # invalid lanes scatter out of bounds and are dropped (no slot-0 races)
    dst = jnp.where(plan.migrate, plan.dst_slot, pcfg.hot_slots).astype(jnp.int32)
    gathered_k = kv.cap_k[:, src]  # [L, K, block, KVS, hd]
    gathered_v = kv.cap_v[:, src]
    hot_k = kv.hot_k.at[:, dst].set(gathered_k, mode="drop")
    hot_v = kv.hot_v.at[:, dst].set(gathered_v, mode="drop")

    s1, new_psn, dram = control.rotate_monitors(ctrl, kv.s1, out.dram)
    new = _replace(
        kv,
        hot_k=hot_k, hot_v=hot_v, remap=out.remap, dram=dram,
        s1=s1,
        s2=counting.stage2_begin(new_psn, pcfg.blocks_per_seq),
        threshold=out.threshold,
        step_in_interval=jnp.zeros((), jnp.int32),
    )
    return new, {"promoted": out.n_migrated, "evicted": out.n_evicted,
                 "plan": plan, "cand_sp": cand_sp, "cand_pg": cand_pg}
