"""Migration bitmap + set-associative bitmap cache (Rainbow §III-D).

The bitmap marks, per 4 KB small page (or per KV block in Layer B), whether the page
has been migrated to the performance tier. Packed 32 pages / uint32 word.

The BitmapCache models the paper's 4000-entry, 8-way SRAM cache in the memory
controller (272 KB total: 4 B PSN tag + 512-bit bitmap per entry, 9-cycle latency).
Layer A uses it to charge translation-path cycles; Layer B does not need it (see
DESIGN.md §2, hardware-adaptation note 2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils import pytree_dataclass


def bitmap_init(num_superpages: int, pages_per_sp: int) -> jax.Array:
    words = (pages_per_sp + 31) // 32
    return jnp.zeros((num_superpages, words), jnp.uint32)


def bitmap_get(bitmap: jax.Array, sp: jax.Array, page: jax.Array) -> jax.Array:
    """Vectorized test of migration flags. sp/page may be any matching shape."""
    word = bitmap[sp, page >> 5]
    return ((word >> (page & 31).astype(jnp.uint32)) & 1).astype(jnp.bool_)


def _segment_or(idx: jax.Array, mask: jax.Array, size: int) -> jax.Array:
    """OR together uint32 masks sharing the same index -> dense [size] array.

    Sorts by index, ORs within runs via associative scan, and scatters the last
    (fully-accumulated) element of each run with .max (safe: one nonzero per index).
    """
    order = jnp.argsort(idx)
    sidx = idx[order]
    smask = mask[order]

    def combine(a, b):
        ia, ma = a
        ib, mb = b
        return ib, jnp.where(ia == ib, ma | mb, mb)

    _, acc = jax.lax.associative_scan(combine, (sidx, smask))
    is_last = jnp.concatenate([sidx[1:] != sidx[:-1], jnp.ones((1,), jnp.bool_)])
    contrib = jnp.where(is_last, acc, jnp.uint32(0))
    return jnp.zeros((size,), jnp.uint32).at[sidx].max(contrib, mode="drop")


def bitmap_update(
    bitmap: jax.Array, sp: jax.Array, page: jax.Array, value: bool
) -> jax.Array:
    """Set (value=True) or clear (value=False) the given (sp, page) positions.

    Duplicates are safe; entries with sp < 0 are dropped.
    """
    valid = sp >= 0
    words = bitmap.shape[1]
    sp_ = jnp.where(valid, sp, 0)
    mask = (jnp.uint32(1) << (page & 31).astype(jnp.uint32)).astype(jnp.uint32)
    mask = jnp.where(valid, mask, jnp.uint32(0))
    fidx = (sp_ * words + (page >> 5)).astype(jnp.int32)
    flat = bitmap.reshape(-1)
    ored = _segment_or(fidx, mask, flat.shape[0])
    out = (flat | ored) if value else (flat & ~ored)
    return out.reshape(bitmap.shape)


def bitmap_popcount(bitmap: jax.Array) -> jax.Array:
    """Number of migrated pages per superpage."""
    return jax.lax.population_count(bitmap).sum(axis=-1).astype(jnp.int32)


@pytree_dataclass
class BitmapCache:
    """8-way set-associative cache of per-superpage bitmaps (Layer A cost model).

    tags: int32[sets, ways] physical superpage number (-1 invalid)
    lru:  int32[sets, ways] last-touch timestamp
    """

    tags: jax.Array
    lru: jax.Array


def bitmap_cache_init(entries: int = 4000, ways: int = 8) -> BitmapCache:
    sets = max(1, entries // ways)
    return BitmapCache(
        tags=jnp.full((sets, ways), -1, jnp.int32),
        lru=jnp.zeros((sets, ways), jnp.int32),
    )


def bitmap_cache_lookup(
    cache: BitmapCache, psn: jax.Array, now: jax.Array
) -> tuple[BitmapCache, jax.Array]:
    """Single-access lookup+fill with LRU replacement. Returns (cache', hit)."""
    sets = cache.tags.shape[0]
    s = (psn % sets).astype(jnp.int32)
    line = cache.tags[s]
    hit_way = line == psn
    hit = hit_way.any()
    victim = jnp.argmin(cache.lru[s])
    way = jnp.where(hit, jnp.argmax(hit_way), victim).astype(jnp.int32)
    tags = cache.tags.at[s, way].set(psn.astype(jnp.int32))
    lru = cache.lru.at[s, way].set(now.astype(jnp.int32))
    return BitmapCache(tags=tags, lru=lru), hit


def storage_overhead_bytes(entries: int = 4000, pages_per_sp: int = 512) -> int:
    """Paper: 4 B PSN + 512-bit bitmap per entry -> 272 KB for 4000 entries."""
    return entries * (4 + pages_per_sp // 8)
