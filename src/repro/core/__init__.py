"""Rainbow core: the paper's contribution as a composable JAX module.

Two-stage access counting (§III-B), utility-based migration with free/clean/dirty
slot management (§III-C), migration bitmap + bitmap cache (§III-D), split TLBs and
NVM->DRAM address remapping (§III-E), composed by RainbowController (§III-A).
"""
from repro.core import bitmap, counting, migration, rainbow, remap, tlb
from repro.core.counting import (
    Stage1State,
    Stage2State,
    select_top_n,
    stage1_init,
    stage1_record,
    stage2_begin,
    stage2_init,
    stage2_record,
    two_stage_interval,
)
from repro.core.migration import (
    DramState,
    MigrationPlan,
    TimingParams,
    adapt_threshold,
    dram_init,
    make_timing,
    migration_benefit,
    plan_migrations,
    swap_benefit,
)
from repro.core.rainbow import (
    RainbowConfig,
    RainbowState,
    end_interval,
    observe,
    rainbow_init,
    translate_accesses,
)
from repro.core.remap import RemapState, remap_evict, remap_init, remap_install, translate
from repro.core.tlb import SplitTLB, TLBState, split_tlb_init, split_tlb_lookup, tlb_init, tlb_lookup
