"""Two-stage memory access counting (Rainbow §III-B), vectorized in JAX.

Stage 1: per-superpage saturating counters (2 bytes each in hardware; we model the
15-bit value + 1-bit overflow layout of Fig. 4 exactly, stored as uint16).

Stage 2: for the top-N hot superpages selected at the end of an interval, per-4KB-page
(or per-KV-block) counters inside each monitored superpage — a (N, pages_per_sp) table
plus the 4-byte PSN tag per row (Fig. 4).

Both stages are pure scatter-adds, so the same code drives:
  * Layer A (the zsim/NVMain-style simulator) with physical-address traces, and
  * Layer B (the serving runtime) with KV-block access streams emitted by attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.utils import pytree_dataclass, static_field

COUNTER_MAX = (1 << 15) - 1  # 15-bit value field
OVERFLOW_BIT = jnp.uint16(1 << 15)  # 1-bit overflow flag (=> "definitely hot")


@pytree_dataclass
class Stage1State:
    """Per-superpage access counters for one interval."""

    counts: jax.Array  # uint16[num_superpages] — 15-bit value + overflow bit


@pytree_dataclass
class Stage2State:
    """Fine-grained counters for the top-N monitored superpages."""

    psn: jax.Array  # int32[N] physical superpage number per row (-1 = unused)
    counts: jax.Array  # uint16[N, pages_per_sp]


def stage1_init(num_superpages: int) -> Stage1State:
    return Stage1State(counts=jnp.zeros((num_superpages,), jnp.uint16))


def stage2_init(top_n: int, pages_per_sp: int) -> Stage2State:
    return Stage2State(
        psn=jnp.full((top_n,), -1, jnp.int32),
        counts=jnp.zeros((top_n, pages_per_sp), jnp.uint16),
    )


def _saturating_add_u16(counts: jax.Array, idx: jax.Array, inc: jax.Array) -> jax.Array:
    """Scatter-add with 15-bit saturation + sticky overflow bit (Fig. 4 layout)."""
    add = jnp.zeros(counts.shape, jnp.uint32).at[idx].add(
        inc.astype(jnp.uint32), mode="drop"
    )
    return saturating_merge(counts, add)


def counter_value(counts: jax.Array) -> jax.Array:
    """Effective hotness: overflowed counters are 'definitely hot' (paper §III-B)."""
    val = (counts & jnp.uint16(COUNTER_MAX)).astype(jnp.int32)
    ovf = (counts & OVERFLOW_BIT) != 0
    return jnp.where(ovf, jnp.int32(COUNTER_MAX + 1), val)


def saturating_merge(counts: jax.Array, hist: jax.Array) -> jax.Array:
    """Fold a pre-reduced uint32 histogram into 15-bit+overflow counters.

    This is the back half of `_saturating_add_u16` — the engine's fused counting
    kernel (kernels/page_counter) produces the batch histogram in one device
    pass; merging it here is bit-identical to the scatter-add path because the
    scatter path also reduces the batch in uint32 before saturating once.
    """
    val = (counts & jnp.uint16(COUNTER_MAX)).astype(jnp.uint32)
    ovf = counts & OVERFLOW_BIT
    new = val + hist.astype(jnp.uint32)
    new_ovf = ovf | jnp.where(new > COUNTER_MAX, OVERFLOW_BIT, jnp.uint16(0))
    return jnp.minimum(new, COUNTER_MAX).astype(jnp.uint16) | new_ovf


def stage1_record(
    state: Stage1State,
    superpage_ids: jax.Array,  # int32[B] superpage index per access (<0 = ignore)
    is_write: jax.Array,  # bool[B]
    write_weight: int = 2,
) -> Stage1State:
    """Count one batch of NVM accesses at superpage granularity.

    NVM writes carry a higher weight than reads (paper: "NVM write operations have a
    higher weighting of the counter value").
    """
    weight = jnp.where(is_write, write_weight, 1).astype(jnp.uint32)
    return stage1_record_weighted(state, superpage_ids, weight)


def stage1_record_weighted(
    state: Stage1State,
    superpage_ids: jax.Array,  # int32[B] superpage index per access (<0 = ignore)
    weight: jax.Array,  # uint32[B] per-lane increment (0 = inert lane)
) -> Stage1State:
    """Count one batch at superpage granularity with explicit per-lane weights
    (Layer B feeds quantized attention mass here)."""
    valid = superpage_ids >= 0
    inc = jnp.where(valid, weight.astype(jnp.uint32), 0)
    idx = jnp.where(valid, superpage_ids, 0)
    # mode="drop" + zeroed increments keeps invalid lanes inert.
    return Stage1State(counts=_saturating_add_u16(state.counts, idx, inc))


def select_top_n(state: Stage1State, top_n: int) -> tuple[jax.Array, jax.Array]:
    """End-of-interval: pick the top-N hot superpages (paper step (2)).

    Returns (psn[int32[N]], counts[int32[N]]); rows with zero accesses get psn=-1.
    """
    hotness = counter_value(state.counts)
    k = min(top_n, hotness.shape[0])
    vals, idx = jax.lax.top_k(hotness, k)
    psn = jnp.where(vals > 0, idx.astype(jnp.int32), -1)
    if k < top_n:  # fewer superpages than monitor rows: pad with empty rows
        pad = top_n - k
        psn = jnp.concatenate([psn, jnp.full((pad,), -1, jnp.int32)])
        vals = jnp.concatenate([vals, jnp.zeros((pad,), vals.dtype)])
    return psn, vals


def stage2_begin(psn: jax.Array, pages_per_sp: int) -> Stage2State:
    """Start fine-grained monitoring of the selected superpages."""
    return Stage2State(
        psn=psn.astype(jnp.int32),
        counts=jnp.zeros((psn.shape[0], pages_per_sp), jnp.uint16),
    )


def _psn_to_slot(psn_table: jax.Array, superpage_ids: jax.Array) -> jax.Array:
    """Map each access's superpage id to its monitor row (-1 if unmonitored).

    O(B·N) compare — N is small (paper: N=100), so this is a cheap, fully
    vectorizable analogue of the hardware CAM lookup.
    """
    eq = superpage_ids[:, None] == psn_table[None, :]  # [B, N]
    eq &= psn_table[None, :] >= 0
    any_hit = eq.any(axis=1)
    slot = jnp.argmax(eq, axis=1).astype(jnp.int32)
    return jnp.where(any_hit, slot, -1)


def stage2_record_weighted(
    state: Stage2State,
    superpage_ids: jax.Array,  # int32[B] (<0 = ignore)
    page_offsets: jax.Array,  # int32[B] small-page index within superpage
    weight: jax.Array,  # uint32[B] per-lane increment (0 = inert lane)
) -> Stage2State:
    """Count accesses in monitored superpages at small-page grain, with an
    explicit per-lane weight. Read/write separation is expressed by the caller's
    weights (e.g. `~is_write` for a read counter) rather than index masking."""
    slot = _psn_to_slot(state.psn, superpage_ids)
    valid = slot >= 0
    n, p = state.counts.shape
    flat_idx = jnp.where(valid, slot * p + page_offsets, 0)
    inc = jnp.where(valid, weight.astype(jnp.uint32), 0)
    flat = _saturating_add_u16(state.counts.reshape(-1), flat_idx, inc)
    return Stage2State(psn=state.psn, counts=flat.reshape(n, p))


def stage2_record(
    state: Stage2State,
    superpage_ids: jax.Array,  # int32[B]
    page_offsets: jax.Array,  # int32[B] small-page index within superpage
    is_write: jax.Array,  # bool[B]
    write_weight: int = 2,
) -> Stage2State:
    """Count accesses that fall inside monitored superpages at small-page grain."""
    weight = jnp.where(is_write, write_weight, 1).astype(jnp.uint32)
    return stage2_record_weighted(state, superpage_ids, page_offsets, weight)


def stage2_split_rw(
    state_reads: Stage2State, state_writes: Stage2State
) -> tuple[jax.Array, jax.Array]:
    """Convenience: effective read/write counts for the utility model (Eq. 1)."""
    return counter_value(state_reads.counts), counter_value(state_writes.counts)


@functools.partial(jax.jit, static_argnames=("top_n", "pages_per_sp", "write_weight"))
def two_stage_interval(
    superpage_ids: jax.Array,
    page_offsets: jax.Array,
    is_write: jax.Array,
    num_superpages: int | None = None,
    *,
    top_n: int,
    pages_per_sp: int,
    write_weight: int = 2,
):
    """One full monitoring interval over a trace batch: stage 1 -> top-N -> stage 2.

    The paper runs stage 1 on interval k and stage 2 on interval k+1 (history-based).
    This helper applies both to the same batch, which is the variant used by the
    serving runtime where access streams are stationary within an interval; the
    simulator (Layer A) drives the two stages across intervals explicitly.
    """
    if num_superpages is None:
        raise ValueError("num_superpages is required")
    s1 = stage1_record(
        stage1_init(num_superpages), superpage_ids, is_write, write_weight
    )
    psn, sp_counts = select_top_n(s1, top_n)
    s2 = stage2_begin(psn, pages_per_sp)
    s2 = stage2_record(s2, superpage_ids, page_offsets, is_write, write_weight)
    return s1, psn, sp_counts, s2


def storage_overhead_bytes(
    num_superpages: int, top_n: int, pages_per_sp: int
) -> dict[str, int]:
    """Table VI storage model: SRAM bytes for counters + monitor table."""
    return {
        "stage1_counters": num_superpages * 2,
        "stage2_psn_tags": top_n * 4,
        "stage2_counters": top_n * pages_per_sp * 2,
    }
