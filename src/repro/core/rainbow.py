"""RainbowController: the paper's memory-controller + OS modules as one JAX pytree.

Composes the pieces of §III into a single functional controller:

  observe(accesses)  -> stage-1 superpage counting, stage-2 small-page counting for
                        the currently-monitored hot superpages, DRAM-tier counter
                        updates (for Eq. 2 victims).
  end_interval()     -> top-N hot-superpage selection (next interval's monitor set),
                        hot-page classification, utility-admission (Eq. 1/2) against
                        the free/clean/dirty slot manager, remap/bitmap install and
                        evict, adaptive threshold update.
  interval_step()    -> observe + end_interval fused into one scannable function:
                        `engine.simloop` runs a whole simulation as a single
                        lax.scan over these steps.

Both the Layer-A simulator and the Layer-B serving runtime drive this control
loop; the phase bodies live once, in `repro.engine.control`, and only the
meaning of "access" differs (post-LLC memory reference vs KV-block read).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import counting, migration
from repro.core.counting import Stage1State, Stage2State
from repro.core.migration import DramState, MigrationPlan, TimingParams
from repro.core.remap import RemapState
from repro.utils import pytree_dataclass, static_field

if TYPE_CHECKING:  # runtime import would cycle (see _control_cfg)
    from repro.engine.policy import ControlPolicy


@pytree_dataclass(init=False)
class RainbowConfig:
    """Layer-A controller config: ControlPolicy + superpage geometry.

    The controller knobs live on ONE surface (`engine.policy.ControlPolicy`);
    this config only adds what is specific to the simulator's address space.
    The pre-redesign flat knobs (`top_n`, `dram_slots`, `write_weight`,
    `max_migrations_per_interval`, `counter_backend`) are kept as
    deprecation-shim init kwargs + read-only properties, so existing call
    sites (and `dataclasses.replace` on them) keep working.
    """

    num_superpages: int = static_field(default=1024)
    pages_per_sp: int = static_field(default=512)
    policy: "ControlPolicy" = static_field(default=None)

    def __init__(
        self,
        num_superpages: int = 1024,
        pages_per_sp: int = 512,
        top_n: int | None = None,
        dram_slots: int | None = None,
        write_weight: int | None = None,
        max_migrations_per_interval: int | None = None,
        counter_backend: str | None = None,
        policy=None,
    ):
        from repro.engine.policy import ControlPolicy

        if policy is None:
            # paper §IV-F defaults (N = 100); interval_steps = 1: Layer A
            # closes the controller once per trace chunk
            policy = ControlPolicy(
                interval_steps=1, top_n=100, max_promotions=512,
                hot_slots=4096, write_weight=2,
            )
        legacy = {
            "top_n": top_n,
            "hot_slots": dram_slots,
            "write_weight": write_weight,
            "max_promotions": max_migrations_per_interval,
            "counter_backend": counter_backend,
        }
        overrides = {k: v for k, v in legacy.items() if v is not None}
        if overrides:
            policy = dataclasses.replace(policy, **overrides)
        object.__setattr__(self, "num_superpages", num_superpages)
        object.__setattr__(self, "pages_per_sp", pages_per_sp)
        object.__setattr__(self, "policy", policy.validate("RainbowConfig"))
        self.validate()

    def validate(self) -> "RainbowConfig":
        if self.num_superpages < 1 or self.pages_per_sp < 1:
            raise ValueError(
                "RainbowConfig: num_superpages and pages_per_sp must be >= 1 "
                f"(got {self.num_superpages}, {self.pages_per_sp})"
            )
        return self

    # -- deprecation shims (old flat-knob surface) --------------------------

    @property
    def top_n(self) -> int:
        return self.policy.top_n

    @property
    def dram_slots(self) -> int:
        return self.policy.hot_slots

    @property
    def write_weight(self) -> int:
        return self.policy.write_weight

    @property
    def max_migrations_per_interval(self) -> int:
        return self.policy.max_promotions

    @property
    def counter_backend(self) -> str:
        return self.policy.counter_backend


@pytree_dataclass
class RainbowState:
    s1: Stage1State
    s2_reads: Stage2State
    s2_writes: Stage2State
    dram: DramState
    remap: RemapState
    threshold: jax.Array  # float32 adaptive admission threshold
    interval: jax.Array  # int32 interval counter
    evictions_last: jax.Array  # int32 bidirectional-traffic monitor
    # Cumulative totals are int32 DELIBERATELY: JAX disables x64 by default, so
    # an int64 request would silently produce int32 anyway (with a warning) and
    # make the scan-carry dtype depend on global config. int32 wraps only after
    # 2^31 migrated pages (~8 TB of 4 KB traffic) — far beyond any simulated
    # horizon here. Revisit alongside jax_enable_x64 if that ever changes.
    migrations_total: jax.Array  # int32 cumulative pages migrated in
    evictions_total: jax.Array  # int32 cumulative pages evicted


class IntervalReport(NamedTuple):
    plan: MigrationPlan
    cand_sp: jax.Array
    cand_page: jax.Array
    n_migrated: jax.Array
    n_evicted: jax.Array
    n_dirty_evicted: jax.Array
    threshold: jax.Array


def _control_cfg(cfg: RainbowConfig):
    # Lazy import: repro.core.__init__ imports this module eagerly, and
    # engine.control imports repro.core leaf modules — a module-level import
    # here would cycle on first import of either package.
    from repro.engine import control

    return control, cfg.policy.control_config(
        num_units=cfg.num_superpages, pages_per_unit=cfg.pages_per_sp
    )


def rainbow_init(cfg: RainbowConfig, threshold: float | None = None) -> RainbowState:
    """Fresh controller state; `threshold` defaults to the policy's
    threshold_init (the explicit argument remains as an override shim)."""
    from repro.core import remap as remap_mod

    if threshold is None:
        threshold = cfg.policy.threshold_init
    return RainbowState(
        s1=counting.stage1_init(cfg.num_superpages),
        s2_reads=counting.stage2_init(cfg.top_n, cfg.pages_per_sp),
        s2_writes=counting.stage2_init(cfg.top_n, cfg.pages_per_sp),
        dram=migration.dram_init(cfg.dram_slots),
        remap=remap_mod.remap_init(cfg.num_superpages, cfg.pages_per_sp),
        threshold=jnp.asarray(threshold, jnp.float32),
        interval=jnp.zeros((), jnp.int32),
        evictions_last=jnp.zeros((), jnp.int32),
        migrations_total=jnp.zeros((), jnp.int32),
        evictions_total=jnp.zeros((), jnp.int32),
    )


def observe(
    cfg: RainbowConfig,
    st: RainbowState,
    sp: jax.Array,  # int32[B] superpage id per access
    page: jax.Array,  # int32[B] small page within superpage
    is_write: jax.Array,  # bool[B]
    now: jax.Array,  # int32 logical time (for LRU)
) -> RainbowState:
    """Record one batch of accesses. Accesses to migrated pages are DRAM-tier hits
    (counted on the slot for Eq. 2); the rest are NVM-tier (stage-1/2 counting)."""
    control, ctrl = _control_cfg(cfg)
    s1, s2r, s2w, dram = control.observe_tiers(
        ctrl, st.s1, st.s2_reads, st.s2_writes, st.dram, st.remap,
        sp, page, is_write, now,
    )
    return dataclasses.replace(st, s1=s1, s2_reads=s2r, s2_writes=s2w, dram=dram)


def plan_interval(cfg: RainbowConfig, st: RainbowState, timing: TimingParams):
    """First half of end_interval: classify hot pages + admit migrations.

    Returns the control.plan_and_apply outcome (plan, remap', dram',
    threshold', counts). Split out so the per-phase profiler
    (engine.profile) can time the planning cost separately; end_interval
    composes plan_interval + apply_interval unchanged.
    """
    control, ctrl = _control_cfg(cfg)
    reads, writes = counting.stage2_split_rw(st.s2_reads, st.s2_writes)
    return control.plan_and_apply(
        ctrl, reads, writes, st.s2_reads.psn,
        st.remap, st.dram, st.threshold, timing, now=st.interval,
    )


def apply_interval(
    cfg: RainbowConfig, st: RainbowState, out
) -> tuple[RainbowState, IntervalReport]:
    """Second half of end_interval: rotate monitors + commit controller state."""
    control, ctrl = _control_cfg(cfg)
    s1, new_psn, dram = control.rotate_monitors(ctrl, st.s1, out.dram)

    new_st = dataclasses.replace(
        st,
        s1=s1,
        s2_reads=counting.stage2_begin(new_psn, cfg.pages_per_sp),
        s2_writes=counting.stage2_begin(new_psn, cfg.pages_per_sp),
        dram=dram,
        remap=out.remap,
        threshold=out.threshold,
        interval=st.interval + 1,
        evictions_last=out.n_evicted,
        migrations_total=st.migrations_total + out.n_migrated,
        evictions_total=st.evictions_total + out.n_evicted,
    )
    report = IntervalReport(
        plan=out.plan,
        cand_sp=out.cand_sp,
        cand_page=out.cand_page,
        n_migrated=out.n_migrated,
        n_evicted=out.n_evicted,
        n_dirty_evicted=out.n_dirty,
        threshold=out.threshold,
    )
    return new_st, report


def end_interval(
    cfg: RainbowConfig, st: RainbowState, timing: TimingParams
) -> tuple[RainbowState, IntervalReport]:
    """Close the interval: classify hot pages, admit migrations, rotate monitors."""
    return apply_interval(cfg, st, plan_interval(cfg, st, timing))


def interval_step(
    cfg: RainbowConfig,
    st: RainbowState,
    sp: jax.Array,
    page: jax.Array,
    is_write: jax.Array,
    timing: TimingParams,
) -> tuple[RainbowState, IntervalReport]:
    """One full monitoring interval (observe batch + end_interval), scannable.

    `jax.lax.scan(lambda st, tr: interval_step(cfg, st, *tr, timing), st, chunks)`
    runs an entire simulation device-resident — this is the EngineStep used by
    engine.simloop's rainbow policy program.
    """
    st = observe(cfg, st, sp, page, is_write, st.interval)
    return end_interval(cfg, st, timing)


def translate_accesses(
    st: RainbowState, sp: jax.Array, page: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Public vectorized translation (Fig. 6 outcome): (in_fast_tier, slot)."""
    from repro.core import remap as remap_mod

    return remap_mod.translate(st.remap, sp, page)
