"""RainbowController: the paper's memory-controller + OS modules as one JAX pytree.

Composes the pieces of §III into a single functional controller:

  observe(accesses)  -> stage-1 superpage counting, stage-2 small-page counting for
                        the currently-monitored hot superpages, DRAM-tier counter
                        updates (for Eq. 2 victims).
  end_interval()     -> top-N hot-superpage selection (next interval's monitor set),
                        hot-page classification, utility-admission (Eq. 1/2) against
                        the free/clean/dirty slot manager, remap/bitmap install and
                        evict, adaptive threshold update.

Both the Layer-A simulator and the Layer-B serving runtime drive this controller;
only the meaning of "access" differs (post-LLC memory reference vs KV-block read).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import counting, migration, remap as remap_mod
from repro.core.counting import Stage1State, Stage2State
from repro.core.migration import DramState, MigrationPlan, TimingParams
from repro.core.remap import RemapState
from repro.utils import pytree_dataclass, static_field


@pytree_dataclass
class RainbowConfig:
    num_superpages: int = static_field(default=1024)
    pages_per_sp: int = static_field(default=512)
    top_n: int = static_field(default=100)  # paper §IV-F: N = 100
    dram_slots: int = static_field(default=4096)
    write_weight: int = static_field(default=2)
    max_migrations_per_interval: int = static_field(default=512)


@pytree_dataclass
class RainbowState:
    s1: Stage1State
    s2_reads: Stage2State
    s2_writes: Stage2State
    dram: DramState
    remap: RemapState
    threshold: jax.Array  # float32 adaptive admission threshold
    interval: jax.Array  # int32 interval counter
    evictions_last: jax.Array  # int32 bidirectional-traffic monitor
    migrations_total: jax.Array  # int64 cumulative pages migrated in
    evictions_total: jax.Array  # int64 cumulative pages evicted


class IntervalReport(NamedTuple):
    plan: MigrationPlan
    cand_sp: jax.Array
    cand_page: jax.Array
    n_migrated: jax.Array
    n_evicted: jax.Array
    n_dirty_evicted: jax.Array
    threshold: jax.Array


def rainbow_init(cfg: RainbowConfig, threshold: float = 0.0) -> RainbowState:
    return RainbowState(
        s1=counting.stage1_init(cfg.num_superpages),
        s2_reads=counting.stage2_init(cfg.top_n, cfg.pages_per_sp),
        s2_writes=counting.stage2_init(cfg.top_n, cfg.pages_per_sp),
        dram=migration.dram_init(cfg.dram_slots),
        remap=remap_mod.remap_init(cfg.num_superpages, cfg.pages_per_sp),
        threshold=jnp.asarray(threshold, jnp.float32),
        interval=jnp.zeros((), jnp.int32),
        evictions_last=jnp.zeros((), jnp.int32),
        migrations_total=jnp.zeros((), jnp.int32),
        evictions_total=jnp.zeros((), jnp.int32),
    )


def observe(
    cfg: RainbowConfig,
    st: RainbowState,
    sp: jax.Array,  # int32[B] superpage id per access
    page: jax.Array,  # int32[B] small page within superpage
    is_write: jax.Array,  # bool[B]
    now: jax.Array,  # int32 logical time (for LRU)
) -> RainbowState:
    """Record one batch of accesses. Accesses to migrated pages are DRAM-tier hits
    (counted on the slot for Eq. 2); the rest are NVM-tier (stage-1/2 counting)."""
    in_dram, slot = remap_mod.translate(st.remap, sp, page)
    nvm_sp = jnp.where(in_dram, -1, sp)

    s1 = counting.stage1_record(st.s1, nvm_sp, is_write, cfg.write_weight)
    s2r = counting.stage2_record(
        st.s2_reads, jnp.where(is_write, -1, nvm_sp), page, is_write * 0 > 0, 1
    )
    s2w = counting.stage2_record(
        st.s2_writes, jnp.where(is_write, nvm_sp, -1), page, is_write, 1
    )
    dram = migration.dram_record_access(
        st.dram, jnp.where(in_dram, slot, -1), is_write, now
    )
    return RainbowState(
        s1=s1,
        s2_reads=s2r,
        s2_writes=s2w,
        dram=dram,
        remap=st.remap,
        threshold=st.threshold,
        interval=st.interval,
        evictions_last=st.evictions_last,
        migrations_total=st.migrations_total,
        evictions_total=st.evictions_total,
    )


def end_interval(
    cfg: RainbowConfig, st: RainbowState, timing: TimingParams
) -> tuple[RainbowState, IntervalReport]:
    """Close the interval: classify hot pages, admit migrations, rotate monitors."""
    # ---- Hot-page candidates from stage-2 counters (monitored superpages). ----
    reads = counting.counter_value(st.s2_reads.counts).astype(jnp.float32)
    writes = counting.counter_value(st.s2_writes.counts).astype(jnp.float32)
    n, p = reads.shape
    psn = st.s2_reads.psn  # monitor rows (-1 unused)

    flat_sp = jnp.repeat(psn, p)
    flat_page = jnp.tile(jnp.arange(p, dtype=jnp.int32), n)
    flat_r = reads.reshape(-1)
    flat_w = writes.reshape(-1)

    # Keep the K best candidates to bound the plan size (K = max migrations).
    k = cfg.max_migrations_per_interval
    score = migration.migration_benefit(flat_r, flat_w, timing)
    score = jnp.where(flat_sp >= 0, score, -jnp.inf)
    # Exclude pages already resident in DRAM.
    already, _ = remap_mod.translate(
        st.remap, jnp.maximum(flat_sp, 0), flat_page
    )
    score = jnp.where(already & (flat_sp >= 0), -jnp.inf, score)
    _, top_idx = jax.lax.top_k(score, min(k, score.shape[0]))
    cand_sp = jnp.where(score[top_idx] > -jnp.inf, flat_sp[top_idx], -1)
    cand_page = flat_page[top_idx]
    cand_r = flat_r[top_idx]
    cand_w = flat_w[top_idx]

    # ---- Utility admission against the slot manager (Eq. 1/2). ----
    plan = migration.plan_migrations(
        cand_sp, cand_page, cand_r, cand_w, st.dram, timing, st.threshold
    )
    dram = migration.dram_apply_plan(st.dram, plan, cand_sp, cand_page, st.interval)

    # ---- Remap/bitmap maintenance: evict first, then install. ----
    rm = remap_mod.remap_evict(st.remap, plan.evict_sp, plan.evict_page)
    rm = remap_mod.remap_install(
        rm,
        jnp.where(plan.migrate, cand_sp, -1),
        cand_page,
        plan.dst_slot,
    )

    n_migrated = plan.migrate.sum().astype(jnp.int32)
    n_evicted = (plan.evict_sp >= 0).sum().astype(jnp.int32)
    n_dirty = plan.evict_dirty.sum().astype(jnp.int32)

    # ---- Adaptive threshold from bidirectional traffic (§III-C). ----
    threshold = migration.adapt_threshold(st.threshold, n_evicted)

    # ---- Rotate monitors: next interval watches this interval's top-N. ----
    new_psn, _ = counting.select_top_n(st.s1, cfg.top_n)
    new_st = RainbowState(
        s1=counting.stage1_init(cfg.num_superpages),
        s2_reads=counting.stage2_begin(new_psn, cfg.pages_per_sp),
        s2_writes=counting.stage2_begin(new_psn, cfg.pages_per_sp),
        dram=migration.dram_new_interval(dram),
        remap=rm,
        threshold=threshold,
        interval=st.interval + 1,
        evictions_last=n_evicted,
        migrations_total=st.migrations_total + n_migrated.astype(jnp.int32),
        evictions_total=st.evictions_total + n_evicted.astype(jnp.int32),
    )
    report = IntervalReport(
        plan=plan,
        cand_sp=cand_sp,
        cand_page=cand_page,
        n_migrated=n_migrated,
        n_evicted=n_evicted,
        n_dirty_evicted=n_dirty,
        threshold=threshold,
    )
    return new_st, report


def translate_accesses(
    st: RainbowState, sp: jax.Array, page: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Public vectorized translation (Fig. 6 outcome): (in_fast_tier, slot)."""
    return remap_mod.translate(st.remap, sp, page)
