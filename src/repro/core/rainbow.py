"""RainbowController: the paper's memory-controller + OS modules as one JAX pytree.

Composes the pieces of §III into a single functional controller:

  observe(accesses)  -> stage-1 superpage counting, stage-2 small-page counting for
                        the currently-monitored hot superpages, DRAM-tier counter
                        updates (for Eq. 2 victims).
  end_interval()     -> top-N hot-superpage selection (next interval's monitor set),
                        hot-page classification, utility-admission (Eq. 1/2) against
                        the free/clean/dirty slot manager, remap/bitmap install and
                        evict, adaptive threshold update.
  interval_step()    -> observe + end_interval fused into one scannable function:
                        `engine.simloop` runs a whole simulation as a single
                        lax.scan over these steps.

Both the Layer-A simulator and the Layer-B serving runtime drive this control
loop; the phase bodies live once, in `repro.engine.control`, and only the
meaning of "access" differs (post-LLC memory reference vs KV-block read).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import counting, migration
from repro.core.counting import Stage1State, Stage2State
from repro.core.migration import DramState, MigrationPlan, TimingParams
from repro.core.remap import RemapState
from repro.utils import pytree_dataclass, static_field


@pytree_dataclass
class RainbowConfig:
    num_superpages: int = static_field(default=1024)
    pages_per_sp: int = static_field(default=512)
    top_n: int = static_field(default=100)  # paper §IV-F: N = 100
    dram_slots: int = static_field(default=4096)
    write_weight: int = static_field(default=2)
    max_migrations_per_interval: int = static_field(default=512)
    # Counting backend: "jax" (saturating scatter-adds) or the fused one-pass
    # kernel under kernels/page_counter ("ref" oracle / "pallas" TPU kernel /
    # "interpret" Pallas-interpret). All are bit-identical; see engine.control.
    counter_backend: str = static_field(default="jax")


@pytree_dataclass
class RainbowState:
    s1: Stage1State
    s2_reads: Stage2State
    s2_writes: Stage2State
    dram: DramState
    remap: RemapState
    threshold: jax.Array  # float32 adaptive admission threshold
    interval: jax.Array  # int32 interval counter
    evictions_last: jax.Array  # int32 bidirectional-traffic monitor
    # Cumulative totals are int32 DELIBERATELY: JAX disables x64 by default, so
    # an int64 request would silently produce int32 anyway (with a warning) and
    # make the scan-carry dtype depend on global config. int32 wraps only after
    # 2^31 migrated pages (~8 TB of 4 KB traffic) — far beyond any simulated
    # horizon here. Revisit alongside jax_enable_x64 if that ever changes.
    migrations_total: jax.Array  # int32 cumulative pages migrated in
    evictions_total: jax.Array  # int32 cumulative pages evicted


class IntervalReport(NamedTuple):
    plan: MigrationPlan
    cand_sp: jax.Array
    cand_page: jax.Array
    n_migrated: jax.Array
    n_evicted: jax.Array
    n_dirty_evicted: jax.Array
    threshold: jax.Array


def _control_cfg(cfg: RainbowConfig):
    # Lazy import: repro.core.__init__ imports this module eagerly, and
    # engine.control imports repro.core leaf modules — a module-level import
    # here would cycle on first import of either package.
    from repro.engine import control

    return control, control.ControlConfig(
        num_units=cfg.num_superpages,
        pages_per_unit=cfg.pages_per_sp,
        top_n=cfg.top_n,
        max_moves=cfg.max_migrations_per_interval,
        write_weight=cfg.write_weight,
        counter_backend=cfg.counter_backend,
    )


def rainbow_init(cfg: RainbowConfig, threshold: float = 0.0) -> RainbowState:
    from repro.core import remap as remap_mod

    return RainbowState(
        s1=counting.stage1_init(cfg.num_superpages),
        s2_reads=counting.stage2_init(cfg.top_n, cfg.pages_per_sp),
        s2_writes=counting.stage2_init(cfg.top_n, cfg.pages_per_sp),
        dram=migration.dram_init(cfg.dram_slots),
        remap=remap_mod.remap_init(cfg.num_superpages, cfg.pages_per_sp),
        threshold=jnp.asarray(threshold, jnp.float32),
        interval=jnp.zeros((), jnp.int32),
        evictions_last=jnp.zeros((), jnp.int32),
        migrations_total=jnp.zeros((), jnp.int32),
        evictions_total=jnp.zeros((), jnp.int32),
    )


def observe(
    cfg: RainbowConfig,
    st: RainbowState,
    sp: jax.Array,  # int32[B] superpage id per access
    page: jax.Array,  # int32[B] small page within superpage
    is_write: jax.Array,  # bool[B]
    now: jax.Array,  # int32 logical time (for LRU)
) -> RainbowState:
    """Record one batch of accesses. Accesses to migrated pages are DRAM-tier hits
    (counted on the slot for Eq. 2); the rest are NVM-tier (stage-1/2 counting)."""
    control, ctrl = _control_cfg(cfg)
    s1, s2r, s2w, dram = control.observe_tiers(
        ctrl, st.s1, st.s2_reads, st.s2_writes, st.dram, st.remap,
        sp, page, is_write, now,
    )
    return dataclasses.replace(st, s1=s1, s2_reads=s2r, s2_writes=s2w, dram=dram)


def end_interval(
    cfg: RainbowConfig, st: RainbowState, timing: TimingParams
) -> tuple[RainbowState, IntervalReport]:
    """Close the interval: classify hot pages, admit migrations, rotate monitors."""
    control, ctrl = _control_cfg(cfg)
    reads, writes = counting.stage2_split_rw(st.s2_reads, st.s2_writes)
    out = control.plan_and_apply(
        ctrl, reads, writes, st.s2_reads.psn,
        st.remap, st.dram, st.threshold, timing, now=st.interval,
    )
    s1, new_psn, dram = control.rotate_monitors(ctrl, st.s1, out.dram)

    new_st = dataclasses.replace(
        st,
        s1=s1,
        s2_reads=counting.stage2_begin(new_psn, cfg.pages_per_sp),
        s2_writes=counting.stage2_begin(new_psn, cfg.pages_per_sp),
        dram=dram,
        remap=out.remap,
        threshold=out.threshold,
        interval=st.interval + 1,
        evictions_last=out.n_evicted,
        migrations_total=st.migrations_total + out.n_migrated,
        evictions_total=st.evictions_total + out.n_evicted,
    )
    report = IntervalReport(
        plan=out.plan,
        cand_sp=out.cand_sp,
        cand_page=out.cand_page,
        n_migrated=out.n_migrated,
        n_evicted=out.n_evicted,
        n_dirty_evicted=out.n_dirty,
        threshold=out.threshold,
    )
    return new_st, report


def interval_step(
    cfg: RainbowConfig,
    st: RainbowState,
    sp: jax.Array,
    page: jax.Array,
    is_write: jax.Array,
    timing: TimingParams,
) -> tuple[RainbowState, IntervalReport]:
    """One full monitoring interval (observe batch + end_interval), scannable.

    `jax.lax.scan(lambda st, tr: interval_step(cfg, st, *tr, timing), st, chunks)`
    runs an entire simulation device-resident — this is the EngineStep used by
    engine.simloop's rainbow policy program.
    """
    st = observe(cfg, st, sp, page, is_write, st.interval)
    return end_interval(cfg, st, timing)


def translate_accesses(
    st: RainbowState, sp: jax.Array, page: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Public vectorized translation (Fig. 6 outcome): (in_fast_tier, slot)."""
    from repro.core import remap as remap_mod

    return remap_mod.translate(st.remap, sp, page)
