"""NVM->DRAM address remapping (Rainbow §III-E), as two-level block tables.

Paper mechanism: when a 4 KB page migrates, its DRAM destination address is written
into the first 8 bytes of its original NVM slot; a lookup that hits the superpage TLB
but misses the 4 KB TLB reads that pointer (one NVM read) instead of walking page
tables. The superpage is never splintered.

TPU-native realization (DESIGN.md adaptation note 1): the pointer lives in a side
table ``remap[superpage, page] -> performance-tier slot`` (-1 = not migrated). The
residency bitmap answers "is it migrated?" and the remap table answers "where?"; both
are tiny and stage into VMEM/SMEM inside kernels. Translation never touches payload.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bitmap import bitmap_get, bitmap_init, bitmap_update
from repro.utils import pytree_dataclass


@pytree_dataclass
class RemapState:
    """bitmap: uint32[num_sp, words]; remap: int32[num_sp, pages_per_sp]."""

    bitmap: jax.Array
    remap: jax.Array


def remap_init(num_superpages: int, pages_per_sp: int) -> RemapState:
    return RemapState(
        bitmap=bitmap_init(num_superpages, pages_per_sp),
        remap=jnp.full((num_superpages, pages_per_sp), -1, jnp.int32),
    )


def remap_install(
    st: RemapState, sp: jax.Array, page: jax.Array, slot: jax.Array
) -> RemapState:
    """Install migrated pages (vectorized; sp < 0 lanes dropped)."""
    valid = sp >= 0
    num_sp = st.remap.shape[0]
    sp_ = jnp.where(valid, sp, num_sp)  # OOB -> dropped (no index-0 races)
    remap = st.remap.at[sp_, page].set(slot.astype(jnp.int32), mode="drop")
    bitmap = bitmap_update(st.bitmap, sp, page, True)
    return RemapState(bitmap=bitmap, remap=remap)


def remap_evict(st: RemapState, sp: jax.Array, page: jax.Array) -> RemapState:
    """Remove mappings for evicted pages (vectorized; sp < 0 lanes dropped)."""
    valid = sp >= 0
    num_sp = st.remap.shape[0]
    sp_ = jnp.where(valid, sp, num_sp)
    remap = st.remap.at[sp_, page].set(jnp.int32(-1), mode="drop")
    bitmap = bitmap_update(st.bitmap, sp, page, False)
    return RemapState(bitmap=bitmap, remap=remap)


def translate(
    st: RemapState, sp: jax.Array, page: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Vectorized translation: returns (in_fast_tier[bool], slot[int32]).

    slot is the performance-tier slot when migrated, else -1 (data is at its
    home superpage location in the capacity tier).
    """
    migrated = bitmap_get(st.bitmap, sp, page)
    slot = jnp.where(migrated, st.remap[sp, page], -1)
    return migrated, slot


def check_consistency(st: RemapState) -> jax.Array:
    """Invariant: bitmap bit set <=> remap slot >= 0 (property-tested)."""
    num_sp, pages = st.remap.shape
    sp = jnp.arange(num_sp)[:, None].repeat(pages, 1)
    pg = jnp.arange(pages)[None, :].repeat(num_sp, 0)
    bits = bitmap_get(st.bitmap, sp, pg)
    return jnp.all(bits == (st.remap >= 0))
