"""Split TLB model (Rainbow §II-A / §III-E): set-associative, LRU, two page sizes.

Used by Layer A to simulate the 4 KB-page TLB and the 2 MB-superpage TLB (L1 + L2
levels per Table IV). Pure-functional: state threads through lax.scan over a trace.

A lookup consults L1 then L2; fills propagate L2 -> L1. The four translation cases of
Fig. 6 are composed in sim/policies.py from two of these TLBs plus the migration
bitmap + remap read.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils import pytree_dataclass, static_field


@pytree_dataclass
class TLBState:
    tags: jax.Array  # int64[sets, ways]; -1 invalid
    lru: jax.Array  # int32[sets, ways] last-touch time
    sets: int = static_field(default=1)
    ways: int = static_field(default=1)


def tlb_init(entries: int, ways: int) -> TLBState:
    sets = max(1, entries // ways)
    return TLBState(
        tags=jnp.full((sets, ways), -1, jnp.int32),
        lru=jnp.zeros((sets, ways), jnp.int32),
        sets=sets,
        ways=ways,
    )


def tlb_lookup(
    st: TLBState, vpn: jax.Array, now: jax.Array, fill: bool | jax.Array = True
) -> tuple[TLBState, jax.Array]:
    """One lookup (+ LRU fill on miss when fill=True). Returns (state', hit)."""
    vpn = vpn.astype(jnp.int32)
    s = (vpn % st.sets).astype(jnp.int32)
    line = st.tags[s]
    hit_way = line == vpn
    hit = hit_way.any()
    victim = jnp.argmin(st.lru[s])
    way = jnp.where(hit, jnp.argmax(hit_way), victim).astype(jnp.int32)
    do_write = hit | jnp.asarray(fill)
    tags = st.tags.at[s, way].set(jnp.where(do_write, vpn, st.tags[s, way]))
    lru = st.lru.at[s, way].set(
        jnp.where(do_write, now.astype(jnp.int32), st.lru[s, way])
    )
    return TLBState(tags=tags, lru=lru, sets=st.sets, ways=st.ways), hit


def tlb_invalidate(st: TLBState, vpn: jax.Array) -> TLBState:
    """Shootdown: invalidate one vpn if present (used on DRAM->NVM writeback)."""
    vpn = vpn.astype(jnp.int32)
    s = (vpn % st.sets).astype(jnp.int32)
    line = st.tags[s]
    tags = st.tags.at[s].set(jnp.where(line == vpn, jnp.int32(-1), line))
    return TLBState(tags=tags, lru=st.lru, sets=st.sets, ways=st.ways)


@pytree_dataclass
class SplitTLB:
    """Two-level split TLB: L1 + L2 for one page size (Table IV geometry)."""

    l1: TLBState
    l2: TLBState


def split_tlb_init(
    l1_entries: int, l1_ways: int, l2_entries: int, l2_ways: int
) -> SplitTLB:
    return SplitTLB(
        l1=tlb_init(l1_entries, l1_ways), l2=tlb_init(l2_entries, l2_ways)
    )


def split_tlb_lookup(
    st: SplitTLB, vpn: jax.Array, now: jax.Array, fill: bool | jax.Array = True
) -> tuple[SplitTLB, jax.Array, jax.Array]:
    """Returns (state', l1_hit, l2_hit). A hit at either level fills upward."""
    l1, h1 = tlb_lookup(st.l1, vpn, now, fill=False)
    l2, h2 = tlb_lookup(st.l2, vpn, now, fill=fill)
    # Fill L1 on L1-miss when the translation was obtained (L2 hit or walk+fill).
    do_l1_fill = (~h1) & (h2 | jnp.asarray(fill))
    l1b, _ = tlb_lookup(l1, vpn, now, fill=do_l1_fill)
    return SplitTLB(l1=l1b, l2=l2), h1, h2


def split_tlb_invalidate(st: SplitTLB, vpn: jax.Array) -> SplitTLB:
    return SplitTLB(l1=tlb_invalidate(st.l1, vpn), l2=tlb_invalidate(st.l2, vpn))


def _invalidate_tags_many(tags: jax.Array, vpns: jax.Array) -> jax.Array:
    """tags with every entry whose tag appears in `vpns` set to -1.

    The sequential shootdown only clears vpn inside its own set (vpn % sets),
    so the membership test is masked to entries whose tag maps to the row it
    sits in — on states the lookup path built the two are the same (a tag is
    only ever installed in its home set), but the equivalence must hold for
    ARBITRARY states (tests/test_hotpath.py fills sets adversarially).
    """
    sets = tags.shape[0]
    matched = (tags[:, :, None] == vpns[None, None, :]).any(-1)
    home_row = (tags % sets) == jnp.arange(sets, dtype=tags.dtype)[:, None]
    return jnp.where(matched & home_row, jnp.int32(-1), tags)


def split_tlb_invalidate_many(st: SplitTLB, vpns: jax.Array) -> SplitTLB:
    """Batch shootdown of a vpn list (vectorized; -1 lanes are no-ops).

    Invalidation only ever writes -1 where tag == vpn and never touches lru,
    so folding the per-vpn sequential loop into one broadcast membership test
    per level is order-independent and idempotent — bit-identical to scanning
    `split_tlb_invalidate` over the list (duplicates and -1 padding lanes
    included; pinned by tests/test_hotpath.py). Shared by the engine's
    shootdown step and the eager oracle's Policy._invalidate_4k.
    """
    vpns = vpns.astype(jnp.int32)
    return SplitTLB(
        l1=TLBState(
            tags=_invalidate_tags_many(st.l1.tags, vpns),
            lru=st.l1.lru, sets=st.l1.sets, ways=st.l1.ways,
        ),
        l2=TLBState(
            tags=_invalidate_tags_many(st.l2.tags, vpns),
            lru=st.l2.lru, sets=st.l2.sets, ways=st.l2.ways,
        ),
    )
