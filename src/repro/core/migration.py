"""Utility-based hot-page migration (Rainbow §III-C) + DRAM list management.

Implements Eq. 1 / Eq. 2 of the paper, the adaptive migration-benefit threshold, and
the free/clean/dirty DRAM slot manager (HSCC-style three lists, realized here as a
per-slot state array with LRU ordering inside each class — functionally equivalent
and fully vectorizable).

All functions are pure; the controller state threads through jit/scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils import pytree_dataclass, static_field

FREE, CLEAN, DIRTY = 0, 1, 2


@pytree_dataclass
class TimingParams:
    """Table III parameters (cycles)."""

    t_nr: jax.Array  # NVM read latency
    t_nw: jax.Array  # NVM write latency
    t_dr: jax.Array  # DRAM read latency
    t_dw: jax.Array  # DRAM write latency
    t_mig: jax.Array  # cycles to migrate one page NVM -> DRAM
    t_writeback: jax.Array  # cycles to write a dirty DRAM page back to NVM


def _check_latency(name: str, value, *, positive: bool) -> None:
    """Reject malformed timing constants loudly at construction.

    Only concrete host scalars are checked (traced values pass through —
    every production caller builds TimingParams from python floats, outside
    any trace).
    """
    try:
        v = float(value)
    except (TypeError, ValueError):
        raise ValueError(
            f"timing parameter {name} must be a real number, got {value!r}"
        ) from None
    if v != v or v in (float("inf"), float("-inf")):
        raise ValueError(f"timing parameter {name} must be finite, got {v!r}")
    if positive and v <= 0:
        raise ValueError(
            f"timing parameter {name} must be positive, got {v!r}"
        )
    if not positive and v < 0:
        raise ValueError(
            f"timing parameter {name} must be non-negative, got {v!r}"
        )


def make_timing(
    t_nr: float, t_nw: float, t_dr: float, t_dw: float, t_mig: float, t_writeback: float
) -> TimingParams:
    for name, value in (("t_nr", t_nr), ("t_nw", t_nw),
                        ("t_dr", t_dr), ("t_dw", t_dw)):
        if not isinstance(value, jax.core.Tracer):
            _check_latency(name, value, positive=True)
    for name, value in (("t_mig", t_mig), ("t_writeback", t_writeback)):
        if not isinstance(value, jax.core.Tracer):
            _check_latency(name, value, positive=False)
    f = lambda x: jnp.asarray(x, jnp.float32)
    return TimingParams(f(t_nr), f(t_nw), f(t_dr), f(t_dw), f(t_mig), f(t_writeback))


# -- named hardware presets (THE single source of both timing tables) --------

#: Paper Table IV machine constants. sim.config re-exports these (CPU_GHZ /
#: PAGE_BYTES) so the clock and page size that build the preset latencies are
#: the same values the rest of the machine model derives from.
SIM_CPU_GHZ = 3.2  # cycles = ns * GHz
SIM_PAGE_BYTES = 4096
_SIM_PAGE_COST = (
    (SIM_PAGE_BYTES / 10.7e9) * 1e9 * SIM_CPU_GHZ * 2  # rd PCM + wr DRAM
)

#: Every hand-maintained latency table lives HERE, once. "paper-table4-sim" is
#: the simulator's machine model (cycles @ 3.2 GHz; MachineConfig's latency
#: defaults read these entries). "v5e-serving" is the serving cost model in
#: ns-per-block units (819 GB/s HBM vs ~50 GB/s host link; t_mig = one block
#: DMA + setup), consumed by memory.kvcache and engine.autotune.
TIMING_PRESETS: dict[str, dict[str, float]] = {
    "paper-table4-sim": {
        "t_nr": 19.5 * SIM_CPU_GHZ,  # PCM read   = 62.4
        "t_nw": 171.0 * SIM_CPU_GHZ,  # PCM write  = 547.2
        "t_dr": 13.5 * SIM_CPU_GHZ,  # DRAM read  = 43.2
        "t_dw": 28.5 * SIM_CPU_GHZ,  # DRAM write = 91.2
        "t_mig": _SIM_PAGE_COST,
        "t_writeback": _SIM_PAGE_COST,
    },
    "v5e-serving": {
        "t_nr": 100.0,
        "t_nw": 180.0,
        "t_dr": 8.0,
        "t_dw": 12.0,
        "t_mig": 400.0,
        "t_writeback": 400.0,
    },
}


_PRESET_KEYS = frozenset(
    {"t_nr", "t_nw", "t_dr", "t_dw", "t_mig", "t_writeback"}
)


def _validate_preset(name: str, entry) -> None:
    """Malformed preset dicts fail HERE with the preset named, not deep in
    the cost model (a bad entry used to flow silently into every latency)."""
    if not isinstance(entry, dict):
        raise ValueError(
            f"timing preset {name!r} must be a dict, got {type(entry).__name__}"
        )
    got = set(entry)
    if got != _PRESET_KEYS:
        missing, extra = sorted(_PRESET_KEYS - got), sorted(got - _PRESET_KEYS)
        raise ValueError(
            f"timing preset {name!r} has malformed keys "
            f"(missing={missing}, unexpected={extra})"
        )
    for key in ("t_nr", "t_nw", "t_dr", "t_dw"):
        _check_latency(f"{name}.{key}", entry[key], positive=True)
    for key in ("t_mig", "t_writeback"):
        _check_latency(f"{name}.{key}", entry[key], positive=False)


def preset_timing(name: str) -> TimingParams:
    """TimingParams for a named hardware preset (see TIMING_PRESETS)."""
    try:
        entry = TIMING_PRESETS[name]
    except KeyError:
        raise KeyError(
            f"unknown timing preset {name!r}; "
            f"available: {sorted(TIMING_PRESETS)}"
        ) from None
    _validate_preset(name, entry)
    return make_timing(**entry)


for _name, _entry in TIMING_PRESETS.items():  # built-ins checked at import
    _validate_preset(_name, _entry)
del _name, _entry


def migration_benefit(c_r: jax.Array, c_w: jax.Array, t: TimingParams) -> jax.Array:
    """Eq. 1: cycles saved by serving (C_r, C_w) from DRAM instead of NVM."""
    return (t.t_nr - t.t_dr) * c_r + (t.t_nw - t.t_dw) * c_w - t.t_mig


def swap_benefit(
    c_r_in: jax.Array,
    c_w_in: jax.Array,
    c_r_out: jax.Array,
    c_w_out: jax.Array,
    t: TimingParams,
    victim_dirty: jax.Array,
) -> jax.Array:
    """Eq. 2: benefit when migrating page p2 in requires evicting DRAM page p1.

    T_writeback applies only when the victim is dirty (clean evictions write back
    just the 8-byte remap pointer — §III-E — which we fold into T_mig noise).
    """
    wb = jnp.where(victim_dirty, t.t_writeback, 0.0)
    return (
        (t.t_nr - t.t_dr) * (c_r_in - c_r_out)
        + (t.t_nw - t.t_dw) * (c_w_in - c_w_out)
        - t.t_mig
        - wb
    )


@pytree_dataclass
class DramState:
    """Performance-tier slot manager (free/clean/dirty lists as a state array).

    slot_state:  int32[S] in {FREE, CLEAN, DIRTY}
    slot_sp:     int32[S] superpage of the cached page (-1 if free)
    slot_page:   int32[S] small-page index within that superpage
    slot_reads:  float32[S] accesses observed this interval (for Eq. 2 victims)
    slot_writes: float32[S]
    last_touch:  int32[S] LRU timestamp within class
    """

    slot_state: jax.Array
    slot_sp: jax.Array
    slot_page: jax.Array
    slot_reads: jax.Array
    slot_writes: jax.Array
    last_touch: jax.Array


def dram_init(num_slots: int) -> DramState:
    z = jnp.zeros((num_slots,), jnp.int32)
    return DramState(
        slot_state=z,
        slot_sp=jnp.full((num_slots,), -1, jnp.int32),
        slot_page=jnp.full((num_slots,), -1, jnp.int32),
        slot_reads=jnp.zeros((num_slots,), jnp.float32),
        slot_writes=jnp.zeros((num_slots,), jnp.float32),
        last_touch=z,
    )


def dram_record_access(
    d: DramState, slot: jax.Array, is_write: jax.Array, now: jax.Array
) -> DramState:
    """Record a batch of DRAM-tier accesses (slot < 0 lanes ignored)."""
    valid = slot >= 0
    s = jnp.where(valid, slot, 0)
    r_inc = jnp.where(valid & ~is_write, 1.0, 0.0)
    w_inc = jnp.where(valid & is_write, 1.0, 0.0)
    reads = d.slot_reads.at[s].add(r_inc)
    writes = d.slot_writes.at[s].add(w_inc)
    state = d.slot_state.at[s].max(jnp.where(valid & is_write, DIRTY, 0))
    touch = d.last_touch.at[s].max(jnp.where(valid, now, 0))
    return DramState(
        slot_state=state,
        slot_sp=d.slot_sp,
        slot_page=d.slot_page,
        slot_reads=reads,
        slot_writes=writes,
        last_touch=touch,
    )


@pytree_dataclass
class MigrationPlan:
    """Output of plan_migrations — aligned arrays of length K (num candidates).

    migrate:   bool[K]   candidate admitted
    dst_slot:  int32[K]  destination performance-tier slot (-1 if not migrated)
    evict_sp / evict_page: int32[K] previous occupant (-1 if the slot was free)
    evict_dirty: bool[K] previous occupant needs full writeback
    benefit:   float32[K] adjusted benefit used for the decision
    """

    migrate: jax.Array
    dst_slot: jax.Array
    evict_sp: jax.Array
    evict_page: jax.Array
    evict_dirty: jax.Array
    benefit: jax.Array


def plan_migrations(
    cand_sp: jax.Array,  # int32[K] candidate superpage ids (-1 = empty lane)
    cand_page: jax.Array,  # int32[K]
    cand_reads: jax.Array,  # float32[K] predicted next-interval reads (history)
    cand_writes: jax.Array,  # float32[K]
    dram: DramState,
    timing: TimingParams,
    threshold: jax.Array,
) -> MigrationPlan:
    """Admit candidates best-first into victims cheapest-first (free→clean→dirty).

    Mirrors the paper's policy: free and clean slots are consumed before any dirty
    eviction; within a class, victims are LRU. Candidate order is by Eq. 1 benefit
    descending so the hottest pages land on the cheapest slots.
    """
    k = cand_sp.shape[0]
    base_benefit = migration_benefit(cand_reads, cand_writes, timing)
    base_benefit = jnp.where(cand_sp >= 0, base_benefit, -jnp.inf)
    # Descending benefit via top_k over the full lane set: identical order to
    # the former stable argsort(-base_benefit) (top_k breaks ties lower-index
    # first, exactly like a stable ascending sort of the negation) and hands
    # back the sorted benefits for free, saving the post-sort gather.
    c_base, cand_order = jax.lax.top_k(base_benefit, k)

    # Victim preference: class priority then LRU. Exclude slots already caching a
    # candidate (cannot evict what we are about to install — caller dedupes).
    prio = dram.slot_state.astype(jnp.float32) * 1e9 + dram.last_touch.astype(
        jnp.float32
    )
    n_slots = dram.slot_state.shape[0]

    take = min(k, n_slots)
    # Partial selection: only the `take` cheapest victims are ever paired with
    # a candidate column, so top_k(-prio, take) replaces the full slot argsort
    # (prio >= 0, so the negation is exact; tie-break matches stable argsort).
    _, victim_idx = jax.lax.top_k(-prio, take)
    vslots = victim_idx.astype(jnp.int32)
    if k > take:  # pad victim columns up to k with -1 (static shapes)
        vslots = jnp.concatenate([vslots, jnp.full((k - take,), -1, jnp.int32)])

    v_valid = vslots >= 0
    vs = jnp.where(v_valid, vslots, 0)
    v_state = jnp.where(v_valid, dram.slot_state[vs], DIRTY)
    v_sp = jnp.where(v_valid, dram.slot_sp[vs], -1)
    v_page = jnp.where(v_valid, dram.slot_page[vs], -1)
    v_reads = jnp.where(v_valid, dram.slot_reads[vs], jnp.inf)
    v_writes = jnp.where(v_valid, dram.slot_writes[vs], jnp.inf)
    v_dirty = v_state == DIRTY
    v_free = v_state == FREE

    c_sp = cand_sp[cand_order]
    c_page = cand_page[cand_order]
    c_r = cand_reads[cand_order]
    c_w = cand_writes[cand_order]

    # Adjusted benefit: Eq. 1 into free slots, Eq. 2 against occupied victims.
    adj = jnp.where(
        v_free,
        c_base,
        swap_benefit(c_r, c_w, v_reads, v_writes, timing, v_dirty),
    )
    migrate = (adj > threshold) & (c_sp >= 0) & v_valid

    plan_sorted = MigrationPlan(
        migrate=migrate,
        dst_slot=jnp.where(migrate, vslots, -1),
        evict_sp=jnp.where(migrate & ~v_free, v_sp, -1),
        evict_page=jnp.where(migrate & ~v_free, v_page, -1),
        evict_dirty=migrate & ~v_free & v_dirty,
        benefit=adj,
    )
    # Un-sort back to caller's candidate order: the inverse of a permutation
    # is a conflict-free scatter (inv[order[i]] = i), no second sort needed.
    inv = (
        jnp.zeros((k,), cand_order.dtype)
        .at[cand_order]
        .set(jnp.arange(k, dtype=cand_order.dtype))
    )
    return jax.tree.map(lambda a: a[inv], plan_sorted)


def dram_apply_plan(
    d: DramState, plan: MigrationPlan, cand_sp: jax.Array, cand_page: jax.Array, now
) -> DramState:
    """Install migrated pages into their slots; reset per-interval counters."""
    valid = plan.migrate
    n = d.slot_state.shape[0]
    # invalid lanes go out of bounds and are DROPPED (never index 0: a real
    # write to slot 0 must not race a stale no-op write)
    slot = jnp.where(valid, plan.dst_slot, n)
    state = d.slot_state.at[slot].set(jnp.int32(CLEAN), mode="drop")
    sp = d.slot_sp.at[slot].set(cand_sp, mode="drop")
    page = d.slot_page.at[slot].set(cand_page, mode="drop")
    reads = d.slot_reads.at[slot].set(0.0, mode="drop")
    writes = d.slot_writes.at[slot].set(0.0, mode="drop")
    touch = d.last_touch.at[slot].set(jnp.asarray(now, jnp.int32), mode="drop")
    return DramState(state, sp, page, reads, writes, touch)


def dram_release(d: DramState, slots: jax.Array) -> DramState:
    """Free a batch of slots (transactional aborts rolling back an install).

    slots: int32[K], -1 lanes ignored. Freed slots go back to FREE with clean
    counters, exactly the dram_init shape, so a later plan can reuse them as
    the cheapest victim class.
    """
    valid = slots >= 0
    n = d.slot_state.shape[0]
    # invalid lanes out of bounds -> dropped (same idiom as dram_apply_plan)
    s = jnp.where(valid, slots, n)
    return DramState(
        slot_state=d.slot_state.at[s].set(jnp.int32(FREE), mode="drop"),
        slot_sp=d.slot_sp.at[s].set(-1, mode="drop"),
        slot_page=d.slot_page.at[s].set(-1, mode="drop"),
        slot_reads=d.slot_reads.at[s].set(0.0, mode="drop"),
        slot_writes=d.slot_writes.at[s].set(0.0, mode="drop"),
        last_touch=d.last_touch.at[s].set(0, mode="drop"),
    )


def dram_new_interval(d: DramState) -> DramState:
    """Zero the per-interval access counters (keep residency + dirty bits)."""
    return DramState(
        slot_state=d.slot_state,
        slot_sp=d.slot_sp,
        slot_page=d.slot_page,
        slot_reads=jnp.zeros_like(d.slot_reads),
        slot_writes=jnp.zeros_like(d.slot_writes),
        last_touch=d.last_touch,
    )


def adapt_threshold(
    threshold: jax.Array,
    evictions: jax.Array,
    *,
    up_per_eviction: float = 8.0,
    decay: float = 0.9,
    floor: float = 0.0,
    ceil: float = 1e6,
) -> jax.Array:
    """§III-C: raise the benefit threshold with bidirectional traffic, decay it back.

    'we monitor the data traffic of bidirectional page migrations, and dynamically
    increase the threshold of migration benefit to select hotter small pages.'
    """
    t = threshold * decay + up_per_eviction * evictions.astype(jnp.float32)
    return jnp.clip(t, floor, ceil)
