"""Fault-tolerant sharded checkpointing (no orbax): npz shards + manifest.

Design for 1000+ nodes (DESIGN.md §5):
  * each host writes only the shards it owns (`process_index` namespacing); this
    CPU build has one host but the layout/namespacing is multi-host ready;
  * writes are atomic: tmp dir -> fsync -> rename; a crash mid-save never
    corrupts the previous checkpoint;
  * restore is *elastic*: arrays are saved unsharded-logical (gathered per host
    range) with their PartitionSpec recorded, and restored under ANY mesh by
    re-sharding with jax.device_put — scaling from N to M pods is a restore;
  * manifest carries step, pytree structure, and a content checksum per leaf;
  * retention: keep_last N checkpoints, never deleting the newest complete one.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

MANIFEST = "manifest.json"


def _flatten_with_paths(tree: Any):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((key, leaf))
    return out


def save_state(
    directory: str,
    step: int,
    state: Any,
    keep_last: int = 3,
    process_index: int | None = None,
) -> str:
    """Atomically write `state` under directory/step_<N>/. Returns final path."""
    pid = process_index if process_index is not None else jax.process_index()
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + f".tmp.{pid}.{int(time.time() * 1e6)}"
    os.makedirs(tmp, exist_ok=True)

    leaves = _flatten_with_paths(state)
    arrays = {}
    manifest_leaves = {}
    for key, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        dtype_name = str(arr.dtype)
        if dtype_name == "bfloat16":  # npz can't hold ml_dtypes; store bits
            arr = arr.view(np.uint16)
        arrays[key] = arr
        manifest_leaves[key] = {
            "shape": list(arr.shape),
            "dtype": dtype_name,
            "crc": hashlib.sha256(arr.tobytes()).hexdigest()[:16],
        }
    shard_file = os.path.join(tmp, f"shard_{pid:05d}.npz")
    np.savez(shard_file, **{k.replace("/", "|"): v for k, v in arrays.items()})
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(
            {
                "step": step,
                "leaves": manifest_leaves,
                "num_processes": jax.process_count(),
                "time": time.time(),
            },
            f,
        )
    with open(os.path.join(tmp, MANIFEST)) as f:  # fsync via re-read barrier
        f.read()
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    _gc(directory, keep_last)
    return final


def _gc(directory: str, keep_last: int) -> None:
    steps = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.count(".tmp")
    )
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
    for d in os.listdir(directory):  # orphaned tmp dirs from crashes
        if ".tmp." in d:
            shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and ".tmp" not in d
        and os.path.exists(os.path.join(directory, d, MANIFEST))
    ]
    return max(steps) if steps else None


def restore_state(
    directory: str,
    like: Any,
    step: int | None = None,
    shardings: Any = None,
    verify: bool = True,
) -> tuple[Any, int]:
    """Restore into the structure of `like`; reshard onto `shardings` if given.

    Elastic: the checkpoint's sharding at save time is irrelevant — leaves are
    logical arrays, placed onto the *current* mesh via device_put.
    """
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)

    arrays: dict[str, np.ndarray] = {}
    for fname in sorted(os.listdir(path)):
        if fname.startswith("shard_") and fname.endswith(".npz"):
            with np.load(os.path.join(path, fname)) as z:
                for k in z.files:
                    arrays[k.replace("|", "/")] = z[k]

    if verify:
        for key, info in manifest["leaves"].items():
            if key not in arrays:
                raise ValueError(f"checkpoint missing leaf {key}")
            crc = hashlib.sha256(arrays[key].tobytes()).hexdigest()[:16]
            if crc != info["crc"]:
                raise ValueError(f"checksum mismatch for {key}")

    keys = [k for k, _ in _flatten_with_paths(like)]
    leaves_like, treedef = jax.tree.flatten(like)
    sh_leaves = (
        jax.tree.flatten(shardings)[0] if shardings is not None else [None] * len(keys)
    )
    out = []
    for key, proto, sh in zip(keys, leaves_like, sh_leaves):
        arr = arrays[key]
        saved_dtype = manifest["leaves"][key]["dtype"]
        if saved_dtype == "bfloat16":  # bit-reinterpret the stored uint16 view
            arr = arr.view(jnp.bfloat16.dtype)
        target_dtype = proto.dtype if hasattr(proto, "dtype") else arr.dtype
        a = jnp.asarray(arr).astype(target_dtype)
        out.append(jax.device_put(a, sh) if sh is not None else a)
    return treedef.unflatten(out), step


class CheckpointManager:
    """Train-loop helper: periodic + emergency (preemption) checkpointing."""

    def __init__(self, directory: str, every_steps: int = 100, keep_last: int = 3):
        self.directory = directory
        self.every = every_steps
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)

    def maybe_save(self, step: int, state: Any, force: bool = False) -> bool:
        if force or (step > 0 and step % self.every == 0):
            save_state(self.directory, step, state, self.keep_last)
            return True
        return False

    def restore_or_init(self, init_fn, shardings: Any = None):
        step = latest_step(self.directory)
        if step is None:
            return init_fn(), 0
        like = jax.eval_shape(init_fn)
        state, step = restore_state(self.directory, like, step, shardings)
        return state, step
