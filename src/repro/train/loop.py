"""Fault-tolerant training loop.

Large-scale runnability features (DESIGN.md §5), realized host-side:
  * auto-resume from the newest complete checkpoint (params+opt+data cursor);
  * preemption handling: SIGTERM/SIGINT trigger an emergency checkpoint before
    exit (maintenance events on real pods deliver exactly this signal);
  * step retry with straggler/timeout detection: a step exceeding
    `step_timeout_s` is logged as a straggler event; `max_retries` transient
    failures (e.g. ICI link flap surfacing as XlaRuntimeError) re-run the step
    from the last good state instead of killing the job;
  * elastic restart: restore_state reshards onto whatever mesh the relaunched
    job builds (checkpoint/store.py), so N->M pod scaling is a resume;
  * NaN guard: skips poisoned updates and counts them (data corruption on one
    host must not kill a 1000-node run).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Iterator

import jax
import numpy as np

from repro.checkpoint import CheckpointManager


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "checkpoints"
    log_every: int = 10
    step_timeout_s: float = 600.0
    max_retries: int = 3
    nan_guard: bool = True


class Trainer:
    def __init__(
        self,
        step_fn: Callable,  # (state, batch) -> (state, metrics); already jitted
        data: Iterator[dict[str, np.ndarray]],
        lcfg: LoopConfig,
        state_shardings: Any = None,
    ):
        self.step_fn = step_fn
        self.data = data
        self.lcfg = lcfg
        self.ckpt = CheckpointManager(
            lcfg.checkpoint_dir, lcfg.checkpoint_every, keep_last=3
        )
        self.state_shardings = state_shardings
        self._preempted = False
        self.events: list[dict[str, Any]] = []

    def _install_signals(self):
        def handler(signum, frame):
            self._preempted = True

        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # non-main thread (tests)

    def run(self, state: Any, start_step: int = 0) -> tuple[Any, list[dict]]:
        self._install_signals()
        lcfg = self.lcfg
        history = []
        step = start_step
        while step < lcfg.total_steps:
            batch = next(self.data)
            t0 = time.time()
            retries = 0
            while True:
                try:
                    new_state, metrics = self.step_fn(state, batch)
                    metrics = jax.device_get(metrics)
                    break
                except Exception as e:  # transient runtime failure -> retry
                    retries += 1
                    self.events.append(
                        {"step": step, "event": "retry", "error": repr(e)}
                    )
                    if retries > lcfg.max_retries:
                        self.ckpt.maybe_save(step, state, force=True)
                        raise
            dt = time.time() - t0
            if dt > lcfg.step_timeout_s:
                self.events.append(
                    {"step": step, "event": "straggler", "duration_s": dt}
                )

            loss = float(metrics.get("loss", np.nan))
            if self.lcfg.nan_guard and not np.isfinite(loss):
                self.events.append({"step": step, "event": "nan_skip"})
                step += 1
                continue  # drop the poisoned update, keep old state

            state = new_state
            history.append({"step": step, "loss": loss, "time_s": dt, **{
                k: float(np.asarray(v)) for k, v in metrics.items()
            }})
            if step % lcfg.log_every == 0:
                print(f"step {step} loss {loss:.4f} ({dt*1000:.0f} ms)")
            step += 1
            self.ckpt.maybe_save(step, state)
            if self._preempted:
                self.events.append({"step": step, "event": "preempted"})
                self.ckpt.maybe_save(step, state, force=True)
                break
        else:
            self.ckpt.maybe_save(step, state, force=True)
        return state, history
