"""Train-step builder: loss -> grads -> AdamW, with remat, grad accumulation,
mixed precision, and sharding specs for pjit.

The returned step is a pure function (state, batch) -> (state, metrics), ready for
jax.jit with donate_argnums=(0,) and the spec trees from train_state_specs().
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch.axes import BATCH_AXES
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_init, adamw_specs, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    tp: int = 1
    remat: str = "full"  # none | full | dots
    attn_impl: str = "dense"  # dense | chunked
    accum_steps: int = 1
    adamw: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


def init_train_state(cfg: ModelConfig, key, tcfg: TrainStepConfig) -> dict[str, Any]:
    params = M.init_params(cfg, key, tp=tcfg.tp)
    return {"params": params, "opt": adamw_init(params)}


def train_state_specs(
    cfg: ModelConfig, tcfg: TrainStepConfig, dp_size: int = 1
) -> dict[str, Any]:
    pspecs = M.param_specs(cfg, tp=tcfg.tp)
    shapes = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0), tcfg.tp))
    return {
        "params": pspecs,
        "opt": adamw_specs(pspecs, tcfg.adamw, param_shapes=shapes, dp_size=dp_size),
    }


def batch_specs(cfg: ModelConfig, batch_replicated: bool = False) -> dict[str, Any]:
    dp = None if batch_replicated else BATCH_AXES
    specs = {"tokens": P(dp, None), "targets": P(dp, None), "loss_mask": P(dp, None)}
    if cfg.family == "vlm":
        specs["vision_embeds"] = P(dp, None, None)
    if cfg.is_encoder_decoder:
        specs["frames"] = P(dp, None, None)
    return specs


def build_train_step(
    cfg: ModelConfig,
    tcfg: TrainStepConfig,
    sc=None,
    lr_schedule: Callable | None = None,
) -> Callable:
    def loss(params, batch):
        return M.loss_fn(
            cfg, params, batch, tp=tcfg.tp, sc=sc,
            attn_impl=tcfg.attn_impl, remat=tcfg.remat,
        )

    def grads_of(params, batch):
        if tcfg.accum_steps == 1:
            return jax.value_and_grad(loss)(params, batch)

        a = tcfg.accum_steps

        def micro(carry, mb):
            acc_loss, acc_g = carry
            l, g = jax.value_and_grad(loss)(params, mb)
            return (acc_loss + l, jax.tree.map(jnp.add, acc_g, g)), None

        micro_batches = jax.tree.map(
            lambda x: x.reshape(a, x.shape[0] // a, *x.shape[1:]), batch
        )
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (tl, tg), _ = jax.lax.scan(micro, (jnp.zeros((), jnp.float32), zero), micro_batches)
        return tl / a, jax.tree.map(lambda g: g / a, tg)

    def step(state, batch):
        l, grads = grads_of(state["params"], batch)
        lr = lr_schedule(state["opt"]["step"]) if lr_schedule else None
        new_params, new_opt, om = adamw_update(
            tcfg.adamw, grads, state["opt"], state["params"], lr=lr
        )
        metrics = {"loss": l, "lr": jnp.asarray(lr if lr is not None else tcfg.adamw.lr)}
        metrics.update(om)
        return {"params": new_params, "opt": new_opt}, metrics

    return step
