from repro.train.step import TrainStepConfig, batch_specs, build_train_step, init_train_state, train_state_specs
