"""Scenario registry: named, engine-consumable trace programs.

A `Scenario` binds one generator program (workloads.generators) to a name and
the trace metadata the fleet scheduler groups compiles by — the same four
keys `sim.trace.probe_meta` reports for the numpy app profiles, so scenario
cells group in `engine.fleet.plan_groups` exactly like app cells do.

Registered presets:

  syn/<app>        the 14 paper app profiles (Tables I/II) re-expressed as
                   ZipfHotspot programs: same footprint, access count,
                   hot-page fraction, zipf skew, write ratio, and CHOP 70%
                   hot-traffic rule — but generated on device, inside the
                   engine scan (engine.simloop fused mode)
  stress/*         scenario-space stressors the host generator never covered:
                   working-set drift, streaming scans, pointer chases, and an
                   interleaved mix of all three

Consumers:

  trace_program(name, accesses)   (setup, emit, meta) for the engine's fused
                                  in-scan generation (engine.simloop)
  materialize(name, seed, i)      one interval pulled to host numpy — the
                                  staged path / differential oracle
                                  (sim.trace.generate dispatches here)
  probe_meta(name, accesses)      compile-signature metadata, no generation

Registration is import-time only: `EngineSpec.source` carries just the
scenario *name* into the jit cache, so re-binding a name after compiles exist
would alias stale programs — the registry therefore rejects duplicates (and
names that shadow a numpy app profile or mix).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.sim.config import APPS, MIXES, SCALE_DOWN, AppProfile
from repro.sim.trace import HOT_TRAFFIC_FRACTION, _mb_to_pages
from repro.workloads.generators import (
    PAGES_PER_SP,
    InterleavedMix,
    PhaseShift,
    PointerChase,
    SequentialScan,
    ZipfHotspot,
    interval_key,
)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named trace program plus the metadata the engine compiles against."""

    name: str
    gen: object  # one of generators.GENERATOR_KINDS
    inst_per_access: float = 12.0

    def generator(self, accesses: int | None = None):
        """The program, resized to `accesses` per interval if requested."""
        if accesses is None:
            return self.gen
        return _with_accesses(self.gen, accesses)

    def probe_meta(self, accesses: int | None = None) -> dict:
        """Same keys as sim.trace.probe_meta — the compile signature."""
        gen = self.generator(accesses)
        fp = gen.footprint_pages
        return {
            "num_superpages": -(-fp // PAGES_PER_SP),
            "footprint_pages": fp,
            "inst_per_access": self.inst_per_access,
            "accesses_per_interval": gen.accesses,
        }


def _with_accesses(gen, accesses: int):
    """Resize a program's per-interval access count (mix: split per member,
    exactly as sim.trace.generate splits `accesses` across MIXES members)."""
    if isinstance(gen, InterleavedMix):
        per = accesses // len(gen.members)
        return dataclasses.replace(
            gen, members=tuple(_with_accesses(m, per) for m in gen.members)
        )
    return dataclasses.replace(gen, accesses=accesses)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_SCENARIOS: dict[str, Scenario] = {}


def register_scenario(obj):
    """Register a Scenario (directly, or as a decorator on a 0-arg factory).

    Names must be globally unique AND must not shadow a numpy app profile or
    mix — scenario names are first-class workload names (`sim.trace.generate`
    / `probe_meta` dispatch on them), so a collision would silently change
    which generator a SweepCell means.
    """
    scenario = obj() if not isinstance(obj, Scenario) else obj
    if not isinstance(scenario, Scenario):
        raise TypeError(f"register_scenario: expected a Scenario factory, "
                        f"got {scenario!r}")
    if scenario.name in _SCENARIOS:
        raise ValueError(f"scenario {scenario.name!r} is already registered")
    if scenario.name in APPS or scenario.name in MIXES:
        raise ValueError(
            f"scenario {scenario.name!r} shadows a numpy app profile/mix"
        )
    scenario.gen.validate()
    _SCENARIOS[scenario.name] = scenario
    return obj


def is_scenario(name: str) -> bool:
    return name in _SCENARIOS


def available_scenarios() -> tuple[str, ...]:
    return tuple(sorted(_SCENARIOS))


def get_scenario(name: str) -> Scenario:
    try:
        return _SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; registered: {available_scenarios()}"
        ) from None


def probe_meta(name: str, accesses: int | None = None) -> dict:
    return get_scenario(name).probe_meta(accesses)


# ---------------------------------------------------------------------------
# Engine + host consumers
# ---------------------------------------------------------------------------


def trace_program(name: str, accesses: int | None = None):
    """(setup, emit, meta) of one scenario, ready for the engine scan.

    setup(seed)        -> aux pytree (interval-invariant, one evaluation per
                          simulation, OUTSIDE the interval scan)
    emit(aux, seed, i) -> (page_idx int32[A], is_write bool[A]) for interval
                          i under fold_in(PRNGKey(seed), i)
    """
    scenario = get_scenario(name)
    gen = scenario.generator(accesses)
    gen.validate()
    meta = scenario.probe_meta(accesses)

    def setup(seed):
        return gen.setup(seed)

    def emit(aux, seed, interval):
        import jax.numpy as jnp

        interval = jnp.asarray(interval, jnp.int32)
        key = interval_key(seed, interval)
        pages, wr = gen.emit(aux, key, interval)
        return pages.astype(jnp.int32), wr

    return setup, emit, meta


@functools.lru_cache(maxsize=None)
def _materialize_fn(name: str, accesses: int | None):
    import jax

    setup, emit, meta = trace_program(name, accesses)

    @jax.jit
    def go(seed, interval):
        return emit(setup(seed), seed, interval)

    return go, meta


def materialize(name: str, seed: int, interval: int,
                accesses: int | None = None):
    """One interval of a scenario pulled to host numpy (the staged oracle).

    Runs the SAME jitted emit program the fused engine scan traces, so the
    returned arrays are bit-identical to what the in-scan generator feeds
    engine_step. Returns (page_idx, is_write, meta); the meta shapes are
    asserted against probe_meta so a scenario can never silently group under
    one compile signature and emit another.
    """
    import jax.numpy as jnp

    go, meta = _materialize_fn(name, accesses)
    pages, wr = go(jnp.int32(seed), jnp.int32(interval))
    pages, wr = np.asarray(pages), np.asarray(wr)
    if pages.shape != (meta["accesses_per_interval"],):
        raise ValueError(
            f"scenario {name!r} emitted {pages.shape} accesses but its "
            f"probe_meta promises {meta['accesses_per_interval']} — compile "
            "grouping would be corrupt"
        )
    return pages, wr, meta


# ---------------------------------------------------------------------------
# Presets
# ---------------------------------------------------------------------------


#: Table II bucket bounds: "% of superpages covered by N hot 4KB pages",
#: upper bounds 32/64/128/256/384/512 — the same rows sim.trace's numpy
#: sampler draws from, here rescaled to SCALE_DOWN'd pages for the device
#: generator (the numpy path divides each drawn count by SCALE_DOWN too).
_TABLE2_UPPERS = (32, 64, 128, 256, 384, 512)
_TABLE2_LOWERS = (1, 33, 65, 129, 257, 385)


def _table2_buckets(prof: AppProfile) -> tuple:
    return tuple(
        (float(w), max(1, lo // SCALE_DOWN), max(1, hi // SCALE_DOWN))
        for w, lo, hi in zip(prof.sp_hot_dist, _TABLE2_LOWERS, _TABLE2_UPPERS)
        if w > 0
    )


def _app_scenario(prof: AppProfile) -> Scenario:
    """A paper app profile as an on-device ZipfHotspot program.

    Footprint, per-interval access count, hot fraction, zipf skew, write
    ratio, the CHOP 70% hot-traffic rule, AND the Table-II hot-page-per-
    superpage clustering come straight from Tables I/II — the clustering via
    the generator's bucket sampler (sp_hot_buckets), so fig-1 calibration
    runs entirely on the device generators (the numpy profiles remain the
    independent cross-check; docs/workloads.md).
    """
    fp = _mb_to_pages(prof.footprint_mb)
    ws = min(_mb_to_pages(prof.working_set_mb), fp)
    n_hot = max(1, int(ws * prof.hot_page_pct / 100.0))
    return Scenario(
        name=f"syn/{prof.name}",
        gen=ZipfHotspot(
            footprint_pages=fp,
            accesses=prof.accesses_per_interval,
            hot_frac=n_hot / fp,
            zipf_alpha=prof.zipf_alpha,
            hot_traffic=HOT_TRAFFIC_FRACTION,
            write_ratio=prof.write_ratio,
            sp_hot_buckets=_table2_buckets(prof),
        ),
        inst_per_access=prof.inst_per_access,
    )


for _prof in APPS.values():
    register_scenario(_app_scenario(_prof))


@register_scenario
def _stress_zipf() -> Scenario:
    """Extreme skew: 2% of pages take 90% of traffic (hotter than any app)."""
    return Scenario(
        name="stress/zipf-hotspot",
        gen=ZipfHotspot(footprint_pages=64 * PAGES_PER_SP, accesses=120_000,
                        hot_frac=0.02, zipf_alpha=1.2, hot_traffic=0.90,
                        write_ratio=0.30),
    )


@register_scenario
def _stress_phase() -> Scenario:
    """Fast working-set drift: the window moves half its width per interval,
    so last interval's hot set is half stale — punishes history-based
    promotion (the Memos ranking-inversion regime)."""
    return Scenario(
        name="stress/phase-shift",
        gen=PhaseShift(footprint_pages=64 * PAGES_PER_SP, accesses=120_000,
                       ws_frac=0.25, drift_frac=0.50, hot_frac=0.20,
                       zipf_alpha=1.1, hot_traffic=0.70, write_ratio=0.25),
    )


@register_scenario
def _stress_seq() -> Scenario:
    """Streaming sweep with zero temporal reuse (GUPS-adjacent, but strictly
    sequential: best case for superpage TLBs, worst for hot-set monitors)."""
    return Scenario(
        name="stress/seq-scan",
        gen=SequentialScan(footprint_pages=128 * PAGES_PER_SP,
                           accesses=120_000, stride=1, write_ratio=0.30),
    )


@register_scenario
def _stress_chase() -> Scenario:
    """Dependent pointer chase over a large footprint: TLB-hostile, no skew."""
    return Scenario(
        name="stress/pointer-chase",
        gen=PointerChase(footprint_pages=256 * PAGES_PER_SP, accesses=120_000,
                         write_ratio=0.10),
    )


@register_scenario
def _stress_mix() -> Scenario:
    """Hot + streaming + chasing interleaved in one address space: the
    inter-/intra-memory asymmetry stressor (Song et al.'s mixed regime)."""
    return Scenario(
        name="stress/mix",
        gen=InterleavedMix(members=(
            ZipfHotspot(footprint_pages=32 * PAGES_PER_SP, accesses=40_000,
                        hot_frac=0.05, zipf_alpha=1.1, hot_traffic=0.80,
                        write_ratio=0.35),
            SequentialScan(footprint_pages=64 * PAGES_PER_SP, accesses=40_000,
                           stride=1, write_ratio=0.20),
            PointerChase(footprint_pages=64 * PAGES_PER_SP, accesses=40_000,
                         write_ratio=0.10),
        )),
    )
