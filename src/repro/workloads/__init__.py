"""repro.workloads: scenario-programmable, device-resident trace generation.

`generators` holds the pure-JAX trace programs (zipf-hotspot, phase-shift,
sequential-scan, pointer-chase, interleaved-mix); `scenarios` names them in a
registry whose entries are first-class workload names across the repo —
`sim.trace.generate`/`probe_meta` dispatch on them, `engine.simloop` fuses
them into the interval scan (EngineSpec.source), and `engine.fleet` sweeps
them without any host trace staging. See docs/workloads.md.
"""
from repro.workloads.generators import (
    InterleavedMix,
    PhaseShift,
    PointerChase,
    SequentialScan,
    ZipfHotspot,
)
from repro.workloads.scenarios import (
    Scenario,
    available_scenarios,
    get_scenario,
    is_scenario,
    materialize,
    probe_meta,
    register_scenario,
    trace_program,
)

__all__ = [
    "InterleavedMix", "PhaseShift", "PointerChase", "SequentialScan",
    "ZipfHotspot", "Scenario", "available_scenarios", "get_scenario",
    "is_scenario", "materialize", "probe_meta", "register_scenario",
    "trace_program",
]
