"""Pure-JAX trace *programs*: deterministic per-interval access generators.

Each generator is a frozen, hashable program description with two phases:

  setup(seed)          seed-dependent, interval-invariant choices (e.g. the
                       hot-page placement) — computed ONCE per simulation,
                       outside the interval scan;
  emit(aux, key, i)    one monitoring interval's accesses as (page_idx,
                       is_write) arrays of static shape [accesses], keyed by
                       ``fold_in(PRNGKey(seed), interval)``.

Because emit runs *inside* the engine's ``lax.scan`` (engine.simloop fused
mode) AND standalone on the host (the staged differential oracle,
sim.trace.generate), its device graph is restricted to operations whose
results cannot depend on the surrounding compile context:

  * threefry bits / fold_in / uniform / randint  (elementwise, deterministic)
  * searchsorted against HOST-precomputed f32 CDF tables (zipf weights are
    built with numpy and closed over as constants — no on-device cumsum/pow
    whose fusion could move a sample across a bucket boundary)
  * integer arithmetic (uint32 LCG closed form: cumprod/cumsum are exact mod
    2^32 under any association; coprime-stride affine index permutations for
    interleaving — emit contains NO device sort: hot/cold traffic is mixed
    per-lane by an elementwise bernoulli, so in-scan generation stays O(A))

so a chunk generated in-scan is bit-identical to the same chunk materialized
to host and fed back through the staged path — the property the differential
gate in tests/test_workloads.py pins. (setup may sort: it runs once per
simulation, outside the scan.)

Generators compose: `InterleavedMix` interleaves member programs in a shared
(superpage-aligned) address space, mirroring sim.trace.generate_mix.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

PAGES_PER_SP = 512  # == sim.config.PAGES_PER_SP (kept literal: no sim import)

# fold_in salts: one stream per random decision, never reused across purposes
_SALT_SETUP = 101
_SALT_HOT = 7
_SALT_COLD = 11
_SALT_SHUFFLE = 13
_SALT_WRITE = 17
_SALT_CHASE = 19
_SALT_BUCKET = 23
_SALT_COUNT = 29
_SALT_RANK = 31
_SALT_TIE = 37

# Numerical Recipes LCG (mod 2^32): the pointer-chase hash chain
_LCG_A = np.uint32(1664525)
_LCG_C = np.uint32(1013904223)


def interval_key(seed: jax.Array, interval: jax.Array) -> jax.Array:
    """The per-interval key stream: fold_in(PRNGKey(seed), interval)."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), interval)


def _zipf_cdf(n: int, alpha: float) -> jnp.ndarray:
    """Host-built zipf CDF over ranks 1..n (f32 constant; cdf[-1] == 1.0)."""
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks ** (-alpha)
    cdf = np.cumsum(w / w.sum()).astype(np.float32)
    cdf[-1] = np.float32(1.0)
    return jnp.asarray(cdf)


def _zipf_pick(key: jax.Array, cdf: jnp.ndarray, size: int) -> jax.Array:
    """size zipf-ranked indices in [0, len(cdf)) via inverse CDF."""
    u = jax.random.uniform(key, (size,), jnp.float32)
    return jnp.clip(
        jnp.searchsorted(cdf, u, side="right"), 0, cdf.shape[0] - 1
    ).astype(jnp.int32)


def _hot_cold_mix(key: jax.Array, hot: jax.Array, cold: jax.Array,
                  hot_traffic: float) -> jax.Array:
    """Route each lane to its hot or cold candidate by an elementwise
    bernoulli(hot_traffic) — the sort-free interleave (binomial hot share)."""
    u = jax.random.uniform(key, hot.shape, jnp.float32)
    return jnp.where(u < hot_traffic, hot, cold)


#: Small primes for affine index permutations j -> (a*j + b) mod n. `a` must
#: be coprime with n (then the map IS a permutation) and small enough that
#: a*(n-1) fits int32 — so the interleave is pure int32 arithmetic, no sort.
_STRIDE_PRIMES = (4093, 2039, 1021, 509, 251, 127, 61, 31, 13, 7, 3, 1)


def _affine_interleave(key: jax.Array, n: int) -> jax.Array:
    """A cheap pseudorandom permutation of arange(n): coprime stride + random
    offset. Statically picks the largest listed prime coprime with n whose
    products stay in int32; the offset is the only per-interval randomness."""
    a = next(p for p in _STRIDE_PRIMES
             if math.gcd(p, n) == 1 and p * (n - 1) < 2**31)
    b = jax.random.randint(key, (), 0, n, jnp.int32)
    return (jnp.arange(n, dtype=jnp.int32) * a + b) % n


def _writes(key: jax.Array, size: int, ratio: float) -> jax.Array:
    return jax.random.uniform(key, (size,), jnp.float32) < ratio


def _require(cond: bool, what: str) -> None:
    if not cond:
        raise ValueError(f"workload generator: {what}")


@dataclasses.dataclass(frozen=True)
class ZipfHotspot:
    """Stable hot set + zipf-skewed traffic (the CHOP/Table-I access shape).

    A seed-fixed random subset of ``hot_frac * footprint`` pages receives
    ``hot_traffic`` of all references, zipf(alpha)-skewed by a stable rank
    order; the rest is uniform background over the footprint.

    ``sp_hot_buckets`` (optional) shapes HOW the hot set clusters across
    superpages — the paper's Table II statistic. Each ``(weight, lo, hi)``
    bucket says: with probability proportional to ``weight``, a superpage
    hosts between ``lo`` and ``hi`` hot pages (bounds in scaled pages,
    inclusive). Setup samples a bucket per superpage off a host-precomputed
    CDF, draws a per-superpage quota, and fills quotas with a vectorized
    rank sort — setup runs once per simulation, outside the scan, so a sort
    is allowed here (unlike emit). The empty default keeps the original
    uniform placement bit-for-bit.
    """

    footprint_pages: int
    accesses: int
    hot_frac: float = 0.05
    zipf_alpha: float = 1.1
    hot_traffic: float = 0.70
    write_ratio: float = 0.25
    sp_hot_buckets: tuple = ()  # ((weight, lo, hi), ...) in scaled pages

    def validate(self) -> None:
        _require(self.footprint_pages >= 1, "footprint_pages must be >= 1")
        _require(self.accesses >= 1, "accesses must be >= 1")
        _require(0.0 < self.hot_frac <= 1.0, "hot_frac must be in (0, 1]")
        _require(self.zipf_alpha > 0.0, "zipf_alpha must be > 0")
        _require(0.0 <= self.hot_traffic <= 1.0, "hot_traffic in [0, 1]")
        _require(0.0 <= self.write_ratio <= 1.0, "write_ratio in [0, 1]")
        for b in self.sp_hot_buckets:
            _require(
                isinstance(b, tuple) and len(b) == 3,
                f"sp_hot_buckets entries must be (weight, lo, hi), got {b!r}",
            )
            w, lo, hi = b
            _require(
                isinstance(w, (int, float)) and w == w and w >= 0.0,
                f"bucket weight must be >= 0, got {w!r}",
            )
            _require(
                isinstance(lo, int) and isinstance(hi, int)
                and 1 <= lo <= hi <= PAGES_PER_SP,
                f"bucket bounds need 1 <= lo <= hi <= {PAGES_PER_SP}, "
                f"got ({lo!r}, {hi!r})",
            )
        if self.sp_hot_buckets:
            _require(
                sum(b[0] for b in self.sp_hot_buckets) > 0.0,
                "sp_hot_buckets weights must not all be zero",
            )

    @property
    def _n_hot(self) -> int:
        # round, not truncate: scenario presets derive hot_frac from an
        # integer page count (n_hot / fp), and int() would lose a page to
        # binary64 rounding for some profiles
        return max(1, round(self.footprint_pages * self.hot_frac))

    def setup(self, seed: jax.Array):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), _SALT_SETUP)
        if not self.sp_hot_buckets:
            perm = jax.random.permutation(key, self.footprint_pages)
            return perm[: self._n_hot].astype(jnp.int32)
        return self._bucket_hot_set(key)

    def _bucket_hot_set(self, key: jax.Array) -> jax.Array:
        """Table-II-shaped hot placement: per-superpage bucket quotas.

        Host constants: the bucket CDF and (lo, hi) bounds. Device work per
        superpage: one inverse-CDF bucket draw, one uniform quota draw in
        [lo, hi], then a within-superpage rank (double argsort of uniforms)
        marks each superpage's `quota` cheapest pages eligible. A final
        global sort keys eligible pages first (random tie-break), partial
        trailing superpages' ghost pages last, and takes `_n_hot` — so the
        hot count stays exact even when quotas over- or under-shoot it.
        """
        fp = self.footprint_pages
        n_sp = -(-fp // PAGES_PER_SP)
        w = np.asarray([b[0] for b in self.sp_hot_buckets], np.float64)
        cdf = np.cumsum(w / w.sum()).astype(np.float32)
        cdf[-1] = np.float32(1.0)
        lo = jnp.asarray([b[1] for b in self.sp_hot_buckets], jnp.int32)
        hi = jnp.asarray([b[2] for b in self.sp_hot_buckets], jnp.int32)

        u_b = jax.random.uniform(
            jax.random.fold_in(key, _SALT_BUCKET), (n_sp,), jnp.float32
        )
        b = jnp.clip(
            jnp.searchsorted(jnp.asarray(cdf), u_b, side="right"),
            0, len(cdf) - 1,
        )
        u_c = jax.random.uniform(
            jax.random.fold_in(key, _SALT_COUNT), (n_sp,), jnp.float32
        )
        span = (hi[b] - lo[b] + 1).astype(jnp.float32)
        quota = jnp.minimum(
            lo[b] + (u_c * span).astype(jnp.int32), hi[b]
        )

        page_grid = jnp.arange(
            n_sp * PAGES_PER_SP, dtype=jnp.int32
        ).reshape(n_sp, PAGES_PER_SP)
        valid = page_grid < fp
        quota = jnp.minimum(quota, valid.sum(axis=1).astype(jnp.int32))

        r_u = jax.random.uniform(
            jax.random.fold_in(key, _SALT_RANK), (n_sp, PAGES_PER_SP),
            jnp.float32,
        )
        r_u = jnp.where(valid, r_u, 2.0)
        rank = jnp.argsort(jnp.argsort(r_u, axis=1), axis=1)
        eligible = (rank < quota[:, None]) & valid

        tie = jax.random.uniform(
            jax.random.fold_in(key, _SALT_TIE), (n_sp, PAGES_PER_SP),
            jnp.float32,
        )
        sort_key = jnp.where(eligible, tie, 2.0 + tie)
        sort_key = jnp.where(valid, sort_key, 4.0 + tie)
        order = jnp.argsort(sort_key.reshape(-1))
        return page_grid.reshape(-1)[order][: self._n_hot]

    def emit(self, aux, key: jax.Array, interval: jax.Array):
        del interval  # the hot set is stationary; only the key stream moves
        a = self.accesses
        cdf = _zipf_cdf(self._n_hot, self.zipf_alpha)
        hot = aux[_zipf_pick(jax.random.fold_in(key, _SALT_HOT), cdf, a)]
        cold = jax.random.randint(
            jax.random.fold_in(key, _SALT_COLD), (a,), 0,
            self.footprint_pages, jnp.int32,
        )
        pages = _hot_cold_mix(
            jax.random.fold_in(key, _SALT_SHUFFLE), hot, cold,
            self.hot_traffic,
        )
        wr = _writes(
            jax.random.fold_in(key, _SALT_WRITE), a, self.write_ratio
        )
        return pages, wr


@dataclasses.dataclass(frozen=True)
class PhaseShift:
    """Working-set drift: a zipf-hot window that slides every interval.

    The working window covers ``ws_frac`` of the footprint and advances by
    ``drift_frac`` of its own width per interval (wrapping) — the phase-change
    stressor history-based policies must chase (Memos' pattern inversion).
    """

    footprint_pages: int
    accesses: int
    ws_frac: float = 0.25
    drift_frac: float = 0.10
    hot_frac: float = 0.20
    zipf_alpha: float = 1.1
    hot_traffic: float = 0.70
    write_ratio: float = 0.25

    def validate(self) -> None:
        _require(self.footprint_pages >= 1, "footprint_pages must be >= 1")
        _require(self.accesses >= 1, "accesses must be >= 1")
        _require(0.0 < self.ws_frac <= 1.0, "ws_frac must be in (0, 1]")
        _require(0.0 <= self.drift_frac <= 1.0, "drift_frac in [0, 1]")
        _require(0.0 < self.hot_frac <= 1.0, "hot_frac must be in (0, 1]")
        _require(self.zipf_alpha > 0.0, "zipf_alpha must be > 0")
        _require(0.0 <= self.hot_traffic <= 1.0, "hot_traffic in [0, 1]")
        _require(0.0 <= self.write_ratio <= 1.0, "write_ratio in [0, 1]")

    @property
    def _ws(self) -> int:
        return max(1, round(self.footprint_pages * self.ws_frac))

    @property
    def _n_hot(self) -> int:
        return max(1, round(self._ws * self.hot_frac))

    def setup(self, seed: jax.Array):
        # hot placement is fixed RELATIVE to the window, so the drift moves
        # the whole phase coherently (hot set included)
        key = jax.random.fold_in(jax.random.PRNGKey(seed), _SALT_SETUP)
        perm = jax.random.permutation(key, self._ws)
        return perm[: self._n_hot].astype(jnp.int32)

    def emit(self, aux, key: jax.Array, interval: jax.Array):
        a = self.accesses
        drift = max(1, int(self._ws * self.drift_frac))
        offset = (interval.astype(jnp.int32) * drift) % self.footprint_pages
        cdf = _zipf_cdf(self._n_hot, self.zipf_alpha)
        hot_rel = aux[_zipf_pick(jax.random.fold_in(key, _SALT_HOT), cdf, a)]
        cold_rel = jax.random.randint(
            jax.random.fold_in(key, _SALT_COLD), (a,), 0, self._ws, jnp.int32
        )
        rel = _hot_cold_mix(
            jax.random.fold_in(key, _SALT_SHUFFLE), hot_rel, cold_rel,
            self.hot_traffic,
        )
        pages = (offset + rel) % self.footprint_pages
        wr = _writes(
            jax.random.fold_in(key, _SALT_WRITE), a, self.write_ratio
        )
        return pages.astype(jnp.int32), wr


@dataclasses.dataclass(frozen=True)
class SequentialScan:
    """Streaming scan: strided sequential sweep that resumes across intervals.

    Interval i continues where i-1 stopped (position ``i * accesses * stride``
    mod footprint) — zero reuse inside the TLB reach, the worst case for
    hot-set monitors and the best case for superpage translations.
    """

    footprint_pages: int
    accesses: int
    stride: int = 1
    write_ratio: float = 0.0

    def validate(self) -> None:
        _require(self.footprint_pages >= 1, "footprint_pages must be >= 1")
        _require(self.accesses >= 1, "accesses must be >= 1")
        _require(self.stride >= 1, "stride must be >= 1")
        _require(0.0 <= self.write_ratio <= 1.0, "write_ratio in [0, 1]")

    def setup(self, seed: jax.Array):
        del seed
        return ()

    def emit(self, aux, key: jax.Array, interval: jax.Array):
        del aux
        start = (
            interval.astype(jnp.int32) * (self.accesses * self.stride)
        ) % self.footprint_pages
        pages = (
            start + jnp.arange(self.accesses, dtype=jnp.int32) * self.stride
        ) % self.footprint_pages
        wr = _writes(
            jax.random.fold_in(key, _SALT_WRITE), self.accesses,
            self.write_ratio,
        )
        return pages, wr


@dataclasses.dataclass(frozen=True)
class PointerChase:
    """Dependent random walk: an LCG hash chain over the footprint.

    Evaluated in closed form (x_k = a^k x_0 + c * sum_{j<k} a^j mod 2^32 via
    uint32 cumprod/cumsum — exact under any association), so the chain is
    vectorizable yet identical to stepping the LCG. A fresh chain start per
    interval, derived from the interval key.
    """

    footprint_pages: int
    accesses: int
    write_ratio: float = 0.10

    def validate(self) -> None:
        _require(self.footprint_pages >= 1, "footprint_pages must be >= 1")
        _require(self.accesses >= 1, "accesses must be >= 1")
        _require(0.0 <= self.write_ratio <= 1.0, "write_ratio in [0, 1]")

    def setup(self, seed: jax.Array):
        del seed
        return ()

    def emit(self, aux, key: jax.Array, interval: jax.Array):
        del aux, interval
        a = self.accesses
        x0 = jax.random.bits(
            jax.random.fold_in(key, _SALT_CHASE), (), jnp.uint32
        )
        a_pow = jnp.cumprod(
            jnp.concatenate([
                jnp.ones((1,), jnp.uint32), jnp.full((a - 1,), _LCG_A)
            ])
        )  # a^0 .. a^{A-1}, exact mod 2^32
        geo = jnp.concatenate([
            jnp.zeros((1,), jnp.uint32), jnp.cumsum(a_pow)[: a - 1]
        ])  # sum_{j<k} a^j
        x = a_pow * x0 + _LCG_C * geo
        # drop the weak low LCG bits before reducing into the footprint
        pages = ((x >> np.uint32(7)) % np.uint32(self.footprint_pages))
        wr = _writes(
            jax.random.fold_in(key, _SALT_WRITE), a, self.write_ratio
        )
        return pages.astype(jnp.int32), wr


@dataclasses.dataclass(frozen=True)
class InterleavedMix:
    """Member programs interleaved in a shared, superpage-aligned space.

    Each member keeps its own footprint (offset to a superpage boundary, as
    sim.trace.generate_mix offsets members by whole superpages) and its own
    key stream (fold_in by member index); the union is interleaved per
    interval by a coprime-stride affine permutation (sort-free) so the
    engine sees one mixed multi-programmed stream.
    """

    members: tuple  # tuple of generator programs

    def validate(self) -> None:
        _require(len(self.members) >= 1, "mix needs at least one member")
        for m in self.members:
            m.validate()

    @property
    def _bases(self) -> tuple[int, ...]:
        """Member page offsets (superpage-aligned cumulative footprints)."""
        bases, base = [], 0
        for m in self.members:
            bases.append(base)
            nsp = -(-m.footprint_pages // PAGES_PER_SP)
            base += nsp * PAGES_PER_SP
        return tuple(bases)

    @property
    def footprint_pages(self) -> int:
        last = self.members[-1]
        return self._bases[-1] + (
            -(-last.footprint_pages // PAGES_PER_SP) * PAGES_PER_SP
        )

    @property
    def accesses(self) -> int:
        return sum(m.accesses for m in self.members)

    def setup(self, seed: jax.Array):
        return tuple(
            m.setup(jax.random.fold_in(jax.random.PRNGKey(seed), i)[0])
            for i, m in enumerate(self.members)
        )

    def emit(self, aux, key: jax.Array, interval: jax.Array):
        pages_l, wr_l = [], []
        for i, (m, a, base) in enumerate(zip(self.members, aux, self._bases)):
            p, w = m.emit(a, jax.random.fold_in(key, i), interval)
            pages_l.append(p + base)
            wr_l.append(w)
        pages = jnp.concatenate(pages_l)
        wr = jnp.concatenate(wr_l)
        perm = _affine_interleave(
            jax.random.fold_in(key, _SALT_SHUFFLE), pages.shape[0]
        )
        return pages[perm], wr[perm]


GENERATOR_KINDS = (ZipfHotspot, PhaseShift, SequentialScan, PointerChase,
                   InterleavedMix)
