"""Oracle for the block-migration gather kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_gather_ref(
    cap: jax.Array,  # [NB, block, KVS, hd] capacity pool
    hot: jax.Array,  # [HOT, block, KVS, hd] hot pool (updated)
    src: jax.Array,  # int32[K] capacity block ids (-1 = skip lane)
    dst: jax.Array,  # int32[K] hot slot ids
) -> jax.Array:
    ok = src >= 0
    s = jnp.where(ok, src, 0)
    d = jnp.where(ok, dst, hot.shape[0])  # OOB -> dropped
    return hot.at[d].set(cap[s], mode="drop")
