"""Block-migration engine — Pallas TPU kernel (the paper's T_mig datapath).

Copies the selected hot blocks capacity->hot pool: grid over the migration plan;
src/dst indices are scalar-prefetched, and BOTH BlockSpec index_maps chase them,
so every grid step is one DMA capacity[src[k]] -> hot[dst[k]] with no compute.
On real hardware this overlaps decode compute (it touches disjoint buffers) —
the async-migration trick of §III-C.

Skip lanes (src < 0) are routed to a sink row appended to the hot pool (writes
land there and are sliced off), so no-op lanes can never race a real write to
slot 0. Untouched hot rows carry through via input/output aliasing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import TPUCompilerParams


def _kernel(src_ref, dst_ref, cap_ref, hot_in_ref, hot_out_ref):
    del hot_in_ref  # present only for the input/output alias
    hot_out_ref[...] = cap_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_gather(
    cap: jax.Array,  # [NB, block, KVS, hd]
    hot: jax.Array,  # [HOT, block, KVS, hd]
    src: jax.Array,  # int32[K] (-1 = skip lane)
    dst: jax.Array,  # int32[K]
    interpret: bool = True,
) -> jax.Array:
    kk = src.shape[0]
    nhot = hot.shape[0]
    block, kvs, hd = cap.shape[1], cap.shape[2], cap.shape[3]
    ok = src >= 0
    src_safe = jnp.where(ok, src, 0).astype(jnp.int32)
    dst_safe = jnp.where(ok, dst, nhot).astype(jnp.int32)  # -> sink row
    hot_padded = jnp.concatenate([hot, jnp.zeros_like(hot[:1])], axis=0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(kk,),
        in_specs=[
            pl.BlockSpec((1, block, kvs, hd), lambda k, s, d: (s[k], 0, 0, 0)),
            pl.BlockSpec((1, block, kvs, hd), lambda k, s, d: (d[k], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block, kvs, hd), lambda k, s, d: (d[k], 0, 0, 0)),
        scratch_shapes=[],
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(hot_padded.shape, hot.dtype),
        interpret=interpret,
        input_output_aliases={3: 0},  # hot_padded -> out (untouched rows keep)
        compiler_params=TPUCompilerParams(dimension_semantics=("arbitrary",)),
    )(src_safe, dst_safe, cap, hot_padded)
    return out[:nhot]
