"""Dispatch wrapper for the migration block-gather."""
from __future__ import annotations

import jax

from repro.kernels.block_gather.block_gather import block_gather
from repro.kernels.block_gather.ref import block_gather_ref


def migrate_blocks(cap, hot, src, dst, force=None):
    backend = jax.default_backend()
    mode = force or ("pallas" if backend == "tpu" else "ref")
    if mode in ("pallas", "interpret"):
        return block_gather(cap, hot, src, dst, interpret=(mode == "interpret"))
    return block_gather_ref(cap, hot, src, dst)
