"""Oracle for the tiled causal flash-attention kernel (single head-group)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(
    q: jax.Array,  # [B, S, H, hd]
    k: jax.Array,  # [B, S, H, hd]  (kv heads pre-expanded to H)
    v: jax.Array,
    causal: bool = True,
) -> jax.Array:
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    s = s / np.sqrt(q.shape[-1])
    if causal:
        sl = q.shape[1]
        mask = jnp.tril(jnp.ones((sl, sl), bool))
        s = jnp.where(mask[None, None], s, -2.0e38)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum(
        "bhqk,bkhd->bqhd", p.astype(q.dtype), v, preferred_element_type=jnp.float32
    ).astype(q.dtype)
