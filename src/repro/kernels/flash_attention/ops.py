"""Dispatch wrapper for flash attention."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref


def attention(q, k, v, causal=True, force=None):
    backend = jax.default_backend()
    mode = force or ("pallas" if backend == "tpu" else "ref")
    if mode in ("pallas", "interpret"):
        return flash_attention(q, k, v, causal=causal, interpret=(mode == "interpret"))
    return flash_attention_ref(q, k, v, causal=causal)
