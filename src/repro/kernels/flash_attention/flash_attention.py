"""Causal flash attention — Pallas TPU kernel (training substrate hot spot).

Standard tiling: grid (B, H, Q_blocks, KV_blocks); online softmax state (m, l,
acc) in VMEM scratch, persisted across the KV_block (innermost, "arbitrary")
grid dim; causal blocks above the diagonal are skipped via pl.when. Q/K/V tiles
are BlockSpec-mapped so each step holds (BQ + 2*BK) x hd in VMEM — sized for
~16 MB VMEM at hd<=256 with BQ=BK=128 (MXU-aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import TPUCompilerParams

NEG_INF = -2.0e38


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, bq, bk, nkv, causal):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if causal:
        run = ki * bk <= qi * bq + bq - 1  # skip blocks above the diagonal
    else:
        run = jnp.bool_(True)

    @pl.when(run)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # [BQ, hd]
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # [BK, hd]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) / np.sqrt(q.shape[-1])
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...][:, 0]
        l_prev = l_ref[...][:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = (l_prev * alpha + p.sum(axis=1))[:, None]
        m_ref[...] = m_new[:, None]
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(ki == nkv - 1)
    def _finish():
        o_ref[0, :, 0, :] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(
    q: jax.Array,  # [B, S, H, hd]
    k: jax.Array,  # [B, S, H, hd] (kv pre-expanded)
    v: jax.Array,
    causal: bool = True,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, s, h, hd = q.shape
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0, "seq must divide block sizes"
    nq, nkv = s // bq, s // bk

    grid = (b, h, nq, nkv)
    qspec = pl.BlockSpec((1, bq, 1, hd), lambda bb, hh, qi, ki: (bb, qi, hh, 0))
    kspec = pl.BlockSpec((1, bk, 1, hd), lambda bb, hh, qi, ki: (bb, ki, hh, 0))
    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, nkv=nkv, causal=causal),
        grid=grid,
        in_specs=[qspec, kspec, kspec],
        out_specs=qspec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ),
    )(q, k, v)
    return out
