"""Pallas API compatibility shims.

`pltpu.TPUCompilerParams` was renamed to `pltpu.CompilerParams` across JAX
releases; resolve whichever this JAX provides so the kernels run on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

TPUCompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)
