"""Jit'd dispatch wrapper: Pallas kernel on TPU, jnp oracle elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.rainbow_attention.rainbow_attention import rainbow_attention
from repro.kernels.rainbow_attention.ref import rainbow_attention_ref


def paged_decode_attention(
    q, pool_k, pool_v, vidx, length, force: str | None = None
):
    """force: None (auto), "pallas", "interpret", "ref"."""
    backend = jax.default_backend()
    mode = force or ("pallas" if backend == "tpu" else "ref")
    if mode == "pallas":
        return rainbow_attention(q, pool_k, pool_v, vidx, length, interpret=False)
    if mode == "interpret":
        return rainbow_attention(q, pool_k, pool_v, vidx, length, interpret=True)
    return rainbow_attention_ref(q, pool_k, pool_v, vidx, length)
