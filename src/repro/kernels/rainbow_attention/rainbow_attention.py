"""Rainbow paged decode attention — Pallas TPU kernel.

The TPU-native form of the paper's split-TLB + bitmap + remap walk (Fig. 6):
block tables arrive as *scalar-prefetch* operands (SMEM — the TLB analogue);
each grid step's BlockSpec index_map dereferences the table to pull ONE KV
block from the [capacity ++ hot] pool straight into VMEM (the DMA the remap
pointer would trigger). Flash-decoding online softmax accumulates in VMEM
scratch across the block-grid.

Grid: (B, nblk). For step (b, i):
  k_blk = pool_k[vidx[b, i]]   (BlockSpec-managed HBM->VMEM DMA)
  scores = q[b] @ k_blk^T; online-softmax update of (m, l, acc) scratch
  at i == nblk-1: out[b] = acc / l
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import TPUCompilerParams

NEG_INF = -2.0e38


def _kernel(
    # scalar-prefetch
    vidx_ref,  # int32[B, nblk]  (SMEM)
    length_ref,  # int32[1]        (SMEM)
    # inputs (VMEM blocks)
    q_ref,  # [1, HP, hd]
    k_ref,  # [1, block, KVS, hd]  selected by index_map via vidx
    v_ref,  # [1, block, KVS, hd]
    # output
    o_ref,  # [1, HP, hd]
    # scratch
    m_ref,  # f32[HP, 1]
    l_ref,  # f32[HP, 1]
    acc_ref,  # f32[HP, hd]
    *,
    block: int,
    nblk: int,
):
    b = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # [HP, hd]
    k = k_ref[0]  # [block, KVS, hd]
    v = v_ref[0]
    hp = q.shape[0]
    kvs = k.shape[1]
    m_rep = hp // kvs

    # expand kv heads to match q heads (local consecutive repeat)
    k = jnp.repeat(k, m_rep, axis=1)  # [block, HP, hd]
    v = jnp.repeat(v, m_rep, axis=1)
    s = jnp.einsum("hd,thd->ht", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * (1.0 / np.sqrt(q.shape[-1]))

    # mask positions beyond the valid length
    base = i * block
    pos = base + jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
    s = jnp.where(pos < length_ref[0], s, NEG_INF)

    m_prev = m_ref[...][:, 0]
    l_prev = l_ref[...][:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * alpha + p.sum(axis=1)
    acc = acc_ref[...] * alpha[:, None] + jnp.einsum(
        "ht,thd->hd", p, v.astype(jnp.float32)
    )
    m_ref[...] = m_new[:, None]
    l_ref[...] = l_new[:, None]
    acc_ref[...] = acc

    @pl.when(i == nblk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rainbow_attention(
    q: jax.Array,  # [B, HP, hd]
    pool_k: jax.Array,  # [NPOOL, block, KVS, hd]
    pool_v: jax.Array,
    vidx: jax.Array,  # int32[B, nblk]
    length: jax.Array,  # int32 scalar
    interpret: bool = True,
) -> jax.Array:
    b, hp, hd = q.shape
    nblk = vidx.shape[1]
    block, kvs = pool_k.shape[1], pool_k.shape[2]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, nblk),
        in_specs=[
            pl.BlockSpec((1, hp, hd), lambda bb, ii, vt, ln: (bb, 0, 0)),
            pl.BlockSpec(
                (1, block, kvs, hd), lambda bb, ii, vt, ln: (vt[bb, ii], 0, 0, 0)
            ),
            pl.BlockSpec(
                (1, block, kvs, hd), lambda bb, ii, vt, ln: (vt[bb, ii], 0, 0, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, hp, hd), lambda bb, ii, vt, ln: (bb, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((hp, 1), jnp.float32),
            pltpu.VMEM((hp, 1), jnp.float32),
            pltpu.VMEM((hp, hd), jnp.float32),
        ],
    )
    kernel = functools.partial(_kernel, block=block, nblk=nblk)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hp, hd), q.dtype),
        interpret=interpret,
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
    )(vidx, jnp.reshape(length, (1,)).astype(jnp.int32), q, pool_k, pool_v)
