"""Pure-jnp oracle for the Rainbow paged decode attention kernel.

Semantics: single-token decode attention where KV blocks are read through the
two-tier translation. The kernel consumes *virtual block indices* (vidx) into
the concatenated [capacity ++ hot] pool — the translation itself (bitmap +
remap -> vidx) is repro.core.remap.translate and is tested separately.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rainbow_attention_ref(
    q: jax.Array,  # [B, HP, hd]
    pool_k: jax.Array,  # [NPOOL, block, KVS, hd]
    pool_v: jax.Array,  # [NPOOL, block, KVS, hd]
    vidx: jax.Array,  # int32[B, nblk] virtual block ids (translated)
    length: jax.Array,  # int32 valid tokens (uniform across batch)
) -> jax.Array:
    """Returns [B, HP, hd]."""
    b, hp, hd = q.shape
    nblk = vidx.shape[1]
    block = pool_k.shape[1]
    kvs = pool_k.shape[2]
    k = pool_k[vidx]  # [B, nblk, block, KVS, hd]
    v = pool_v[vidx]
    k = k.reshape(b, nblk * block, kvs, hd)
    v = v.reshape(b, nblk * block, kvs, hd)
    m = hp // kvs
    k = jnp.repeat(k, m, axis=2)
    v = jnp.repeat(v, m, axis=2)
    s = jnp.einsum("bhk,bshk->bhs", q, k, preferred_element_type=jnp.float32)
    s = s / np.sqrt(hd)
    pos = jnp.arange(nblk * block)
    s = jnp.where(pos[None, None, :] < length, s, -2.0e38)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum(
        "bhs,bshk->bhk", p.astype(q.dtype), v, preferred_element_type=jnp.float32
    ).astype(q.dtype)
