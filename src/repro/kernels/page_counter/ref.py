"""Oracle for the two-stage page-access counter kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def two_stage_count_ref(
    sp: jax.Array,  # int32[A] superpage per access (-1 = skip)
    page: jax.Array,  # int32[A] page within superpage
    weight: jax.Array,  # uint32[A]
    num_superpages: int,
    monitored: jax.Array,  # int32[N] monitored superpage ids (-1 = unused row)
    pages_per_sp: int,
) -> tuple[jax.Array, jax.Array]:
    """Returns (stage1 uint32[num_superpages], stage2 uint32[N, pages_per_sp])."""
    valid = sp >= 0
    w = jnp.where(valid, weight, 0).astype(jnp.uint32)
    s1 = jnp.zeros((num_superpages,), jnp.uint32).at[
        jnp.where(valid, sp, 0)
    ].add(w)
    eq = sp[:, None] == monitored[None, :]
    eq &= (monitored >= 0)[None, :]
    row = jnp.argmax(eq, axis=1)
    hit = eq.any(axis=1)
    n = monitored.shape[0]
    flat = jnp.zeros((n * pages_per_sp,), jnp.uint32).at[
        jnp.where(hit, row * pages_per_sp + page, 0)
    ].add(jnp.where(hit, w, 0))
    return s1, flat.reshape(n, pages_per_sp)


def fused_observe_count_ref(
    sp: jax.Array,  # int32[A] superpage per access (-1 = skip)
    page: jax.Array,  # int32[A] page within superpage
    is_write: jax.Array,  # bool[A]
    monitored: jax.Array,  # int32[N] monitored superpage ids (-1 = unused row)
    num_superpages: int,
    pages_per_sp: int,
    write_weight: int = 2,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Oracle for the fused observe kernel.

    Returns (stage1 uint32[NSP] weighted by write_weight, stage2-read and
    stage2-write uint32[N, pages_per_sp] histograms of the monitored rows).
    """
    valid = sp >= 0
    w1 = jnp.where(valid, jnp.where(is_write, write_weight, 1), 0).astype(jnp.uint32)
    s1 = jnp.zeros((num_superpages,), jnp.uint32).at[jnp.where(valid, sp, 0)].add(w1)

    eq = (sp[:, None] == monitored[None, :]) & (monitored >= 0)[None, :]
    row = jnp.argmax(eq, axis=1)
    hit = eq.any(axis=1)
    n = monitored.shape[0]
    idx = jnp.where(hit, row * pages_per_sp + page, 0)

    def hist(w):
        flat = jnp.zeros((n * pages_per_sp,), jnp.uint32).at[idx].add(
            jnp.where(hit, w, 0).astype(jnp.uint32)
        )
        return flat.reshape(n, pages_per_sp)

    return s1, hist(~is_write), hist(is_write)
