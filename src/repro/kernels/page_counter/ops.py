"""Dispatch wrapper for the two-stage counter."""
from __future__ import annotations

import jax

from repro.kernels.page_counter.page_counter import two_stage_count
from repro.kernels.page_counter.ref import two_stage_count_ref


def count_accesses(
    sp, page, weight, monitored, num_superpages, pages_per_sp, force=None
):
    backend = jax.default_backend()
    mode = force or ("pallas" if backend == "tpu" else "ref")
    if mode in ("pallas", "interpret"):
        return two_stage_count(
            sp, page, weight, monitored, num_superpages, pages_per_sp,
            interpret=(mode == "interpret"),
        )
    return two_stage_count_ref(
        sp, page, weight, num_superpages, monitored, pages_per_sp
    )
