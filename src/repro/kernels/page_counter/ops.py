"""Dispatch wrapper for the two-stage counter."""
from __future__ import annotations

import jax

from repro.kernels.page_counter.page_counter import two_stage_count
from repro.kernels.page_counter.ref import two_stage_count_ref


def _kernel_mode(sp, force) -> str:
    """Resolve the backend; zero-access chunks always take the ref oracle.

    Pallas cannot slice a zero-length operand (grid of zero A-tiles), and an
    empty interval's histograms are exactly the ref scatter's zeros — so the
    TPU-default flip keeps working for degenerate chunks.
    """
    mode = force or ("pallas" if jax.default_backend() == "tpu" else "ref")
    if sp.shape[0] == 0:
        return "ref"
    return mode


def count_accesses(
    sp, page, weight, monitored, num_superpages, pages_per_sp, force=None
):
    mode = _kernel_mode(sp, force)
    if mode in ("pallas", "interpret"):
        return two_stage_count(
            sp, page, weight, monitored, num_superpages, pages_per_sp,
            interpret=(mode == "interpret"),
        )
    return two_stage_count_ref(
        sp, page, weight, num_superpages, monitored, pages_per_sp
    )


def observe_counts(
    sp, page, is_write, monitored, num_superpages, pages_per_sp,
    write_weight=2, force=None,
):
    """Fused one-pass observe histograms: (s1, s2_reads, s2_writes).

    The MemoryEngine's counting step (engine.control.observe_tiers) dispatches
    here when `counter_backend` != "jax": "pallas" on TPU, "interpret" for the
    Pallas interpreter, "ref" for the pure-jnp oracle.
    """
    from repro.kernels.page_counter.page_counter import fused_observe_count
    from repro.kernels.page_counter.ref import fused_observe_count_ref

    mode = _kernel_mode(sp, force)
    if mode in ("pallas", "interpret"):
        return fused_observe_count(
            sp, page, is_write, monitored, num_superpages, pages_per_sp,
            write_weight=write_weight, interpret=(mode == "interpret"),
        )
    return fused_observe_count_ref(
        sp, page, is_write, monitored, num_superpages, pages_per_sp,
        write_weight=write_weight,
    )
