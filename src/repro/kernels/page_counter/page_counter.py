"""Two-stage access counter — Pallas TPU kernel (paper §III-B in hardware).

The memory-controller counting path as a tiled streaming kernel: accesses arrive
in VMEM tiles of A_TILE; both counter tables live in VMEM scratch across the
grid (they are small by design — that is the paper's point: O(mem/2MB) + N*1KB)
and are flushed to HBM on the last tile.

Scatter-adds inside a tile are expressed as one-hot matmuls — the MXU-friendly
realization of "CAM + counter array" (TPU has no per-element atomic scatter;
a [A_TILE, SP] one-hot times a ones-vector IS the histogram).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import TPUCompilerParams


def _kernel(
    monitored_ref,  # int32[N] (SMEM, scalar-prefetch)
    sp_ref,  # int32[1, A_TILE]
    page_ref,  # int32[1, A_TILE]
    w_ref,  # f32[1, A_TILE]
    s1_out,  # f32[NSP]
    s2_out,  # f32[N, PAGES]
    s1_acc,  # scratch f32[NSP]
    s2_acc,  # scratch f32[N, PAGES]
    *,
    nsp: int,
    pages: int,
    n_mon: int,
    tiles: int,
):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        s1_acc[...] = jnp.zeros_like(s1_acc)
        s2_acc[...] = jnp.zeros_like(s2_acc)

    sp = sp_ref[0]
    page = page_ref[0]
    w = w_ref[0]
    valid = sp >= 0
    wv = jnp.where(valid, w, 0.0)

    # stage 1: histogram over superpages via one-hot matmul
    onehot = (sp[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, nsp), 1)).astype(
        jnp.float32
    )  # [A, NSP]
    s1_acc[...] += jnp.einsum("an,a->n", onehot, wv)

    # stage 2: monitored rows only
    mon = monitored_ref[...]  # [N]
    row_eq = (sp[:, None] == mon[None, :]) & (mon >= 0)[None, :]  # [A, N]
    page_oh = (
        page[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, pages), 1)
    ).astype(jnp.float32)  # [A, PAGES]
    contrib = jnp.einsum(
        "an,ap->np", row_eq.astype(jnp.float32) * wv[:, None], page_oh
    )
    s2_acc[...] += contrib

    @pl.when(t == tiles - 1)
    def _flush():
        s1_out[...] = s1_acc[...]
        s2_out[...] = s2_acc[...]


@functools.partial(
    jax.jit, static_argnames=("num_superpages", "pages_per_sp", "a_tile", "interpret")
)
def two_stage_count(
    sp: jax.Array,
    page: jax.Array,
    weight: jax.Array,
    monitored: jax.Array,
    num_superpages: int,
    pages_per_sp: int,
    a_tile: int = 512,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    a = sp.shape[0]
    tiles = (a + a_tile - 1) // a_tile
    pad = tiles * a_tile - a
    if pad:
        sp = jnp.pad(sp, (0, pad), constant_values=-1)
        page = jnp.pad(page, (0, pad))
        weight = jnp.pad(weight, (0, pad))
    n_mon = monitored.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((1, a_tile), lambda t, mon: (t, 0)),
            pl.BlockSpec((1, a_tile), lambda t, mon: (t, 0)),
            pl.BlockSpec((1, a_tile), lambda t, mon: (t, 0)),
        ],
        out_specs=[
            pl.BlockSpec((num_superpages,), lambda t, mon: (0,)),
            pl.BlockSpec((n_mon, pages_per_sp), lambda t, mon: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((num_superpages,), jnp.float32),
            pltpu.VMEM((n_mon, pages_per_sp), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _kernel, nsp=num_superpages, pages=pages_per_sp, n_mon=n_mon, tiles=tiles
    )
    s1, s2 = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((num_superpages,), jnp.float32),
            jax.ShapeDtypeStruct((n_mon, pages_per_sp), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=TPUCompilerParams(dimension_semantics=("arbitrary",)),
    )(
        monitored.astype(jnp.int32),
        sp.reshape(tiles, a_tile),
        page.reshape(tiles, a_tile),
        weight.astype(jnp.float32).reshape(tiles, a_tile),
    )
    return s1.astype(jnp.uint32), s2.astype(jnp.uint32)


# ---------------------------------------------------------------------------
# Fused observe kernel: stage-1 (weighted) + stage-2 read/write histograms in
# ONE pass over an access batch — the counting step of engine.control's
# observe_tiers. Three counter tables ride in VMEM scratch across the grid and
# flush on the last tile, so each access element is read exactly once.
# ---------------------------------------------------------------------------


def _fused_kernel(
    monitored_ref,  # int32[N] (SMEM, scalar-prefetch)
    sp_ref,  # int32[1, A_TILE]
    page_ref,  # int32[1, A_TILE]
    wr_ref,  # int32[1, A_TILE] is_write as 0/1
    s1_out,  # f32[NSP]
    s2r_out,  # f32[N, PAGES]
    s2w_out,  # f32[N, PAGES]
    s1_acc,  # scratch f32[NSP]
    s2r_acc,  # scratch f32[N, PAGES]
    s2w_acc,  # scratch f32[N, PAGES]
    *,
    nsp: int,
    pages: int,
    write_weight: int,
    tiles: int,
):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        s1_acc[...] = jnp.zeros_like(s1_acc)
        s2r_acc[...] = jnp.zeros_like(s2r_acc)
        s2w_acc[...] = jnp.zeros_like(s2w_acc)

    sp = sp_ref[0]
    page = page_ref[0]
    is_write = wr_ref[0] > 0
    valid = sp >= 0

    # per-lane weights: stage-1 counts writes heavier (§III-B); stage-2 keeps
    # reads and writes in separate tables for the Eq. 1 utility split.
    w1 = jnp.where(valid, jnp.where(is_write, float(write_weight), 1.0), 0.0)
    w_r = jnp.where(valid & ~is_write, 1.0, 0.0)
    w_w = jnp.where(valid & is_write, 1.0, 0.0)

    # stage 1: histogram over superpages via one-hot matmul
    onehot = (sp[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, nsp), 1)).astype(
        jnp.float32
    )  # [A, NSP]
    s1_acc[...] += jnp.einsum("an,a->n", onehot, w1)

    # stage 2: monitored rows only, read/write split
    mon = monitored_ref[...]  # [N]
    row_eq = ((sp[:, None] == mon[None, :]) & (mon >= 0)[None, :]).astype(
        jnp.float32
    )  # [A, N]
    page_oh = (
        page[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, pages), 1)
    ).astype(jnp.float32)  # [A, PAGES]
    s2r_acc[...] += jnp.einsum("an,ap->np", row_eq * w_r[:, None], page_oh)
    s2w_acc[...] += jnp.einsum("an,ap->np", row_eq * w_w[:, None], page_oh)

    @pl.when(t == tiles - 1)
    def _flush():
        s1_out[...] = s1_acc[...]
        s2r_out[...] = s2r_acc[...]
        s2w_out[...] = s2w_acc[...]


@functools.partial(
    jax.jit,
    static_argnames=(
        "num_superpages", "pages_per_sp", "write_weight", "a_tile", "interpret",
    ),
)
def fused_observe_count(
    sp: jax.Array,  # int32[A] superpage per access (-1 = skip)
    page: jax.Array,  # int32[A]
    is_write: jax.Array,  # bool[A]
    monitored: jax.Array,  # int32[N] monitored superpage ids (-1 = unused row)
    num_superpages: int,
    pages_per_sp: int,
    write_weight: int = 2,
    a_tile: int = 512,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-pass batch histograms: (s1 u32[NSP], s2_reads, s2_writes u32[N, P])."""
    a = sp.shape[0]
    tiles = (a + a_tile - 1) // a_tile
    pad = tiles * a_tile - a
    wr = is_write.astype(jnp.int32)
    if pad:
        sp = jnp.pad(sp, (0, pad), constant_values=-1)
        page = jnp.pad(page, (0, pad))
        wr = jnp.pad(wr, (0, pad))
    n_mon = monitored.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((1, a_tile), lambda t, mon: (t, 0)),
            pl.BlockSpec((1, a_tile), lambda t, mon: (t, 0)),
            pl.BlockSpec((1, a_tile), lambda t, mon: (t, 0)),
        ],
        out_specs=[
            pl.BlockSpec((num_superpages,), lambda t, mon: (0,)),
            pl.BlockSpec((n_mon, pages_per_sp), lambda t, mon: (0, 0)),
            pl.BlockSpec((n_mon, pages_per_sp), lambda t, mon: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((num_superpages,), jnp.float32),
            pltpu.VMEM((n_mon, pages_per_sp), jnp.float32),
            pltpu.VMEM((n_mon, pages_per_sp), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _fused_kernel,
        nsp=num_superpages,
        pages=pages_per_sp,
        write_weight=write_weight,
        tiles=tiles,
    )
    s1, s2r, s2w = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((num_superpages,), jnp.float32),
            jax.ShapeDtypeStruct((n_mon, pages_per_sp), jnp.float32),
            jax.ShapeDtypeStruct((n_mon, pages_per_sp), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=TPUCompilerParams(dimension_semantics=("arbitrary",)),
    )(
        monitored.astype(jnp.int32),
        sp.reshape(tiles, a_tile),
        page.reshape(tiles, a_tile),
        wr.reshape(tiles, a_tile),
    )
    return s1.astype(jnp.uint32), s2r.astype(jnp.uint32), s2w.astype(jnp.uint32)
