"""Mamba2 SSD (state-space duality, arXiv:2405.21060) in pure JAX.

Chunked algorithm: within-chunk quadratic (attention-like) term + across-chunk
state recurrence (lax.scan), giving O(S·Q) work per head instead of O(S^2).
Used by mamba2-1.3b (full layer) and hymba-1.5b (parallel SSM branch).

TP sharding: heads over "model" (d_inner split); B/C (ngroups=1) replicated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import Params, dtype_of, normal_init


def _ssm_dims(cfg, tp: int) -> tuple[int, int, int, int]:
    """(d_inner, heads, headdim, state) padded so heads % tp == 0."""
    p_dim = cfg.ssm_head_dim
    h = cfg.ssm_d_inner // p_dim
    hp = ((h + tp - 1) // tp) * tp
    return hp * p_dim, hp, p_dim, cfg.ssm_state


def ssm_init(cfg, key, tp: int, stacked: int | None = None) -> Params:
    dt_ = dtype_of(cfg)
    d = cfg.d_model
    di, h, p_dim, n = _ssm_dims(cfg, tp)
    lead = () if stacked is None else (stacked,)
    ks = jax.random.split(key, 6)
    conv_ch = di + 2 * n
    scale_out = 0.02 / max(1.0, (2 * cfg.num_layers) ** 0.5)
    out_proj = normal_init(ks[2], (*lead, di, d), scale_out, dt_)
    orig_di = cfg.ssm_d_inner
    if di != orig_di:  # zero rows of padded heads -> exact original function
        alive = (jnp.arange(di) < orig_di).astype(out_proj.dtype)
        out_proj = out_proj * alive[..., :, None]
    return {
        # fused input projection -> [z (di), x (di), B (n), C (n), dt (h)]
        "in_proj": normal_init(ks[0], (*lead, d, 2 * di + 2 * n + h), 0.02, dt_),
        "conv_w": normal_init(ks[1], (*lead, cfg.ssm_conv_width, conv_ch), 0.2, jnp.float32),
        "conv_b": jnp.zeros((*lead, conv_ch), jnp.float32),
        "a_log": jnp.zeros((*lead, h), jnp.float32),  # A = -exp(a_log) = -1
        "d_skip": jnp.ones((*lead, h), jnp.float32),
        "dt_bias": jnp.zeros((*lead, h), jnp.float32),
        "norm": jnp.ones((*lead, di), jnp.float32),
        "out_proj": out_proj,
    }


def ssm_specs(cfg, stacked: bool = False) -> Params:
    l = (None,) if stacked else ()
    return {
        "in_proj": P(*l, None, "model"),
        "conv_w": P(*l, None, "model"),
        "conv_b": P(*l, "model"),
        "a_log": P(*l, "model"),
        "d_skip": P(*l, "model"),
        "dt_bias": P(*l, "model"),
        "norm": P(*l, "model"),
        "out_proj": P(*l, "model", None),
    }


def _split_proj(cfg, tp: int, zxbcdt: jax.Array):
    di, h, p_dim, n = _ssm_dims(cfg, tp)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n :]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over seq. xbc: [B,S,C]; w: [W,C]."""
    width = w.shape[0]
    xf = xbc.astype(jnp.float32)
    pad = jnp.pad(xf, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(xf)
    for i in range(width):  # width is tiny (4): unrolled taps beat conv lowering
        out = out + pad[:, i : i + xf.shape[1], :] * w[i]
    return jax.nn.silu(out + b).astype(xbc.dtype)


def ssd_chunked(
    x: jax.Array,  # [B, S, H, P] (pre-scaled inputs)
    dt: jax.Array,  # [B, S, H] softplus'd step sizes
    a: jax.Array,  # [H] negative decay rates (A = -exp(a_log))
    b_in: jax.Array,  # [B, S, N]
    c_in: jax.Array,  # [B, S, N]
    chunk: int,
    init_state: jax.Array | None = None,  # [B, H, P, N]
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    bsz, s, h, p_dim = x.shape
    n = b_in.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, f"seq {s} must divide into chunks of {chunk}"
    nc = s // chunk

    xc = x.reshape(bsz, nc, chunk, h, p_dim).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(bsz, nc, chunk, h).transpose(1, 0, 2, 3)
    bc = b_in.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)
    cc = c_in.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)

    if init_state is None:
        init_state = jnp.zeros((bsz, h, p_dim, n), jnp.float32)

    def body(state, xs):
        xk, dtk, bk, ck = xs  # [B,Q,H,P], [B,Q,H], [B,Q,N], [B,Q,N]
        log_a = dtk * a  # [B,Q,H]  (negative)
        la = jnp.cumsum(log_a, axis=1)  # inclusive cumsum
        la_end = la[:, -1]  # [B,H]
        xdt = (xk.astype(jnp.float32)) * dtk[..., None]
        cbf = jnp.einsum("bqn,bkn->bqk", ck.astype(jnp.float32), bk.astype(jnp.float32))
        # decay factor exp(la_i - la_j), causal-masked (j <= i)
        rel = la[:, :, None, :] - la[:, None, :, :]  # [B,Q,K,H]
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        decay = jnp.where(causal[None, :, :, None], jnp.exp(rel), 0.0)
        att = cbf[..., None] * decay  # [B,Q,K,H]
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", att, xdt)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum(
            "bqn,bhpn,bqh->bqhp", cc_f(ck), state, jnp.exp(la)
        )
        # new state: decay old + sum_j exp(la_end - la_j) * xdt_j B_j^T
        to_end = jnp.exp(la_end[:, None] - la)  # [B,Q,H]
        s_contrib = jnp.einsum("bqh,bqn,bqhp->bhpn", to_end, cc_f(bk), xdt)
        state_new = state * jnp.exp(la_end)[:, :, None, None] + s_contrib
        return state_new, (y_intra + y_inter)

    def cc_f(t):
        return t.astype(jnp.float32)

    from repro.models.unroll_flag import unroll_inner as _unroll

    state, ys = jax.lax.scan(body, init_state, (xc, dtc, bc, cc), unroll=_unroll(nc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, s, h, p_dim)
    return y.astype(x.dtype), state


def apply_ssm(
    cfg,
    p: Params,
    x: jax.Array,  # [B, S, D]
    tp: int,
    conv_state: jax.Array | None = None,  # decode: [B, W-1, C]
    ssm_state: jax.Array | None = None,  # decode: [B, H, P, N]
    mode: str = "train",
) -> tuple[jax.Array, jax.Array | None, jax.Array | None]:
    """Full SSM block. Returns (out [B,S,D], conv_state', ssm_state').

    mode "train"/"prefill": full-sequence chunked SSD (states returned for
    prefill hand-off). mode "decode": single-token recurrent update (S == 1).
    """
    di, h, p_dim, n = _ssm_dims(cfg, tp)
    acc = jnp.float32
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"], preferred_element_type=acc).astype(
        x.dtype
    )
    z, xbc, dt_raw = _split_proj(cfg, tp, zxbcdt)
    a = -jnp.exp(p["a_log"])  # [H]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])

    if mode == "decode":
        width = cfg.ssm_conv_width
        assert conv_state is not None and ssm_state is not None
        hist = jnp.concatenate([conv_state, xbc.astype(jnp.float32)], axis=1)
        conv_out = (hist * p["conv_w"][None]).sum(axis=1) + p["conv_b"]
        xbc_act = jax.nn.silu(conv_out)[:, None, :].astype(x.dtype)  # [B,1,C]
        new_conv_state = hist[:, 1:, :]
        xs = xbc_act[..., :di].reshape(-1, 1, h, p_dim)
        b_in = xbc_act[..., di : di + n]
        c_in = xbc_act[..., di + n :]
        dtv = dt[:, 0]  # [B,H]
        da = jnp.exp(dtv * a)  # [B,H]
        xdt = xs[:, 0].astype(jnp.float32) * dtv[..., None]  # [B,H,P]
        new_state = ssm_state * da[..., None, None] + jnp.einsum(
            "bhp,bn->bhpn", xdt, b_in[:, 0].astype(jnp.float32)
        )
        y = jnp.einsum("bn,bhpn->bhp", c_in[:, 0].astype(jnp.float32), new_state)
        y = y + p["d_skip"][:, None] * xs[:, 0].astype(jnp.float32)
        y = y.reshape(-1, 1, di)
    else:
        xbc_act = _causal_conv(xbc, p["conv_w"], p["conv_b"])
        xs = xbc_act[..., :di].reshape(x.shape[0], -1, h, p_dim)
        b_in = xbc_act[..., di : di + n]
        c_in = xbc_act[..., di + n :]
        y4, final_state = ssd_chunked(xs, dt, a, b_in, c_in, cfg.ssm_chunk)
        y = y4.reshape(x.shape[0], -1, di).astype(jnp.float32)
        y = y + (p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)).reshape(
            x.shape[0], -1, di
        )
        new_state = final_state
        width = cfg.ssm_conv_width
        tail = xbc.astype(jnp.float32)[:, -(width - 1) :, :]
        new_conv_state = tail

    # gated RMS norm: norm(y * silu(z))
    g = y * jax.nn.silu(z.astype(jnp.float32))
    var = (g * g).mean(-1, keepdims=True)
    g = g * jax.lax.rsqrt(var + 1e-6) * p["norm"]
    out = jnp.einsum(
        "bsk,kd->bsd", g.astype(x.dtype), p["out_proj"], preferred_element_type=acc
    ).astype(x.dtype)
    return out, new_conv_state, new_state


def ssm_cache_init(cfg, batch: int, tp: int, layers: int) -> Params:
    di, h, p_dim, n = _ssm_dims(cfg, tp)
    conv_ch = di + 2 * n
    return {
        "conv": jnp.zeros((layers, batch, cfg.ssm_conv_width - 1, conv_ch), jnp.float32),
        "ssm": jnp.zeros((layers, batch, h, p_dim, n), jnp.float32),
    }


def ssm_cache_specs(batch_axes) -> Params:
    return {
        "conv": P(None, batch_axes, None, "model"),
        "ssm": P(None, batch_axes, "model", None, None),
    }
