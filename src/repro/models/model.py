"""Model assembly for all 10 assigned architectures.

A model is a list of *segments* — homogeneous runs of layers executed with one
lax.scan each (keeps HLO compact for 36-48 layer configs):

  dense   : ln1 -> GQA attn -> +res ; ln2 -> MLP -> +res        (dense/vlm archs)
  moe     : ln1 -> GQA attn -> +res ; ln2 -> MoE -> +res
  ssm     : ln1 -> Mamba2 SSD -> +res                           (mamba2)
  hybrid  : ln1 -> [attn || ssm] avg -> +res ; ln2 -> MLP -> +res  (hymba)
  encdec  : ln1 -> self-attn -> +res ; ln2 -> cross-attn -> +res ; ln3 -> MLP
            (whisper decoder; the encoder is a separate stack of dense layers
             with bidirectional attention and sinusoidal positions)

deepseek-moe's leading dense-FFN layer forms its own 1-layer "dense" segment.
Per-layer sliding windows (hymba) ride through the scan as traced int32 flags.

Three entry points build the three step kinds: forward() (train/score),
prefill(), decode_step(). The flat KV cache lives here; the Rainbow paged cache
wraps decode in repro.memory/repro.serving.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch.axes import BATCH_AXES, MODEL_AXIS
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig

Params = dict[str, Any]

from repro.models.unroll_flag import set_scan_unroll, unroll as _unroll  # noqa: E402

# §Perf knob: shard the inter-layer residual stream over the model axis along
# the SEQUENCE dim (Megatron-style sequence parallelism). GSPMD then lowers the
# per-layer TP boundary collectives as reduce-scatter + all-gather instead of
# all-reduce — half the bytes on the wire and a smaller live residual.
_RESID_SEQ_PARALLEL = False


def set_resid_seq_parallel(value: bool) -> None:
    global _RESID_SEQ_PARALLEL
    _RESID_SEQ_PARALLEL = value


def _resid_spec():
    if _RESID_SEQ_PARALLEL:
        return (BATCH_AXES, MODEL_AXIS, None)
    return (BATCH_AXES, None, MODEL_AXIS)


# ---------------------------------------------------------------------------
# Segment plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SegSpec:
    name: str
    kind: str  # dense | moe | ssm | hybrid | encdec
    start: int
    length: int


def segments(cfg: ModelConfig) -> list[SegSpec]:
    lyr = cfg.num_layers
    if cfg.family == "moe":
        fd = cfg.moe_first_dense
        segs = []
        if fd:
            segs.append(SegSpec("dense0", "dense", 0, fd))
        segs.append(SegSpec("blocks", "moe", fd, lyr - fd))
        return segs
    if cfg.family == "ssm":
        return [SegSpec("blocks", "ssm", 0, lyr)]
    if cfg.family == "hybrid":
        return [SegSpec("blocks", "hybrid", 0, lyr)]
    if cfg.family == "audio":
        return [SegSpec("blocks", "encdec", 0, lyr)]
    return [SegSpec("blocks", "dense", 0, lyr)]  # dense, vlm


def seg_windows(cfg: ModelConfig, seg: SegSpec) -> np.ndarray:
    """Per-layer attention window (0 = unlimited) for a segment."""
    idx = np.arange(seg.start, seg.start + seg.length)
    if cfg.sliding_window and cfg.global_attn_every:
        w = np.where(idx % cfg.global_attn_every == 0, 0, cfg.sliding_window)
    elif cfg.sliding_window:
        w = np.full_like(idx, cfg.sliding_window)
    else:
        w = np.zeros_like(idx)
    return w.astype(np.int32)


# ---------------------------------------------------------------------------
# Init + specs
# ---------------------------------------------------------------------------


def _seg_init(cfg, key, tp, seg: SegSpec) -> Params:
    n = seg.length
    ks = jax.random.split(key, 8)
    p: Params = {"ln1": L.norm_init(cfg, cfg.d_model, n)}
    if seg.kind in ("dense", "moe", "hybrid", "encdec"):
        p["attn"] = attn.attn_init(cfg, ks[0], tp, stacked=n)
    if seg.kind == "ssm" or seg.kind == "hybrid":
        p["ssm"] = ssm_mod.ssm_init(cfg, ks[1], tp, stacked=n)
    if seg.kind in ("dense", "hybrid", "encdec"):
        p["ln2"] = L.norm_init(cfg, cfg.d_model, n)
        p["mlp"] = L.mlp_init(cfg, ks[2], cfg.d_model, cfg.d_ff, stacked=n)
    if seg.kind == "moe":
        p["ln2"] = L.norm_init(cfg, cfg.d_model, n)
        p["moe"] = moe_mod.moe_init(cfg, ks[3], tp, stacked=n)
    if seg.kind == "encdec":
        p["xattn"] = attn.attn_init(cfg, ks[4], tp, stacked=n, cross=True)
        p["ln3"] = L.norm_init(cfg, cfg.d_model, n)
    return p


def _seg_specs(cfg, seg: SegSpec) -> Params:
    p: Params = {"ln1": L.norm_specs(cfg, stacked=True)}
    if seg.kind in ("dense", "moe", "hybrid", "encdec"):
        p["attn"] = attn.attn_specs(cfg, stacked=True)
    if seg.kind in ("ssm", "hybrid"):
        p["ssm"] = ssm_mod.ssm_specs(cfg, stacked=True)
    if seg.kind in ("dense", "hybrid", "encdec"):
        p["ln2"] = L.norm_specs(cfg, stacked=True)
        p["mlp"] = L.mlp_specs(cfg, stacked=True)
    if seg.kind == "moe":
        p["ln2"] = L.norm_specs(cfg, stacked=True)
        p["moe"] = moe_mod.moe_specs(cfg, stacked=True)
    if seg.kind == "encdec":
        p["xattn"] = attn.attn_specs(cfg, stacked=True, cross=True)
        p["ln3"] = L.norm_specs(cfg, stacked=True)
    return p


def init_params(cfg: ModelConfig, key, tp: int = 1) -> Params:
    keys = jax.random.split(key, 4 + len(segments(cfg)))
    p: Params = {"embed": L.embed_init(cfg, keys[0])}
    p["segments"] = {
        seg.name: _seg_init(cfg, keys[2 + i], tp, seg)
        for i, seg in enumerate(segments(cfg))
    }
    p["final_norm"] = L.norm_init(cfg, cfg.d_model)
    if cfg.is_encoder_decoder:
        ne = cfg.num_encoder_layers
        enc_seg = SegSpec("enc", "dense", 0, ne)
        p["encoder"] = {
            "layers": _seg_init(cfg, keys[1], tp, enc_seg),
            "norm": L.norm_init(cfg, cfg.d_model),
        }
    return p


def param_specs(cfg: ModelConfig, tp: int = 1) -> Params:
    p: Params = {"embed": L.embed_specs(cfg)}
    p["segments"] = {seg.name: _seg_specs(cfg, seg) for seg in segments(cfg)}
    p["final_norm"] = L.norm_specs(cfg)
    if cfg.is_encoder_decoder:
        p["encoder"] = {
            "layers": _seg_specs(cfg, SegSpec("enc", "dense", 0, 1)),
            "norm": L.norm_specs(cfg),
        }
    return p


# ---------------------------------------------------------------------------
# Layer bodies (full-sequence: train / prefill)
# ---------------------------------------------------------------------------


def _sc(sc, x, *spec):
    return sc(x, P(*spec)) if sc is not None else x


def _attn_full_seq(
    cfg, pl, x, positions, window, *, causal, use_rope, tp, sc, impl, kv_out=False
):
    q, k, v = attn.qkv_project(cfg, pl, x, positions, use_rope=use_rope)
    q = _sc(sc, q, BATCH_AXES, None, MODEL_AXIS, None)
    k = _sc(sc, k, BATCH_AXES, None, MODEL_AXIS, None)
    v = _sc(sc, v, BATCH_AXES, None, MODEL_AXIS, None)
    if impl == "chunked":
        o = attn.attend_chunked(
            q, k, v, positions[0] if positions.ndim == 2 else positions,
            positions[0] if positions.ndim == 2 else positions, window, causal
        )
    else:
        qp = positions if positions.ndim == 2 else positions[None]
        mask = attn._causal_window_mask(qp, qp, window, causal)[:, None]  # [B|1,1,S,S]
        o = attn.attend_dense(q, k, v, mask)
    out = attn.attn_output(pl, o)
    if kv_out:
        return out, k, v
    return out, None, None


def _block_full_seq(cfg, kind, pl, x, positions, window, tp, sc, impl, enc_out=None):
    """One layer, full sequence. Returns (x', (k, v) or None, ssm_states or None)."""
    kv = None
    ssm_states = None
    h = L.apply_norm(cfg, pl["ln1"], x)
    if kind == "ssm":
        o, conv_st, ssm_st = ssm_mod.apply_ssm(cfg, pl["ssm"], h, tp, mode="train")
        x = x + o
        ssm_states = (conv_st, ssm_st)
    elif kind == "hybrid":
        ao, k, v = _attn_full_seq(
            cfg, pl["attn"], h, positions, window,
            causal=True, use_rope=True, tp=tp, sc=sc, impl=impl, kv_out=True,
        )
        so, conv_st, ssm_st = ssm_mod.apply_ssm(cfg, pl["ssm"], h, tp, mode="train")
        x = x + 0.5 * (ao + so)
        kv = (k, v)
        ssm_states = (conv_st, ssm_st)
        h2 = L.apply_norm(cfg, pl["ln2"], x)
        x = x + L.apply_mlp(cfg, pl["mlp"], h2, sc=sc)
    else:
        causal = kind != "encoder"
        ao, k, v = _attn_full_seq(
            cfg, pl["attn"], h, positions, window,
            causal=causal, use_rope=causal, tp=tp, sc=sc, impl=impl, kv_out=True,
        )
        x = x + ao
        kv = (k, v)
        if kind == "encdec":
            hx = L.apply_norm(cfg, pl["ln2"], x)
            qx, _, _ = attn.qkv_project(cfg, pl["xattn"], hx, positions, use_rope=False)
            # cross k/v come from encoder output (precomputed per layer)
            ek, ev = enc_out
            o = attn.attend_dense(qx, ek, ev, None)
            x = x + attn.attn_output(pl["xattn"], o)
            h3 = L.apply_norm(cfg, pl["ln3"], x)
            x = x + L.apply_mlp(cfg, pl["mlp"], h3, sc=sc)
        elif kind == "moe":
            h2 = L.apply_norm(cfg, pl["ln2"], x)
            x = x + moe_mod.apply_moe(cfg, pl["moe"], h2, tp, sc=sc)
        else:  # dense / encoder
            h2 = L.apply_norm(cfg, pl["ln2"], x)
            x = x + L.apply_mlp(cfg, pl["mlp"], h2, sc=sc)
    x = _sc(sc, x, *_resid_spec())
    return x, kv, ssm_states


def _remat_wrap(fn, remat: str):
    if remat == "none":
        return fn
    policy = {
        "full": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.checkpoint_dots,
    }[remat]
    return jax.checkpoint(fn, policy=policy)


def _run_segment_full(
    cfg, seg: SegSpec, seg_params, x, positions, tp, sc, impl, remat,
    enc_kv=None, collect_cache=False,
):
    """Scan a segment over the full sequence. Returns (x, per-layer cache ys)."""
    windows = jnp.asarray(seg_windows(cfg, seg))

    def body(carry, xs):
        if enc_kv is not None:
            pl, w, ekv = xs
        else:
            pl, w = xs
            ekv = None
        x_new, kv, ssm_states = _block_full_seq(
            cfg, seg.kind, pl, carry, positions, w, tp, sc, impl, enc_out=ekv
        )
        ys = {}
        if collect_cache:
            if kv is not None:
                ys["k"], ys["v"] = kv
            if ssm_states is not None:
                ys["conv"], ys["ssm"] = ssm_states
        return x_new, ys

    body = _remat_wrap(body, remat)
    xs = (seg_params, windows) if enc_kv is None else (seg_params, windows, enc_kv)
    x, ys = jax.lax.scan(body, x, xs, unroll=_unroll(seg.length))
    return x, ys


# ---------------------------------------------------------------------------
# forward (train / score)
# ---------------------------------------------------------------------------


def _encode(cfg, params, frames, tp, sc, impl, remat):
    """Whisper encoder: frames [B,Se,D] (stub embeddings) + sinusoid positions."""
    b, se, d = frames.shape
    pos = jnp.arange(se)
    x = frames.astype(L.dtype_of(cfg)) + _sinusoid(se, d).astype(L.dtype_of(cfg))
    x = _sc(sc, x, BATCH_AXES, None, None)
    seg = SegSpec("enc", "encoder", 0, cfg.num_encoder_layers)
    ep = params["encoder"]["layers"]
    x, _ = _run_segment_full(cfg, seg, ep, x, pos, tp, sc, impl, remat)
    return L.apply_norm(cfg, params["encoder"]["norm"], x)


def _sinusoid(s: int, d: int) -> jax.Array:
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-np.log(10000.0) / d))
    pe = jnp.zeros((s, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def _cross_kv_all_layers(cfg, params, enc_out, tp, sc):
    """Precompute cross-attention K/V for every decoder layer: [Lyr,B,Se,KVS,hd]."""
    seg_params = params["segments"]["blocks"]["xattn"]
    se = enc_out.shape[1]
    pos = jnp.arange(se)

    def per_layer(pl):
        _, k, v = attn.qkv_project(cfg, pl, enc_out, pos, use_rope=False)
        return k, v

    k, v = jax.vmap(per_layer)(seg_params)
    # vmap over stacked layer params maps q-projection too; recompute cheaply.
    return k, v


def forward(
    cfg: ModelConfig,
    params: Params,
    batch: dict[str, jax.Array],
    tp: int = 1,
    sc=None,
    attn_impl: str = "dense",
    remat: str = "none",
) -> jax.Array:
    """Full-sequence logits [B, S_dec, Vp] (train / scoring path)."""
    tokens = batch["tokens"]
    x = L.embed_lookup(cfg, params["embed"], tokens)
    if cfg.family == "vlm":
        ve = batch["vision_embeds"].astype(x.dtype)
        x = jnp.concatenate([ve, x], axis=1)
    x = _sc(sc, x, BATCH_AXES, None, None)
    positions = jnp.arange(x.shape[1])

    enc_kv = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(cfg, params, batch["frames"], tp, sc, attn_impl, remat)
        ek, ev = _cross_kv_all_layers(cfg, params, enc_out, tp, sc)
        enc_kv = (ek, ev)

    for seg in segments(cfg):
        x, _ = _run_segment_full(
            cfg, seg, params["segments"][seg.name], x, positions, tp, sc,
            attn_impl, remat, enc_kv=enc_kv if seg.kind == "encdec" else None,
        )
    x = L.apply_norm(cfg, params["final_norm"], x)
    if cfg.family == "vlm":  # only text positions produce logits
        nv = batch["vision_embeds"].shape[1]
        x = x[:, nv:]
    logits = L.lm_logits(cfg, params["embed"], x)
    return logits


def loss_fn(cfg, params, batch, tp=1, sc=None, attn_impl="dense", remat="none"):
    logits = forward(cfg, params, batch, tp, sc, attn_impl, remat)
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(batch["targets"], jnp.float32)
    return L.softmax_xent(logits, batch["targets"], mask)


# ---------------------------------------------------------------------------
# KV cache: init / prefill / decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, tp: int = 1) -> Params:
    cache: Params = {"len": jnp.zeros((), jnp.int32)}
    for seg in segments(cfg):
        c: Params = {}
        if seg.kind in ("dense", "moe", "hybrid", "encdec"):
            c.update(attn.cache_init(cfg, batch, max_len, tp, seg.length))
        if seg.kind in ("ssm", "hybrid"):
            c.update(ssm_mod.ssm_cache_init(cfg, batch, tp, seg.length))
        cache[f"seg:{seg.name}"] = c
    if cfg.is_encoder_decoder:
        enc_len = max_len  # cross cache sized by encoder frames at prefill
        cache["cross"] = attn.cache_init(cfg, batch, enc_len, tp, cfg.num_layers)
        cache["enc_len"] = jnp.zeros((), jnp.int32)
    return cache


def cache_specs(cfg: ModelConfig, seq_axis=None) -> Params:
    specs: Params = {"len": P()}
    for seg in segments(cfg):
        c: Params = {}
        if seg.kind in ("dense", "moe", "hybrid", "encdec"):
            c.update(attn.cache_specs(BATCH_AXES, seq_axis))
        if seg.kind in ("ssm", "hybrid"):
            c.update(ssm_mod.ssm_cache_specs(BATCH_AXES))
        specs[f"seg:{seg.name}"] = c
    if cfg.is_encoder_decoder:
        specs["cross"] = attn.cache_specs(BATCH_AXES, seq_axis)
        specs["enc_len"] = P()
    return specs


def prefill(
    cfg: ModelConfig,
    params: Params,
    batch: dict[str, jax.Array],
    cache: Params,
    tp: int = 1,
    sc=None,
    attn_impl: str = "dense",
) -> tuple[jax.Array, Params]:
    """Process the prompt; fill caches; return (last-position logits, cache)."""
    tokens = batch["tokens"]
    x = L.embed_lookup(cfg, params["embed"], tokens)
    if cfg.family == "vlm":
        ve = batch["vision_embeds"].astype(x.dtype)
        x = jnp.concatenate([ve, x], axis=1)
    x = _sc(sc, x, BATCH_AXES, None, None)
    s = x.shape[1]
    positions = jnp.arange(s)

    enc_kv = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(cfg, params, batch["frames"], tp, sc, attn_impl, "none")
        ek, ev = _cross_kv_all_layers(cfg, params, enc_out, tp, sc)
        enc_kv = (ek, ev)
        cache = dict(cache)
        cross = dict(cache["cross"])
        se = ek.shape[2]
        cross["k"] = jax.lax.dynamic_update_slice(
            cross["k"], ek.astype(cross["k"].dtype), (0, 0, 0, 0, 0)
        )
        cross["v"] = jax.lax.dynamic_update_slice(
            cross["v"], ev.astype(cross["v"].dtype), (0, 0, 0, 0, 0)
        )
        cache["cross"] = cross
        cache["enc_len"] = jnp.asarray(se, jnp.int32)

    cache = dict(cache)
    for seg in segments(cfg):
        x, ys = _run_segment_full(
            cfg, seg, params["segments"][seg.name], x, positions, tp, sc,
            attn_impl, "none",
            enc_kv=enc_kv if seg.kind == "encdec" else None,
            collect_cache=True,
        )
        c = dict(cache[f"seg:{seg.name}"])
        if "k" in ys:  # write prompt K/V into the flat cache at offset 0
            c["k"] = jax.lax.dynamic_update_slice(
                c["k"], ys["k"].astype(c["k"].dtype), (0, 0, 0, 0, 0)
            )
            c["v"] = jax.lax.dynamic_update_slice(
                c["v"], ys["v"].astype(c["v"].dtype), (0, 0, 0, 0, 0)
            )
        if "ssm" in ys:
            c["conv"] = ys["conv"]
            c["ssm"] = ys["ssm"]
        cache[f"seg:{seg.name}"] = c
    cache["len"] = jnp.asarray(s, jnp.int32)

    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.lm_logits(cfg, params["embed"], x[:, -1:])
    return logits, cache


def _block_decode(cfg, kind, pl, x, pos, window, c_slices, cur_len, tp, sc):
    """One layer, one token. c_slices holds this layer's cache leaves."""
    updates = {}
    h = L.apply_norm(cfg, pl["ln1"], x)
    if kind in ("dense", "moe", "hybrid", "encdec"):
        q, k, v = attn.qkv_project(cfg, pl["attn"], h, pos, use_rope=True)
        ck, cv = attn.cache_update(c_slices["k"], c_slices["v"], k, v, cur_len)
        updates["k"], updates["v"] = ck, cv
        ao = attn.decode_attend(q, ck, cv, cur_len + 1, window)
        ao = attn.attn_output(pl["attn"], ao)
    if kind in ("ssm", "hybrid"):
        so, conv_st, ssm_st = ssm_mod.apply_ssm(
            cfg, pl["ssm"], h, tp,
            conv_state=c_slices["conv"], ssm_state=c_slices["ssm"], mode="decode",
        )
        updates["conv"], updates["ssm"] = conv_st, ssm_st
    if kind == "ssm":
        x = x + so
    elif kind == "hybrid":
        x = x + 0.5 * (ao + so)
        h2 = L.apply_norm(cfg, pl["ln2"], x)
        x = x + L.apply_mlp(cfg, pl["mlp"], h2, sc=sc)
    elif kind == "encdec":
        x = x + ao
        hx = L.apply_norm(cfg, pl["ln2"], x)
        qx, _, _ = attn.qkv_project(cfg, pl["xattn"], hx, pos, use_rope=False)
        xo = attn.decode_attend(
            qx, c_slices["xk"], c_slices["xv"], c_slices["enc_len"], 0
        )
        x = x + attn.attn_output(pl["xattn"], xo)
        h3 = L.apply_norm(cfg, pl["ln3"], x)
        x = x + L.apply_mlp(cfg, pl["mlp"], h3, sc=sc)
    elif kind == "moe":
        x = x + ao
        h2 = L.apply_norm(cfg, pl["ln2"], x)
        x = x + moe_mod.apply_moe(cfg, pl["moe"], h2, tp, sc=sc)
    else:  # dense
        x = x + ao
        h2 = L.apply_norm(cfg, pl["ln2"], x)
        x = x + L.apply_mlp(cfg, pl["mlp"], h2, sc=sc)
    return x, updates


def decode_step(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,  # [B, 1]
    cache: Params,
    tp: int = 1,
    sc=None,
) -> tuple[jax.Array, Params]:
    """One decode step over all layers. Returns (logits [B,1,Vp], cache')."""
    cur = cache["len"]
    x = L.embed_lookup(cfg, params["embed"], tokens)
    x = _sc(sc, x, BATCH_AXES, None, None)
    pos = jnp.full((x.shape[0], 1), cur, jnp.int32)

    cache = dict(cache)
    for seg in segments(cfg):
        seg_cache = cache[f"seg:{seg.name}"]
        windows = jnp.asarray(seg_windows(cfg, seg))

        def body(carry, xs):
            pl, w, c_sl = xs
            if cfg.is_encoder_decoder:
                c_sl = dict(c_sl)
                c_sl["enc_len"] = cache["enc_len"]
            x_new, upd = _block_decode(
                cfg, seg.kind, pl, carry, pos, w, c_sl, cur, tp, sc
            )
            return x_new, upd

        xs_cache = dict(seg_cache)
        if cfg.is_encoder_decoder and seg.kind == "encdec":
            xs_cache["xk"] = cache["cross"]["k"]
            xs_cache["xv"] = cache["cross"]["v"]
        x, new_cache = jax.lax.scan(
            body, x, (params["segments"][seg.name], windows, xs_cache),
            unroll=_unroll(seg.length),
        )
        for k_ in ("xk", "xv"):
            new_cache.pop(k_, None)
        cache[f"seg:{seg.name}"] = {
            k_: v_ for k_, v_ in new_cache.items() if k_ in seg_cache
        }
    cache["len"] = cur + 1

    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.lm_logits(cfg, params["embed"], x)
    return logits, cache
