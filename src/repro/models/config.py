"""Model configuration covering all 10 assigned architecture families."""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

Family = Literal["dense", "moe", "audio", "hybrid", "vlm", "ssm"]


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int  # query heads (0 for attn-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # --- MoE ---
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_num_shared: int = 0
    moe_d_ff: int = 0  # per-expert ffn width (fine-grained MoE)
    moe_first_dense: int = 0  # leading dense-FFN layers (deepseek layer 0)
    moe_capacity_factor: float = 1.25

    # --- SSM (mamba2 / hymba) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # --- attention flavor ---
    qk_norm: bool = False
    rope_theta: float = 1e6
    sliding_window: int = 0  # 0 = full attention
    global_attn_every: int = 0  # hybrid: every k-th layer uses full attention

    # --- enc-dec (whisper) ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_divisor: int = 2  # enc frames = seq_len // divisor

    # --- multimodal stub frontends ---
    num_vision_tokens: int = 0  # vlm: patch embeddings prepended (stub input)

    # --- norms/activations ---
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    act: Literal["silu", "gelu"] = "silu"
    gated_mlp: bool = True
    tie_embeddings: bool = False

    # --- numerics ---
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    # --- padding for TP (computed; see padded_* properties) ---
    vocab_pad_multiple: int = 256

    @property
    def attn_free(self) -> bool:
        return self.num_heads == 0

    @property
    def padded_vocab(self) -> int:
        return _ceil_to(self.vocab_size, self.vocab_pad_multiple)

    def padded_heads(self, tp: int) -> int:
        if self.attn_free:
            return 0
        return _ceil_to(self.num_heads, tp)

    def kv_store(self, tp: int) -> int:
        """Stored kv-head slots under tp-way sharding (MaxText-style replication).

        kv >= tp: pad to a multiple of tp (no replication). kv < tp: exactly tp
        slots, slot j holding original head (j*kv)//tp (proportional stretch; exact
        GQA grouping whenever tp % kv == 0 -- see DESIGN.md section 5). Guarantees
        padded_heads(tp) % kv_store(tp) == 0 so the q->kv map is a local repeat.
        """
        if self.attn_free:
            return 0
        kv = self.num_kv_heads
        return _ceil_to(kv, tp) if kv >= tp else tp

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim if self.ssm_state else 0

    def param_count(self) -> int:
        """Approximate parameter count (for 6·N·D roofline bookkeeping)."""
        d, l, v = self.d_model, self.num_layers, self.vocab_size
        n = v * d * (1 if self.tie_embeddings else 2)
        hd = self.head_dim
        per_layer = 0
        if not self.attn_free:
            h, kv = self.num_heads, self.num_kv_heads
            per_layer += d * h * hd + 2 * d * kv * hd + h * hd * d
        if self.family == "moe":
            e, fe = self.moe_num_experts, self.moe_d_ff
            factor = 3 if self.gated_mlp else 2
            per_layer += d * e  # router
            per_layer += e * factor * d * fe
            per_layer += self.moe_num_shared * factor * d * fe
        elif self.d_ff:
            factor = 3 if self.gated_mlp else 2
            per_layer += factor * d * self.d_ff
        if self.ssm_state:
            di = self.ssm_d_inner
            per_layer += d * (2 * di + 2 * self.ssm_state)  # in_proj (x,z,B,C approx)
            per_layer += di * d  # out_proj
            per_layer += di * self.ssm_conv_width
        n += l * per_layer
        if self.is_encoder_decoder:
            h, kv = self.num_heads, self.num_kv_heads
            enc_per = d * h * hd + 2 * d * kv * hd + h * hd * d
            factor = 3 if self.gated_mlp else 2
            enc_per += factor * d * self.d_ff
            n += self.num_encoder_layers * enc_per
            # decoder cross-attention
            n += l * (d * h * hd + 2 * d * kv * hd + h * hd * d)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE counts only routed top-k + shared)."""
        if self.family != "moe":
            return self.param_count()
        d, l = self.d_model, self.num_layers
        n = self.vocab_size * d * 2
        hd = self.head_dim
        h, kv = self.num_heads, self.num_kv_heads
        per_layer = d * h * hd + 2 * d * kv * hd + h * hd * d + d * self.moe_num_experts
        factor = 3 if self.gated_mlp else 2
        per_layer += (self.moe_top_k + self.moe_num_shared) * factor * d * self.moe_d_ff
        return n + l * per_layer


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
