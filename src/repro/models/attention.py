"""GQA attention with TP head padding, KV caches, dense + chunked (flash-style)
implementations, sliding windows, and cross-attention (enc-dec).

TP layout (DESIGN.md §5): query heads padded to HP = ceil(H/tp)*tp (dead heads have
zeroed output rows — exact outputs, wasted FLOPs show up in the MODEL_FLOPS ratio);
kv heads stored in KVS = cfg.kv_store(tp) slots, slot j holding original head
(j*KV)//KVS (weights replicated at init). HP % KVS == 0 always, so the q->kv map is
a *local consecutive repeat* that GSPMD executes without cross-shard traffic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.layers import Params, dtype_of, normal_init, rms_head_norm, rope

NEG_INF = -2.0e38  # fp32-safe mask value


def _slot_to_orig(kv: int, kvs: int) -> np.ndarray:
    return (np.arange(kvs) * kv) // kvs


def attn_init(cfg, key, tp: int, stacked: int | None = None, cross: bool = False) -> Params:
    dt = dtype_of(cfg)
    d, hd = cfg.d_model, cfg.head_dim
    hp = cfg.padded_heads(tp)
    kvs = cfg.kv_store(tp)
    kv = cfg.num_kv_heads
    lead = () if stacked is None else (stacked,)
    ks = jax.random.split(key, 5)
    scale_out = 0.02 / max(1.0, (2 * cfg.num_layers) ** 0.5)

    wq = normal_init(ks[0], (*lead, d, hp, hd), 0.02, dt)
    # draw original kv heads, then place into slots (replication for kv < tp)
    wk_base = normal_init(ks[1], (*lead, d, kv, hd), 0.02, dt)
    wv_base = normal_init(ks[2], (*lead, d, kv, hd), 0.02, dt)
    sl = _slot_to_orig(kv, kvs)
    wk = jnp.take(wk_base, jnp.asarray(sl), axis=-2)
    wv = jnp.take(wv_base, jnp.asarray(sl), axis=-2)
    wo = normal_init(ks[3], (*lead, hp, hd, d), scale_out, dt)
    # zero output rows of dead (padded) query heads -> exact original function
    if hp != cfg.num_heads:
        head_alive = jnp.arange(hp) < cfg.num_heads
        wo = wo * head_alive[..., :, None, None].astype(wo.dtype)
    p = {"wq": wq, "wk": wk, "wv": wv, "wo": wo}
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((*lead, hd), jnp.float32)
        p["k_norm"] = jnp.ones((*lead, hd), jnp.float32)
    return p


def attn_specs(cfg, stacked: bool = False, cross: bool = False) -> Params:
    l = (None,) if stacked else ()
    p = {
        "wq": P(*l, None, "model", None),
        "wk": P(*l, None, "model", None),
        "wv": P(*l, None, "model", None),
        "wo": P(*l, "model", None, None),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = P(*l, None)
        p["k_norm"] = P(*l, None)
    return p


def _expand_kv(k: jax.Array, hp: int) -> jax.Array:
    """Repeat kv slots to match query heads (local under TP: consecutive repeat)."""
    b, s, kvs, hd = k.shape
    m = hp // kvs
    if m == 1:
        return k
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kvs, m, hd)).reshape(
        b, s, kvs * m, hd
    )


def _causal_window_mask(
    q_pos: jax.Array, k_pos: jax.Array, window, causal: bool
) -> jax.Array:
    """bool[?, Q, K] mask; window may be a traced scalar (0 = unlimited)."""
    rel = q_pos[..., :, None] - k_pos[..., None, :]
    mask = jnp.ones(rel.shape, jnp.bool_)
    if causal:
        mask &= rel >= 0
    w = jnp.asarray(window)
    mask &= (w <= 0) | (rel < w)
    return mask


def qkv_project(cfg, p: Params, x: jax.Array, positions, *, use_rope: bool) -> tuple:
    """x -> (q [B,S,HP,hd], k/v [B,S,KVS,hd]) with qk-norm + RoPE applied."""
    acc = jnp.float32
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"], preferred_element_type=acc).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"], preferred_element_type=acc).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"], preferred_element_type=acc).astype(x.dtype)
    if "q_norm" in p:
        q = rms_head_norm(p["q_norm"], q)
        k = rms_head_norm(p["k_norm"], k)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attend_dense(
    q: jax.Array,  # [B, Sq, HP, hd]
    k: jax.Array,  # [B, Sk, KVS, hd]
    v: jax.Array,
    mask: jax.Array | None,  # bool broadcastable to [B, HP, Sq, Sk]
) -> jax.Array:
    """Reference O(Sq*Sk)-memory attention (baseline path)."""
    hp = q.shape[2]
    k = _expand_kv(k, hp)
    v = _expand_kv(v, hp)
    scale = 1.0 / np.sqrt(q.shape[-1])
    scores = jnp.einsum("bqhk,bshk->bhqs", q, k, preferred_element_type=jnp.float32)
    scores = scores * scale
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqs,bshk->bqhk", w, v, preferred_element_type=jnp.float32).astype(
        q.dtype
    )


def attend_chunked(
    q: jax.Array,  # [B, Sq, HP, hd]
    k: jax.Array,  # [B, Sk, KVS, hd]
    v: jax.Array,
    q_pos: jax.Array,  # int32[Sq]
    k_pos: jax.Array,  # int32[Sk]
    window,
    causal: bool,
    chunk: int = 1024,
) -> jax.Array:
    """Flash-style online-softmax over KV chunks: O(Sq*chunk) score memory.

    Pure-JAX (lowers on any backend); the Pallas flash kernel is the TPU-tiled
    version of the same recurrence (kernels/flash_attention).
    """
    b, sq, hp, hd = q.shape
    sk = k.shape[1]
    chunk = min(chunk, sk)
    n_chunks = (sk + chunk - 1) // chunk
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=-(10**9))
    k = _expand_kv(k, hp)
    v = _expand_kv(v, hp)
    kc = k.reshape(b, n_chunks, chunk, hp, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, hp, hd).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(n_chunks, chunk)
    scale = 1.0 / np.sqrt(hd)

    def body(carry, xs):
        m, l, acc = carry  # [B,HP,Sq], [B,HP,Sq], [B,Sq,HP,hd]
        kb, vb, pb = xs
        s = jnp.einsum("bqhk,bshk->bhqs", q, kb, preferred_element_type=jnp.float32)
        s = s * scale
        mask = _causal_window_mask(q_pos[None], pb[None], window, causal)  # [1,Sq,C]
        mask &= (pb >= 0)[None, None, :]
        s = jnp.where(mask[:, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        pexp = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + pexp.sum(-1)
        acc_new = acc * alpha.transpose(0, 2, 1)[..., None] + jnp.einsum(
            "bhqs,bshk->bqhk", pexp.astype(q.dtype), vb, preferred_element_type=jnp.float32
        )
        return (m_new, l_new, acc_new), None

    from repro.models.unroll_flag import unroll as _unroll

    m0 = jnp.full((b, hp, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hp, sq), jnp.float32)
    acc0 = jnp.zeros((b, sq, hp, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (kc, vc, pc), unroll=_unroll(n_chunks)
    )
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


def attn_output(p: Params, o: jax.Array) -> jax.Array:
    return jnp.einsum(
        "bqhk,hkd->bqd", o, p["wo"], preferred_element_type=jnp.float32
    ).astype(o.dtype)


# ---------------------------------------------------------------------------
# KV cache (flat / dense layout; the Rainbow paged cache lives in repro.memory)
# ---------------------------------------------------------------------------


def cache_init(cfg, batch: int, max_len: int, tp: int, layers: int) -> Params:
    kvs = cfg.kv_store(tp)
    dt = dtype_of(cfg)
    shape = (layers, batch, max_len, kvs, cfg.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def cache_specs(batch_axes, seq_axis=None) -> Params:
    spec = P(None, batch_axes, seq_axis, "model", None)
    return {"k": spec, "v": spec}


def cache_update(
    cache_k: jax.Array,  # [B, S_max, KVS, hd]  (single layer slice)
    cache_v: jax.Array,
    k_new: jax.Array,  # [B, S_new, KVS, hd]
    v_new: jax.Array,
    start: jax.Array,  # int32 scalar write offset
) -> tuple[jax.Array, jax.Array]:
    ck = jax.lax.dynamic_update_slice(cache_k, k_new.astype(cache_k.dtype), (0, start, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache_v, v_new.astype(cache_v.dtype), (0, start, 0, 0))
    return ck, cv


def decode_attend(
    q: jax.Array,  # [B, 1, HP, hd]
    cache_k: jax.Array,  # [B, S_max, KVS, hd]
    cache_v: jax.Array,
    cur_len: jax.Array,  # int32 valid prefix length (q is at position cur_len-1)
    window,
) -> jax.Array:
    """Single-token attention over the cache (mask-based; baseline path)."""
    b, smax, kvs, hd = cache_k.shape
    hp = q.shape[2]
    k = _expand_kv(cache_k, hp)
    v = _expand_kv(cache_v, hp)
    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bqhk,bshk->bhqs", q, k, preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(smax)
    q_pos = cur_len - 1
    valid = pos <= q_pos
    w = jnp.asarray(window)
    valid &= (w <= 0) | (pos > q_pos - w)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqs,bshk->bqhk", p, v, preferred_element_type=jnp.float32).astype(
        q.dtype
    )
