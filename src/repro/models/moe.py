"""Mixture-of-Experts with capacity-bounded sort/gather dispatch + shared experts.

Dispatch (Megablocks/MaxText-style, all static shapes):
  router top-k -> flatten (token, k) slots -> argsort by expert -> rank within
  expert via sorted-segment position -> scatter into [E, C, D] buffers (slots past
  capacity dropped) -> per-expert batched ffn -> gather back, weighted by gate.

Expert dim E is sharded over "model" (EP inside the TP axis); the token->expert
scatter/gather induces the all-to-all-equivalent resharding under GSPMD.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import Params, dtype_of, mlp_init, mlp_specs, normal_init


def _padded_experts(cfg, tp: int) -> int:
    e = cfg.moe_num_experts
    return ((e + tp - 1) // tp) * tp


def moe_init(cfg, key, tp: int, stacked: int | None = None) -> Params:
    dt = dtype_of(cfg)
    d, fe = cfg.d_model, cfg.moe_d_ff
    ep = _padded_experts(cfg, tp)
    lead = () if stacked is None else (stacked,)
    ks = jax.random.split(key, 6)
    scale_out = 0.02 / max(1.0, (2 * cfg.num_layers) ** 0.5)
    p = {
        "router": normal_init(ks[0], (*lead, d, ep), 0.02, jnp.float32),
        "wi": normal_init(ks[1], (*lead, ep, d, fe), 0.02, dt),
        "wg": normal_init(ks[2], (*lead, ep, d, fe), 0.02, dt),
        "wo": normal_init(ks[3], (*lead, ep, fe, d), scale_out, dt),
    }
    if cfg.moe_num_shared:
        fs = cfg.moe_num_shared * fe
        p["shared"] = mlp_init(cfg, ks[4], d, fs, stacked=stacked)
    return p


def moe_specs(cfg, stacked: bool = False) -> Params:
    l = (None,) if stacked else ()
    p = {
        "router": P(*l, None, None),
        "wi": P(*l, "model", None, None),
        "wg": P(*l, "model", None, None),
        "wo": P(*l, "model", None, None),
    }
    if cfg.moe_num_shared:
        p["shared"] = mlp_specs(cfg, stacked=stacked)
    return p


def apply_moe(cfg, p: Params, x: jax.Array, tp: int, sc=None) -> jax.Array:
    """x: [B, S, D] -> [B, S, D]."""
    bsz, s, d = x.shape
    t = bsz * s
    e = _padded_experts(cfg, tp)
    k = cfg.moe_top_k
    cap = int(t * k / e * cfg.moe_capacity_factor) + 1
    cap = min(cap, t)
    xt = x.reshape(t, d)

    logits = jnp.einsum(
        "td,de->te", xt.astype(jnp.float32), p["router"], preferred_element_type=jnp.float32
    )
    if e != cfg.moe_num_experts:  # mask padded experts out of routing
        logits = jnp.where(jnp.arange(e) < cfg.moe_num_experts, logits, -1e9)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)  # [T, K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)  # renormalize

    # ---- sort-based dispatch ----
    flat_e = idx.reshape(-1)  # [T*K]
    order = jnp.argsort(flat_e)  # slots grouped by expert
    sorted_e = flat_e[order]
    # rank within expert = position - first position of that expert
    pos = jnp.arange(t * k)
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    rank = pos - seg_start[sorted_e]
    keep = rank < cap
    dst = jnp.where(keep, sorted_e * cap + rank, e * cap)  # overflow -> dropped row
    token_of_slot = order // k

    xe = jnp.zeros((e * cap + 1, d), x.dtype)
    xe = xe.at[dst].set(xt[token_of_slot], mode="drop")
    xe = xe[: e * cap].reshape(e, cap, d)
    if sc is not None:
        xe = sc(xe, P("model", None, None))

    # ---- per-expert gated ffn ----
    acc = jnp.float32
    hi = jnp.einsum("ecd,edf->ecf", xe, p["wi"], preferred_element_type=acc)
    hg = jnp.einsum("ecd,edf->ecf", xe, p["wg"], preferred_element_type=acc)
    h = (jax.nn.silu(hg) * hi).astype(x.dtype)
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"], preferred_element_type=acc).astype(x.dtype)

    # ---- combine: gather back and weight by gate ----
    ye_flat = jnp.concatenate([ye.reshape(e * cap, d), jnp.zeros((1, d), x.dtype)])
    slot_out = ye_flat[dst]  # [T*K, D] (dropped slots read zeros)
    gate_sorted = gate.reshape(-1)[order]
    contrib = slot_out * gate_sorted[:, None].astype(x.dtype)
    out = jnp.zeros((t, d), jnp.float32).at[token_of_slot].add(contrib.astype(jnp.float32))

    if cfg.moe_num_shared:
        from repro.models.layers import apply_mlp

        out = out + apply_mlp(cfg, p["shared"], x, sc=sc).reshape(t, d)
    return out.astype(x.dtype).reshape(bsz, s, d)


def aux_load_balance_loss(cfg, logits: jax.Array, idx: jax.Array) -> jax.Array:
    """Switch-style load-balance auxiliary loss (optional training extra)."""
    e = logits.shape[-1]
    probs = jax.nn.softmax(logits, -1)
    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0) / idx.size
    return e * (me * ce).sum()
