"""Scan-unroll switch for cost-analysis lowerings.

XLA's HloCostAnalysis counts while-loop bodies once, so the dry-run's *cost*
lowering unrolls every structural scan (layer stacks, SSD chunk scans, chunked
attention) to get true flops/bytes/collective counts. The *memory* lowering keeps
scans rolled — that is the production program.
"""
_SCAN_UNROLL = False

# Inner (sequence-chunk) scans nested inside the layer scan explode compile time
# when fully unrolled under autodiff+remat (layer_count x chunk_count bodies).
# Cap them: the flop undercount is (1 - cap/n_chunks) x (SSD share of flops),
# single-digit percent for the hybrid/SSM archs; the exact jaxpr counter
# (launch/jaxpr_flops.py) reports the true number alongside.
INNER_UNROLL_CAP = 2


def set_scan_unroll(value: bool) -> None:
    global _SCAN_UNROLL
    _SCAN_UNROLL = value


def unroll(length: int) -> int:
    return max(1, length) if _SCAN_UNROLL else 1


def unroll_inner(length: int) -> int:
    return max(1, min(length, INNER_UNROLL_CAP)) if _SCAN_UNROLL else 1
