"""Shared model building blocks (pure JAX, no flax): norms, RoPE, MLPs, embeddings.

Conventions:
  * params are nested dicts of jnp arrays; layer-stacked leaves carry a leading L
    dim and are consumed by lax.scan.
  * matmuls run in the config compute dtype (bf16) with fp32 accumulation
    (preferred_element_type); norms and softmax run in fp32.
  * every init_* has a matching specs_* returning a PartitionSpec tree of the same
    structure ("model" = TP axis; batch/data axes are activation-only).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Params = dict[str, Any]


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def normal_init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_init(cfg, d: int, stacked: int | None = None) -> Params:
    shape = (d,) if stacked is None else (stacked, d)
    p = {"scale": jnp.ones(shape, jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros(shape, jnp.float32)
    return p


def norm_specs(cfg, stacked: bool = False) -> Params:
    spec = P(None, None) if stacked else P(None)
    p = {"scale": spec}
    if cfg.norm == "layernorm":
        p["bias"] = spec
    return p


def apply_norm(cfg, p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:  # rmsnorm
        var = (xf * xf).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


def rms_head_norm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head RMS norm over head_dim (qwen3 qk_norm). x: [..., hd]."""
    xf = x.astype(jnp.float32)
    var = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply RoPE. x: [B, S, H, hd]; positions: [B, S] or [S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (gated SwiGLU or plain GELU)
# ---------------------------------------------------------------------------


def mlp_init(cfg, key, d: int, f: int, stacked: int | None = None) -> Params:
    ks = jax.random.split(key, 3)
    dt = dtype_of(cfg)
    lead = () if stacked is None else (stacked,)
    scale_in = 0.02
    scale_out = 0.02 / max(1.0, (2 * cfg.num_layers) ** 0.5)
    p = {
        "wi": normal_init(ks[0], (*lead, d, f), scale_in, dt),
        "wo": normal_init(ks[1], (*lead, f, d), scale_out, dt),
    }
    if cfg.gated_mlp:
        p["wg"] = normal_init(ks[2], (*lead, d, f), scale_in, dt)
    return p


def mlp_specs(cfg, stacked: bool = False) -> Params:
    l = (None,) if stacked else ()
    p = {"wi": P(*l, None, "model"), "wo": P(*l, "model", None)}
    if cfg.gated_mlp:
        p["wg"] = P(*l, None, "model")
    return p


def apply_mlp(cfg, p: Params, x: jax.Array, sc=None) -> jax.Array:
    acc = jnp.float32
    h = jnp.einsum("bsd,df->bsf", x, p["wi"], preferred_element_type=acc)
    if cfg.gated_mlp:
        g = jnp.einsum("bsd,df->bsf", x, p["wg"], preferred_element_type=acc)
        h = jax.nn.silu(g) * h if cfg.act == "silu" else jax.nn.gelu(g) * h
    else:
        h = jax.nn.gelu(h) if cfg.act == "gelu" else jax.nn.silu(h)
    h = h.astype(x.dtype)
    if sc is not None:
        h = sc(h, P(("pod", "data"), None, "model"))
    out = jnp.einsum("bsf,fd->bsd", h, p["wo"], preferred_element_type=acc)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding + LM head
# ---------------------------------------------------------------------------


def embed_init(cfg, key) -> Params:
    dt = dtype_of(cfg)
    vp = cfg.padded_vocab
    k1, k2 = jax.random.split(key)
    p = {"tok": normal_init(k1, (vp, cfg.d_model), 0.02, dt)}
    if not cfg.tie_embeddings:
        p["head"] = normal_init(k2, (cfg.d_model, vp), 0.02, dt)
    return p


def embed_specs(cfg) -> Params:
    p = {"tok": P("model", None)}
    if not cfg.tie_embeddings:
        p["head"] = P(None, "model")
    return p


def embed_lookup(cfg, p: Params, tokens: jax.Array) -> jax.Array:
    return p["tok"][tokens]


def lm_logits(cfg, p: Params, x: jax.Array) -> jax.Array:
    head = p["head"] if not cfg.tie_embeddings else p["tok"].T
    logits = jnp.einsum("bsd,dv->bsv", x, head, preferred_element_type=jnp.float32)
    # mask vocab padding so it can never win argmax / leak into the loss
    vp = logits.shape[-1]
    if vp != cfg.vocab_size:
        mask = jnp.arange(vp) < cfg.vocab_size
        logits = jnp.where(mask, logits, -1e9)
    return logits


def softmax_xent(logits: jax.Array, labels: jax.Array, mask: jax.Array) -> jax.Array:
    """Mean CE over mask==1 positions. logits fp32 [B,S,V]; labels int [B,S]."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
